"""Beyond-paper benchmark: the ETICA two-tier KV manager vs a global-LRU
write-back manager on a multi-tenant serving trace (hit ratio, host-DMA
traffic — the serving analogs of Fig. 13/14)."""
from __future__ import annotations

import numpy as np

from repro.kvcache import GlobalLRUManager, TwoTierConfig, TwoTierKVManager

from .common import Timer, row

CFG = TwoTierConfig(page_size=16, hbm_pages=48, num_kv_heads=2, head_dim=8,
                    num_layers=1, dtype="float32",
                    maintenance_interval=32, resize_interval=128)
SESSIONS = 24
TENANTS = 2
ROUNDS = 600


def drive(mgr, seed=1):
    rng = np.random.default_rng(seed)
    for sid in range(SESSIONS):
        mgr.new_session(sid, 0 if sid < 4 else 1)
    for _ in range(ROUNDS):
        sid = int(rng.integers(0, 4)) if rng.random() < 0.7 \
            else int(rng.integers(4, SESSIONS))
        mgr.activate(sid)
        if rng.random() < 0.3 and len(mgr.sessions[sid].pages) < 6:
            pg = rng.normal(size=(1, CFG.page_size, CFG.num_kv_heads,
                                  CFG.head_dim)).astype(np.float32)
            mgr.append_page(sid, pg, pg)
    return mgr.stats.as_dict()


def main():
    with Timer() as t1:
        a = drive(TwoTierKVManager(CFG, TENANTS))
    with Timer() as t2:
        b = drive(GlobalLRUManager(CFG, TENANTS))
    row("serving/etica_two_tier", t1.us / ROUNDS,
        f"hit={a['hit_ratio']:.3f} dma_w={a['dma_write_bytes']} "
        f"dma_r={a['dma_read_bytes']}")
    row("serving/global_lru_wb", t2.us / ROUNDS,
        f"hit={b['hit_ratio']:.3f} dma_w={b['dma_write_bytes']} "
        f"dma_r={b['dma_read_bytes']}")
    row("serving/summary", 0.0,
        f"dma_write_reduction={1 - a['dma_write_bytes']/max(b['dma_write_bytes'],1):.3f}")


if __name__ == "__main__":
    main()
