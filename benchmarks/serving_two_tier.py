"""Beyond-paper benchmark: the ETICA two-tier KV manager vs a global-LRU
write-back manager on a churn-driven multi-tenant serving trace (hit
ratio, host-DMA traffic — the serving analogs of Fig. 13/14), at a
serving-scale population (thousands of sessions, ~1k concurrently live).

Three managers run the SAME arrival/churn stream:

  * ``etica``      — batched controller (fused device maintenance);
  * ``etica-seq``  — the host-dict sequential oracle;
  * ``lru``        — global LRU with datapath write-back.

Strict gates (AssertionError = regression):
  * batched == sequential oracle, bit for bit — Stats, final quotas,
    final slot placements, free-list order;
  * WBWO write bound — ETICA's host-DMA writes are EXACTLY one page per
    appended page (the endurance claim);
  * popularity-table capacity held (``pop_drops == 0``);
  * head-to-head — ETICA strictly beats global-LRU write-back on DMA
    writes (the endurance headline; LRU may hold a few hit-ratio points
    since it never proactively trims to quota — recorded, and sanity-
    bounded rather than asserted away).

``--smoke`` runs a seconds-scale population for CI.
"""
from __future__ import annotations

import numpy as np

from repro.kvcache import GlobalLRUManager, TwoTierConfig, TwoTierKVManager
from repro.launch.serve import run_events
from repro.traces import SessionSpec, generate_sessions

from .common import Timer, row

FULL = dict(events=20_000, live=1024, hbm_pages=512, tenants=4,
            maintenance_interval=64, resize_interval=512, pop_capacity=2048)
SMOKE = dict(events=1_200, live=64, hbm_pages=48, tenants=3,
             maintenance_interval=32, resize_interval=128, pop_capacity=256)


def _mk_cfg(p) -> TwoTierConfig:
    return TwoTierConfig(
        page_size=16, hbm_pages=p["hbm_pages"], num_kv_heads=2, head_dim=8,
        num_layers=1, dtype="float32",
        maintenance_interval=p["maintenance_interval"],
        resize_interval=p["resize_interval"],
        pop_capacity=p["pop_capacity"], materialize=False)


def _bank(cfg: TwoTierConfig, seed=7):
    rng = np.random.default_rng(seed)
    pages = rng.normal(size=(8, 1, cfg.page_size, cfg.num_kv_heads,
                             cfg.head_dim)).astype(np.float32)
    return pages, pages


def _placements(mgr):
    return (dict(mgr.slot_owner), tuple(mgr.free),
            tuple(int(q) for q in mgr.tenant_quota),
            tuple(int(u) for u in mgr.tenant_used))


def drive(mgr, trace, cfg, seed=1):
    kb, vb = _bank(cfg)
    run_events(mgr, trace, kb, vb, decode_every=0, seed=seed)
    return mgr.stats.as_dict()


def main(smoke: bool = False):
    p = SMOKE if smoke else FULL
    cfg = _mk_cfg(p)
    spec = SessionSpec(num_tenants=p["tenants"], target_live=p["live"],
                       max_pages=6)
    trace = generate_sessions(spec, p["events"], seed=1)
    assert smoke or trace.num_sessions >= 1000, trace.num_sessions

    with Timer() as t1:
        m_b = TwoTierKVManager(cfg, p["tenants"], batched=True)
        a = drive(m_b, trace, cfg)
    with Timer() as t2:
        m_s = TwoTierKVManager(cfg, p["tenants"], batched=False)
        a_seq = drive(m_s, trace, cfg)
    with Timer() as t3:
        m_l = GlobalLRUManager(cfg, p["tenants"])
        b = drive(m_l, trace, cfg)

    # gate 1: batched controller == sequential host-dict oracle, bit for bit
    assert a == a_seq, (a, a_seq)
    assert _placements(m_b) == _placements(m_s)
    # gate 2: WBWO endurance bound — exactly one host write per append
    assert a["dma_write_bytes"] == a["appends"] * cfg.page_bytes
    # gate 3: device popularity table big enough to mirror the tracker
    assert a["pop_drops"] == 0
    # gate 4: head-to-head vs push-mode global LRU
    assert a["dma_write_bytes"] < b["dma_write_bytes"], (a, b)
    assert a["hit_ratio"] >= b["hit_ratio"] - 0.1, (a, b)

    n = p["events"]
    row("serving/etica_two_tier", t1.us / n,
        f"sessions={trace.num_sessions} max_live={trace.max_live} "
        f"hit={a['hit_ratio']:.3f} dma_w={a['dma_write_bytes']} "
        f"dma_r={a['dma_read_bytes']} drops={a['pop_drops']}")
    row("serving/etica_sequential_oracle", t2.us / n,
        f"hit={a_seq['hit_ratio']:.3f} bit_identical=True")
    row("serving/global_lru_wb", t3.us / n,
        f"hit={b['hit_ratio']:.3f} dma_w={b['dma_write_bytes']} "
        f"dma_r={b['dma_read_bytes']}")
    row("serving/summary", 0.0,
        f"dma_write_reduction="
        f"{1 - a['dma_write_bytes']/max(b['dma_write_bytes'],1):.3f} "
        f"hit_delta={a['hit_ratio']-b['hit_ratio']:+.3f}")
    return a, b


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv[1:])
