"""Beyond-paper: IO-classification head-to-head on the scan-heavy mix.

Open-CAS-style sequential-cutoff bypass (``repro.classify.seq_cutoff``)
vs the unclassified controllers on ``SCAN_HEAVY_MIX`` — two scan
streams (``scan_mix``, ``backup_scan``) consolidated next to two
reuse-friendly victims (``hm_1``, ``src2_0``) whose working sets the
scans flush out of a push-mode cache. Three gates, in order:

  * ``class/match_all_identity`` — a single match-all class produces
    aggregate Stats **bit-identical** to ``classifier=None`` on both
    controllers (the fig15-style equality assert for the classified
    datapath);
  * ``class/chassis_*`` — single-level WB chassis (Centaur) with
    seq-cutoff: **strictly higher read-hit ratio and strictly fewer SSD
    writes** than unclassified, asserted, plus the batched==sequential
    equality of the classified path itself;
  * ``class/etica_*`` — the two-level ETICA controller with the same
    cutoff, recorded (bypass protects the DRAM level from scan churn).

Results are recorded in ``BENCH_classification.json``. ``--smoke`` runs
a CI-sized version of the same protocol, assertions included.
"""
from __future__ import annotations

import dataclasses

from repro.classify import match_all, seq_cutoff
from repro.core import EticaCache, make_centaur
from repro.traces import SCAN_HEAVY_MIX

from .common import GEO, RESIZE, SSD_CAP, Timer, aggregate_stats, \
    etica_config, row, vm_mix

CUTOFF = 48          # blocks of one run before requests go straight to disk
REQS = 8_000
SMOKE_REQS = 2_000


def _read_hit_ratio(agg: dict) -> float:
    return ((agg.get("read_hits_l1", 0.0) + agg["read_hits_l2"])
            / max(agg["reads"], 1))


def _chassis(classifier, batched=True):
    return make_centaur(SSD_CAP, len(SCAN_HEAVY_MIX), geometry=GEO,
                        resize_interval=RESIZE, sim_chunk=500,
                        batched=batched, classifier=classifier)


def _etica(classifier, batched=True):
    cfg = dataclasses.replace(etica_config("full"), batched=batched,
                              classifier=classifier)
    return EticaCache(cfg, len(SCAN_HEAVY_MIX))


def _run(build, trace):
    with Timer() as t:
        res = build().run(trace)
    return aggregate_stats(res), t


def main(smoke: bool = False) -> dict:
    reqs = SMOKE_REQS if smoke else REQS
    trace = vm_mix(SCAN_HEAVY_MIX, reqs=reqs)
    out = {}

    # gate 1: match-all class == no classifier, bit for bit, both layers
    for name, build in [("chassis", _chassis), ("etica", _etica)]:
        agg_none, _ = _run(lambda: build(None), trace)
        agg_ma, _ = _run(lambda: build(match_all()), trace)
        assert agg_none == agg_ma, (
            f"{name}: match-all classifier diverged from classifier=None:\n"
            f"  none:      {agg_none}\n  match_all: {agg_ma}")
    row("class/match_all_identity", 0.0, "stats_equal=True")

    # gate 2: WB chassis, seq-cutoff vs unclassified (strict wins)
    cutoff = seq_cutoff(CUTOFF)
    base, t_base = _run(lambda: _chassis(None), trace)
    cls_b, t_cls = _run(lambda: _chassis(cutoff), trace)
    cls_s, _ = _run(lambda: _chassis(cutoff, batched=False), trace)
    assert cls_b == cls_s, (
        f"classified chassis batched/sequential diverged:\n"
        f"  batched:    {cls_b}\n  sequential: {cls_s}")
    hit_base, hit_cls = _read_hit_ratio(base), _read_hit_ratio(cls_b)
    wr_base, wr_cls = base["cache_writes_l2"], cls_b["cache_writes_l2"]
    assert hit_cls > hit_base, (
        f"seq-cutoff did not raise the chassis read-hit ratio: "
        f"{hit_cls:.4f} <= {hit_base:.4f}")
    assert wr_cls < wr_base, (
        f"seq-cutoff did not cut chassis SSD writes: "
        f"{wr_cls:.0f} >= {wr_base:.0f}")
    out["chassis"] = dict(
        read_hit_unclassified=hit_base, read_hit_classified=hit_cls,
        ssd_writes_unclassified=wr_base, ssd_writes_classified=wr_cls,
        bypassed=cls_b.get("bypassed", 0.0))
    row("class/chassis_unclassified", t_base.us / len(trace),
        f"read_hit={hit_base:.4f} ssd_writes={wr_base:.0f}")
    row("class/chassis_seq_cutoff", t_cls.us / len(trace),
        f"read_hit={hit_cls:.4f} ssd_writes={wr_cls:.0f} "
        f"bypassed={cls_b.get('bypassed', 0):.0f} "
        f"batched_eq_sequential=True")

    # gate 3: ETICA two-level with the same cutoff (recorded)
    e_base, te_b = _run(lambda: _etica(None), trace)
    e_cls, te_c = _run(lambda: _etica(cutoff), trace)
    out["etica"] = dict(
        read_hit_unclassified=_read_hit_ratio(e_base),
        read_hit_classified=_read_hit_ratio(e_cls),
        ssd_writes_unclassified=e_base["cache_writes_l2"],
        ssd_writes_classified=e_cls["cache_writes_l2"],
        bypassed=e_cls.get("bypassed", 0.0),
        pop_drops=e_cls.get("pop_drops", 0.0))
    row("class/etica_unclassified", te_b.us / len(trace),
        f"read_hit={_read_hit_ratio(e_base):.4f} "
        f"ssd_writes={e_base['cache_writes_l2']:.0f}")
    row("class/etica_seq_cutoff", te_c.us / len(trace),
        f"read_hit={_read_hit_ratio(e_cls):.4f} "
        f"ssd_writes={e_cls['cache_writes_l2']:.0f} "
        f"bypassed={e_cls.get('bypassed', 0):.0f}")
    return out


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
