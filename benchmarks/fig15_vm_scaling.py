"""Paper Figs. 15 & 16: cache reallocation and hit ratio as VMs come
online (1 -> 2 -> 4 -> 8 VMs against a fixed total cache)."""
from __future__ import annotations

import numpy as np

from repro.core import EticaCache, Trace
from repro.traces import make

from .common import Timer, etica_config, row

PHASES = [1, 2, 4, 8]
REQS_PER_PHASE = 4_000
WORKLOADS = ["hm_1", "proj_0", "stg_1", "usr_0", "ts_0", "wdev_0",
             "web_3", "src2_0"]


def main():
    num_vms = max(PHASES)
    vm_traces = [make(w, REQS_PER_PHASE * len(PHASES), seed=i,
                      addr_offset=i * 10_000_000, scale=0.25)
                 for i, w in enumerate(WORKLOADS)]
    cache = EticaCache(etica_config("full", dram=200, ssd=400), num_vms)
    with Timer() as t:
        for phase, active in enumerate(PHASES):
            # interleave only the active VMs for this phase
            chunks, vm_ids = [], []
            for v in range(active):
                seg = vm_traces[v][phase * REQS_PER_PHASE:
                                   (phase + 1) * REQS_PER_PHASE]
                chunks.append(np.asarray(seg.addr))
                vm_ids.append(np.full(len(seg), v, np.int32))
            rng = np.random.default_rng(phase)
            order = rng.permutation(sum(len(c) for c in chunks))
            addr = np.concatenate(chunks)[order]
            wr = np.concatenate(
                [np.asarray(vm_traces[v][phase * REQS_PER_PHASE:
                                         (phase + 1) * REQS_PER_PHASE]
                            .is_write) for v in range(active)])[order]
            vm = np.concatenate(vm_ids)[order]
            res = cache.run(Trace(addr=addr, is_write=wr, vm=vm))
            hits = np.mean([r.hit_ratio for r in res[:active]])
            allocs = [int(l.alloc.sum()) for l in cache.logs_ssd[-2:]]
            row(f"fig15/phase_{active}vms", 0.0,
                f"avg_hit={hits:.3f} ssd_alloc_total={allocs[-1]}")
    row("fig15/total", t.us / (REQS_PER_PHASE * sum(PHASES)), "done")


if __name__ == "__main__":
    main()
