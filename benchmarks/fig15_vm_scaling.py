"""Paper Figs. 15 & 16: cache reallocation and hit ratio as VMs come
online (1 -> 2 -> 4 -> 8 VMs against a fixed total cache), plus the
batched-datapath head-to-heads: one vmapped dispatch for all VMs
(``batched=True``, the default) vs the sequential per-VM dispatch loop
(``batched=False``, the reference oracle) — for ETICA's two-level
controller AND for the one-level baseline chassis (ECI-Cache), whose
sizing metrics now ride the same batched reuse pipeline. Each
head-to-head asserts both paths produce *exactly* the same aggregate
Stats before reporting the wall-clock speedup. The batched ETICA run
uses the DEFAULT fused maintenance (device popularity table + Pallas
promote/evict kernels through the CPU interpreter), so the equality
assert is also the gate that fused maintenance stays bit-identical to
the sequential per-VM numpy oracle end to end.

The ``fig15/streaming_*`` rows scale consolidation to 32–128 VMs fed
from a chunked on-disk :class:`TraceStore` (per-VM demux = one stable
sort per shard, ``[V, chunk]`` blocks double-buffered host->device):
wall-clock per request plus peak host RSS, with the full trace never
resident — one resize window at a time. At the smallest streaming scale
the streamed run is asserted bit-identical to the in-memory run.

The ``fig15/sharded_*`` rows weak-scale the mesh-sharded controller
(``EticaConfig.mesh``, PR: VM-axis sharding) over 1/2/4/8 device shards
at a fixed VM count per shard — 128/shard at full scale, so the 8-shard
row is the 1000-VM-class consolidation run (1024 VMs). Per-VM state,
datapath, maintenance and sizing all stay shard-local; the largest scale
is asserted bit-identical to the single-device batched oracle before its
timing row is reported. On CPU, force placeholder devices first:
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
``sharding-smoke`` job runs exactly that with ``--smoke``).
"""
from __future__ import annotations

import dataclasses
import resource
import sys
import tempfile
import tracemalloc
from pathlib import Path

import numpy as np

from repro.core import EticaCache, Trace, make_eci_cache
from repro.traces import TraceStore, make, make_store

from .common import GEO, Timer, aggregate_stats as _aggregate
from .common import etica_config, row, vm_mix

PHASES = [1, 2, 4, 8, 16]
REQS_PER_PHASE = 4_000
WORKLOADS = ["hm_1", "proj_0", "stg_1", "usr_0", "ts_0", "wdev_0",
             "web_3", "src2_0"] * 2  # 16 consolidated VMs (ECI-Cache scale)
STREAM_PHASES = [32, 64, 128]        # ECI-Cache-paper consolidation x8
STREAM_REQS_PER_VM = 750


def _phase_trace(vm_traces, phase: int, active: int) -> Trace:
    """Interleave the active VMs' segments for one phase."""
    chunks, vm_ids = [], []
    for v in range(active):
        seg = vm_traces[v][phase * REQS_PER_PHASE:
                           (phase + 1) * REQS_PER_PHASE]
        chunks.append(np.asarray(seg.addr))
        vm_ids.append(np.full(len(seg), v, np.int32))
    rng = np.random.default_rng(phase)
    order = rng.permutation(sum(len(c) for c in chunks))
    addr = np.concatenate(chunks)[order]
    wr = np.concatenate(
        [np.asarray(vm_traces[v][phase * REQS_PER_PHASE:
                                 (phase + 1) * REQS_PER_PHASE]
                    .is_write) for v in range(active)])[order]
    vm = np.concatenate(vm_ids)[order]
    return Trace(addr=addr, is_write=wr, vm=vm)


def scaling_ramp(vm_traces) -> None:
    """The paper's figure: VMs coming online against a fixed cache."""
    num_vms = max(PHASES)
    cache = EticaCache(etica_config("full", dram=200, ssd=400), num_vms)
    with Timer() as t:
        for phase, active in enumerate(PHASES):
            res = cache.run(_phase_trace(vm_traces, phase, active))
            hits = np.mean([r.hit_ratio for r in res[:active]])
            allocs = [int(l.alloc.sum()) for l in cache.logs_ssd[-2:]]
            row(f"fig15/phase_{active}vms", 0.0,
                f"avg_hit={hits:.3f} ssd_alloc_total={allocs[-1]}")
    row("fig15/total", t.us / (REQS_PER_PHASE * sum(PHASES)), "done")


def _head_to_head(build, label: str, vm_traces, active: int) -> None:
    """Batched-vs-sequential protocol shared by every head-to-head:
    warm-up compile per path, timed runs, exact aggregate-Stats equality
    assert, then the speedup row. ``build(batched)`` returns a fresh
    controller."""
    trace = _phase_trace(vm_traces, 0, active)

    # warm-up pass per path compiles every executable (shapes repeat)
    for batched in (True, False):
        build(batched).run(trace)

    runs = {}
    for batched in (True, False):
        cache = build(batched)
        with Timer() as t:
            res = cache.run(trace)
        runs[batched] = (_aggregate(res), t.dt)
    agg_b, time_b = runs[True]
    agg_s, time_s = runs[False]
    assert agg_b == agg_s, (
        f"{label}: batched and sequential paths diverged at {active} VMs:\n"
        f"  batched:    {agg_b}\n  sequential: {agg_s}")
    speedup = time_s / time_b
    row(f"fig15/{label}_{active}vms",
        time_b * 1e6 / (active * REQS_PER_PHASE),
        f"speedup={speedup:.2f}x sequential_s={time_s:.2f} "
        f"batched_s={time_b:.2f} stats_equal=True")


def batched_vs_sequential(vm_traces, active: int) -> None:
    """Head-to-head at ``active`` VMs: identical results, fewer
    dispatches. ``batched=True`` runs the fused maintenance dispatch
    (Pallas kernels, interpret mode on CPU) — the Stats equality assert
    inside :func:`_head_to_head` is the fused-vs-sequential-oracle
    bit-identity gate."""

    def build(batched: bool) -> EticaCache:
        cfg = dataclasses.replace(etica_config("full", dram=200, ssd=400),
                                  batched=batched)
        return EticaCache(cfg, active)

    _head_to_head(build, "batched_speedup", vm_traces, active)


def baseline_batched_vs_sequential(vm_traces, active: int) -> None:
    """Same head-to-head for the one-level baseline chassis (ECI-Cache):
    with batched sizing, URD for all VMs is one vmapped reduction per
    resize interval instead of a per-VM Python metric loop."""

    def build(batched: bool):
        return make_eci_cache(600, active, geometry=GEO,
                              resize_interval=2_000, sim_chunk=500,
                              batched=batched)

    _head_to_head(build, "eci_batched_speedup", vm_traces, active)


def _rss_mb() -> float:
    # ru_maxrss is KB on Linux but bytes on macOS
    scale = 2**20 if sys.platform == "darwin" else 1024
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / scale


def _store_mb(path: Path) -> float:
    return sum(f.stat().st_size for f in Path(path).iterdir()) / 2**20


def streaming_scaling(tmp: str) -> None:
    """32–128 consolidated VMs fed from an on-disk TraceStore.

    Each scale generates its mix straight into a store, then drives the
    batched two-level controller from the store: the full trace stays on
    disk; host memory holds one resize window + the two in-flight
    ``[V, chunk]`` blocks. Reported per scale: wall-clock per request,
    the run's own peak Python-heap use (``tracemalloc``, the host-side
    trace/window/block allocations — this is the bounded quantity; the
    full trace would show up here if it were ever materialized) and
    ``ru_maxrss`` (cumulative process peak, dominated by whatever ran
    earlier in the process). The smallest scale is cross-checked
    bit-identically against the in-memory path before any timing is
    trusted."""
    for active in STREAM_PHASES:
        workloads = (WORKLOADS * ((active + len(WORKLOADS) - 1)
                                  // len(WORKLOADS)))[:active]
        path = Path(tmp) / f"mix_{active}"
        store = make_store(path, workloads, STREAM_REQS_PER_VM, scale=0.25,
                           shard_size=4 * REQS_PER_PHASE)
        cfg = etica_config("full", dram=200, ssd=400)
        if active == STREAM_PHASES[0]:
            ref = EticaCache(cfg, active).run(store.to_trace())
            agg_ref = _aggregate(ref)
        # warm-up pass compiles this scale's [V, chunk] executables so the
        # timed row measures streaming throughput, not one-time JIT
        EticaCache(cfg, active).run(TraceStore.open(path))
        cache = EticaCache(cfg, active)
        tracemalloc.start()
        with Timer() as t:
            res = cache.run(TraceStore.open(path))
        _, peak_py = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        if active == STREAM_PHASES[0]:
            assert _aggregate(res) == agg_ref, (
                f"streamed and in-memory paths diverged at {active} VMs")
        hits = np.mean([r.hit_ratio for r in res])
        row(f"fig15/streaming_{active}vms",
            t.us / (active * STREAM_REQS_PER_VM),
            f"avg_hit={hits:.3f} peak_py_mb={peak_py / 2**20:.1f} "
            f"peak_rss_mb={_rss_mb():.0f} store_mb={_store_mb(path):.2f} "
            f"stats_equal={'True' if active == STREAM_PHASES[0] else 'n/a'}")


def sharded_consolidation(smoke: bool = False) -> None:
    """Weak scaling over a VM-axis device mesh: fixed VMs per shard,
    1/2/4/8 shards (capped at the visible device count). Every per-VM
    dispatch is shard-local (asserted by ``tests/test_sharding.py``); the
    largest scale re-runs on a single device (the batched oracle) and the
    aggregate Stats must match bit for bit before the rows are trusted.
    At full scale the 8-shard row is the 1024-VM consolidation run."""
    import jax

    from repro.launch.mesh import make_vm_mesh

    ndev = len(jax.devices())
    shard_counts = [n for n in (1, 2, 4, 8) if n <= ndev]
    per_shard = 16 if smoke else 128
    reqs = 100 if smoke else 150
    if ndev < 8:
        row("fig15/sharded_devices", 0.0,
            f"only {ndev} device(s) visible — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 for the "
            "full weak-scaling sweep")

    def build(active: int, total: int, mesh) -> EticaCache:
        cfg = dataclasses.replace(
            etica_config("full", dram=12 * active, ssd=25 * active),
            resize_interval=max(500, total // 3),
            promo_interval=max(125, total // 12), mesh=mesh)
        return EticaCache(cfg, active)

    agg_at: dict[int, dict] = {}
    for n in shard_counts:
        active = per_shard * n
        workloads = (WORKLOADS * ((active + len(WORKLOADS) - 1)
                                  // len(WORKLOADS)))[:active]
        trace = vm_mix(workloads, reqs=reqs)
        mesh = make_vm_mesh(n)
        build(active, len(trace), mesh).run(trace)   # warm-up compile
        with Timer() as t:
            res = build(active, len(trace), mesh).run(trace)
        agg_at[n] = _aggregate(res)
        hits = np.mean([r.hit_ratio for r in res])
        row(f"fig15/sharded_{n}shards_{active}vms", t.us / len(trace),
            f"avg_hit={hits:.3f} reqs={len(trace)} wall_s={t.dt:.2f}")

    # bit-identity gate at the largest scale: same VMs on ONE device
    n = shard_counts[-1]
    active = per_shard * n
    workloads = (WORKLOADS * ((active + len(WORKLOADS) - 1)
                              // len(WORKLOADS)))[:active]
    trace = vm_mix(workloads, reqs=reqs)
    oracle = _aggregate(build(active, len(trace), None).run(trace))
    assert oracle == agg_at[n], (
        f"sharded ({n} shards) and single-device batched runs diverged "
        f"at {active} VMs:\n  sharded: {agg_at[n]}\n  oracle:  {oracle}")
    row(f"fig15/sharded_oracle_{active}vms", 0.0,
        f"stats_equal=True shards={n}")


def main(smoke: bool = False):
    global PHASES, REQS_PER_PHASE, STREAM_PHASES, STREAM_REQS_PER_VM
    if smoke:
        PHASES = [1, 2, 4]
        REQS_PER_PHASE = 1_000
        STREAM_PHASES = [32]
        STREAM_REQS_PER_VM = 400
    vm_traces = [make(w, REQS_PER_PHASE * len(PHASES), seed=i,
                      addr_offset=i * 10_000_000, scale=0.25)
                 for i, w in enumerate(WORKLOADS)]
    scaling_ramp(vm_traces)
    batched_vs_sequential(vm_traces, max(PHASES))
    baseline_batched_vs_sequential(vm_traces, max(PHASES))
    with tempfile.TemporaryDirectory() as tmp:
        streaming_scaling(tmp)
    sharded_consolidation(smoke)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        description="fig15: VM-scaling / consolidation benchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer phases/requests, smallest "
                         "streaming scale only, 16 VMs per shard")
    main(ap.parse_args().smoke)
