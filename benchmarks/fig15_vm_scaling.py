"""Paper Figs. 15 & 16: cache reallocation and hit ratio as VMs come
online (1 -> 2 -> 4 -> 8 VMs against a fixed total cache), plus the
batched-datapath head-to-heads: one vmapped dispatch for all VMs
(``batched=True``, the default) vs the sequential per-VM dispatch loop
(``batched=False``, the reference oracle) — for ETICA's two-level
controller AND for the one-level baseline chassis (ECI-Cache), whose
sizing metrics now ride the same batched reuse pipeline. Each
head-to-head asserts both paths produce *exactly* the same aggregate
Stats before reporting the wall-clock speedup.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import EticaCache, Trace, make_eci_cache
from repro.traces import make

from .common import GEO, Timer, etica_config, row

PHASES = [1, 2, 4, 8, 16]
REQS_PER_PHASE = 4_000
WORKLOADS = ["hm_1", "proj_0", "stg_1", "usr_0", "ts_0", "wdev_0",
             "web_3", "src2_0"] * 2  # 16 consolidated VMs (ECI-Cache scale)


def _phase_trace(vm_traces, phase: int, active: int) -> Trace:
    """Interleave the active VMs' segments for one phase."""
    chunks, vm_ids = [], []
    for v in range(active):
        seg = vm_traces[v][phase * REQS_PER_PHASE:
                           (phase + 1) * REQS_PER_PHASE]
        chunks.append(np.asarray(seg.addr))
        vm_ids.append(np.full(len(seg), v, np.int32))
    rng = np.random.default_rng(phase)
    order = rng.permutation(sum(len(c) for c in chunks))
    addr = np.concatenate(chunks)[order]
    wr = np.concatenate(
        [np.asarray(vm_traces[v][phase * REQS_PER_PHASE:
                                 (phase + 1) * REQS_PER_PHASE]
                    .is_write) for v in range(active)])[order]
    vm = np.concatenate(vm_ids)[order]
    return Trace(addr=addr, is_write=wr, vm=vm)


def _aggregate(results) -> dict[str, float]:
    agg: dict[str, float] = {}
    for r in results:
        for k, v in r.stats.items():
            agg[k] = agg.get(k, 0.0) + v
    return agg


def scaling_ramp(vm_traces) -> None:
    """The paper's figure: VMs coming online against a fixed cache."""
    num_vms = max(PHASES)
    cache = EticaCache(etica_config("full", dram=200, ssd=400), num_vms)
    with Timer() as t:
        for phase, active in enumerate(PHASES):
            res = cache.run(_phase_trace(vm_traces, phase, active))
            hits = np.mean([r.hit_ratio for r in res[:active]])
            allocs = [int(l.alloc.sum()) for l in cache.logs_ssd[-2:]]
            row(f"fig15/phase_{active}vms", 0.0,
                f"avg_hit={hits:.3f} ssd_alloc_total={allocs[-1]}")
    row("fig15/total", t.us / (REQS_PER_PHASE * sum(PHASES)), "done")


def _head_to_head(build, label: str, vm_traces, active: int) -> None:
    """Batched-vs-sequential protocol shared by every head-to-head:
    warm-up compile per path, timed runs, exact aggregate-Stats equality
    assert, then the speedup row. ``build(batched)`` returns a fresh
    controller."""
    trace = _phase_trace(vm_traces, 0, active)

    # warm-up pass per path compiles every executable (shapes repeat)
    for batched in (True, False):
        build(batched).run(trace)

    runs = {}
    for batched in (True, False):
        cache = build(batched)
        with Timer() as t:
            res = cache.run(trace)
        runs[batched] = (_aggregate(res), t.dt)
    agg_b, time_b = runs[True]
    agg_s, time_s = runs[False]
    assert agg_b == agg_s, (
        f"{label}: batched and sequential paths diverged at {active} VMs:\n"
        f"  batched:    {agg_b}\n  sequential: {agg_s}")
    speedup = time_s / time_b
    row(f"fig15/{label}_{active}vms",
        time_b * 1e6 / (active * REQS_PER_PHASE),
        f"speedup={speedup:.2f}x sequential_s={time_s:.2f} "
        f"batched_s={time_b:.2f} stats_equal=True")


def batched_vs_sequential(vm_traces, active: int) -> None:
    """Head-to-head at ``active`` VMs: identical results, fewer dispatches."""

    def build(batched: bool) -> EticaCache:
        cfg = dataclasses.replace(etica_config("full", dram=200, ssd=400),
                                  batched=batched)
        return EticaCache(cfg, active)

    _head_to_head(build, "batched_speedup", vm_traces, active)


def baseline_batched_vs_sequential(vm_traces, active: int) -> None:
    """Same head-to-head for the one-level baseline chassis (ECI-Cache):
    with batched sizing, URD for all VMs is one vmapped reduction per
    resize interval instead of a per-VM Python metric loop."""

    def build(batched: bool):
        return make_eci_cache(600, active, geometry=GEO,
                              resize_interval=2_000, sim_chunk=500,
                              batched=batched)

    _head_to_head(build, "eci_batched_speedup", vm_traces, active)


def main():
    num_vms = max(PHASES)
    vm_traces = [make(w, REQS_PER_PHASE * len(PHASES), seed=i,
                      addr_offset=i * 10_000_000, scale=0.25)
                 for i, w in enumerate(WORKLOADS)]
    scaling_ramp(vm_traces)
    batched_vs_sequential(vm_traces, max(PHASES))
    baseline_batched_vs_sequential(vm_traces, max(PHASES))


if __name__ == "__main__":
    main()
