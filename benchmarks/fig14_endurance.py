"""Paper Fig. 14: number of write operations committed to the SSD cache,
ETICA vs ECI-Cache, per workload (paper: 33.8% fewer on average, up to
95% for read-heavy web_3).

PR 8 extends the figure with the background cleaner's traffic: a second
ETICA run with ``clean_quota > 0`` reports the SSD write channels split
by source — datapath inserts (``cache_writes_l2``), eviction/resize
force-flushes (``evict_flushes``), and background clean flushes
(``flushes``) — plus the dirty-occupancy trajectory, all under asserted
invariants (cleaning never changes hit/miss stats; the dirty population
drains; the Prometheus exporter round-trips with the exact counts).

``--smoke`` shrinks to 3 VMs / 2k requests for CI; ``--streamed`` runs
the same mix through the sharded TraceStore and spot-checks that the
cleaning run's aggregate stats are bit-identical to in-memory.
"""
from __future__ import annotations

import numpy as np

from repro.core import EticaCache, make_eci_cache
from repro.runtime import metrics

from .common import (DRAM_CAP, GEO, REQS, RESIZE, SSD_CAP, Timer,
                     aggregate_stats, etica_config, row, vm_mix,
                     vm_mix_source)

VMS = ["web_3", "stg_1", "src2_0", "rsrch_0", "hm_1", "usr_0"]
CLEAN_QUOTA = 4


def _cleaning_section(vms, trace, reqs, etica, streamed):
    """The cleaner run + its asserted rows; returns total clean flushes."""
    ccfg = etica_config("full")
    ccfg.clean_quota = CLEAN_QUOTA
    cache = EticaCache(ccfg, len(vms))
    with Timer() as t3:
        cleaned = cache.run(trace)

    clog = np.stack(cache.clean_log)          # [intervals, V]
    dlog = np.stack(cache.dirty_log)
    for v, (vm, rb, rc) in enumerate(zip(vms, etica, cleaned)):
        s = rc.stats
        # cleaning only moves write-back traffic — served stats identical
        for k in ("reads", "writes", "read_hits_l1", "read_hits_l2",
                  "write_hits_l2"):
            assert s[k] == rb.stats[k], (vm, k, s[k], rb.stats[k])
        assert s["flushes"] == clog[:, v].sum(), vm
        row(f"fig14/clean/{vm}", t3.us / len(trace),
            f"insert={s['cache_writes_l2']:.0f} "
            f"evict_flush={s.get('evict_flushes', 0):.0f} "
            f"clean_flush={s['flushes']:.0f} "
            f"dirty_resident={s['dirty_resident']:.0f}")
    assert clog.sum() > 0, "cleaner never flushed"
    # the dirty population actually drains between intervals
    occ = dlog.sum(axis=1)
    assert occ.min() < occ.max(), "dirty occupancy never dipped"

    # telemetry self-check: exposition renders, parses, and carries the
    # exact flush counters
    text = metrics.render_cache(cache)
    fams = metrics.parse_exposition(text)
    for v in range(len(vms)):
        assert fams["etica_flushes_total"]["samples"][
            (("vm", str(v)),)] == cleaned[v].stats["flushes"]
    row("fig14/clean/summary", 0.0,
        f"clean_flushes={clog.sum():.0f} "
        f"peak_dirty={occ.max():.0f} final_dirty={occ[-1]:.0f} "
        f"exporter_families={len(fams)}")

    if streamed:
        # parity spot-check: the sharded TraceStore arrival stream is
        # bit-identical to the in-memory mix under cleaning
        mem = EticaCache(ccfg, len(vms)).run(vm_mix(vms, reqs))
        assert aggregate_stats(mem) == aggregate_stats(cleaned)
        row("fig14/clean/streamed_parity", 0.0, "stats_equal=True")
    return float(clog.sum())


def main(streamed: bool = False, smoke: bool = False):
    vms = VMS[:3] if smoke else VMS
    reqs = 2_000 if smoke else REQS
    trace = vm_mix_source(vms, reqs=reqs, streamed=streamed)
    with Timer() as t1:
        etica = EticaCache(etica_config("full"), len(vms)).run(trace)
    with Timer() as t2:
        eci = make_eci_cache(DRAM_CAP + SSD_CAP, len(vms), geometry=GEO,
                             resize_interval=RESIZE).run(trace)
    tot_e = tot_c = 0.0
    for vm, re_, rc in zip(vms, etica, eci):
        tot_e += re_.ssd_writes
        tot_c += rc.ssd_writes
        red = 1 - re_.ssd_writes / max(rc.ssd_writes, 1)
        row(f"fig14/{vm}", (t1.us + t2.us) / (2 * len(trace)),
            f"etica_writes={re_.ssd_writes:.0f} "
            f"eci_writes={rc.ssd_writes:.0f} reduction={red:.3f}")
    row("fig14/summary", 0.0,
        f"avg_ssd_write_reduction={1 - tot_e/max(tot_c,1):.3f} "
        f"(paper: 0.338)")
    _cleaning_section(vms, trace, reqs, etica, streamed)
    return 1 - tot_e / max(tot_c, 1)


if __name__ == "__main__":
    import sys
    main(streamed="--streamed" in sys.argv, smoke="--smoke" in sys.argv)
