"""Paper Fig. 14: number of write operations committed to the SSD cache,
ETICA vs ECI-Cache, per workload (paper: 33.8% fewer on average, up to
95% for read-heavy web_3)."""
from __future__ import annotations

import numpy as np

from repro.core import EticaCache, make_eci_cache

from .common import (DRAM_CAP, GEO, RESIZE, SSD_CAP, Timer, etica_config,
                     row, vm_mix_source)

VMS = ["web_3", "stg_1", "src2_0", "rsrch_0", "hm_1", "usr_0"]


def main(streamed: bool = False):
    trace = vm_mix_source(VMS, streamed=streamed)
    with Timer() as t1:
        etica = EticaCache(etica_config("full"), len(VMS)).run(trace)
    with Timer() as t2:
        eci = make_eci_cache(DRAM_CAP + SSD_CAP, len(VMS), geometry=GEO,
                             resize_interval=RESIZE).run(trace)
    tot_e = tot_c = 0.0
    for vm, re_, rc in zip(VMS, etica, eci):
        tot_e += re_.ssd_writes
        tot_c += rc.ssd_writes
        red = 1 - re_.ssd_writes / max(rc.ssd_writes, 1)
        row(f"fig14/{vm}", (t1.us + t2.us) / (2 * len(trace)),
            f"etica_writes={re_.ssd_writes:.0f} "
            f"eci_writes={rc.ssd_writes:.0f} reduction={red:.3f}")
    row("fig14/summary", 0.0,
        f"avg_ssd_write_reduction={1 - tot_e/max(tot_c,1):.3f} "
        f"(paper: 0.338)")
    return 1 - tot_e / max(tot_c, 1)


if __name__ == "__main__":
    import sys
    main(streamed="--streamed" in sys.argv)
