"""Paper Figs. 12 & 13: mean I/O latency and total hit ratio of the VMs
under ETICA-Full / ETICA-NPE / ECI-Cache at equal total cache space
(paper: 45% lower latency on average; ETICA-NPE 27%; +30% hit ratio)."""
from __future__ import annotations

import numpy as np

from repro.core import EticaCache, make_eci_cache

from .common import (DRAM_CAP, GEO, RESIZE, SSD_CAP, Timer, etica_config,
                     row, vm_mix_source)

VMS = ["hm_1", "ts_0", "usr_0", "web_3", "wdev_0", "src2_0"]


def main(streamed: bool = False):
    trace = vm_mix_source(VMS, streamed=streamed)
    out = {}
    for name, runner in [
        ("etica_full", lambda: EticaCache(etica_config("full"), len(VMS))),
        ("etica_npe", lambda: EticaCache(etica_config("npe"), len(VMS))),
        ("eci_cache", lambda: make_eci_cache(
            DRAM_CAP + SSD_CAP, len(VMS), geometry=GEO,
            resize_interval=RESIZE)),
    ]:
        with Timer() as t:
            res = runner().run(trace)
        lat = np.mean([r.mean_latency for r in res])
        clat = np.mean([r.contended_latency() for r in res])
        hit = np.mean([r.hit_ratio for r in res])
        out[name] = (lat, hit, clat)
        row(f"fig12/{name}", t.us / len(trace),
            f"mean_latency_ms={lat*1e3:.3f} "
            f"contended_ms={clat*1e3:.3f} hit_ratio={hit:.3f}")
        for vm, r in zip(VMS, res):
            row(f"fig12/{name}/{vm}", 0.0,
                f"latency_ms={r.mean_latency*1e3:.3f} hit={r.hit_ratio:.3f}")
    imp_full = 1 - out["etica_full"][0] / out["eci_cache"][0]
    imp_npe = 1 - out["etica_npe"][0] / out["eci_cache"][0]
    imp_cont = 1 - out["etica_full"][2] / out["eci_cache"][2]
    row("fig12/summary", 0.0,
        f"etica_latency_improvement={imp_full:.3f} (paper: 0.45) "
        f"npe={imp_npe:.3f} (paper: 0.27) "
        f"with_ssd_write_contention={imp_cont:.3f} "
        f"hit_gain={out['etica_full'][1]-out['eci_cache'][1]:.3f}")
    return out


if __name__ == "__main__":
    import sys
    main(streamed="--streamed" in sys.argv)
