"""Shared benchmark plumbing: CSV rows + a consistent small-scale setup.

Every benchmark prints ``name,us_per_call,derived`` rows (derived carries
the figure's headline metric). Scales are CPU-sized but structurally
identical to the paper's setup (set-associative caches, 10k-request
resize intervals scaled down proportionally).
"""
from __future__ import annotations

import time

from repro.core import EticaCache, EticaConfig, Geometry
from repro.core.trace import interleave
from repro.traces import make

GEO = Geometry(num_sets=16, max_ways=32)
RESIZE = 2_000
PROMO = 500
DRAM_CAP = 400
SSD_CAP = 800
REQS = 8_000
SCALE = 0.25


def aggregate_stats(results) -> dict[str, float]:
    """Sum per-VM ``VMResult.stats`` dicts — the quantity the
    batched-vs-sequential and streamed-vs-in-memory gates compare."""
    agg: dict[str, float] = {}
    for r in results:
        for k, v in r.stats.items():
            agg[k] = agg.get(k, 0.0) + v
    return agg


def row(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0

    @property
    def us(self) -> float:
        return self.dt * 1e6


def vm_mix(names, reqs=REQS, scale=SCALE):
    traces = [make(n, reqs, seed=i, addr_offset=i * 10_000_000, scale=scale)
              for i, n in enumerate(names)]
    return interleave(traces, seed=42)


def vm_mix_source(names, reqs=REQS, scale=SCALE, streamed=False,
                  shard_size=4096):
    """The benchmark mix as either an in-memory Trace or — with
    ``streamed`` — the same arrival stream persisted shard-by-shard via
    :func:`repro.traces.make_store` (same per-VM seeds / address stride /
    interleave seed, so results are bit-identical). Controllers accept
    the returned :class:`TraceStore` directly."""
    if not streamed:
        return vm_mix(names, reqs, scale)
    import tempfile
    from pathlib import Path
    from repro.traces import make_store
    root = Path(tempfile.mkdtemp(prefix="bench_trace_store_"))
    return make_store(root / "store", list(names), reqs, seed=0, scale=scale,
                      shard_size=shard_size)


def etica_config(mode="full", dram=DRAM_CAP, ssd=SSD_CAP):
    return EticaConfig(dram_capacity=dram, ssd_capacity=ssd,
                       geometry_dram=GEO, geometry_ssd=GEO,
                       resize_interval=RESIZE, promo_interval=PROMO,
                       mode=mode)
