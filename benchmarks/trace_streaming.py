"""Beyond-paper: the streaming trace-store ingestion layer.

Measures the pieces that let million-request traces (paper §5.1 runs
MSR Cambridge + FIO) drive the batched controllers at bounded host
memory:

  * ``stream/import_msr``   — MSR-CSV parse -> chunked store (us/req);
  * ``stream/store_scan``   — memory-mapped shard iteration (us/req);
  * ``stream/etica_*``      — EticaCache off a TraceStore vs the
    materialized in-memory trace: aggregate Stats asserted **equal**,
    then wall-clock for streamed (double-buffered), streamed with
    prefetch disabled, and in-memory; peak Python-heap use
    (``tracemalloc``) for the streamed vs in-memory run — the streamed
    path holds one resize window instead of the whole trace;
  * ``stream/eci_*``        — same protocol for the one-level ECI-Cache
    chassis (dynamic policies riding the batched sizing dispatch).
"""
from __future__ import annotations

import io
import tempfile
import tracemalloc
from pathlib import Path

from repro.core import EticaCache, make_eci_cache
from repro.traces import TraceStore, make_store, parse_msr_csv

from .common import GEO, RESIZE, Timer, aggregate_stats as _aggregate
from .common import etica_config, row

NUM_VMS = 8
REQS_PER_VM = 4_000
WORKLOADS = ["hm_1", "proj_0", "stg_1", "usr_0", "ts_0", "wdev_0",
             "web_3", "src2_0"]
SHARD = 6_000
BLOCK = 4096


def _msr_csv_of(trace) -> str:
    buf = io.StringIO()
    buf.write("Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime\n")
    for i in range(len(trace)):
        typ = "Write" if bool(trace.is_write[i]) else "Read"
        buf.write(f"{i},vm{int(trace.vm[i])},0,{typ},"
                  f"{int(trace.addr[i]) * BLOCK},{BLOCK},100\n")
    return buf.getvalue()


def ingestion(tmp: Path, trace) -> None:
    csv_text = _msr_csv_of(trace)
    with Timer() as t:
        TraceStore.from_chunks(tmp / "imported",
                               parse_msr_csv(io.StringIO(csv_text)),
                               shard_size=SHARD)
    row("stream/import_msr", t.us / len(trace),
        f"reqs={len(trace)} shards={-(-len(trace) // SHARD)}")

    store = TraceStore.open(tmp / "imported")
    with Timer() as t:
        total = sum(len(s) for s in store.iter_shards())
    assert total == len(trace)
    row("stream/store_scan", t.us / total, f"mmap_shards={store.num_shards}")


def _head_to_head(label: str, build, store_path: Path, trace) -> None:
    """Warm up both paths, assert streamed == in-memory aggregate Stats,
    then report the three timed variants + Python-heap peaks."""
    build().run(TraceStore.open(store_path))      # compile warm-up
    n = len(trace)

    tracemalloc.start()
    with Timer() as t_str:
        res_str = build().run(TraceStore.open(store_path))
    _, peak_str = tracemalloc.get_traced_memory()
    tracemalloc.stop()

    with Timer() as t_nopf:
        res_nopf = build(prefetch=False).run(TraceStore.open(store_path))

    tracemalloc.start()
    with Timer() as t_mem:
        res_mem = build().run(trace)
    _, peak_mem = tracemalloc.get_traced_memory()
    tracemalloc.stop()

    agg_str, agg_mem = _aggregate(res_str), _aggregate(res_mem)
    assert agg_str == agg_mem, (
        f"{label}: streamed and in-memory diverged:\n"
        f"  streamed:  {agg_str}\n  in-memory: {agg_mem}")
    assert _aggregate(res_nopf) == agg_mem
    row(f"stream/{label}_streamed", t_str.us / n,
        f"stats_equal=True peak_py_mb={peak_str / 2**20:.1f} "
        f"window_resident={RESIZE}")
    row(f"stream/{label}_no_prefetch", t_nopf.us / n,
        f"prefetch_gain={t_nopf.dt / t_str.dt:.2f}x")
    row(f"stream/{label}_in_memory", t_mem.us / n,
        f"peak_py_mb={peak_mem / 2**20:.1f} trace_resident={n}")


def main():
    with tempfile.TemporaryDirectory() as tmp:
        tmp = Path(tmp)
        store = make_store(tmp / "mix", WORKLOADS, REQS_PER_VM, scale=0.25,
                           shard_size=SHARD)
        trace = store.to_trace()
        ingestion(tmp, trace)

        def etica(prefetch=True):
            import dataclasses
            cfg = dataclasses.replace(etica_config("full", dram=200, ssd=400),
                                      prefetch=prefetch)
            return EticaCache(cfg, NUM_VMS)

        _head_to_head("etica", etica, tmp / "mix", trace)

        def eci(prefetch=True):
            return make_eci_cache(600, NUM_VMS, geometry=GEO,
                                  resize_interval=2_000, sim_chunk=500,
                                  prefetch=prefetch)

        _head_to_head("eci", eci, tmp / "mix", trace)


if __name__ == "__main__":
    main()
