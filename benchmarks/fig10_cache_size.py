"""Paper Figs. 10 & 11: per-interval cache sizes estimated by URD vs
POD(RO) vs POD(WBWO), and the average size reduction (paper: POD
allocates 51.7% less on average than URD)."""
from __future__ import annotations

import numpy as np

from repro.core import Policy, demand_blocks, pod, urd
from repro.traces import make

from .common import Timer, row

WORKLOADS = ["hm_1", "proj_0", "rsrch_0", "web_3", "ts_0", "wdev_0",
             "usr_0", "src2_0"]
INTERVAL = 1_000
N_INTERVALS = 10


def main():
    total_urd = total_ro = total_wbwo = 0
    for w in WORKLOADS:
        tr = make(w, INTERVAL * N_INTERVALS, seed=1, scale=0.25)
        sizes_u, sizes_r, sizes_w = [], [], []
        with Timer() as t:
            for win in tr.intervals(INTERVAL):
                sizes_u.append(demand_blocks(urd(win)))
                sizes_r.append(demand_blocks(pod(win, Policy.RO)))
                sizes_w.append(demand_blocks(pod(win, Policy.WBWO)))
        u, r, wb = map(np.mean, (sizes_u, sizes_r, sizes_w))
        total_urd += u
        total_ro += r
        total_wbwo += wb
        row(f"fig10/{w}", t.us / N_INTERVALS,
            f"avg_urd={u:.0f} avg_pod_ro={r:.0f} avg_pod_wbwo={wb:.0f}")
    red = 1 - (total_ro + total_wbwo) / (2 * total_urd)
    row("fig11/average_reduction", 0.0,
        f"pod_vs_urd_size_reduction={red:.3f} (paper: 0.517)")
    return red


if __name__ == "__main__":
    main()
