"""Paper Fig. 17: impact of the promotion/eviction interval on ETICA's
performance and endurance (interval swept 100 -> 10,000 requests; scaled
here proportionally to the benchmark trace size).

Journal-driven since PR 9: each swept run records one telemetry row per
interval into a bounded :class:`repro.runtime.telemetry
.TelemetryRecorder` journal, the headline metrics are derived from the
*journal* columns (latency / SSD-write sums over interval deltas), and
the derivation is cross-checked against the controller's own Stats plus
a JSONL spill round-trip — so the figure doubles as the observability
smoke path. ``--journal PATH`` keeps the last swept run's spill for
``tools/run_report.py``; ``--streamed`` feeds the identical mix through
the on-disk :class:`TraceStore` (bit-identical results); ``--smoke``
shrinks the sweep for CI.
"""
from __future__ import annotations

import argparse
import tempfile
from pathlib import Path

import numpy as np

from repro.core import EticaCache
from repro.runtime.telemetry import (TelemetryRecorder, load_journal,
                                     summarize_journal)

from .common import Timer, etica_config, row, vm_mix_source

VMS = ["hm_1", "usr_0", "ts_0"]
INTERVALS = [100, 250, 500, 1000, 2000]


def _sweep_one(iv: int, trace, spill: Path):
    """One swept run: controller with a journal-spilling recorder."""
    rec = TelemetryRecorder(spill=spill)
    cfg = etica_config("full")
    cfg.promo_interval = iv
    cfg.telemetry = rec
    with Timer() as t:
        cache = EticaCache(cfg, len(VMS))
        res = cache.run(trace)
    rec.journal.close()
    # journal <-> JSONL round-trip, asserted: the spill reloads to the
    # same per-interval series the in-memory ring retains
    cols = load_journal(spill)
    tail = cols["requests"][-rec.journal.retained:]
    assert np.array_equal(tail, rec.journal.column("requests"))
    # journal <-> Stats cross-check: interval deltas sum back to the
    # cumulative counters the controller kept independently
    stats = [r.stats for r in res]
    assert abs(cols["requests"].sum()
               - sum(s["reads"] + s["writes"] for s in stats)) < 1e-6
    assert abs(cols["ssd_writes"].sum()
               - sum(s["cache_writes_l2"] for s in stats)) < 1e-6
    return t, cols, len(trace)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep for CI")
    ap.add_argument("--streamed", action="store_true",
                    help="feed the mix through an on-disk TraceStore")
    ap.add_argument("--journal", default=None,
                    help="keep the last swept run's JSONL spill here")
    args = ap.parse_args(argv)

    reqs = 1_500 if args.smoke else 6_000
    intervals = [100, 500] if args.smoke else INTERVALS
    trace = vm_mix_source(VMS, reqs=reqs, streamed=args.streamed)
    tmp = Path(tempfile.mkdtemp(prefix="fig17_journal_"))
    base = None
    for iv in intervals:
        spill = (Path(args.journal) if args.journal and iv == intervals[-1]
                 else tmp / f"interval_{iv}.jsonl")
        t, cols, n = _sweep_one(iv, trace, spill)
        s = summarize_journal(cols)
        # latency / endurance from the journal columns (not VMResult)
        lat = cols["latency"].sum() / max(cols["requests"].sum(), 1)
        writes = cols["ssd_writes"].sum()
        if base is None:
            base = (lat, writes)
        row(f"fig17/interval_{iv}", t.us / n,
            f"latency_norm={lat/base[0]:.3f} "
            f"ssd_writes_norm={writes/max(base[1],1):.3f} "
            f"intervals={s['intervals']} "
            f"mean_hit={s['mean_hit_ratio']:.3f} "
            f"overloaded={s['overloaded_intervals']}")


if __name__ == "__main__":
    main()
