"""Paper Fig. 17: impact of the promotion/eviction interval on ETICA's
performance and endurance (interval swept 100 -> 10,000 requests; scaled
here proportionally to the benchmark trace size)."""
from __future__ import annotations

import numpy as np

from repro.core import EticaCache

from .common import Timer, etica_config, row, vm_mix

VMS = ["hm_1", "usr_0", "ts_0"]
INTERVALS = [100, 250, 500, 1000, 2000]


def main():
    trace = vm_mix(VMS, reqs=6_000)
    base = None
    for iv in INTERVALS:
        cfg = etica_config("full")
        cfg.promo_interval = iv
        with Timer() as t:
            res = EticaCache(cfg, len(VMS)).run(trace)
        lat = np.mean([r.mean_latency for r in res])
        writes = sum(r.ssd_writes for r in res)
        if base is None:
            base = (lat, writes)
        row(f"fig17/interval_{iv}", t.us / len(trace),
            f"latency_norm={lat/base[0]:.3f} "
            f"ssd_writes_norm={writes/max(base[1],1):.3f}")


if __name__ == "__main__":
    main()
