"""Paper Fig. 3: effect of the cache write policy on performance and SSD
endurance, per motivational workload (FIO-RandRW, Web Server, Video
Server, Varmail) x policy (WB, RO, WBWO)."""
from __future__ import annotations

import numpy as np

from repro.core import Policy, Stats, make_cache, simulate_single_level
from repro.traces import make

from .common import GEO, Timer, row

WORKLOADS = ["fio_randrw", "web_server", "video_server", "varmail"]
POLICIES = [Policy.WB, Policy.RO, Policy.WBWO]
N = 6_000


def _workload_chunks(workload: str, streamed: bool):
    """The workload as an iterable of request chunks; with ``streamed``
    the trace is persisted through :func:`repro.traces.make_store` (one
    single-VM mix) and consumed shard-by-shard at bounded memory."""
    if not streamed:
        yield make(workload, N, seed=0, scale=0.25)
        return
    import tempfile
    from pathlib import Path
    from repro.traces import make_store
    root = Path(tempfile.mkdtemp(prefix="fig3_store_"))
    store = make_store(root / workload, [workload], N, seed=0, scale=0.25,
                       shard_size=1024)
    yield from store.iter_shards()


def run_one(workload: str, policy: Policy, streamed: bool = False):
    state = make_cache(GEO.num_sets, GEO.max_ways)
    stats, t0 = Stats.zero(), 0
    with Timer() as t:
        for chunk in _workload_chunks(workload, streamed):
            state, st, t0 = simulate_single_level(
                np.asarray(chunk.addr), np.asarray(chunk.is_write), state,
                GEO.max_ways, policy, t0=t0)
            stats = stats.merge(st)
        iops = 1.0 / max(stats.mean_latency(), 1e-12)
    return t.us, iops, int(stats.cache_writes_l2)


def main(streamed: bool = False):
    results = {}
    for w in WORKLOADS:
        for p in POLICIES:
            us, iops, writes = run_one(w, p, streamed=streamed)
            results[(w, p)] = (iops, writes)
            row(f"fig3/{w}/{p.value}", us / N,
                f"iops={iops:.0f} ssd_writes={writes}")
    # headline checks mirroring the paper's four observations
    for w in WORKLOADS:
        wb_i, wb_w = results[(w, Policy.WB)]
        wo_i, wo_w = results[(w, Policy.WBWO)]
        ro_i, ro_w = results[(w, Policy.RO)]
        row(f"fig3/{w}/summary", 0.0,
            f"WBWO_writes/WB={wo_w/max(wb_w,1):.2f} "
            f"RO_writes/WB={ro_w/max(wb_w,1):.2f} "
            f"WBWO_iops/WB={wo_i/max(wb_i,1e-9):.2f}")
    return results


if __name__ == "__main__":
    main()
