"""Benchmark harness: one module per paper table/figure (+ the beyond-
paper serving and kernel benches). Prints ``name,us_per_call,derived``
CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig12]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "fig3_write_policy",
    "fig10_cache_size",
    "fig12_latency",
    "fig14_endurance",
    "fig15_vm_scaling",
    "fig17_intervals",
    "serving_two_tier",
    "kernels_bench",
    "trace_streaming",
    "classification_bench",
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
            mod.main()
            print(f"# {mod_name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
