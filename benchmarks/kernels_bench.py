"""Kernel micro-benchmarks: Pallas (interpret on CPU) parity + jnp-ref
timing. On-TPU wall time is not measurable here; the derived column
reports the kernel's arithmetic/byte characteristics used in §Roofline.

The ``maintenance/fused_*`` rows are the fused-vs-staged head-to-head
for the between-interval maintenance pipeline: one fused jitted
dispatch (device popularity table + Pallas promote/evict kernels, zero
host round-trips between stages) against the staged path (host
trackers, separate vmapped dispatches, two state syncs per interval) at
8/32/128 VMs — states asserted bit-identical before timing. On CPU the
fused column pays the Pallas *interpreter* tax (the kernels execute
through the interpreter so the real kernel bodies are what is
validated); the quantity that transfers to a real accelerator is the
dispatch structure — 1 fused jitted call and 0 host syncs per interval
vs the staged path's 2 kernel dispatches + 2 device->host state syncs +
per-VM host queue loops.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EticaCache, EticaConfig, Geometry, Trace
from repro.core.simulator import (make_cache, make_cache_batch,
                                  simulate_two_level,
                                  simulate_two_level_batch)
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.popularity.kernel import popularity
from repro.kernels.popularity.ref import popularity_ref
from repro.kernels.reuse_distance.kernel import count_between
from repro.kernels.reuse_distance.ref import count_between_ref

from .common import row


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def main():
    rng = np.random.default_rng(0)

    # reuse distance: N=4096 window (paper's 10k interval scaled)
    n = 4096
    prev = jnp.asarray(rng.integers(-1, n, n), jnp.int32)
    touch = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    nt = jnp.asarray(rng.integers(0, n + 1, n), jnp.int32)
    us_ref = _time(jax.jit(count_between_ref), prev, touch, nt)
    got = count_between(prev, touch, nt)
    want = count_between_ref(prev, touch, nt)
    ok = bool((np.asarray(got) == np.asarray(want)).all())
    row("kernels/reuse_distance_ref_n4096", us_ref,
        f"pairwise_ops={n*n} kernel_matches_ref={ok}")

    # popularity: N=8192 accesses, 1024 blocks
    n, nb = 8192, 1024
    dist = jnp.asarray(rng.integers(-1, 500, n), jnp.int32)
    served = jnp.asarray(rng.integers(0, 2, n).astype(bool))
    seg = jnp.asarray(rng.integers(0, nb, n), jnp.int32)
    us_ref = _time(jax.jit(lambda d, s, g: popularity_ref(d, s, g, nb, 64.0)),
                   dist, served, seg)
    got = popularity(dist, served, seg, nb, 64.0)
    want = popularity_ref(dist, served, seg, nb, 64.0)
    ok = bool(np.allclose(np.asarray(got), np.asarray(want), atol=1e-5))
    row("kernels/popularity_ref_n8192", us_ref,
        f"exp_evals={n} kernel_matches_ref={ok}")

    # flash attention: B1 H4 S512 D64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 512, 64))
    k = jax.random.normal(ks[1], (1, 2, 512, 64))
    v = jax.random.normal(ks[2], (1, 2, 512, 64))
    us_ref = _time(jax.jit(
        lambda a, b, c: attention_ref(a, b, c, causal=True)), q, k, v)
    got = flash_attention(q, k, v, causal=True, tq=128, tk=128)
    want = attention_ref(q, k, v, causal=True)
    ok = bool(np.allclose(np.asarray(got), np.asarray(want), atol=2e-5))
    flops = 4 * 1 * 4 * 512 * 512 * 64
    row("kernels/flash_attention_ref_s512", us_ref,
        f"flops={flops} kernel_matches_ref={ok}")

    # batched multi-VM datapath: one vmapped 500-step scan for V VMs vs V
    # sequential dispatches of the same scan (the tentpole's raw win)
    num_vms, steps, sets, ways = 8, 500, 16, 32
    addr = jnp.asarray(rng.integers(0, 4000, (num_vms, steps)), jnp.int32)
    wr = jnp.asarray(rng.random((num_vms, steps)) < 0.4)
    ways_arr = jnp.full(num_vms, 16, jnp.int32)
    dram = make_cache_batch(num_vms, sets, ways)
    ssd = make_cache_batch(num_vms, sets, ways)
    t0 = jnp.zeros(num_vms, jnp.int32)

    def batched():
        return simulate_two_level_batch(addr, wr, dram, ssd, ways_arr,
                                        ways_arr, mode="full", t0=t0)[2]

    def sequential():
        d1, s1 = make_cache(sets, ways), make_cache(sets, ways)
        out = [simulate_two_level(addr[v], wr[v], d1, s1, 16, 16,
                                  mode="full")[2] for v in range(num_vms)]
        return out[-1]

    us_b = _time(batched)
    us_s = _time(sequential)
    row("datapath/two_level_batched_v8", us_b,
        f"steps={num_vms * steps} seq_us={us_s:.1f} "
        f"speedup={us_s / us_b:.2f}x")

    maintenance_bench()


def _maintenance_chunks(num_vms: int, reqs: int, seed: int) -> list[Trace]:
    """One promo-interval window per VM: enough re-references that the
    popularity table fills the partition and the evict path engages."""
    rng = np.random.default_rng(seed)
    return [Trace(addr=(rng.integers(0, 400, reqs) + v * 100_000)
                  .astype(np.int32),
                  is_write=rng.random(reqs) < 0.4)
            for v in range(num_vms)]


def maintenance_bench(vm_counts=(8, 32, 128), reqs=256, rounds=3) -> None:
    """Fused vs staged maintenance at 8/32/128 VMs, states asserted equal."""
    geo = Geometry(num_sets=16, max_ways=32)

    def build(fused: bool) -> EticaCache:
        cfg = EticaConfig(dram_capacity=16 * num_vms,
                          ssd_capacity=64 * num_vms,
                          geometry_dram=geo, geometry_ssd=geo,
                          fused_maintenance=fused)
        cache = EticaCache(cfg, num_vms)
        cache.ways_ssd = np.full(num_vms, 8, np.int32)  # 128-block parts
        return cache

    for num_vms in vm_counts:
        windows = [_maintenance_chunks(num_vms, reqs, r)
                   for r in range(rounds)]
        caches, times = {}, {}
        for fused in (True, False):
            build(fused)._maintain_all(windows[0])      # compile/warm-up
            cache = build(fused)
            t0 = time.time()
            for chunks in windows:
                cache._maintain_all(chunks)
            jax.block_until_ready(cache.ssd)
            times[fused] = time.time() - t0
            caches[fused] = cache
        ok = all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(caches[True].ssd, caches[False].ssd)
        ) and caches[True].stats == caches[False].stats
        assert ok, f"fused and staged maintenance diverged at {num_vms} VMs"
        us_f = times[True] / rounds * 1e6
        us_s = times[False] / rounds * 1e6
        row(f"maintenance/fused_{num_vms}vms", us_f,
            f"staged_us={us_s:.1f} speedup={us_s / us_f:.2f}x "
            f"reqs_per_vm={reqs} rounds={rounds} states_equal=True "
            f"pallas=interpret host_syncs_fused=0 host_syncs_staged=2")


if __name__ == "__main__":
    main()
