#!/usr/bin/env python3
"""Summarize a telemetry journal spill (JSONL) on the terminal.

    PYTHONPATH=src python tools/run_report.py journal.jsonl [--last N]
        [--vm V]

Prints one line per recorded interval — requests, hit ratio, dirty
occupancy, overload flags — plus a run summary, for journals written by
either controller family (per-VM columns) or the serving manager
(scalar columns + per-tenant quota). ``--vm`` narrows the per-interval
series to one VM's columns; ``--last N`` keeps the tail only.

The heavy lifting lives in :mod:`repro.runtime.telemetry`
(``load_journal`` / ``summarize_journal`` / ``format_report``) so
benchmarks (fig17) render from exactly the same code path.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

# usable straight from a checkout without PYTHONPATH gymnastics
_SRC = pathlib.Path(__file__).resolve().parent.parent / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from repro.runtime.telemetry import (format_report,  # noqa: E402
                                     load_journal)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Per-interval telemetry journal report")
    ap.add_argument("journal", help="JSONL spill written by a "
                                    "TelemetryRecorder journal")
    ap.add_argument("--last", type=int, default=None,
                    help="print only the last N intervals")
    ap.add_argument("--vm", type=int, default=None,
                    help="narrow the series to one VM/tenant index")
    args = ap.parse_args(argv)
    cols = load_journal(args.journal)
    for line in format_report(cols, last=args.last, vm=args.vm):
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
