#!/usr/bin/env python3
"""Docs gate (CI): relative links must resolve, verify command must match.

Checks, over README.md and docs/*.md:

  1. every relative markdown link target exists on disk (external URLs
     and pure #-anchors are skipped);
  2. the tier-1 verify command quoted in README.md matches ROADMAP.md's
     **Tier-1 verify:** command (after normalizing the optional
     ``${PYTHONPATH:+:$PYTHONPATH}`` suffix, which only matters for
     pre-populated environments);
  3. the streaming-layer docs stay wired up: README documents the
     trace-import CLI (``python -m repro.traces.store import``) for a
     module that actually exists, and docs/architecture.md links both
     streaming modules (``traces/store.py`` and ``traces/stream.py``),
     so the link check in (1) keeps validating them;
  4. the maintenance-pipeline docs stay wired up: docs/architecture.md
     links the ``kernels/maintenance`` package (kernel + ops) and the
     README module map names ``kernels/maintenance/``, for a package
     that actually exists on disk;
  5. the IO-classification docs stay wired up: docs/architecture.md
     links both classify modules (``classify/rules.py`` and
     ``classify/classifier.py``) and the README module map names
     ``classify/``, for a package that actually exists on disk;
  6. the serving-workload docs stay wired up: docs/architecture.md
     links the serving modules (``kvcache/manager.py``,
     ``launch/serve.py``, ``traces/generators.py``) and the README
     module map names ``kvcache/``, for modules that actually exist;
  7. the cleaning/telemetry docs stay wired up: docs/architecture.md
     has a "Background cleaning & telemetry" section that links
     ``runtime/metrics.py``, the README module map names
     ``runtime/metrics.py``, and the module actually exists on disk;
  8. the observability docs stay wired up: the interval-telemetry
     runtime modules (``runtime/telemetry.py``, ``runtime/http.py``,
     ``tools/run_report.py``) exist on disk, the README module map
     names the first two, and docs/architecture.md has an
     "Observability" section that links all three and documents the
     ``etica_dispatch_seconds`` histogram family.

Stdlib only; exits non-zero with a per-problem report.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _normalize_cmd(cmd: str) -> str:
    return " ".join(cmd.replace("${PYTHONPATH:+:$PYTHONPATH}", "").split())


def _code_lines(text: str) -> set[str]:
    """Inline code spans plus individual lines of fenced code blocks."""
    spans = set(re.findall(r"`([^`\n]+)`", text))
    for block in re.findall(r"```[^\n]*\n(.*?)```", text, re.DOTALL):
        spans.update(line.strip() for line in block.splitlines())
    return spans


def _code_commands(text: str) -> set[str]:
    return {s for s in _code_lines(text) if "pytest" in s}


def check_links(md: Path) -> list[str]:
    problems = []
    for target in LINK_RE.findall(md.read_text()):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target) or target.startswith("#"):
            continue  # external URL (http:, mailto:, ...) or in-page anchor
        path = target.split("#", 1)[0]
        if not (md.parent / path).exists():
            problems.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return problems


def check_verify_command() -> list[str]:
    roadmap = (ROOT / "ROADMAP.md").read_text()
    readme = (ROOT / "README.md").read_text()
    m = re.search(r"\*\*Tier-1 verify:\*\*\s*`([^`]+)`", roadmap)
    if not m:
        return ["ROADMAP.md: no '**Tier-1 verify:** `...`' line found"]
    want = _normalize_cmd(m.group(1))
    have = {_normalize_cmd(c) for c in _code_commands(readme)}
    if want not in have:
        return [f"README.md: tier-1 verify command not found or != ROADMAP's "
                f"({want!r}; README has {sorted(have)!r})"]
    return []


def check_streaming_docs() -> list[str]:
    problems = []
    cli_module = ROOT / "src/repro/traces/store.py"
    readme = (ROOT / "README.md").read_text()
    cli_cmds = [c for c in _code_lines(readme)
                if re.search(r"python -m repro\.traces\.store\s+import", c)]
    if not cli_cmds:
        problems.append("README.md: no 'python -m repro.traces.store import'"
                        " command documented (external-traces section)")
    elif not cli_module.exists():
        problems.append("README.md documents the trace-import CLI but "
                        "src/repro/traces/store.py does not exist")
    arch = (ROOT / "docs" / "architecture.md")
    if arch.exists():
        targets = set(LINK_RE.findall(arch.read_text()))
        for mod in ("traces/store.py", "traces/stream.py"):
            if not any(t.endswith(mod) for t in targets):
                problems.append(f"docs/architecture.md: streaming module "
                                f"{mod} is not linked")
    return problems


def check_maintenance_docs() -> list[str]:
    problems = []
    pkg = ROOT / "src/repro/kernels/maintenance"
    for mod in ("kernel.py", "ops.py", "ref.py"):
        if not (pkg / mod).exists():
            problems.append(f"src/repro/kernels/maintenance/{mod} missing "
                            "(docs describe the maintenance kernel package)")
    readme = (ROOT / "README.md").read_text()
    if "kernels/maintenance/" not in readme:
        problems.append("README.md: module map does not name "
                        "kernels/maintenance/")
    arch = ROOT / "docs" / "architecture.md"
    if arch.exists():
        targets = set(LINK_RE.findall(arch.read_text()))
        for mod in ("kernels/maintenance", "kernels/maintenance/kernel.py",
                    "kernels/maintenance/ops.py"):
            if not any(t.rstrip("/").endswith(mod) for t in targets):
                problems.append(f"docs/architecture.md: maintenance module "
                                f"{mod} is not linked")
    return problems


def check_classification_docs() -> list[str]:
    problems = []
    pkg = ROOT / "src/repro/classify"
    for mod in ("rules.py", "classifier.py", "__init__.py"):
        if not (pkg / mod).exists():
            problems.append(f"src/repro/classify/{mod} missing "
                            "(docs describe the IO-classification package)")
    readme = (ROOT / "README.md").read_text()
    if "`classify/`" not in readme:
        problems.append("README.md: module map does not name classify/")
    arch = ROOT / "docs" / "architecture.md"
    if arch.exists():
        targets = set(LINK_RE.findall(arch.read_text()))
        for mod in ("classify/rules.py", "classify/classifier.py"):
            if not any(t.endswith(mod) for t in targets):
                problems.append(f"docs/architecture.md: classification "
                                f"module {mod} is not linked")
    return problems


def check_serving_docs() -> list[str]:
    problems = []
    for mod in ("kvcache/manager.py", "kvcache/baseline.py",
                "launch/serve.py", "traces/generators.py"):
        if not (ROOT / "src/repro" / mod).exists():
            problems.append(f"src/repro/{mod} missing "
                            "(docs describe the serving workload)")
    readme = (ROOT / "README.md").read_text()
    if "`kvcache/`" not in readme:
        problems.append("README.md: module map does not name kvcache/")
    arch = ROOT / "docs" / "architecture.md"
    if arch.exists():
        targets = set(LINK_RE.findall(arch.read_text()))
        for mod in ("kvcache/manager.py", "launch/serve.py",
                    "traces/generators.py"):
            if not any(t.endswith(mod) for t in targets):
                problems.append(f"docs/architecture.md: serving module "
                                f"{mod} is not linked")
    return problems


def check_cleaning_docs() -> list[str]:
    problems = []
    if not (ROOT / "src/repro/runtime/metrics.py").exists():
        problems.append("src/repro/runtime/metrics.py missing "
                        "(docs describe the telemetry exporter)")
    readme = (ROOT / "README.md").read_text()
    if "runtime/metrics.py" not in readme:
        problems.append("README.md: module map does not name "
                        "runtime/metrics.py")
    arch = ROOT / "docs" / "architecture.md"
    if arch.exists():
        text = arch.read_text()
        if "Background cleaning & telemetry" not in text:
            problems.append("docs/architecture.md: no 'Background cleaning "
                            "& telemetry' section")
        targets = set(LINK_RE.findall(text))
        if not any(t.endswith("runtime/metrics.py") for t in targets):
            problems.append("docs/architecture.md: telemetry module "
                            "runtime/metrics.py is not linked")
    return problems


def check_observability_docs() -> list[str]:
    problems = []
    modules = ("src/repro/runtime/telemetry.py", "src/repro/runtime/http.py",
               "tools/run_report.py")
    for mod in modules:
        if not (ROOT / mod).exists():
            problems.append(f"{mod} missing (docs describe the interval "
                            "telemetry runtime)")
    readme = (ROOT / "README.md").read_text()
    for mod in ("runtime/telemetry.py", "runtime/http.py"):
        if mod not in readme:
            problems.append(f"README.md: module map does not name {mod}")
    arch = ROOT / "docs" / "architecture.md"
    if arch.exists():
        text = arch.read_text()
        if "## Observability" not in text:
            problems.append("docs/architecture.md: no 'Observability' "
                            "section")
        if "etica_dispatch_seconds" not in text:
            problems.append("docs/architecture.md: the "
                            "etica_dispatch_seconds histogram family is "
                            "not documented")
        targets = set(LINK_RE.findall(text))
        for mod in ("runtime/telemetry.py", "runtime/http.py",
                    "tools/run_report.py"):
            if not any(t.endswith(mod) for t in targets):
                problems.append(f"docs/architecture.md: observability "
                                f"module {mod} is not linked")
    return problems


def check_sharding_docs() -> list[str]:
    problems = []
    if not (ROOT / "src/repro/launch/mesh.py").exists():
        problems.append("src/repro/launch/mesh.py missing (docs describe "
                        "the VM-axis sharding layer)")
    if not (ROOT / "tests/test_sharding.py").exists():
        problems.append("tests/test_sharding.py missing (docs promise the "
                        "sharded bit-identity / no-collective tests)")
    readme = (ROOT / "README.md").read_text()
    if "launch/mesh.py" not in readme:
        problems.append("README.md: module map does not name "
                        "launch/mesh.py")
    arch = ROOT / "docs" / "architecture.md"
    if arch.exists():
        text = arch.read_text()
        if "## Sharded consolidation" not in text:
            problems.append("docs/architecture.md: no 'Sharded "
                            "consolidation' section")
        for needle, what in (
                ("make_vm_mesh", "the VM mesh builder"),
                ("aggregate_stats_sharded", "the one intended collective"),
                ("device_row_blocks", "the manual per-device dispatch")):
            if needle not in text:
                problems.append(f"docs/architecture.md: {what} "
                                f"({needle}) is not documented")
        targets = set(LINK_RE.findall(text))
        for mod in ("launch/mesh.py", "tests/test_sharding.py"):
            if not any(t.endswith(mod) for t in targets):
                problems.append(f"docs/architecture.md: sharding file "
                                f"{mod} is not linked")
    return problems


def main() -> int:
    docs = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    problems: list[str] = []
    for md in docs:
        if not md.exists():
            problems.append(f"missing required doc: {md.relative_to(ROOT)}")
            continue
        problems.extend(check_links(md))
    problems.extend(check_verify_command())
    problems.extend(check_streaming_docs())
    problems.extend(check_maintenance_docs())
    problems.extend(check_classification_docs())
    problems.extend(check_serving_docs())
    problems.extend(check_cleaning_docs())
    problems.extend(check_observability_docs())
    problems.extend(check_sharding_docs())
    for p in problems:
        print(f"FAIL: {p}", file=sys.stderr)
    if not problems:
        print(f"docs OK: {len(docs)} files, links resolve, "
              "verify command matches ROADMAP")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
