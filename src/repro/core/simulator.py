"""Trace-driven cache simulators (exact datapath, `jax.lax.scan`).

Two entry points:

  * :func:`simulate_single_level` — one cache device in front of the disk
    under any :class:`~repro.core.policies.Policy` (used for the paper's
    motivational Fig. 3 study and the one-level baselines ECI-Cache,
    Centaur, S-CAVE, vCacheShare).
  * :func:`simulate_two_level` — ETICA's DRAM(RO) + SSD(WBWO) hierarchy
    (paper §4.1/§4.2), in ``"full"`` (pull-mode SSD: misses never update
    the SSD on the datapath) or ``"npe"`` (no promotion/eviction: write
    misses allocate in the SSD datapath) modes.

Caches are set-associative (paper: 512-block sets; geometry configurable).
The *allocated* capacity of a VM's cache is expressed as active ways —
resizing between intervals activates/deactivates ways (deactivation
flushes dirty blocks, counted as disk writes). All datapath state is a
pytree scanned over the request stream, so a full interval simulates as
one fused XLA loop.

Batched multi-VM contract
-------------------------

ETICA partitions one physical cache across V VMs; the batched entry
points run one interval for *all* VMs as a single jitted dispatch instead
of V sequential ones:

  * :func:`simulate_single_level_batch` — ``addr``/``is_write`` are
    ``[V, N]``, the :class:`CacheState` pytree carries a leading VM axis
    (``tags``/``lru``/``dirty`` are ``[V, S, W]``), ``ways_active`` and
    ``t0`` are ``[V]``, and the write policy is a :class:`PolicyFlags` of
    ``[V]`` booleans (build with :func:`policy_flags`) — so heterogeneous
    per-VM policies (ECI-Cache's dynamic RO/WB) and per-VM allocations
    batch in one executable.
  * :func:`simulate_two_level_batch` — same layout for both levels;
    ``mode`` stays static (it is global to the hierarchy).

Both return the same (state(s), :class:`Stats`, ``t_end``) tuple with a
leading ``[V]`` axis on every leaf, **bit-identical** per VM to running
the unbatched functions per VM (the batched path vmaps the very same
step function; integer counters and float32 latency accumulate in the
same order). Padding requests with ``addr == -1`` makes them exact
no-ops, which is how ragged per-VM windows batch to a rectangle. Use
:func:`make_cache_batch` / :func:`stack_states` / :func:`unstack_states`
to build and take apart the stacked pytrees.

The between-interval maintenance helpers (:func:`resize`,
:func:`evict_blocks`, :func:`promote_blocks`) are vectorized ``jnp`` ops
with ``(state, count)`` contracts, jit-able and vmappable
(:func:`resize_batch` maps :func:`resize` over the VM axis); the original
numpy implementations are kept as ``*_ref`` reference oracles for the
tests.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .policies import Policy, T_DRAM, T_HDD, T_HDD_WRITE, T_SSD


class CacheState(NamedTuple):
    tags: jax.Array   # int32 [S, W], -1 = invalid
    lru: jax.Array    # int32 [S, W], last-touch time (-1 = never)
    dirty: jax.Array  # bool  [S, W]


class Stats(NamedTuple):
    reads: jax.Array
    writes: jax.Array
    read_hits_l1: jax.Array    # DRAM hits (two-level only)
    read_hits_l2: jax.Array    # SSD / single-level cache read hits
    write_hits_l2: jax.Array
    cache_writes_l2: jax.Array  # endurance metric: writes committed to SSD
    disk_reads: jax.Array
    disk_writes: jax.Array
    latency_sum: jax.Array     # seconds (float32)
    # numpy scalar defaults: they carry a .dtype for the padding mask
    # multiply without forcing JAX backend init at import time
    bypassed: jax.Array = np.int32(0)    # classifier bypass channel
    pop_drops: jax.Array = np.int32(0)   # popularity-table merge overflow
    flushes: jax.Array = np.int32(0)     # background-cleaner dirty flushes
    dirty_resident: jax.Array = np.int32(0)  # gauge: dirty blocks resident
                                             # after the last maintenance

    @staticmethod
    def zero() -> "Stats":
        z = jnp.int32(0)
        return Stats(z, z, z, z, z, z, z, z, jnp.float32(0.0), z, z, z, z)

    def merge(self, o: "Stats") -> "Stats":
        return Stats(*[a + b for a, b in zip(self, o)])

    # -- derived metrics -------------------------------------------------
    @property
    def total(self):
        return self.reads + self.writes

    @property
    def hits(self):
        return self.read_hits_l1 + self.read_hits_l2 + self.write_hits_l2

    def hit_ratio(self) -> float:
        return float(self.hits) / max(int(self.total), 1)

    def mean_latency(self) -> float:
        return float(self.latency_sum) / max(int(self.total), 1)


class PolicyFlags(NamedTuple):
    """Traced write-policy predicates (see :mod:`repro.core.policies`).

    As scalars these jit-fold to the static-policy code; as ``[V]`` arrays
    they let one batched dispatch serve VMs with different policies.
    """
    allocates_reads: jax.Array   # bool
    write_invalidates: jax.Array
    holds_dirty: jax.Array
    write_through: jax.Array


def policy_flags(policy: Policy | Sequence[Policy]) -> PolicyFlags:
    """Build :class:`PolicyFlags` from one Policy (scalars) or a per-VM
    sequence (``[V]`` bool arrays)."""
    if isinstance(policy, Policy):
        return PolicyFlags(
            jnp.asarray(policy.allocates_reads),
            jnp.asarray(policy.write_invalidates),
            jnp.asarray(policy.holds_dirty),
            jnp.asarray(policy.write_through),
        )
    ps = list(policy)
    return PolicyFlags(
        jnp.asarray([p.allocates_reads for p in ps]),
        jnp.asarray([p.write_invalidates for p in ps]),
        jnp.asarray([p.holds_dirty for p in ps]),
        jnp.asarray([p.write_through for p in ps]),
    )


def make_cache(num_sets: int, ways: int) -> CacheState:
    return CacheState(
        tags=jnp.full((num_sets, ways), -1, jnp.int32),
        lru=jnp.full((num_sets, ways), -1, jnp.int32),
        dirty=jnp.zeros((num_sets, ways), bool),
    )


def make_cache_batch(num_vms: int, num_sets: int, ways: int) -> CacheState:
    """Stacked per-VM caches: every leaf carries a leading ``[V]`` axis."""
    return CacheState(
        tags=jnp.full((num_vms, num_sets, ways), -1, jnp.int32),
        lru=jnp.full((num_vms, num_sets, ways), -1, jnp.int32),
        dirty=jnp.zeros((num_vms, num_sets, ways), bool),
    )


def stack_states(states: Sequence[CacheState]) -> CacheState:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def unstack_states(state: CacheState) -> list[CacheState]:
    v = state.tags.shape[0]
    return [jax.tree_util.tree_map(lambda x: x[i], state) for i in range(v)]


def capacity_to_ways(capacity_blocks: int | jax.Array, num_sets: int,
                     max_ways: int) -> jax.Array:
    """Blocks -> active ways (ceil), clipped to the geometry."""
    w = (jnp.asarray(capacity_blocks) + num_sets - 1) // num_sets
    return jnp.clip(w, 0, max_ways).astype(jnp.int32)


# ---------------------------------------------------------------------------
# datapath primitives (single request, single set)
# ---------------------------------------------------------------------------

def _lookup(state: CacheState, s, addr, ways_active):
    active = jnp.arange(state.tags.shape[1]) < ways_active
    eq = (state.tags[s] == addr) & active
    hit = jnp.any(eq)
    way = jnp.argmax(eq)
    return hit, way, active


def _touch(state: CacheState, s, way, t, set_dirty):
    return state._replace(
        lru=state.lru.at[s, way].set(t),
        dirty=state.dirty.at[s, way].set(state.dirty[s, way] | set_dirty),
    )


def _victim(state: CacheState, s, active):
    """Pick insert way: first invalid active way, else LRU-min active way."""
    lru_s = state.lru[s]
    tags_s = state.tags[s]
    score = jnp.where(active, jnp.where(tags_s < 0, -1, lru_s), jnp.int32(2**31 - 1))
    return jnp.argmin(score)


def _insert(state: CacheState, s, addr, t, dirty, ways_active):
    """Insert a block; returns (state, evicted_valid, evicted_dirty)."""
    active = jnp.arange(state.tags.shape[1]) < ways_active
    can = ways_active > 0
    way = _victim(state, s, active)
    ev_valid = can & (state.tags[s, way] >= 0)
    ev_dirty = ev_valid & state.dirty[s, way]
    new = CacheState(
        tags=state.tags.at[s, way].set(jnp.where(can, addr, state.tags[s, way])),
        lru=state.lru.at[s, way].set(jnp.where(can, t, state.lru[s, way])),
        dirty=state.dirty.at[s, way].set(jnp.where(can, dirty, state.dirty[s, way])),
    )
    return new, can, ev_valid, ev_dirty


def _insert_range(state: CacheState, s, addr, t, dirty, way_lo, way_hi):
    """:func:`_insert` restricted to the way range ``[way_lo, way_hi)`` —
    the sub-partition slice an IO class may allocate into. With
    ``way_lo == 0`` and ``way_hi == ways_active`` this is exactly
    :func:`_insert`. An empty range means the class cannot allocate."""
    idx = jnp.arange(state.tags.shape[1])
    active = (idx >= way_lo) & (idx < way_hi)
    can = way_hi > way_lo
    way = _victim(state, s, active)
    ev_valid = can & (state.tags[s, way] >= 0)
    ev_dirty = ev_valid & state.dirty[s, way]
    new = CacheState(
        tags=state.tags.at[s, way].set(jnp.where(can, addr, state.tags[s, way])),
        lru=state.lru.at[s, way].set(jnp.where(can, t, state.lru[s, way])),
        dirty=state.dirty.at[s, way].set(jnp.where(can, dirty, state.dirty[s, way])),
    )
    return new, can, ev_valid, ev_dirty


def _invalidate(state: CacheState, s, way, pred):
    return CacheState(
        tags=state.tags.at[s, way].set(jnp.where(pred, -1, state.tags[s, way])),
        lru=state.lru.at[s, way].set(jnp.where(pred, -1, state.lru[s, way])),
        dirty=state.dirty.at[s, way].set(jnp.where(pred, False, state.dirty[s, way])),
    )


# ---------------------------------------------------------------------------
# single level
# ---------------------------------------------------------------------------

def _simulate_single_level(addr, is_write, state: CacheState, ways_active,
                           flags: PolicyFlags, t_cache, t0):
    """Unjitted single-level core over traced :class:`PolicyFlags`.

    With scalar (Python-bool) flags XLA folds the selects back to the
    static-policy code; with traced flags the same step serves any policy,
    which is what lets :func:`simulate_single_level_batch` vmap VMs with
    heterogeneous policies in one dispatch.
    """
    num_sets = state.tags.shape[0]
    ways_active = jnp.asarray(ways_active, jnp.int32)
    t_cache = jnp.float32(t_cache)

    def step(carry, req):
        st0, stats, t = carry
        a, w = req
        valid = a >= 0  # padded no-op requests carry addr == -1
        a = jnp.maximum(a, 0)
        st = st0
        s = a % num_sets
        hit, way, active = _lookup(st, s, a, ways_active)

        def on_read(st):
            lat = jnp.where(hit, t_cache, jnp.float32(T_HDD))
            st = jax.lax.cond(hit, lambda c: _touch(c, s, way, t, False),
                              lambda c: c, st)
            do_alloc = (~hit) & flags.allocates_reads
            st2, ins, _, ev_dirty = _insert(st, s, a, t, False, ways_active)
            st = jax.tree_util.tree_map(
                lambda x, y: jnp.where(do_alloc, y, x), st, st2)
            cw = jnp.where(do_alloc & ins, 1, 0)
            dw = jnp.where(do_alloc & ins & ev_dirty, 1, 0)
            return st, Stats(1, 0, 0, hit.astype(jnp.int32), 0, cw,
                             (~hit).astype(jnp.int32), dw, lat)

        def on_write(st):
            inval = flags.write_invalidates
            # RO branch: bypass + invalidate the stale cached copy
            st_ro = _invalidate(st, s, way, hit & inval)
            # allocating branch (WB/WT/WO/WBWO): write-allocate. WT commits
            # synchronously, so its cached copy stays clean.
            mark_dirty = flags.holds_dirty
            st_hit = _touch(st, s, way, t, mark_dirty)
            st_ins, ins, _, ev_dirty = _insert(st, s, a, t, mark_dirty,
                                               ways_active)
            st_alloc = jax.tree_util.tree_map(
                lambda h, i: jnp.where(hit, h, i), st_hit, st_ins)
            st = jax.tree_util.tree_map(
                lambda r, al: jnp.where(inval, r, al), st_ro, st_alloc)
            committed = hit | ins
            cw = jnp.where(inval, 0, committed.astype(jnp.int32))
            wh = jnp.where(inval, 0, hit.astype(jnp.int32))
            # write-through also commits to disk synchronously
            sync = flags.write_through.astype(jnp.int32)
            dw_alloc = sync + jnp.where((~hit) & ins & ev_dirty, 1, 0) \
                + jnp.where(~committed, 1, 0)
            dw = jnp.where(inval, 1, dw_alloc)
            lat_alloc = jnp.where(
                committed,
                jnp.where(flags.write_through, jnp.float32(T_HDD_WRITE),
                          t_cache),
                jnp.float32(T_HDD_WRITE))
            lat = jnp.where(inval, jnp.float32(T_HDD_WRITE), lat_alloc)
            return st, Stats(0, 1, 0, 0, wh, cw, 0, dw, lat)

        st, ds = jax.lax.cond(w, lambda c: on_write(c), lambda c: on_read(c), st)
        # mask out padded requests entirely
        st = jax.tree_util.tree_map(
            lambda new, old: jnp.where(valid, new, old), st, st0)
        ds = Stats(*[d * valid.astype(d.dtype) for d in ds])
        return (st, stats.merge(ds), t + valid.astype(jnp.int32)), None

    (state, stats, t_end), _ = jax.lax.scan(
        step, (state, Stats.zero(), jnp.asarray(t0, jnp.int32)),
        (jnp.asarray(addr, jnp.int32), jnp.asarray(is_write)))
    return state, stats, t_end


@functools.partial(jax.jit, static_argnames=("policy",))
def simulate_single_level(addr, is_write, state: CacheState, ways_active,
                          policy: Policy, t_cache=T_SSD, t0=0):
    """Run one request window through a single-level cache.

    Returns (state, Stats, t_end). ``t0`` is the running logical clock so
    LRU order survives across windows.
    """
    return _simulate_single_level(addr, is_write, state, ways_active,
                                  policy_flags(policy), t_cache, t0)


@jax.jit
def simulate_single_level_batch(addr, is_write, state: CacheState,
                                ways_active, flags: PolicyFlags,
                                t_cache=T_SSD, t0=0):
    """Batched :func:`simulate_single_level`: one dispatch for V VMs.

    ``addr``/``is_write`` are ``[V, N]``; ``state`` leaves are
    ``[V, S, W]``; ``ways_active``, ``t0`` and each :class:`PolicyFlags`
    field are ``[V]`` (build with :func:`policy_flags`); ``t_cache`` is a
    shared scalar. Returns (state, Stats, t_end) with a ``[V]`` axis on
    every leaf, bit-identical per VM to the unbatched function.
    """
    v = jnp.shape(addr)[0]
    t0 = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (v,))
    return jax.vmap(
        _simulate_single_level, in_axes=(0, 0, 0, 0, 0, None, 0)
    )(jnp.asarray(addr, jnp.int32), jnp.asarray(is_write), state,
      jnp.asarray(ways_active, jnp.int32), flags, jnp.float32(t_cache), t0)


# ---------------------------------------------------------------------------
# two level (ETICA §4.1/§4.2)
# ---------------------------------------------------------------------------

def _simulate_two_level(addr, is_write, dram: CacheState, ssd: CacheState,
                        ways_dram, ways_ssd, mode: str, t0):
    """Unjitted two-level core (``mode`` is a Python static)."""
    assert mode in ("full", "npe")
    ns_d = dram.tags.shape[0]
    ns_s = ssd.tags.shape[0]
    ways_dram = jnp.asarray(ways_dram, jnp.int32)
    ways_ssd = jnp.asarray(ways_ssd, jnp.int32)

    def step(carry, req):
        dr0, ss0, stats, t = carry
        a, w = req
        valid = a >= 0
        a = jnp.maximum(a, 0)
        dr, ss = dr0, ss0
        sd = a % ns_d
        s2 = a % ns_s
        d_hit, d_way, _ = _lookup(dr, sd, a, ways_dram)
        s_hit, s_way, _ = _lookup(ss, s2, a, ways_ssd)

        def on_read(dr, ss):
            # paper Fig. 6a: DRAM hit -> serve; SSD hit -> promote to DRAM,
            # serve; miss -> disk, promote to DRAM only (never to SSD).
            lat = jnp.where(d_hit, jnp.float32(T_DRAM),
                            jnp.where(s_hit, jnp.float32(T_SSD),
                                      jnp.float32(T_HDD)))
            dr = jax.lax.cond(d_hit, lambda c: _touch(c, sd, d_way, t, False),
                              lambda c: c, dr)
            ss = jax.lax.cond(s_hit & ~d_hit,
                              lambda c: _touch(c, s2, s_way, t, False),
                              lambda c: c, ss)
            dr_ins, _, _, _ = _insert(dr, sd, a, t, False, ways_dram)
            promote = ~d_hit
            dr = jax.tree_util.tree_map(
                lambda x, y: jnp.where(promote, y, x), dr, dr_ins)
            return dr, ss, Stats(
                1, 0, d_hit.astype(jnp.int32),
                (s_hit & ~d_hit).astype(jnp.int32), 0, 0,
                (~(d_hit | s_hit)).astype(jnp.int32), 0, lat)

        def on_write(dr, ss):
            # bypass DRAM; invalidate stale DRAM copy (§4.2 "Write")
            dr = _invalidate(dr, sd, d_way, d_hit)
            ss_hit_st = _touch(ss, s2, s_way, t, True)
            if mode == "npe":
                ss_ins, ins, _, ev_dirty = _insert(ss, s2, a, t, True, ways_ssd)
                ss = jax.tree_util.tree_map(
                    lambda h, i: jnp.where(s_hit, h, i), ss_hit_st, ss_ins)
                committed = s_hit | ins
                cw = committed.astype(jnp.int32)
                dw = jnp.where((~s_hit) & ins & ev_dirty, 1, 0) \
                    + jnp.where(~committed, 1, 0)
                lat = jnp.where(committed, jnp.float32(T_SSD),
                                jnp.float32(T_HDD_WRITE))
            else:  # full: SSD miss -> straight to disk
                ss = jax.tree_util.tree_map(
                    lambda h, i: jnp.where(s_hit, h, i), ss_hit_st, ss)
                cw = s_hit.astype(jnp.int32)
                dw = (~s_hit).astype(jnp.int32)
                lat = jnp.where(s_hit, jnp.float32(T_SSD),
                                jnp.float32(T_HDD_WRITE))
            return dr, ss, Stats(0, 1, 0, 0, s_hit.astype(jnp.int32), cw,
                                 0, dw, lat)

        dr, ss, ds = jax.lax.cond(w, on_write, on_read, dr, ss)
        dr = jax.tree_util.tree_map(
            lambda new, old: jnp.where(valid, new, old), dr, dr0)
        ss = jax.tree_util.tree_map(
            lambda new, old: jnp.where(valid, new, old), ss, ss0)
        ds = Stats(*[d * valid.astype(d.dtype) for d in ds])
        return (dr, ss, stats.merge(ds), t + valid.astype(jnp.int32)), None

    (dram, ssd, stats, t_end), _ = jax.lax.scan(
        step, (dram, ssd, Stats.zero(), jnp.asarray(t0, jnp.int32)),
        (jnp.asarray(addr, jnp.int32), jnp.asarray(is_write)))
    return dram, ssd, stats, t_end


@functools.partial(jax.jit, static_argnames=("mode",))
def simulate_two_level(addr, is_write, dram: CacheState, ssd: CacheState,
                       ways_dram, ways_ssd, mode: str = "full", t0=0):
    """ETICA datapath: DRAM is RO (reads allocate, writes bypass+invalidate);
    SSD is WBWO. ``mode="full"`` = pull-mode SSD (no datapath updates on
    miss — contents only change via write hits and the periodic
    promotion/eviction maintenance). ``mode="npe"`` = write misses allocate
    in the SSD on the datapath (ETICA-NPE in §5.3).
    """
    return _simulate_two_level(addr, is_write, dram, ssd, ways_dram,
                               ways_ssd, mode, t0)


@functools.partial(jax.jit, static_argnames=("mode",))
def simulate_two_level_batch(addr, is_write, dram: CacheState,
                             ssd: CacheState, ways_dram, ways_ssd,
                             mode: str = "full", t0=0):
    """Batched :func:`simulate_two_level`: one dispatch for V VMs.

    ``addr``/``is_write`` are ``[V, N]``; both cache pytrees carry a
    leading ``[V]`` axis; ``ways_dram``/``ways_ssd``/``t0`` are ``[V]``.
    ``mode`` stays static (global to the hierarchy). Bit-identical per VM
    to the unbatched function.
    """
    v = jnp.shape(addr)[0]
    t0 = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (v,))
    return jax.vmap(
        lambda a, w, dr, ss, wd, ws, tt: _simulate_two_level(
            a, w, dr, ss, wd, ws, mode, tt),
        in_axes=(0, 0, 0, 0, 0, 0, 0),
    )(jnp.asarray(addr, jnp.int32), jnp.asarray(is_write), dram, ssd,
      jnp.asarray(ways_dram, jnp.int32), jnp.asarray(ways_ssd, jnp.int32),
      t0)


# ---------------------------------------------------------------------------
# classified datapath (IO-class sub-partitions — repro.classify)
# ---------------------------------------------------------------------------
#
# The classified cores take a per-request class id ``cls`` alongside
# ``addr``/``is_write`` and three per-class tables: way-range bounds
# (``[C]`` per level — the sub-partition slice a class may allocate into),
# per-class :class:`PolicyFlags` (single level only; the two-level
# hierarchy keeps its fixed DRAM-RO / SSD-WBWO policies), and a ``[C]``
# bypass mask. A bypass-class read goes straight to disk without touching
# the cache; a bypass-class write goes straight to disk and drops (without
# flushing) any cached copy, which the disk write supersedes. Both count
# in the ``Stats.bypassed`` channel. Lookups stay global over the VM's
# active ways — classes share residency, they only partition *insertion*.
# With one match-all class (``lo = 0``, ``hi = ways_active``, no bypass)
# every operation below folds to the unclassified step, so results are
# bit-identical to the plain simulators.

def _simulate_single_level_classified(addr, is_write, cls, state: CacheState,
                                      ways_active, flags: PolicyFlags,
                                      way_lo, way_hi, bypass, t_cache, t0):
    """Unjitted classified single-level core: per-class policy flags
    (``[C]`` fields), per-class way ranges, bypass mask."""
    num_sets = state.tags.shape[0]
    ways_active = jnp.asarray(ways_active, jnp.int32)
    t_cache = jnp.float32(t_cache)
    nc = way_lo.shape[0]
    zero = jnp.int32(0)
    one = jnp.int32(1)

    def step(carry, req):
        st0, stats, t = carry
        a, w, c = req
        valid = a >= 0
        a = jnp.maximum(a, 0)
        c = jnp.clip(c, 0, nc - 1)
        fc = PolicyFlags(flags.allocates_reads[c], flags.write_invalidates[c],
                         flags.holds_dirty[c], flags.write_through[c])
        hi = jnp.minimum(way_hi[c], ways_active)
        lo = jnp.minimum(way_lo[c], hi)
        byp = bypass[c]
        st = st0
        s = a % num_sets
        hit, way, active = _lookup(st, s, a, ways_active)

        def on_read(st):
            lat = jnp.where(hit, t_cache, jnp.float32(T_HDD))
            st = jax.lax.cond(hit, lambda cc: _touch(cc, s, way, t, False),
                              lambda cc: cc, st)
            do_alloc = (~hit) & fc.allocates_reads
            st2, ins, _, ev_dirty = _insert_range(st, s, a, t, False, lo, hi)
            st = jax.tree_util.tree_map(
                lambda x, y: jnp.where(do_alloc, y, x), st, st2)
            cw = jnp.where(do_alloc & ins, one, zero)
            dw = jnp.where(do_alloc & ins & ev_dirty, one, zero)
            return st, Stats(one, zero, zero, hit.astype(jnp.int32), zero, cw,
                             (~hit).astype(jnp.int32), dw, lat, zero, zero)

        def on_write(st):
            inval = fc.write_invalidates
            st_ro = _invalidate(st, s, way, hit & inval)
            mark_dirty = fc.holds_dirty
            st_hit = _touch(st, s, way, t, mark_dirty)
            st_ins, ins, _, ev_dirty = _insert_range(st, s, a, t, mark_dirty,
                                                     lo, hi)
            st_alloc = jax.tree_util.tree_map(
                lambda h, i: jnp.where(hit, h, i), st_hit, st_ins)
            st = jax.tree_util.tree_map(
                lambda r, al: jnp.where(inval, r, al), st_ro, st_alloc)
            committed = hit | ins
            cw = jnp.where(inval, zero, committed.astype(jnp.int32))
            wh = jnp.where(inval, zero, hit.astype(jnp.int32))
            sync = fc.write_through.astype(jnp.int32)
            dw_alloc = sync + jnp.where((~hit) & ins & ev_dirty, one, zero) \
                + jnp.where(~committed, one, zero)
            dw = jnp.where(inval, one, dw_alloc)
            lat_alloc = jnp.where(
                committed,
                jnp.where(fc.write_through, jnp.float32(T_HDD_WRITE),
                          t_cache),
                jnp.float32(T_HDD_WRITE))
            lat = jnp.where(inval, jnp.float32(T_HDD_WRITE), lat_alloc)
            return st, Stats(zero, one, zero, zero, wh, cw, zero, dw, lat,
                             zero, zero)

        def on_bypass(st):
            st = _invalidate(st, s, way, hit & w)
            rd = jnp.where(w, zero, one)
            wr = jnp.where(w, one, zero)
            lat = jnp.where(w, jnp.float32(T_HDD_WRITE), jnp.float32(T_HDD))
            return st, Stats(rd, wr, zero, zero, zero, zero, rd, wr, lat,
                             one, zero)

        st, ds = jax.lax.cond(
            byp, on_bypass,
            lambda cc: jax.lax.cond(w, on_write, on_read, cc), st)
        st = jax.tree_util.tree_map(
            lambda new, old: jnp.where(valid, new, old), st, st0)
        ds = Stats(*[d * valid.astype(d.dtype) for d in ds])
        serve_hit = jnp.where(byp, False,
                              jnp.where(w & fc.write_invalidates, False, hit))
        elig = valid & ~byp
        return ((st, stats.merge(ds), t + valid.astype(jnp.int32)),
                (serve_hit, elig, c))

    (state, stats, t_end), (sh, el, cs) = jax.lax.scan(
        step, (state, Stats.zero(), jnp.asarray(t0, jnp.int32)),
        (jnp.asarray(addr, jnp.int32), jnp.asarray(is_write),
         jnp.asarray(cls, jnp.int32)))
    cls_hits = jnp.zeros(nc, jnp.int32).at[cs].add(
        (el & sh).astype(jnp.int32))
    cls_miss = jnp.zeros(nc, jnp.int32).at[cs].add(
        (el & ~sh).astype(jnp.int32))
    return state, stats, t_end, cls_hits, cls_miss


@jax.jit
def simulate_single_level_classified(addr, is_write, cls, state: CacheState,
                                     ways_active, flags: PolicyFlags,
                                     way_lo, way_hi, bypass,
                                     t_cache=T_SSD, t0=0):
    """Classified :func:`simulate_single_level`: ``cls`` is a per-request
    ``[N]`` class id, ``flags`` fields / ``way_lo`` / ``way_hi`` /
    ``bypass`` are ``[C]`` per-class tables. Returns ``(state, stats,
    t_end, cls_hits, cls_miss)`` — the last two are per-class ``[C]``
    served hit/miss counts over non-bypassed valid requests
    (``cls_hits + cls_miss`` sums to ``stats.reads + stats.writes -
    stats.bypassed`` and ``cls_hits`` sums to the served hits)."""
    return _simulate_single_level_classified(
        addr, is_write, cls, state, ways_active, flags,
        jnp.asarray(way_lo, jnp.int32), jnp.asarray(way_hi, jnp.int32),
        jnp.asarray(bypass, bool), t_cache, t0)


@jax.jit
def simulate_single_level_classified_batch(addr, is_write, cls,
                                           state: CacheState, ways_active,
                                           flags: PolicyFlags,
                                           way_lo, way_hi, bypass,
                                           t_cache=T_SSD, t0=0):
    """Batched classified single level: ``addr``/``is_write``/``cls`` are
    ``[V, N]``, ``flags`` fields and way bounds are ``[V, C]``, ``bypass``
    is a shared ``[C]`` mask. Per-class hit/miss counts come back as
    ``[V, C]``."""
    v = jnp.shape(addr)[0]
    t0 = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (v,))
    return jax.vmap(
        _simulate_single_level_classified,
        in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None, None, 0)
    )(jnp.asarray(addr, jnp.int32), jnp.asarray(is_write),
      jnp.asarray(cls, jnp.int32), state, jnp.asarray(ways_active, jnp.int32),
      flags, jnp.asarray(way_lo, jnp.int32), jnp.asarray(way_hi, jnp.int32),
      jnp.asarray(bypass, bool), jnp.float32(t_cache), t0)


def _simulate_two_level_classified(addr, is_write, cls, dram: CacheState,
                                   ssd: CacheState, ways_dram, ways_ssd,
                                   bypass, lo_d, hi_d, lo_s, hi_s,
                                   mode: str, t0):
    """Unjitted classified two-level core: per-class way ranges for both
    levels plus the bypass mask; policies stay DRAM-RO / SSD-WBWO."""
    assert mode in ("full", "npe")
    ns_d = dram.tags.shape[0]
    ns_s = ssd.tags.shape[0]
    ways_dram = jnp.asarray(ways_dram, jnp.int32)
    ways_ssd = jnp.asarray(ways_ssd, jnp.int32)
    nc = bypass.shape[0]
    zero = jnp.int32(0)
    one = jnp.int32(1)

    def step(carry, req):
        dr0, ss0, stats, t = carry
        a, w, c = req
        valid = a >= 0
        a = jnp.maximum(a, 0)
        c = jnp.clip(c, 0, nc - 1)
        d_hi = jnp.minimum(hi_d[c], ways_dram)
        d_lo = jnp.minimum(lo_d[c], d_hi)
        s_hi = jnp.minimum(hi_s[c], ways_ssd)
        s_lo = jnp.minimum(lo_s[c], s_hi)
        byp = bypass[c]
        dr, ss = dr0, ss0
        sd = a % ns_d
        s2 = a % ns_s
        d_hit, d_way, _ = _lookup(dr, sd, a, ways_dram)
        s_hit, s_way, _ = _lookup(ss, s2, a, ways_ssd)

        def on_read(dr, ss):
            lat = jnp.where(d_hit, jnp.float32(T_DRAM),
                            jnp.where(s_hit, jnp.float32(T_SSD),
                                      jnp.float32(T_HDD)))
            dr = jax.lax.cond(d_hit, lambda c_: _touch(c_, sd, d_way, t, False),
                              lambda c_: c_, dr)
            ss = jax.lax.cond(s_hit & ~d_hit,
                              lambda c_: _touch(c_, s2, s_way, t, False),
                              lambda c_: c_, ss)
            dr_ins, _, _, _ = _insert_range(dr, sd, a, t, False, d_lo, d_hi)
            promote = ~d_hit
            dr = jax.tree_util.tree_map(
                lambda x, y: jnp.where(promote, y, x), dr, dr_ins)
            return dr, ss, Stats(
                one, zero, d_hit.astype(jnp.int32),
                (s_hit & ~d_hit).astype(jnp.int32), zero, zero,
                (~(d_hit | s_hit)).astype(jnp.int32), zero, lat, zero, zero)

        def on_write(dr, ss):
            dr = _invalidate(dr, sd, d_way, d_hit)
            ss_hit_st = _touch(ss, s2, s_way, t, True)
            if mode == "npe":
                ss_ins, ins, _, ev_dirty = _insert_range(ss, s2, a, t, True,
                                                         s_lo, s_hi)
                ss = jax.tree_util.tree_map(
                    lambda h, i: jnp.where(s_hit, h, i), ss_hit_st, ss_ins)
                committed = s_hit | ins
                cw = committed.astype(jnp.int32)
                dw = jnp.where((~s_hit) & ins & ev_dirty, one, zero) \
                    + jnp.where(~committed, one, zero)
                lat = jnp.where(committed, jnp.float32(T_SSD),
                                jnp.float32(T_HDD_WRITE))
            else:  # full: SSD miss -> straight to disk
                ss = jax.tree_util.tree_map(
                    lambda h, i: jnp.where(s_hit, h, i), ss_hit_st, ss)
                cw = s_hit.astype(jnp.int32)
                dw = (~s_hit).astype(jnp.int32)
                lat = jnp.where(s_hit, jnp.float32(T_SSD),
                                jnp.float32(T_HDD_WRITE))
            return dr, ss, Stats(zero, one, zero, zero,
                                 s_hit.astype(jnp.int32), cw, zero, dw, lat,
                                 zero, zero)

        def on_bypass(dr, ss):
            dr = _invalidate(dr, sd, d_way, d_hit & w)
            ss = _invalidate(ss, s2, s_way, s_hit & w)
            rd = jnp.where(w, zero, one)
            wr = jnp.where(w, one, zero)
            lat = jnp.where(w, jnp.float32(T_HDD_WRITE), jnp.float32(T_HDD))
            return dr, ss, Stats(rd, wr, zero, zero, zero, zero, rd, wr, lat,
                                 one, zero)

        dr, ss, ds = jax.lax.cond(
            byp, on_bypass,
            lambda d_, s_: jax.lax.cond(w, on_write, on_read, d_, s_),
            dr, ss)
        dr = jax.tree_util.tree_map(
            lambda new, old: jnp.where(valid, new, old), dr, dr0)
        ss = jax.tree_util.tree_map(
            lambda new, old: jnp.where(valid, new, old), ss, ss0)
        ds = Stats(*[d * valid.astype(d.dtype) for d in ds])
        serve_hit = jnp.where(byp, False,
                              jnp.where(w, s_hit, d_hit | s_hit))
        elig = valid & ~byp
        return ((dr, ss, stats.merge(ds), t + valid.astype(jnp.int32)),
                (serve_hit, elig, c))

    (dram, ssd, stats, t_end), (sh, el, cs) = jax.lax.scan(
        step, (dram, ssd, Stats.zero(), jnp.asarray(t0, jnp.int32)),
        (jnp.asarray(addr, jnp.int32), jnp.asarray(is_write),
         jnp.asarray(cls, jnp.int32)))
    cls_hits = jnp.zeros(nc, jnp.int32).at[cs].add(
        (el & sh).astype(jnp.int32))
    cls_miss = jnp.zeros(nc, jnp.int32).at[cs].add(
        (el & ~sh).astype(jnp.int32))
    return dram, ssd, stats, t_end, cls_hits, cls_miss


@functools.partial(jax.jit, static_argnames=("mode",))
def simulate_two_level_classified(addr, is_write, cls, dram: CacheState,
                                  ssd: CacheState, ways_dram, ways_ssd,
                                  bypass, lo_d, hi_d, lo_s, hi_s,
                                  mode: str = "full", t0=0):
    """Classified :func:`simulate_two_level`: per-request ``[N]`` class
    ids, per-class ``[C]`` way bounds per level, ``[C]`` bypass mask.
    Returns ``(dram, ssd, stats, t_end, cls_hits, cls_miss)`` with
    per-class ``[C]`` served hit/miss counts (any-level hit on reads,
    SSD hit on writes; bypassed requests excluded)."""
    return _simulate_two_level_classified(
        addr, is_write, cls, dram, ssd, ways_dram, ways_ssd,
        jnp.asarray(bypass, bool),
        jnp.asarray(lo_d, jnp.int32), jnp.asarray(hi_d, jnp.int32),
        jnp.asarray(lo_s, jnp.int32), jnp.asarray(hi_s, jnp.int32), mode, t0)


@functools.partial(jax.jit, static_argnames=("mode",))
def simulate_two_level_classified_batch(addr, is_write, cls,
                                        dram: CacheState, ssd: CacheState,
                                        ways_dram, ways_ssd, bypass,
                                        lo_d, hi_d, lo_s, hi_s,
                                        mode: str = "full", t0=0):
    """Batched classified two level: ``addr``/``is_write``/``cls`` are
    ``[V, N]``, way bounds are ``[V, C]``, ``bypass`` is shared ``[C]``.
    Per-class hit/miss counts come back as ``[V, C]``."""
    v = jnp.shape(addr)[0]
    t0 = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (v,))
    return jax.vmap(
        lambda a, w, c, dr, ss, wd, ws, ld, hd, ls, hs, tt:
            _simulate_two_level_classified(
                a, w, c, dr, ss, wd, ws, jnp.asarray(bypass, bool),
                ld, hd, ls, hs, mode, tt),
        in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0),
    )(jnp.asarray(addr, jnp.int32), jnp.asarray(is_write),
      jnp.asarray(cls, jnp.int32), dram, ssd,
      jnp.asarray(ways_dram, jnp.int32), jnp.asarray(ways_ssd, jnp.int32),
      jnp.asarray(lo_d, jnp.int32), jnp.asarray(hi_d, jnp.int32),
      jnp.asarray(lo_s, jnp.int32), jnp.asarray(hi_s, jnp.int32), t0)


# ---------------------------------------------------------------------------
# maintenance ops (between-interval — paper: asynchronous). Vectorized
# jnp implementations with (state, count) contracts; jit-able/vmappable.
# ---------------------------------------------------------------------------

def resize(state: CacheState, old_ways, new_ways):
    """Deactivate ways >= new_ways; returns (state, flushed_dirty_blocks).

    Pure ``jnp`` (jit-able; counts are 0-d arrays). A grow (``new_ways >=
    old_ways``) is a no-op with 0 flushes, matching :func:`resize_ref`.
    """
    old_ways = jnp.asarray(old_ways, jnp.int32)
    new_ways = jnp.asarray(new_ways, jnp.int32)
    w = state.tags.shape[1]
    shrink = new_ways < old_ways
    clear = shrink & (jnp.arange(w) >= new_ways)          # [W]
    flushed = jnp.sum(state.dirty & clear[None, :]).astype(jnp.int32)
    return CacheState(
        tags=jnp.where(clear[None, :], -1, state.tags),
        lru=jnp.where(clear[None, :], -1, state.lru),
        dirty=jnp.where(clear[None, :], False, state.dirty),
    ), flushed


resize_batch = jax.jit(jax.vmap(resize))
"""Map :func:`resize` over stacked ``[V, S, W]`` states and ``[V]`` way
counts in one dispatch; returns (stacked state, ``[V]`` flush counts)."""


@jax.jit
def resize_levels(dram: CacheState, ssd: CacheState, old_dram, new_dram,
                  old_ssd, new_ssd):
    """Resize BOTH cache levels of all VMs in one jitted dispatch.

    The two-level controller's per-interval resize: equivalent to two
    :data:`resize_batch` calls but fused into a single executable.
    Returns (dram, ssd, dram_flushed ``[V]``, ssd_flushed ``[V]``).
    """
    dram, fl_d = jax.vmap(resize)(dram, jnp.asarray(old_dram, jnp.int32),
                                  jnp.asarray(new_dram, jnp.int32))
    ssd, fl_s = jax.vmap(resize)(ssd, jnp.asarray(old_ssd, jnp.int32),
                                 jnp.asarray(new_ssd, jnp.int32))
    return dram, ssd, fl_d, fl_s


# ---------------------------------------------------------------------------
# sharded dispatches (VM axis split across a 1-d device mesh)
# ---------------------------------------------------------------------------
#
# Same vmapped step functions as the batched entry points, wrapped in
# ``shard_map`` over a VM mesh (``launch.mesh.make_vm_mesh``): each device
# scans its own ``[V/d, N]`` block against its own ``[V/d, S, W]`` state
# shard. Everything is shard-local — the compiled HLO contains no
# collectives (asserted by the sharding tests) — so per-VM results are
# bit-identical to the single-device batched dispatch. The ONLY
# cross-device traffic in a sharded controller run is
# :func:`aggregate_stats_sharded`'s psum. Builders are lru-cached on
# (mesh, statics) so controller intervals reuse compiled executables.

def _vm_io(mesh):
    from ..launch.mesh import require_vm_divisible, vm_spec
    return vm_spec(mesh), require_vm_divisible


@functools.lru_cache(maxsize=None)
def _two_level_sharded(mesh, mode):
    from jax.experimental import shard_map
    spec, _ = _vm_io(mesh)

    def body(addr, is_write, dram, ssd, ways_dram, ways_ssd, t0):
        return jax.vmap(
            lambda a, w, dr, ss, wd, ws, tt: _simulate_two_level(
                a, w, dr, ss, wd, ws, mode, tt)
        )(addr, is_write, dram, ssd, ways_dram, ways_ssd, t0)

    return jax.jit(shard_map.shard_map(
        body, mesh=mesh, in_specs=(spec,) * 7, out_specs=spec,
        check_rep=False))


def simulate_two_level_sharded(addr, is_write, dram: CacheState,
                               ssd: CacheState, ways_dram, ways_ssd,
                               mesh, mode: str = "full", t0=0):
    """:func:`simulate_two_level_batch` with VM rows split over ``mesh``."""
    spec, require = _vm_io(mesh)
    v = np.shape(addr)[0]
    require(v, mesh)
    t0 = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (v,))
    return _two_level_sharded(mesh, mode)(
        jnp.asarray(addr, jnp.int32), jnp.asarray(is_write), dram, ssd,
        jnp.asarray(ways_dram, jnp.int32), jnp.asarray(ways_ssd, jnp.int32),
        t0)


@functools.lru_cache(maxsize=None)
def _single_level_sharded(mesh):
    from jax.experimental import shard_map
    from jax.sharding import PartitionSpec
    spec, _ = _vm_io(mesh)

    def body(addr, is_write, state, ways_active, flags, t_cache, t0):
        return jax.vmap(
            _simulate_single_level, in_axes=(0, 0, 0, 0, 0, None, 0)
        )(addr, is_write, state, ways_active, flags, t_cache, t0)

    return jax.jit(shard_map.shard_map(
        body, mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec, PartitionSpec(), spec),
        out_specs=spec, check_rep=False))


def simulate_single_level_sharded(addr, is_write, state: CacheState,
                                  ways_active, flags: PolicyFlags, mesh,
                                  t_cache=T_SSD, t0=0):
    """:func:`simulate_single_level_batch` with VM rows split over ``mesh``.

    ``flags`` fields are broadcast to ``[V]`` (scalar flags replicate)."""
    spec, require = _vm_io(mesh)
    v = np.shape(addr)[0]
    require(v, mesh)
    t0 = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (v,))
    flags = PolicyFlags(
        *[jnp.broadcast_to(jnp.asarray(f), (v,)) for f in flags])
    return _single_level_sharded(mesh)(
        jnp.asarray(addr, jnp.int32), jnp.asarray(is_write), state,
        jnp.asarray(ways_active, jnp.int32), flags, jnp.float32(t_cache), t0)


@functools.lru_cache(maxsize=None)
def _resize_levels_sharded(mesh):
    from jax.experimental import shard_map
    spec, _ = _vm_io(mesh)

    def body(dram, ssd, old_dram, new_dram, old_ssd, new_ssd):
        dram, fl_d = jax.vmap(resize)(dram, old_dram, new_dram)
        ssd, fl_s = jax.vmap(resize)(ssd, old_ssd, new_ssd)
        return dram, ssd, fl_d, fl_s

    return jax.jit(shard_map.shard_map(
        body, mesh=mesh, in_specs=(spec,) * 6, out_specs=spec,
        check_rep=False))


def resize_levels_sharded(dram: CacheState, ssd: CacheState, old_dram,
                          new_dram, old_ssd, new_ssd, mesh):
    """:func:`resize_levels` with VM rows split over ``mesh``."""
    _, require = _vm_io(mesh)
    require(int(dram.tags.shape[0]), mesh)
    as_i32 = lambda x: jnp.asarray(x, jnp.int32)
    return _resize_levels_sharded(mesh)(
        dram, ssd, as_i32(old_dram), as_i32(new_dram), as_i32(old_ssd),
        as_i32(new_ssd))


@functools.lru_cache(maxsize=None)
def _resize_batch_sharded(mesh):
    from jax.experimental import shard_map
    spec, _ = _vm_io(mesh)
    return jax.jit(shard_map.shard_map(
        lambda st, old, new: jax.vmap(resize)(st, old, new),
        mesh=mesh, in_specs=(spec,) * 3, out_specs=spec, check_rep=False))


def resize_batch_sharded(state: CacheState, old_ways, new_ways, mesh):
    """:data:`resize_batch` with VM rows split over ``mesh``."""
    _, require = _vm_io(mesh)
    require(int(state.tags.shape[0]), mesh)
    return _resize_batch_sharded(mesh)(
        state, jnp.asarray(old_ways, jnp.int32),
        jnp.asarray(new_ways, jnp.int32))


@functools.lru_cache(maxsize=None)
def _aggregate_stats_sharded(mesh):
    from jax.experimental import shard_map
    from jax.sharding import PartitionSpec
    spec, _ = _vm_io(mesh)
    ax = mesh.axis_names[0]

    def body(st):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.psum(jnp.sum(x, axis=0), ax), st)

    return jax.jit(shard_map.shard_map(
        body, mesh=mesh, in_specs=(spec,), out_specs=PartitionSpec(),
        check_rep=False))


def aggregate_stats_sharded(stats: Stats, mesh) -> Stats:
    """Total :class:`Stats` over sharded ``[V]`` per-VM stats: one
    shard-local sum + ONE psum per leaf — the only cross-device collective
    a sharded controller run performs."""
    stats = Stats(*[jnp.asarray(x) for x in stats])
    return _aggregate_stats_sharded(mesh)(stats)


def resident_blocks(state: CacheState, ways_active: int) -> np.ndarray:
    tags = np.asarray(state.tags)[:, : max(ways_active, 0)]
    return tags[tags >= 0]


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _pad_addrs(addrs) -> np.ndarray:
    """Round a maintenance queue up to the next power-of-two length with
    -1 no-op entries, so jitted maintenance compiles O(log max_len) times
    instead of once per distinct queue length."""
    a = np.asarray(addrs).reshape(-1).astype(np.int32)
    return np.pad(a, (0, _next_pow2(a.size) - a.size), constant_values=-1)


@jax.jit
def _evict_blocks_impl(state: CacheState, addrs):
    mask = jnp.isin(state.tags, addrs) & (state.tags >= 0)
    flushed = jnp.sum(state.dirty & mask).astype(jnp.int32)
    return CacheState(
        tags=jnp.where(mask, -1, state.tags),
        lru=jnp.where(mask, -1, state.lru),
        dirty=jnp.where(mask, False, state.dirty),
    ), flushed


def evict_blocks(state: CacheState, addrs):
    """Evict given blocks (maintenance). Returns (state, flushed_dirty).

    Vectorized jitted ``jnp``; ``addrs`` entries of -1 are ignored
    (padding), and inputs are bucketed to power-of-two lengths so ragged
    per-VM eviction queues reuse a handful of compiled executables.
    """
    if np.size(addrs) == 0:
        return state, jnp.int32(0)
    return _evict_blocks_impl(state, _pad_addrs(addrs))


@jax.jit
def _promote_blocks_impl(state: CacheState, addrs, ways_active, t):
    tags, lru, dirty = state
    s_count, w_count = tags.shape
    n = addrs.shape[0]
    valid = addrs >= 0
    sets = jnp.where(valid, addrs % s_count, 0)
    active = jnp.arange(w_count) < ways_active               # [W]

    # first-occurrence dedupe: stable sort groups duplicates with original
    # order preserved, so the group head is the first occurrence
    order = jnp.argsort(addrs, stable=True)
    sorted_a = addrs[order]
    head = jnp.concatenate(
        [jnp.ones(1, bool), sorted_a[1:] != sorted_a[:-1]])
    first = jnp.zeros(n, bool).at[order].set(head)

    present = jnp.any((tags[sets] == addrs[:, None]) & active[None, :],
                      axis=1)
    elig = valid & first & ~present & (ways_active > 0)

    # rank of each eligible address among eligible addresses of its set,
    # in original order: stable-sort by set (ineligible -> sentinel group),
    # then position within group = index - running group start
    key = jnp.where(elig, sets, jnp.int32(s_count))
    perm = jnp.argsort(key, stable=True)
    ksort = key[perm]
    newgrp = jnp.concatenate([jnp.ones(1, bool), ksort[1:] != ksort[:-1]])
    idx = jnp.arange(n)
    grp_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(newgrp, idx, 0))
    rank = jnp.zeros(n, jnp.int32).at[perm].set(
        (idx - grp_start).astype(jnp.int32))

    # k-th eligible address of a set lands in the set's k-th free way
    free = active[None, :] & (tags < 0)                      # [S, W]
    freerank = jnp.cumsum(free, axis=1) - 1                  # [S, W]
    nfree = free.sum(axis=1)                                 # [S]
    promoted = elig & (rank < nfree[sets])
    way = jnp.argmax((freerank[sets] == rank[:, None]) & free[sets], axis=1)

    rows = jnp.where(promoted, sets, jnp.int32(s_count))     # OOB -> dropped
    return CacheState(
        tags=tags.at[rows, way].set(addrs, mode="drop"),
        lru=lru.at[rows, way].set(t, mode="drop"),
        dirty=dirty.at[rows, way].set(False, mode="drop"),
    ), jnp.sum(promoted).astype(jnp.int32)


def promote_blocks(state: CacheState, addrs, ways_active, t):
    """Insert blocks into FREE active ways only (paper: promote "only when
    there is free space in SSD"). Returns (state, n_promoted).

    Vectorized jitted ``jnp`` with the exact semantics of the sequential
    reference (:func:`promote_blocks_ref`): first occurrence of each
    address wins, addresses already resident are skipped, and free ways
    fill in ascending way order in ``addrs`` order. ``addrs`` entries of
    -1 are ignored (padding), and inputs are bucketed to power-of-two
    lengths to bound recompiles across queue sizes.
    """
    if np.size(addrs) == 0:
        return state, jnp.int32(0)
    return _promote_blocks_impl(state, _pad_addrs(addrs),
                                jnp.asarray(ways_active, jnp.int32),
                                jnp.asarray(t, jnp.int32))


_evict_blocks_vmapped = jax.jit(jax.vmap(_evict_blocks_impl))
_promote_blocks_vmapped = jax.jit(jax.vmap(_promote_blocks_impl))


def _pad_addrs_batch(queues: Sequence[np.ndarray]) -> np.ndarray:
    """Stack ragged per-VM maintenance queues into a [V, Q] rectangle of a
    power-of-two width, padding with -1 no-ops."""
    q = _next_pow2(max((np.size(a) for a in queues), default=0))
    out = np.full((len(queues), max(q, 1)), -1, np.int32)
    for v, a in enumerate(queues):
        a = np.asarray(a).reshape(-1)
        out[v, : a.size] = a
    return out


def evict_blocks_batch(state: CacheState, queues: Sequence[np.ndarray]):
    """Per-VM :func:`evict_blocks` over a stacked ``[V, S, W]`` state in
    one vmapped dispatch. ``queues`` is one (possibly empty) address array
    per VM; returns (stacked state, ``[V]`` flush counts)."""
    return _evict_blocks_vmapped(state, _pad_addrs_batch(queues))


def promote_blocks_batch(state: CacheState, queues: Sequence[np.ndarray],
                         ways_active, t):
    """Per-VM :func:`promote_blocks` over a stacked ``[V, S, W]`` state in
    one vmapped dispatch. ``ways_active``/``t`` are ``[V]``; returns
    (stacked state, ``[V]`` promotion counts)."""
    return _promote_blocks_vmapped(state, _pad_addrs_batch(queues),
                                   jnp.asarray(ways_active, jnp.int32),
                                   jnp.asarray(t, jnp.int32))


def _clean_blocks_impl(state: CacheState, ways_active, quota):
    s, w = state.tags.shape
    active = jnp.arange(w, dtype=jnp.int32)[None, :] < ways_active
    cflat = (state.dirty & active).reshape(-1)
    lflat = state.lru.reshape(-1)
    # int32-safe lexsort by (lru, flat index): stable argsort by lru, then
    # stably float the candidates to the front — candidate order is the
    # (lru, index) age order with no composite keys or lru sentinels
    ord1 = jnp.argsort(lflat, stable=True)
    order = ord1[jnp.argsort(~cflat[ord1], stable=True)]
    n_cand = jnp.sum(cflat).astype(jnp.int32)
    take = jnp.minimum(jnp.asarray(quota, jnp.int32), n_cand)
    flush = jnp.zeros(s * w, bool).at[order].set(
        jnp.arange(s * w) < take)
    return CacheState(state.tags, state.lru,
                      state.dirty & ~flush.reshape(s, w)), take, n_cand - take


@jax.jit
def clean_blocks(state: CacheState, ways_active, quota):
    """Background cleaner (maintenance): flush the ``quota`` oldest dirty
    blocks in active ways — age order (lru, flat ``set * W + way`` index)
    ascending. Flushing clears only the dirty bit; the block stays
    resident and clean. Returns (state, flushed, dirty_left), matching
    :func:`clean_blocks_ref` exactly.
    """
    return _clean_blocks_impl(state, jnp.asarray(ways_active, jnp.int32),
                              jnp.asarray(quota, jnp.int32))


_clean_blocks_vmapped = jax.jit(jax.vmap(_clean_blocks_impl))


def clean_batch(state: CacheState, ways_active, quota):
    """Per-VM :func:`clean_blocks` over a stacked ``[V, S, W]`` state in
    one vmapped dispatch. ``ways_active``/``quota`` are ``[V]``; returns
    (stacked state, ``[V]`` flush counts, ``[V]`` dirty-left counts)."""
    return _clean_blocks_vmapped(state, jnp.asarray(ways_active, jnp.int32),
                                 jnp.asarray(quota, jnp.int32))


# ---------------------------------------------------------------------------
# numpy reference oracles for the maintenance ops (sequential semantics the
# vectorized versions above must reproduce exactly — kept for the tests)
# ---------------------------------------------------------------------------

def resize_ref(state: CacheState, old_ways: int, new_ways: int):
    """Sequential numpy reference for :func:`resize`."""
    if new_ways >= old_ways:
        return state, 0
    tags = np.asarray(state.tags).copy()
    lru = np.asarray(state.lru).copy()
    dirty = np.asarray(state.dirty).copy()
    flushed = int(dirty[:, new_ways:].sum())
    tags[:, new_ways:] = -1
    lru[:, new_ways:] = -1
    dirty[:, new_ways:] = False
    return CacheState(jnp.asarray(tags), jnp.asarray(lru), jnp.asarray(dirty)), flushed


def evict_blocks_ref(state: CacheState, addrs: np.ndarray):
    """Sequential numpy reference for :func:`evict_blocks`."""
    tags = np.asarray(state.tags).copy()
    lru = np.asarray(state.lru).copy()
    dirty = np.asarray(state.dirty).copy()
    mask = np.isin(tags, addrs) & (tags >= 0)
    flushed = int((dirty & mask).sum())
    tags[mask] = -1
    lru[mask] = -1
    dirty[mask] = False
    return CacheState(jnp.asarray(tags), jnp.asarray(lru), jnp.asarray(dirty)), flushed


def promote_blocks_ref(state: CacheState, addrs: np.ndarray,
                       ways_active: int, t: int):
    """Sequential numpy reference for :func:`promote_blocks`."""
    tags = np.asarray(state.tags).copy()
    lru = np.asarray(state.lru).copy()
    dirty = np.asarray(state.dirty).copy()
    num_sets, _ = tags.shape
    n = 0
    for a in np.asarray(addrs):
        if a < 0:
            continue
        s = int(a) % num_sets
        if (tags[s, :ways_active] == a).any():
            continue
        free = np.nonzero(tags[s, :ways_active] < 0)[0]
        if free.size == 0:
            continue
        w = free[0]
        tags[s, w] = a
        lru[s, w] = t
        dirty[s, w] = False
        n += 1
    return CacheState(jnp.asarray(tags), jnp.asarray(lru), jnp.asarray(dirty)), n


def clean_blocks_ref(state: CacheState, ways_active: int, quota: int):
    """Sequential numpy reference for :func:`clean_blocks`."""
    tags = np.asarray(state.tags).copy()
    lru = np.asarray(state.lru).copy()
    dirty = np.asarray(state.dirty).copy()
    num_sets, num_ways = tags.shape
    wa = min(max(int(ways_active), 0), num_ways)
    cand = [(int(lru[s, w]), s * num_ways + w, s, w)
            for s in range(num_sets) for w in range(wa) if dirty[s, w]]
    cand.sort()
    take = min(max(int(quota), 0), len(cand))
    for _, _, s, w in cand[:take]:
        dirty[s, w] = False
    return (CacheState(jnp.asarray(tags), jnp.asarray(lru), jnp.asarray(dirty)),
            take, len(cand) - take)
