"""Trace-driven cache simulators (exact datapath, `jax.lax.scan`).

Two entry points:

  * :func:`simulate_single_level` — one cache device in front of the disk
    under any :class:`~repro.core.policies.Policy` (used for the paper's
    motivational Fig. 3 study and the one-level baselines ECI-Cache,
    Centaur, S-CAVE, vCacheShare).
  * :func:`simulate_two_level` — ETICA's DRAM(RO) + SSD(WBWO) hierarchy
    (paper §4.1/§4.2), in ``"full"`` (pull-mode SSD: misses never update
    the SSD on the datapath) or ``"npe"`` (no promotion/eviction: write
    misses allocate in the SSD datapath) modes.

Caches are set-associative (paper: 512-block sets; geometry configurable).
The *allocated* capacity of a VM's cache is expressed as active ways —
resizing between intervals activates/deactivates ways (deactivation
flushes dirty blocks, counted as disk writes). All datapath state is a
pytree scanned over the request stream, so a full interval simulates as
one fused XLA loop.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .policies import Policy, T_DRAM, T_HDD, T_HDD_WRITE, T_SSD


class CacheState(NamedTuple):
    tags: jax.Array   # int32 [S, W], -1 = invalid
    lru: jax.Array    # int32 [S, W], last-touch time (-1 = never)
    dirty: jax.Array  # bool  [S, W]


class Stats(NamedTuple):
    reads: jax.Array
    writes: jax.Array
    read_hits_l1: jax.Array    # DRAM hits (two-level only)
    read_hits_l2: jax.Array    # SSD / single-level cache read hits
    write_hits_l2: jax.Array
    cache_writes_l2: jax.Array  # endurance metric: writes committed to SSD
    disk_reads: jax.Array
    disk_writes: jax.Array
    latency_sum: jax.Array     # seconds (float32)

    @staticmethod
    def zero() -> "Stats":
        z = jnp.int32(0)
        return Stats(z, z, z, z, z, z, z, z, jnp.float32(0.0))

    def merge(self, o: "Stats") -> "Stats":
        return Stats(*[a + b for a, b in zip(self, o)])

    # -- derived metrics -------------------------------------------------
    @property
    def total(self):
        return self.reads + self.writes

    @property
    def hits(self):
        return self.read_hits_l1 + self.read_hits_l2 + self.write_hits_l2

    def hit_ratio(self) -> float:
        return float(self.hits) / max(int(self.total), 1)

    def mean_latency(self) -> float:
        return float(self.latency_sum) / max(int(self.total), 1)


def make_cache(num_sets: int, ways: int) -> CacheState:
    return CacheState(
        tags=jnp.full((num_sets, ways), -1, jnp.int32),
        lru=jnp.full((num_sets, ways), -1, jnp.int32),
        dirty=jnp.zeros((num_sets, ways), bool),
    )


def capacity_to_ways(capacity_blocks: int | jax.Array, num_sets: int,
                     max_ways: int) -> jax.Array:
    """Blocks -> active ways (ceil), clipped to the geometry."""
    w = (jnp.asarray(capacity_blocks) + num_sets - 1) // num_sets
    return jnp.clip(w, 0, max_ways).astype(jnp.int32)


# ---------------------------------------------------------------------------
# datapath primitives (single request, single set)
# ---------------------------------------------------------------------------

def _lookup(state: CacheState, s, addr, ways_active):
    active = jnp.arange(state.tags.shape[1]) < ways_active
    eq = (state.tags[s] == addr) & active
    hit = jnp.any(eq)
    way = jnp.argmax(eq)
    return hit, way, active


def _touch(state: CacheState, s, way, t, set_dirty):
    return state._replace(
        lru=state.lru.at[s, way].set(t),
        dirty=state.dirty.at[s, way].set(state.dirty[s, way] | set_dirty),
    )


def _victim(state: CacheState, s, active):
    """Pick insert way: first invalid active way, else LRU-min active way."""
    lru_s = state.lru[s]
    tags_s = state.tags[s]
    score = jnp.where(active, jnp.where(tags_s < 0, -1, lru_s), jnp.int32(2**31 - 1))
    return jnp.argmin(score)


def _insert(state: CacheState, s, addr, t, dirty, ways_active):
    """Insert a block; returns (state, evicted_valid, evicted_dirty)."""
    active = jnp.arange(state.tags.shape[1]) < ways_active
    can = ways_active > 0
    way = _victim(state, s, active)
    ev_valid = can & (state.tags[s, way] >= 0)
    ev_dirty = ev_valid & state.dirty[s, way]
    new = CacheState(
        tags=state.tags.at[s, way].set(jnp.where(can, addr, state.tags[s, way])),
        lru=state.lru.at[s, way].set(jnp.where(can, t, state.lru[s, way])),
        dirty=state.dirty.at[s, way].set(jnp.where(can, dirty, state.dirty[s, way])),
    )
    return new, can, ev_valid, ev_dirty


def _invalidate(state: CacheState, s, way, pred):
    return CacheState(
        tags=state.tags.at[s, way].set(jnp.where(pred, -1, state.tags[s, way])),
        lru=state.lru.at[s, way].set(jnp.where(pred, -1, state.lru[s, way])),
        dirty=state.dirty.at[s, way].set(jnp.where(pred, False, state.dirty[s, way])),
    )


# ---------------------------------------------------------------------------
# single level
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("policy",))
def simulate_single_level(addr, is_write, state: CacheState, ways_active,
                          policy: Policy, t_cache=T_SSD, t0=0):
    """Run one request window through a single-level cache.

    Returns (state, Stats, t_end). ``t0`` is the running logical clock so
    LRU order survives across windows.
    """
    num_sets = state.tags.shape[0]
    ways_active = jnp.asarray(ways_active, jnp.int32)
    t_cache = jnp.float32(t_cache)

    def step(carry, req):
        st0, stats, t = carry
        a, w = req
        valid = a >= 0  # padded no-op requests carry addr == -1
        a = jnp.maximum(a, 0)
        st = st0
        s = a % num_sets
        hit, way, active = _lookup(st, s, a, ways_active)

        def on_read(st):
            lat = jnp.where(hit, t_cache, jnp.float32(T_HDD))
            st = jax.lax.cond(hit, lambda c: _touch(c, s, way, t, False),
                              lambda c: c, st)
            do_alloc = (~hit) & policy.allocates_reads
            st2, ins, _, ev_dirty = _insert(st, s, a, t, False, ways_active)
            st = jax.tree_util.tree_map(
                lambda x, y: jnp.where(do_alloc, y, x), st, st2)
            cw = jnp.where(do_alloc & ins, 1, 0)
            dw = jnp.where(do_alloc & ins & ev_dirty, 1, 0)
            return st, Stats(1, 0, 0, hit.astype(jnp.int32), 0, cw,
                             (~hit).astype(jnp.int32), dw, lat)

        def on_write(st):
            if policy.write_invalidates:  # RO: bypass + invalidate stale copy
                st = _invalidate(st, s, way, hit)
                return st, Stats(0, 1, 0, 0, 0, 0, 0, 1,
                                 jnp.float32(T_HDD_WRITE))
            # WB/WT/WO/WBWO: write-allocate. WT commits synchronously, so
            # its cached copy stays clean (no write-pending data).
            mark_dirty = policy.holds_dirty
            st_hit = _touch(st, s, way, t, mark_dirty)
            st_ins, ins, _, ev_dirty = _insert(st, s, a, t, mark_dirty,
                                               ways_active)
            st = jax.tree_util.tree_map(
                lambda h, i: jnp.where(hit, h, i), st_hit, st_ins)
            committed = hit | ins
            cw = committed.astype(jnp.int32)
            # write-through also commits to disk synchronously
            sync = jnp.int32(1 if policy.write_through else 0)
            dw = sync + jnp.where((~hit) & ins & ev_dirty, 1, 0) \
                + jnp.where(~committed, 1, 0)
            lat = jnp.where(
                committed,
                jnp.float32(T_HDD_WRITE) if policy.write_through else t_cache,
                jnp.float32(T_HDD_WRITE))
            return st, Stats(0, 1, 0, 0, hit.astype(jnp.int32), cw, 0, dw, lat)

        st, ds = jax.lax.cond(w, lambda c: on_write(c), lambda c: on_read(c), st)
        # mask out padded requests entirely
        st = jax.tree_util.tree_map(
            lambda new, old: jnp.where(valid, new, old), st, st0)
        ds = Stats(*[d * valid.astype(d.dtype) for d in ds])
        return (st, stats.merge(ds), t + valid.astype(jnp.int32)), None

    (state, stats, t_end), _ = jax.lax.scan(
        step, (state, Stats.zero(), jnp.asarray(t0, jnp.int32)),
        (jnp.asarray(addr, jnp.int32), jnp.asarray(is_write)))
    return state, stats, t_end


# ---------------------------------------------------------------------------
# two level (ETICA §4.1/§4.2)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("mode",))
def simulate_two_level(addr, is_write, dram: CacheState, ssd: CacheState,
                       ways_dram, ways_ssd, mode: str = "full", t0=0):
    """ETICA datapath: DRAM is RO (reads allocate, writes bypass+invalidate);
    SSD is WBWO. ``mode="full"`` = pull-mode SSD (no datapath updates on
    miss — contents only change via write hits and the periodic
    promotion/eviction maintenance). ``mode="npe"`` = write misses allocate
    in the SSD on the datapath (ETICA-NPE in §5.3).
    """
    assert mode in ("full", "npe")
    ns_d = dram.tags.shape[0]
    ns_s = ssd.tags.shape[0]
    ways_dram = jnp.asarray(ways_dram, jnp.int32)
    ways_ssd = jnp.asarray(ways_ssd, jnp.int32)

    def step(carry, req):
        dr0, ss0, stats, t = carry
        a, w = req
        valid = a >= 0
        a = jnp.maximum(a, 0)
        dr, ss = dr0, ss0
        sd = a % ns_d
        s2 = a % ns_s
        d_hit, d_way, _ = _lookup(dr, sd, a, ways_dram)
        s_hit, s_way, _ = _lookup(ss, s2, a, ways_ssd)

        def on_read(dr, ss):
            # paper Fig. 6a: DRAM hit -> serve; SSD hit -> promote to DRAM,
            # serve; miss -> disk, promote to DRAM only (never to SSD).
            lat = jnp.where(d_hit, jnp.float32(T_DRAM),
                            jnp.where(s_hit, jnp.float32(T_SSD),
                                      jnp.float32(T_HDD)))
            dr = jax.lax.cond(d_hit, lambda c: _touch(c, sd, d_way, t, False),
                              lambda c: c, dr)
            ss = jax.lax.cond(s_hit & ~d_hit,
                              lambda c: _touch(c, s2, s_way, t, False),
                              lambda c: c, ss)
            dr_ins, _, _, _ = _insert(dr, sd, a, t, False, ways_dram)
            promote = ~d_hit
            dr = jax.tree_util.tree_map(
                lambda x, y: jnp.where(promote, y, x), dr, dr_ins)
            return dr, ss, Stats(
                1, 0, d_hit.astype(jnp.int32),
                (s_hit & ~d_hit).astype(jnp.int32), 0, 0,
                (~(d_hit | s_hit)).astype(jnp.int32), 0, lat)

        def on_write(dr, ss):
            # bypass DRAM; invalidate stale DRAM copy (§4.2 "Write")
            dr = _invalidate(dr, sd, d_way, d_hit)
            ss_hit_st = _touch(ss, s2, s_way, t, True)
            if mode == "npe":
                ss_ins, ins, _, ev_dirty = _insert(ss, s2, a, t, True, ways_ssd)
                ss = jax.tree_util.tree_map(
                    lambda h, i: jnp.where(s_hit, h, i), ss_hit_st, ss_ins)
                committed = s_hit | ins
                cw = committed.astype(jnp.int32)
                dw = jnp.where((~s_hit) & ins & ev_dirty, 1, 0) \
                    + jnp.where(~committed, 1, 0)
                lat = jnp.where(committed, jnp.float32(T_SSD),
                                jnp.float32(T_HDD_WRITE))
            else:  # full: SSD miss -> straight to disk
                ss = jax.tree_util.tree_map(
                    lambda h, i: jnp.where(s_hit, h, i), ss_hit_st, ss)
                cw = s_hit.astype(jnp.int32)
                dw = (~s_hit).astype(jnp.int32)
                lat = jnp.where(s_hit, jnp.float32(T_SSD),
                                jnp.float32(T_HDD_WRITE))
            return dr, ss, Stats(0, 1, 0, 0, s_hit.astype(jnp.int32), cw,
                                 0, dw, lat)

        dr, ss, ds = jax.lax.cond(w, on_write, on_read, dr, ss)
        dr = jax.tree_util.tree_map(
            lambda new, old: jnp.where(valid, new, old), dr, dr0)
        ss = jax.tree_util.tree_map(
            lambda new, old: jnp.where(valid, new, old), ss, ss0)
        ds = Stats(*[d * valid.astype(d.dtype) for d in ds])
        return (dr, ss, stats.merge(ds), t + valid.astype(jnp.int32)), None

    (dram, ssd, stats, t_end), _ = jax.lax.scan(
        step, (dram, ssd, Stats.zero(), jnp.asarray(t0, jnp.int32)),
        (jnp.asarray(addr, jnp.int32), jnp.asarray(is_write)))
    return dram, ssd, stats, t_end


# ---------------------------------------------------------------------------
# maintenance helpers (between-interval, host side — paper: asynchronous)
# ---------------------------------------------------------------------------

def resize(state: CacheState, old_ways: int, new_ways: int):
    """Deactivate ways >= new_ways; returns (state, flushed_dirty_blocks)."""
    if new_ways >= old_ways:
        return state, 0
    tags = np.asarray(state.tags).copy()
    lru = np.asarray(state.lru).copy()
    dirty = np.asarray(state.dirty).copy()
    flushed = int(dirty[:, new_ways:].sum())
    tags[:, new_ways:] = -1
    lru[:, new_ways:] = -1
    dirty[:, new_ways:] = False
    return CacheState(jnp.asarray(tags), jnp.asarray(lru), jnp.asarray(dirty)), flushed


def resident_blocks(state: CacheState, ways_active: int) -> np.ndarray:
    tags = np.asarray(state.tags)[:, : max(ways_active, 0)]
    return tags[tags >= 0]


def evict_blocks(state: CacheState, addrs: np.ndarray):
    """Evict given blocks (maintenance). Returns (state, flushed_dirty)."""
    tags = np.asarray(state.tags).copy()
    lru = np.asarray(state.lru).copy()
    dirty = np.asarray(state.dirty).copy()
    mask = np.isin(tags, addrs) & (tags >= 0)
    flushed = int((dirty & mask).sum())
    tags[mask] = -1
    lru[mask] = -1
    dirty[mask] = False
    return CacheState(jnp.asarray(tags), jnp.asarray(lru), jnp.asarray(dirty)), flushed


def promote_blocks(state: CacheState, addrs: np.ndarray, ways_active: int,
                   t: int):
    """Insert blocks into FREE active ways only (paper: promote "only when
    there is free space in SSD"). Returns (state, n_promoted)."""
    tags = np.asarray(state.tags).copy()
    lru = np.asarray(state.lru).copy()
    dirty = np.asarray(state.dirty).copy()
    num_sets, _ = tags.shape
    n = 0
    for a in np.asarray(addrs):
        s = int(a) % num_sets
        if (tags[s, :ways_active] == a).any():
            continue
        free = np.nonzero(tags[s, :ways_active] < 0)[0]
        if free.size == 0:
            continue
        w = free[0]
        tags[s, w] = a
        lru[s, w] = t
        dirty[s, w] = False
        n += 1
    return CacheState(jnp.asarray(tags), jnp.asarray(lru), jnp.asarray(dirty)), n
