"""Baseline caching schemes the paper compares against (§2, Table 1).

One-level hypervisor baselines (share the PartitionedSingleLevelCache
chassis; they differ in sizing metric + policy chooser):

  * ECI-Cache [6]  — URD sizing, dynamic per-VM WB/RO policy. The paper's
    primary comparison point.
  * Centaur [11]   — TRD sizing, WB.
  * S-CAVE [10]    — WSS (working-set size) sizing, WT.
  * vCacheShare [9]— reuse-intensity sizing, RO (write-around).

Sizing metric definitions (see :mod:`repro.core.reuse` for the shared
distance engine; ETICA §2.1, Fig. 5):

  * **URD** (ECI-Cache, arXiv:1805.00976): max reuse distance over read
    re-references only (RAR + RAW); ``demand = max URD + 1`` blocks.
  * **TRD** (Centaur; classic Mattson stack distance): max reuse distance
    over *all* re-accesses, read or write; ``demand = max TRD + 1``.
  * **WSS** (S-CAVE): distinct blocks touched in the window — no distance
    filtering at all, the over-allocating estimator ETICA criticizes.
  * **reuse intensity** (vCacheShare): distinct *re-referenced read*
    blocks — a locality x burstiness proxy; its curve uses POD(RO)
    distances since vCacheShare runs a read-only (write-around) cache.
  * ETICA itself replaces all of these with **POD** (§4.3.1, Eq. 2),
    which also conditions on the cache write policy.

Each metric exists in two forms with bit-identical results: a
:class:`SizingMetric` whose ``batch`` method reduces *all* VMs' stacked
reuse-distance histograms in one vmapped jitted dispatch
(:func:`repro.core.reuse.sizing_metrics_batch`), and the original per-VM
``*_ref`` closure kept as the sequential oracle that
``SingleLevelConfig(batched=False)`` exercises.

Global (non-partitioned) two-level baselines, simplified to their content
policies (used in the motivational comparisons):

  * FAST [3]   — DRAM(WB) + SSD(WB); blocks with > 3 accesses in the last
    window are promoted to the SSD; no eviction rule.
  * L2ARC [33] — DRAM read cache; DRAM evictions pushed to a FIFO SSD;
    read-only benefit.
  * uCache [37]— all requests land in DRAM; DRAM evictions demoted to SSD.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import reuse
from .controller import (Geometry, MetricFn, PartitionedSingleLevelCache,
                         PolicyChooser, SingleLevelConfig, _mrc_grid)
from .policies import Policy
from .trace import Trace


# ---------------------------------------------------------------------------
# sizing metrics — sequential per-VM reference closures (*_ref oracles)
# ---------------------------------------------------------------------------

def _metric_from_dist(r, n: int, geom: Geometry, points: int):
    grid = _mrc_grid(geom, points)
    hits = reuse.hit_counts_at_sizes(r.dist, r.served, grid)
    curve = np.asarray(hits, np.float64) / max(n, 1)
    return reuse.demand_blocks(int(r.max)), grid, curve


def urd_metric_ref(geom: Geometry, points: int = 17) -> MetricFn:
    def metric(sub: Trace):
        r = reuse.urd_distances(sub.addr, sub.is_write)
        return _metric_from_dist(r, len(sub), geom, points)
    return metric


def trd_metric_ref(geom: Geometry, points: int = 17) -> MetricFn:
    def metric(sub: Trace):
        r = reuse.trd_distances(sub.addr, sub.is_write)
        return _metric_from_dist(r, len(sub), geom, points)
    return metric


def wss_metric_ref(geom: Geometry, points: int = 17) -> MetricFn:
    """S-CAVE: demand = working-set size (distinct blocks touched).

    The MRC is still needed for partitioning under pressure; use the
    TRD-based curve (WSS has no native notion of a curve — this is the
    'deprecated' estimation the paper criticizes, and it over-allocates
    for sequential workloads by construction)."""
    def metric(sub: Trace):
        wss = int(np.unique(np.asarray(sub.addr)).size)
        r = reuse.trd_distances(sub.addr, sub.is_write)
        _, grid, curve = _metric_from_dist(r, len(sub), geom, points)
        return wss, grid, curve
    return metric


def reuse_intensity_metric_ref(geom: Geometry, points: int = 17) -> MetricFn:
    """vCacheShare: locality x burstiness proxy — distinct re-referenced
    read blocks scaled by access intensity."""
    def metric(sub: Trace):
        addr = np.asarray(sub.addr)
        rd = addr[~np.asarray(sub.is_write)]
        uniq, cnt = np.unique(rd, return_counts=True)
        rereferenced = int((cnt > 1).sum())
        r = reuse.pod_distances(sub.addr, sub.is_write, Policy.RO)
        _, grid, curve = _metric_from_dist(r, len(sub), geom, points)
        return rereferenced, grid, curve
    return metric


# ---------------------------------------------------------------------------
# batched metric protocol: all VMs sized in one vmapped dispatch
# ---------------------------------------------------------------------------

def _use_kernel_sizing() -> bool:
    """Route batched sizing through the Pallas ``sizing_reduction`` path.

    Default: only where Pallas compiles natively (TPU). Override with
    ``ETICA_SIZING_KERNEL=1`` (forces the kernel path — through the
    interpreter on CPU, which is how CI parity-checks it) or ``=0``
    (forces the jnp fallback everywhere).
    """
    from repro.kernels import env_flag
    forced = env_flag("ETICA_SIZING_KERNEL")
    if forced is not None:
        return forced
    import jax
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class SizingMetric:
    """A baseline sizing metric in both batched and sequential forms.

    ``batch`` reduces every VM's stacked reuse-distance histogram in one
    vmapped jitted dispatch; ``ref`` is the original per-VM closure the
    sequential (``batched=False``) controller path uses as its
    bit-identical oracle. :class:`PartitionedSingleLevelCache` accepts
    either a plain closure or this object.
    """

    kind: str                 # one of reuse.SIZING_KINDS
    # the metric's own MRC size grid (blocks); excluded from eq/hash so
    # the frozen dataclass stays comparable/hashable despite the ndarray
    grid: np.ndarray = dataclasses.field(compare=False)
    ref: MetricFn = dataclasses.field(compare=False)  # sequential oracle

    def batch(self, addrs: list[np.ndarray], writes: list[np.ndarray],
              with_reads: bool = False, mesh=None):
        """(demands [V], grid [G], curves [V, G]) for all VMs at once.

        Rows for empty traces are zero — exactly what the sequential loop
        produces by skipping them. With ``with_reads`` the per-VM read
        counts (already reduced inside the same dispatch, for the dynamic
        write-policy choosers) are appended to the return.

        On backends that compile Pallas (TPU; forced anywhere by
        ``ETICA_SIZING_KERNEL=1``) the O(N^2) distance channel runs
        through the ``kernels/reuse_distance`` Pallas kernel; the pure
        jnp reduction stays the CPU fallback, parity-asserted in
        ``tests/test_kernels.py``. ``mesh`` shards the VM rows across a
        device mesh on either route (shard-local, bit-identical).
        """
        if _use_kernel_sizing():
            from repro.kernels import use_interpret
            from repro.kernels.reuse_distance import ops as rd_ops
            demands, hits, reads = rd_ops.sizing_metrics_batch(
                addrs, writes, self.kind, self.grid,
                interpret=use_interpret(), mesh=mesh)
        else:
            demands, hits, reads = reuse.sizing_metrics_batch(
                addrs, writes, self.kind, self.grid, mesh=mesh)
        ns = np.array([max(np.shape(a)[0], 1) for a in addrs], np.float64)
        curves = hits.astype(np.float64) / ns[:, None]
        if with_reads:
            return demands, self.grid, curves, reads
        return demands, self.grid, curves


def _sizing_metric(kind: str, geom: Geometry, points: int,
                   ref: MetricFn) -> SizingMetric:
    return SizingMetric(kind=kind, grid=_mrc_grid(geom, points), ref=ref)


def urd_metric(geom: Geometry, points: int = 17) -> SizingMetric:
    """ECI-Cache's URD sizing (batched + sequential oracle)."""
    return _sizing_metric("urd", geom, points, urd_metric_ref(geom, points))


def trd_metric(geom: Geometry, points: int = 17) -> SizingMetric:
    """Centaur's TRD sizing (batched + sequential oracle)."""
    return _sizing_metric("trd", geom, points, trd_metric_ref(geom, points))


def wss_metric(geom: Geometry, points: int = 17) -> SizingMetric:
    """S-CAVE's working-set-size sizing (batched + sequential oracle)."""
    return _sizing_metric("wss", geom, points, wss_metric_ref(geom, points))


def reuse_intensity_metric(geom: Geometry, points: int = 17) -> SizingMetric:
    """vCacheShare's reuse-intensity sizing (batched + sequential oracle)."""
    return _sizing_metric("reuse_intensity", geom, points,
                          reuse_intensity_metric_ref(geom, points))


# ---------------------------------------------------------------------------
# policy choosers
# ---------------------------------------------------------------------------

def eci_policy(read_heavy_threshold: float = 0.8) -> PolicyChooser:
    """ECI-Cache dynamically assigns RO to read-dominated VMs (endurance)
    and WB otherwise (performance).

    Returned as a :class:`~repro.core.controller.PolicyChooser`: with a
    batched :class:`SizingMetric` the per-VM read ratios come out of the
    same vmapped sizing dispatch (zero per-VM host work); the host-loop
    closure stays as the ``ref`` oracle the sequential path runs."""
    def from_ratio(read_ratio: float) -> Policy:
        return (Policy.RO if read_ratio >= read_heavy_threshold
                else Policy.WB)

    def chooser(sub: Trace) -> Policy:
        return from_ratio(sub.n_reads / max(len(sub), 1))

    return PolicyChooser(from_read_ratio=from_ratio, ref=chooser)


def fixed_policy(p: Policy):
    return lambda sub: p


# ---------------------------------------------------------------------------
# factories
# ---------------------------------------------------------------------------

def make_eci_cache(capacity: int, num_vms: int,
                   geometry: Geometry | None = None,
                   resize_interval: int = 10_000,
                   **kw) -> PartitionedSingleLevelCache:
    geometry = geometry or Geometry()
    cfg = SingleLevelConfig(capacity=capacity, geometry=geometry,
                            resize_interval=resize_interval, **kw)
    return PartitionedSingleLevelCache(cfg, num_vms,
                                       urd_metric(geometry), eci_policy())


def make_centaur(capacity: int, num_vms: int,
                 geometry: Geometry | None = None, **kw):
    geometry = geometry or Geometry()
    cfg = SingleLevelConfig(capacity=capacity, geometry=geometry, **kw)
    return PartitionedSingleLevelCache(cfg, num_vms,
                                       trd_metric(geometry),
                                       fixed_policy(Policy.WB))


def make_scave(capacity: int, num_vms: int,
               geometry: Geometry | None = None, **kw):
    geometry = geometry or Geometry()
    cfg = SingleLevelConfig(capacity=capacity, geometry=geometry, **kw)
    return PartitionedSingleLevelCache(cfg, num_vms,
                                       wss_metric(geometry),
                                       fixed_policy(Policy.WT))


def make_vcacheshare(capacity: int, num_vms: int,
                     geometry: Geometry | None = None, **kw):
    geometry = geometry or Geometry()
    cfg = SingleLevelConfig(capacity=capacity, geometry=geometry, **kw)
    return PartitionedSingleLevelCache(cfg, num_vms,
                                       reuse_intensity_metric(geometry),
                                       fixed_policy(Policy.RO))


# ---------------------------------------------------------------------------
# global (non-partitioned) two-level baselines — Table 1's uCache/FAST/L2ARC
# family, reduced to their content policies over our two-level datapath
# ---------------------------------------------------------------------------

import numpy as np  # noqa: E402

from .controller import VMResult, _acc, _pad  # noqa: E402
from .simulator import (Stats, make_cache, promote_blocks,  # noqa: E402
                        resident_blocks, simulate_single_level,
                        simulate_two_level)


class FastCache:
    """Dell EMC FAST-style global two-level cache: DRAM(WB) + SSD(WB),
    blocks with > ``hot_threshold`` accesses in the last window promoted
    to the SSD, no eviction rule beyond LRU (paper §2.2.2)."""

    def __init__(self, dram_capacity: int, ssd_capacity: int,
                 geometry: Geometry | None = None, window: int = 1_000,
                 hot_threshold: int = 3):
        self.geom = geometry or Geometry()
        self.dram = make_cache(self.geom.num_sets, self.geom.max_ways)
        self.ssd = make_cache(self.geom.num_sets, self.geom.max_ways)
        from .simulator import capacity_to_ways
        self.wd = int(capacity_to_ways(dram_capacity, self.geom.num_sets,
                                       self.geom.max_ways))
        self.ws = int(capacity_to_ways(ssd_capacity, self.geom.num_sets,
                                       self.geom.max_ways))
        self.window = window
        self.hot_threshold = hot_threshold
        self.stats: dict = {}
        self.t = 0

    def run(self, trace: Trace) -> VMResult:
        for win in trace.intervals(self.window):
            a, w = _pad(np.asarray(win.addr, np.int32),
                        np.asarray(win.is_write), self.window)
            # NPE-mode two-level datapath approximates WB+WB content flow
            self.dram, self.ssd, st, t_end = simulate_two_level(
                a, w, self.dram, self.ssd, self.wd, self.ws,
                mode="npe", t0=self.t)
            self.t = int(t_end)
            _acc(self.stats, st)
            # FAST promotion: > threshold accesses in the window
            uniq, counts = np.unique(np.asarray(win.addr),
                                     return_counts=True)
            hot = uniq[counts > self.hot_threshold]
            hot = hot[~np.isin(hot, resident_blocks(self.ssd, self.ws))]
            if hot.size:
                self.ssd, n = promote_blocks(self.ssd, hot, self.ws, self.t)
                self.stats["cache_writes_l2"] = (
                    self.stats.get("cache_writes_l2", 0.0) + int(n))
        return VMResult(dict(self.stats), np.zeros(1, np.int64))


def make_fast(dram_capacity: int, ssd_capacity: int, **kw) -> FastCache:
    return FastCache(dram_capacity, ssd_capacity, **kw)


class L2ARCCache:
    """ZFS L2ARC-style global two-level cache (paper §2.2.2): DRAM read
    cache; blocks evicted from DRAM are pushed into a FIFO SSD; reads
    only — writes bypass both levels. No popularity logic."""

    def __init__(self, dram_capacity: int, ssd_capacity: int,
                 geometry: Geometry | None = None, window: int = 1_000):
        from .simulator import capacity_to_ways
        self.geom = geometry or Geometry()
        self.dram = make_cache(self.geom.num_sets, self.geom.max_ways)
        self.ssd = make_cache(self.geom.num_sets, self.geom.max_ways)
        self.wd = int(capacity_to_ways(dram_capacity, self.geom.num_sets,
                                       self.geom.max_ways))
        self.ws = int(capacity_to_ways(ssd_capacity, self.geom.num_sets,
                                       self.geom.max_ways))
        self.window = window
        self.stats: dict = {}
        self.t = 0

    def run(self, trace: Trace) -> VMResult:
        prev_resident = resident_blocks(self.dram, self.wd)
        for win in trace.intervals(self.window):
            a, w = _pad(np.asarray(win.addr, np.int32),
                        np.asarray(win.is_write), self.window)
            # reads-only two-level flow: full mode never writes misses to
            # the SSD; writes pass through (DRAM level is RO already)
            self.dram, self.ssd, st, t_end = simulate_two_level(
                a, w, self.dram, self.ssd, self.wd, self.ws,
                mode="full", t0=self.t)
            self.t = int(t_end)
            _acc(self.stats, st)
            # L2ARC: push predicted-to-be-evicted DRAM blocks to the SSD
            # (approximated as blocks that left DRAM this window)
            now_resident = resident_blocks(self.dram, self.wd)
            evicted = prev_resident[~np.isin(prev_resident, now_resident)]
            prev_resident = now_resident
            evicted = evicted[~np.isin(evicted,
                                       resident_blocks(self.ssd, self.ws))]
            if evicted.size:
                self.ssd, n = promote_blocks(self.ssd, evicted, self.ws,
                                             self.t)
                self.stats["cache_writes_l2"] = (
                    self.stats.get("cache_writes_l2", 0.0) + int(n))
        return VMResult(dict(self.stats), np.zeros(1, np.int64))


def make_l2arc(dram_capacity: int, ssd_capacity: int, **kw) -> L2ARCCache:
    return L2ARCCache(dram_capacity, ssd_capacity, **kw)
