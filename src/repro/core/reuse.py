"""Reuse-distance engine: TRD, URD, and the paper's POD metric (§4.3.1).

Metric definitions (the sizing metrics of ETICA and its baselines):

  * **TRD** — Traditional (Mattson) Reuse Distance: the number of distinct
    blocks accessed between two consecutive accesses to the same block,
    counting *every* re-access, read or write (Centaur's sizing metric;
    ETICA §2.1 / Fig. 5a).
  * **URD** — Useful Reuse Distance (ECI-Cache, arXiv:1805.00976): TRD
    restricted to *read* re-references (RAR + RAW) — writes refresh blocks
    but their own distances do not count toward sizing (ETICA §2.1 /
    Fig. 5b).
  * **POD** — Policy Optimized reuse Distance (ETICA §4.3.1, Eq. 2): URD
    further filtered by the cache *write policy*, so only requests the
    policy would actually serve occupy blocks or earn a distance (key
    ideas 1–4, Figs. 8–9). ``demand = max POD + 1`` blocks (Eq. 2's
    allocation rule; 0 when nothing is served).
  * **WSS** — Working-Set Size (S-CAVE): the count of distinct blocks
    touched in the window, regardless of type or policy — the
    over-allocating estimator ETICA §2.1 criticizes.

All of them are instances of one computation over a *policy-filtered
sub-trace*:

  * ``touch[j]``  — access j inserts-or-hits the cache under the policy and
    therefore both occupies a block and refreshes its LRU position.
  * ``served[i]`` — access i would hit an infinite cache under the policy
    (these are the accesses whose distances matter for sizing; per the
    paper, only *read* accesses count toward sizing in every policy).
  * ``dist[i]``   — for served i: the number of DISTINCT addresses touched
    strictly between ``p(i)`` (the previous touch of ``addr[i]``) and i.
    Blocks invalidated in the window still count until their next touch
    (a conservative upper bound; the paper's worked examples are exact).

Then  ``metric = max(dist[served])``  and the allocation is ``metric + 1``
blocks (0 if nothing is served).

Policy filters (paper §4.3.1 key ideas 1-4):

  * TRD        : touch = all,           served = any re-access (R or W)
  * URD        : touch = all,           served = RAR + RAW reads
  * POD(WB/WT) : identical to URD.
  * POD(RO)    : touch = reads,         served = reads whose previous access
                 to the same address is a read (writes invalidate).
  * POD(WBWO)  : touch = writes + served reads,
                 served = reads with an earlier write to the same address
                 (RAW and, transitively, RARAW).

The pairwise distinct-count is O(N·N) with tiny constants — it is exactly
the windowed-counting computation that ``repro.kernels.reuse_distance``
tiles for TPU (this module is the oracle the kernel is tested against; the
kernel is used by ``ops.reuse_distances`` when running on TPU).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .policies import Policy

# Sentinel distance for cold / not-served accesses. A numpy scalar, not a
# device-committed jnp constant: closing over a committed array inside a
# ``shard_map`` body makes GSPMD treat it as sharded operand state and
# insert spurious all-reduces (observed on CPU host devices), corrupting
# every shard but the first. np scalars weave into traces as literals.
COLD = np.int32(-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DistResult:
    """Per-access reuse-distance decomposition."""

    dist: jax.Array     # int32 [N]; -1 where not served
    served: jax.Array   # bool  [N]; access would hit an infinite cache
    touch: jax.Array    # bool  [N]; access occupies/refreshes a block

    def tree_flatten(self):
        return (self.dist, self.served, self.touch), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def max(self) -> jax.Array:
        return jnp.max(jnp.where(self.served, self.dist, COLD))


# ---------------------------------------------------------------------------
# prev/next same-address helpers (sort-based, O(N log N))
# ---------------------------------------------------------------------------

def argsort_stable(x: jax.Array) -> jax.Array:
    """Stable ascending argsort over the last axis via raw ``lax.sort``.

    Equivalent to ``jnp.argsort(x, stable=True)`` (a stable argsort is
    uniquely determined), but shard-safe: ``jnp.argsort`` is internally
    jitted, and under ``vmap`` inside a manual ``shard_map`` region GSPMD
    wraps the nested sort in spurious cross-shard all-reduces (observed on
    CPU host devices — results corrupt on every device but the first).
    Sorting ``(keys, iota)`` with ``num_keys=1`` stays a plain sort HLO.
    """
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    _, out = jax.lax.sort((x, iota), dimension=x.ndim - 1,
                          is_stable=True, num_keys=1)
    return out


def _prev_same(addr: jax.Array, mask: jax.Array) -> jax.Array:
    """prev[i] = largest j < i with addr[j] == addr[i] and mask[j]; else -1.

    Defined for every i (masked or not): the previous *masked* occurrence.
    """
    n = addr.shape[0]
    # Stable sort by address keeps original index order within each address
    # run; a scan down the sorted sequence then yields, for every position
    # (masked or not), the nearest *masked* predecessor in its run.
    order = argsort_stable(addr)
    s_addr = addr[order]
    s_mask = mask[order]
    s_idx = order.astype(jnp.int32)

    def body(carry, x):
        last_addr, last_masked = carry
        a, m, i = x
        same_run = a == last_addr
        prev_m = jnp.where(same_run, last_masked, -1)
        new_last = jnp.where(m, i, prev_m)
        return (a, new_last), prev_m

    (_, _), prev_sorted = jax.lax.scan(
        body, (jnp.int32(-(2**31) + 1), jnp.int32(-1)), (s_addr, s_mask, s_idx)
    )
    return jnp.zeros(n, dtype=jnp.int32).at[order].set(prev_sorted)


def _next_same(addr: jax.Array, mask: jax.Array) -> jax.Array:
    """next[i] = smallest j > i with addr[j]==addr[i] and mask[j]; else N."""
    n = addr.shape[0]
    rev_prev = _prev_same(addr[::-1], mask[::-1])
    # index transform: position i in reversed array is n-1-i originally
    nxt = jnp.where(rev_prev[::-1] >= 0, n - 1 - rev_prev[::-1], n)
    return nxt.astype(jnp.int32)


# ---------------------------------------------------------------------------
# distinct-count between previous touch and current access
# ---------------------------------------------------------------------------

def _count_between(prev_touch: jax.Array, touch: jax.Array,
                   next_touch: jax.Array, chunk: int = 256) -> jax.Array:
    """count[i] = #{ j : prev_touch[i] < j < i, touch[j], next_touch[j] >= i }.

    Each qualifying j is the LAST touch of its address inside the window,
    so the count equals the number of distinct addresses touched in the
    window. O(N^2) pairwise, evaluated in row chunks.
    """
    n = touch.shape[0]
    j = jnp.arange(n, dtype=jnp.int32)
    tj = touch
    ntj = next_touch

    def rows(i_block):
        i = i_block  # [chunk]
        p = prev_touch[i]  # [chunk]
        m = (
            (j[None, :] > p[:, None])
            & (j[None, :] < i[:, None])
            & tj[None, :]
            & (ntj[None, :] >= i[:, None])
        )
        return jnp.sum(m, axis=1, dtype=jnp.int32)

    pad = (-n) % chunk
    i_all = jnp.arange(n + pad, dtype=jnp.int32).reshape(-1, chunk)
    i_all = jnp.minimum(i_all, n - 1)
    counts = jax.lax.map(rows, i_all).reshape(-1)[:n]
    return counts


# ---------------------------------------------------------------------------
# per-policy decomposition
# ---------------------------------------------------------------------------

def _decompose(addr: jax.Array, is_write: jax.Array, policy: Policy,
               *, sizing_reads_only: bool = True,
               chunk: int = 256) -> DistResult:
    addr = addr.astype(jnp.int32)
    is_read = ~is_write
    all_mask = jnp.ones_like(is_write)

    prev_any = _prev_same(addr, all_mask)
    has_prev = prev_any >= 0

    if policy in (Policy.WB, Policy.WT):
        touch = all_mask
        served = is_read & has_prev
    elif policy is Policy.RO:
        touch = is_read
        prev_is_read = jnp.where(has_prev, ~is_write[jnp.maximum(prev_any, 0)], False)
        served = is_read & prev_is_read
    elif policy in (Policy.WBWO, Policy.WO):
        prev_write = _prev_same(addr, is_write)
        served = is_read & (prev_write >= 0)
        touch = is_write | served
    else:  # pragma: no cover
        raise ValueError(policy)

    prev_touch = _prev_same(addr, touch)
    next_touch = _next_same(addr, touch)
    dist = _count_between(prev_touch, touch, next_touch, chunk=chunk)
    if not sizing_reads_only:
        served = served | (is_write & has_prev)
    dist = jnp.where(served, dist, COLD)
    return DistResult(dist=dist, served=served, touch=touch)


# Public API ----------------------------------------------------------------
#
# Inputs are padded up to power-of-two buckets with trailing writes to
# fresh, never-reused addresses. Appended accesses sit after every real
# access, so no real (p, i) window contains them; they themselves are
# cold writes (never "served"); and as WBWO touches they only ever occupy
# positions after all real windows. Hence bucketing is exact while keeping
# the number of distinct jit shapes logarithmic.

_PAD_BASE = np.int32(2**30)


def _bucket(n: int, min_size: int = 256) -> int:
    return max(min_size, 1 << (n - 1).bit_length())


def _pad_trace(addr, is_write):
    addr = np.asarray(addr, np.int32)
    is_write = np.asarray(is_write, bool)
    n = addr.shape[0]
    b = _bucket(n)
    if b == n:
        return addr, is_write, n
    k = b - n
    pad_addr = _PAD_BASE + np.arange(k, dtype=np.int32)
    return (np.concatenate([addr, pad_addr]),
            np.concatenate([is_write, np.ones(k, bool)]), n)


_decompose_jit = jax.jit(
    _decompose, static_argnames=("policy", "sizing_reads_only", "chunk"))


def _slice(r: DistResult, n: int) -> DistResult:
    return DistResult(dist=r.dist[:n], served=r.served[:n], touch=r.touch[:n])


def pod_distances(addr, is_write, policy: Policy, chunk: int = 256) -> DistResult:
    """POD decomposition for a policy (paper §4.3.1)."""
    a, w, n = _pad_trace(addr, is_write)
    return _slice(_decompose_jit(a, w, policy, chunk=chunk), n)


def _pad_rows(addrs, writes, live: list[int], lens: list[int]):
    """Stack the live rows of ragged per-VM request lists into rectangular
    ``[L, b]`` arrays, padded to a common power-of-two bucket with the same
    never-reused trailing writes as :func:`_pad_trace` (exact, see above)."""
    b = _bucket(max(lens[v] for v in live))
    amat = np.empty((len(live), b), np.int32)
    wmat = np.empty((len(live), b), bool)
    for i, v in enumerate(live):
        pad_addr = _PAD_BASE + np.arange(b - lens[v], dtype=np.int32)
        amat[i] = np.concatenate([np.asarray(addrs[v], np.int32), pad_addr])
        wmat[i] = np.concatenate(
            [np.asarray(writes[v], bool), np.ones(b - lens[v], bool)])
    return amat, wmat


@functools.partial(jax.jit,
                   static_argnames=("policy", "sizing_reads_only", "chunk"))
def _decompose_vmapped(amat, wmat, policy, sizing_reads_only, chunk):
    return jax.vmap(
        lambda a, w: _decompose(a, w, policy,
                                sizing_reads_only=sizing_reads_only,
                                chunk=chunk))(amat, wmat)


# --- sharded variants (VM axis split across a 1-d device mesh) -------------
#
# These routes deliberately do NOT use ``shard_map``: on CPU host devices
# the GSPMD partitioner wraps the decompose body (``_count_between`` fed by
# a data-dependent touch mask, as the RO/WBWO policies and the
# reuse_intensity metric produce) in spurious cross-shard all-reduces that
# corrupt every device but the first — and which outputs trigger it shifts
# unpredictably with the returned pytree. Instead each device runs the
# *same* single-device jitted executable as the oracle path on its own
# ``[V/d, b]`` row block (dispatched asynchronously, gathered on the
# host), so results are bit-identical and zero collectives exist by
# construction. The clean shard_map routes (datapath, maintenance,
# resize, stats aggregation) live in ``core.simulator`` /
# ``kernels.maintenance``.

def _decompose_sharded(mesh, amat, wmat, policy, sizing_reads_only, chunk):
    from ..launch.mesh import device_row_blocks
    parts = []
    for dev, rows in device_row_blocks(amat.shape[0], mesh):
        a = jax.device_put(jnp.asarray(amat[rows]), dev)
        w = jax.device_put(jnp.asarray(wmat[rows]), dev)
        parts.append(_decompose_vmapped(a, w, policy=policy,
                                        sizing_reads_only=sizing_reads_only,
                                        chunk=chunk))
    return DistResult(*[
        np.concatenate([np.asarray(getattr(p, f)) for p in parts], axis=0)
        for f in ("dist", "served", "touch")])


def _sizing_sharded(mesh, amat, wmat, nvec, grid, kind, chunk):
    from ..launch.mesh import device_row_blocks
    parts = []
    for dev, rows in device_row_blocks(amat.shape[0], mesh):
        a = jax.device_put(jnp.asarray(amat[rows]), dev)
        w = jax.device_put(jnp.asarray(wmat[rows]), dev)
        n = jax.device_put(jnp.asarray(nvec[rows]), dev)
        g = jax.device_put(jnp.asarray(grid), dev)
        parts.append(_sizing_reduce_vmapped(a, w, n, g,
                                            kind=kind, chunk=chunk))
    return tuple(
        np.concatenate([np.asarray(p[i]) for p in parts], axis=0)
        for i in range(3))


def _require_divisible(num_rows: int, mesh) -> None:
    from ..launch.mesh import require_vm_divisible
    require_vm_divisible(num_rows, mesh)


def _distances_batch(addrs, writes, policy: Policy, sizing_reads_only: bool,
                     chunk: int, mesh=None) -> list[DistResult | None]:
    """Decompose many traces in ONE vmapped dispatch.

    ``addrs``/``writes`` are ragged per-VM request lists; rows are padded
    to a common power-of-two bucket with the same never-reused trailing
    writes as :func:`_pad_trace` (exact, see above), so per-VM results are
    bit-identical to calling the unbatched functions per VM. Empty rows
    come back as ``None``.

    With ``mesh`` the rows are split over the mesh's VM axis and each
    device decomposes its own block shard-locally. Empty rows are then
    packed too (as pure-pad rows, which the row-wise computation treats
    identically), so the row count — which must be divisible by the mesh
    size — lines up with the shard layout.
    """
    lens = [int(np.shape(a)[0]) for a in addrs]
    live = [v for v, n in enumerate(lens) if n > 0]
    if not live:
        return [None] * len(lens)
    if mesh is not None:
        _require_divisible(len(lens), mesh)
        rows = list(range(len(lens)))
        amat, wmat = _pad_rows(addrs, writes, rows, lens)
        r = _decompose_sharded(mesh, amat, wmat, policy,
                               sizing_reads_only, chunk)
        idx = rows
    else:
        amat, wmat = _pad_rows(addrs, writes, live, lens)
        r = _decompose_vmapped(amat, wmat, policy=policy,
                               sizing_reads_only=sizing_reads_only,
                               chunk=chunk)
        idx = live
    out: list[DistResult | None] = [None] * len(lens)
    dist, served, touch = (np.asarray(r.dist), np.asarray(r.served),
                           np.asarray(r.touch))
    for i, v in enumerate(idx):
        if lens[v] > 0:
            out[v] = DistResult(dist=dist[i, : lens[v]],
                                served=served[i, : lens[v]],
                                touch=touch[i, : lens[v]])
    return out


def pod_distances_batch(addrs, writes, policy: Policy,
                        chunk: int = 256, mesh=None) -> list[DistResult | None]:
    """Per-VM :func:`pod_distances` in one vmapped dispatch (ragged input,
    bit-identical per-VM results; empty traces -> ``None``). ``mesh``
    shards the VM rows across devices (shard-local, no collectives)."""
    return _distances_batch(addrs, writes, policy, True, chunk, mesh=mesh)


def trd_distances_batch(addrs, writes,
                        chunk: int = 256, mesh=None) -> list[DistResult | None]:
    """Per-VM :func:`trd_distances` in one vmapped dispatch."""
    return _distances_batch(addrs, writes, Policy.WB, False, chunk, mesh=mesh)


def urd_distances(addr, is_write, chunk: int = 256) -> DistResult:
    """URD (ECI-Cache): read re-references over WB content semantics."""
    a, w, n = _pad_trace(addr, is_write)
    return _slice(_decompose_jit(a, w, Policy.WB, chunk=chunk), n)


def trd_distances(addr, is_write, chunk: int = 256) -> DistResult:
    """Traditional reuse distance: every re-access counts (Centaur)."""
    a, w, n = _pad_trace(addr, is_write)
    return _slice(
        _decompose_jit(a, w, Policy.WB, sizing_reads_only=False, chunk=chunk), n)


def pod(trace, policy: Policy) -> int:
    """max POD of a trace under ``policy`` (−1 if nothing is served)."""
    r = pod_distances(jnp.asarray(trace.addr), jnp.asarray(trace.is_write), policy)
    return int(r.max)


def urd(trace) -> int:
    r = urd_distances(jnp.asarray(trace.addr), jnp.asarray(trace.is_write))
    return int(r.max)


def trd(trace) -> int:
    r = trd_distances(jnp.asarray(trace.addr), jnp.asarray(trace.is_write))
    return int(r.max)


def demand_blocks(metric_value: int) -> int:
    """Cache size (blocks) implied by a max reuse distance (paper: POD+1)."""
    return int(metric_value) + 1 if metric_value >= 0 else 0


# ---------------------------------------------------------------------------
# Miss-ratio curves (analytic path)
# ---------------------------------------------------------------------------

def hit_counts_at_sizes(dist, served, sizes) -> np.ndarray:
    """hits[s] = #served accesses with dist < sizes[s] (LRU inclusion).

    Host-side analytics (variable shapes); the heavy part — computing the
    distances — is the jitted/kernelized piece upstream.
    """
    d = np.where(np.asarray(served), np.asarray(dist), np.int32(2**30))
    return np.sum(d[None, :] < np.asarray(sizes)[:, None], axis=1, dtype=np.int64)


def hit_counts_at_sizes_weighted(dist, served, sizes, weights) -> np.ndarray:
    """:func:`hit_counts_at_sizes` with per-request sizing weights.

    Used by the classified controllers: each request contributes its IO
    class's ``weight`` to the hit curve instead of 1. With all-one
    weights the float64 sums are exact integer counts, equal to the
    unweighted path bit for bit.
    """
    d = np.where(np.asarray(served), np.asarray(dist), np.int32(2**30))
    w = np.asarray(weights, np.float64)
    return ((d[None, :] < np.asarray(sizes)[:, None]) * w[None, :]).sum(axis=1)


def mrc(trace, policy: Policy, sizes: np.ndarray) -> np.ndarray:
    """Hit-ratio curve H(c) for the trace under ``policy`` at ``sizes``.

    By LRU stack inclusion, a served access hits iff its policy-filtered
    stack distance is < allocated blocks. Ratio is over *all* requests, so
    curves are comparable across policies.
    """
    r = pod_distances(jnp.asarray(trace.addr), jnp.asarray(trace.is_write), policy)
    hits = hit_counts_at_sizes(r.dist, r.served, jnp.asarray(sizes, jnp.int32))
    return np.asarray(hits, dtype=np.float64) / max(len(trace), 1)


# ---------------------------------------------------------------------------
# Batched sizing reductions (the one-level baselines' metrics, §2.1)
# ---------------------------------------------------------------------------
#
# The one-level baselines (ECI-Cache, Centaur, S-CAVE, vCacheShare) size
# their per-VM partitions from four metrics that are all reductions over
# the same policy-filtered distance decompositions computed above:
#
#   kind               demand (blocks)              hit-curve channel
#   ----               ---------------              -----------------
#   "urd"              max URD + 1                  URD (WB dist, read re-refs)
#   "trd"              max TRD + 1                  TRD (WB dist, all re-refs)
#   "wss"              distinct blocks touched      TRD
#   "reuse_intensity"  re-referenced read blocks    POD(RO)
#
# URD and TRD share one decomposition (same all-touch distances, different
# served masks), so each kind costs exactly one O(N^2) distance pass.
# ``sizing_metrics_batch`` evaluates one metric for many VM sub-traces in
# ONE vmapped jitted dispatch — the baseline analogue of
# :func:`pod_distances_batch` — so controllers never loop over VMs.

SIZING_KINDS = ("urd", "trd", "wss", "reuse_intensity")

_SERVED_BIG = np.int32(2**30)  # not-served sentinel (np: shard-safe, see COLD)


def read_count(is_write, n_valid=None) -> jax.Array:
    """#reads among the first ``n_valid`` requests (int32).

    The per-VM read-ratio the ECI-style dynamic write-policy choosers
    consume, computed inside the same batched sizing dispatch instead of
    a host loop. ``n_valid=None`` counts the whole row — exact for
    bucket-padded rows too, whose pads are all writes."""
    is_read = ~is_write
    if n_valid is not None:
        is_read = is_read & (jnp.arange(is_write.shape[0],
                                        dtype=jnp.int32) < n_valid)
    return jnp.sum(is_read, dtype=jnp.int32)


def sizing_policy(kind: str) -> tuple[Policy, bool]:
    """The (policy, sizing_reads_only) decomposition a sizing kind rides."""
    if kind == "reuse_intensity":
        return Policy.RO, True
    return Policy.WB, False


def sizing_from_dists(addr, is_write, r: DistResult, n_valid, grid,
                      kind: str):
    """``(demand, hit_counts[G])`` from a decomposed distance channel.

    The shared post-distance reduction behind both the pure-jnp batched
    path (:func:`sizing_metrics_batch`) and the Pallas-kernel path
    (``repro.kernels.reuse_distance.ops.sizing_reduction``): served-mask
    selection, hit histogram, and the demand scalar. ``r`` must be the
    :func:`sizing_policy` decomposition for ``kind``. ``n_valid`` masks
    any pad tail out of the WSS distinct-count (the other reductions are
    pad-invariant by construction: pads are cold writes to fresh
    addresses).
    """
    is_read = ~is_write
    served = (r.served & is_read) if kind == "urd" else r.served
    d = jnp.where(served, r.dist, _SERVED_BIG)
    hits = jnp.sum(d[None, :] < grid[:, None], axis=1, dtype=jnp.int32)
    if kind == "wss":
        valid = jnp.arange(addr.shape[0], dtype=jnp.int32) < n_valid
        first = _prev_same(addr, jnp.ones_like(is_write)) < 0
        demand = jnp.sum(first & valid, dtype=jnp.int32)
    elif kind == "reuse_intensity":
        prev_read = _prev_same(addr, is_read)
        next_read = _next_same(addr, is_read)
        demand = jnp.sum(is_read & (prev_read < 0)
                         & (next_read < addr.shape[0]), dtype=jnp.int32)
    else:
        demand = jnp.maximum(jnp.max(jnp.where(served, r.dist, COLD)) + 1, 0)
    return demand, hits


def _sizing_one(addr, is_write, n_valid, grid, kind: str, chunk: int):
    """``(demand, hit_counts[G], n_reads)`` for one (possibly padded)
    trace: one O(N^2) :func:`_decompose` pass + the shared reduction, with
    the policy choosers' read count riding the same dispatch."""
    policy, reads_only = sizing_policy(kind)
    r = _decompose(addr, is_write, policy,
                   sizing_reads_only=reads_only, chunk=chunk)
    demand, hits = sizing_from_dists(addr, is_write, r, n_valid, grid, kind)
    return demand, hits, read_count(is_write, n_valid)


@functools.partial(jax.jit, static_argnames=("kind", "chunk"))
def _sizing_reduce_vmapped(amat, wmat, nvec, grid, kind, chunk):
    return jax.vmap(
        lambda a, w, n: _sizing_one(a, w, n, grid, kind, chunk)
    )(amat, wmat, nvec)


def sizing_metrics_batch(addrs, writes, kind: str, grid,
                         chunk: int = 256, mesh=None
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Evaluate one sizing metric for many VM sub-traces in ONE dispatch.

    Args:
      addrs/writes: ragged per-VM request arrays (empty rows allowed).
      kind: one of :data:`SIZING_KINDS`.
      grid: ascending candidate cache sizes (blocks) for the hit curve.

    Returns:
      ``(demands, hit_counts, read_counts)``: int64 ``[V]`` demanded
      blocks, int64 ``[V, G]`` served-access hit counts at each grid size,
      and int64 ``[V]`` per-VM read counts (for the dynamic write-policy
      choosers) — zero rows for empty traces. Per-VM values are
      bit-identical to evaluating the sequential per-VM closures in
      :mod:`repro.core.baselines` — the padding is the same never-reused
      trailing writes as :func:`_pad_trace`, which no real distance window
      can see, and the WSS distinct-count and read count mask the pad tail
      explicitly.

    With ``mesh`` the VM rows (all of them, empty ones packed as pure-pad
    rows that reduce to zeros) are split over the mesh's VM axis; each
    device runs its own shard-local reduction and no collectives exist.
    """
    if kind not in SIZING_KINDS:
        raise ValueError(f"kind must be one of {SIZING_KINDS}, got {kind!r}")
    lens = [int(np.shape(a)[0]) for a in addrs]
    grid = np.asarray(grid, np.int32)
    demands = np.zeros(len(lens), np.int64)
    hits = np.zeros((len(lens), grid.size), np.int64)
    reads = np.zeros(len(lens), np.int64)
    live = [v for v, n in enumerate(lens) if n > 0]
    if not live:
        return demands, hits, reads
    if mesh is not None:
        _require_divisible(len(lens), mesh)
        rows = list(range(len(lens)))
        amat, wmat = _pad_rows(addrs, writes, rows, lens)
        nvec = np.array(lens, np.int32)
        d, h, r = _sizing_sharded(mesh, amat, wmat, nvec, grid, kind, chunk)
        demands[:] = np.asarray(d, np.int64)
        hits[:] = np.asarray(h, np.int64)
        reads[:] = np.asarray(r, np.int64)
        # pure-pad rows reduce to zeros row-wise; re-zero anyway so empty
        # traces match the unsharded contract exactly by construction
        empty = [v for v, n in enumerate(lens) if n == 0]
        demands[empty] = 0
        hits[empty] = 0
        reads[empty] = 0
        return demands, hits, reads
    amat, wmat = _pad_rows(addrs, writes, live, lens)
    nvec = np.array([lens[v] for v in live], np.int32)
    d, h, r = _sizing_reduce_vmapped(amat, wmat, nvec, jnp.asarray(grid),
                                     kind=kind, chunk=chunk)
    demands[live] = np.asarray(d, np.int64)
    hits[live] = np.asarray(h, np.int64)
    reads[live] = np.asarray(r, np.int64)
    return demands, hits, reads
