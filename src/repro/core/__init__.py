"""ETICA core: the paper's contribution as composable JAX modules.

Layering (bottom-up):

  * :mod:`~repro.core.policies`  — write-policy semantics + device model.
  * :mod:`~repro.core.trace`     — block-I/O trace pytrees.
  * :mod:`~repro.core.reuse`     — TRD / URD / POD reuse-distance engine
    and analytic miss-ratio curves (the TPU kernel's oracle).
  * :mod:`~repro.core.popularity`— Eq. 1 popularity scoring.
  * :mod:`~repro.core.partition` — PPC (Eq. 3) cache-space partitioning.
  * :mod:`~repro.core.simulator` — exact set-associative datapath sims
    (single-level + ETICA two-level) under ``lax.scan``.
  * :mod:`~repro.core.controller`— interval-driven controllers (ETICA and
    the shared one-level baseline chassis).
  * :mod:`~repro.core.baselines` — ECI-Cache, Centaur, S-CAVE, vCacheShare.
"""
from .policies import LEVEL_LATENCY, Level, Policy, T_DRAM, T_HDD, T_SSD
from .trace import Trace, interleave, pad_batch, split_by_vm
from .reuse import (DistResult, demand_blocks, hit_counts_at_sizes,
                    hit_counts_at_sizes_weighted, mrc, pod, pod_distances,
                    trd, trd_distances, urd, urd_distances)
from .popularity import (PopularityTable, PopularityTracker, block_scores,
                         contributions, table_init, table_least_popular,
                         table_len, table_scores, table_top_known,
                         table_update)
from .partition import PartitionResult, partition
from .simulator import (CacheState, PolicyFlags, Stats,
                        aggregate_stats_sharded, capacity_to_ways,
                        evict_blocks, make_cache, make_cache_batch,
                        policy_flags, promote_blocks, resize, resize_batch,
                        resize_batch_sharded, resize_levels,
                        resize_levels_sharded, simulate_single_level,
                        simulate_single_level_batch,
                        simulate_single_level_classified,
                        simulate_single_level_classified_batch,
                        simulate_single_level_sharded,
                        simulate_two_level, simulate_two_level_batch,
                        simulate_two_level_classified,
                        simulate_two_level_classified_batch,
                        simulate_two_level_sharded, stack_states,
                        unstack_states)
from .controller import (EticaCache, EticaConfig, Geometry, IntervalLog,
                         PartitionedSingleLevelCache, PolicyChooser,
                         SingleLevelConfig, VMResult)
from .baselines import (SizingMetric, make_centaur, make_eci_cache,
                        make_scave, make_vcacheshare, reuse_intensity_metric,
                        reuse_intensity_metric_ref, trd_metric,
                        trd_metric_ref, urd_metric, urd_metric_ref,
                        wss_metric, wss_metric_ref)

__all__ = [
    "LEVEL_LATENCY", "Level", "Policy", "T_DRAM", "T_HDD", "T_SSD",
    "Trace", "interleave", "pad_batch", "split_by_vm",
    "DistResult", "demand_blocks", "hit_counts_at_sizes",
    "hit_counts_at_sizes_weighted", "mrc", "pod",
    "pod_distances", "trd", "trd_distances", "urd", "urd_distances",
    "PopularityTable", "PopularityTracker", "block_scores", "contributions",
    "table_init", "table_least_popular", "table_len", "table_scores",
    "table_top_known", "table_update",
    "PartitionResult", "partition",
    "CacheState", "PolicyFlags", "Stats", "aggregate_stats_sharded",
    "capacity_to_ways",
    "evict_blocks", "make_cache", "make_cache_batch", "policy_flags",
    "promote_blocks", "resize", "resize_batch", "resize_batch_sharded",
    "resize_levels", "resize_levels_sharded",
    "simulate_single_level", "simulate_single_level_batch",
    "simulate_single_level_classified",
    "simulate_single_level_classified_batch",
    "simulate_single_level_sharded",
    "simulate_two_level", "simulate_two_level_batch",
    "simulate_two_level_classified", "simulate_two_level_classified_batch",
    "simulate_two_level_sharded",
    "stack_states", "unstack_states",
    "EticaCache", "EticaConfig", "Geometry", "IntervalLog",
    "PartitionedSingleLevelCache", "PolicyChooser", "SingleLevelConfig",
    "VMResult",
    "SizingMetric", "make_centaur", "make_eci_cache", "make_scave",
    "make_vcacheshare", "reuse_intensity_metric",
    "reuse_intensity_metric_ref", "trd_metric", "trd_metric_ref",
    "urd_metric", "urd_metric_ref", "wss_metric", "wss_metric_ref",
]
