"""Popularity detection (paper §4.2.1, Eq. 1).

    popularity(B_i) = sum_t exp(-POD(i, t) / cacheSize)

Per-access contributions are computed in JAX (``contributions`` is what
``repro.kernels.popularity`` fuses on TPU). The running per-block scores
exist in two bit-identical forms, per the repo's batched-vs-sequential
convention:

  * :class:`PopularityTable` — ONE device-resident ``[V, K]`` jnp table
    for all VMs, whose :func:`table_update` / :func:`table_least_popular`
    / :func:`table_top_known` are batched jitted ops. This is what the
    batched controller's fused maintenance dispatch
    (``repro.kernels.maintenance.ops.maintenance_interval``) consumes —
    popularity refresh and queue building never leave the accelerator.
  * :class:`PopularityTracker` — the original host-side sorted-numpy
    table, kept as the sequential reference oracle (``batched=False``).

Both accumulate in **float32 with identical operation order** (decay
multiply, per-window per-block left-to-right contribution sums, then one
table+score add), so on CPU the device table reproduces the tracker bit
for bit — ties in the promotion/eviction orderings break identically.
Cold accesses (no finite POD) contribute 0 — a block becomes popular
only through re-references, which encodes both temporal locality (small
POD) and frequency (the sum over accesses).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# sentinel for an empty table slot; sorts after every real block address
# (block addresses are int32 and < 2**30 by the trace-store contract)
TABLE_EMPTY = np.int32(2**31 - 1)


@jax.jit
def contributions(dist: jax.Array, served: jax.Array, cache_size) -> jax.Array:
    """Eq. 1 per-access popularity contribution.

    ``cache_size`` may be a scalar or any shape broadcastable against
    ``dist`` (e.g. ``[V, 1]`` per-VM sizes against ``[V, N]`` windows).
    """
    cs = jnp.maximum(jnp.asarray(cache_size, jnp.float32), 1.0)
    d = dist.astype(jnp.float32)
    return jnp.where(served & (dist >= 0), jnp.exp(-d / cs), 0.0)


def block_scores(addr: np.ndarray, contrib: np.ndarray):
    """Aggregate per-access contributions into per-block scores.

    float32 accumulation in access order — the same partial-sum order the
    device table's segment reduction uses, so both stay bit-identical.
    """
    addr = np.asarray(addr)
    uniq, inv = np.unique(addr, return_inverse=True)
    scores = np.zeros(uniq.shape[0], np.float32)
    np.add.at(scores, inv, np.asarray(contrib, np.float32))
    return uniq, scores


class PopularityTracker:
    """Running per-block popularity with exponential aging across windows.

    8 bytes/page in the paper; here a sorted (address, score) numpy table
    — the same asymptotic overhead, kept off the datapath, with every
    operation (aging, merge, lookup, top/bottom-k) vectorized instead of
    per-key dict loops. Scores are float32, accumulated in the same
    order as :class:`PopularityTable`, so the host tracker is the
    bit-exact sequential oracle of the device table.
    """

    def __init__(self, decay: float = 0.5):
        self.decay = np.float32(decay)
        self._addr = np.empty(0, np.int64)   # sorted block addresses
        self._val = np.empty(0, np.float32)  # scores, aligned with _addr

    def __len__(self) -> int:
        return int(self._addr.size)

    def update(self, addr: np.ndarray, contrib: np.ndarray) -> None:
        self._val *= self.decay
        uniq, scores = block_scores(addr, contrib)
        uniq = uniq.astype(np.int64)
        found = np.zeros(uniq.size, bool)
        if self._addr.size and uniq.size:
            pos = np.searchsorted(self._addr, uniq)
            in_range = pos < self._addr.size
            found[in_range] = self._addr[pos[in_range]] == uniq[in_range]
            self._val[pos[found]] += scores[found]
        if (~found).any():
            merged_a = np.concatenate([self._addr, uniq[~found]])
            merged_v = np.concatenate([self._val, scores[~found]])
            order = np.argsort(merged_a, kind="stable")
            self._addr, self._val = merged_a[order], merged_v[order]
        # drop negligible entries to bound memory (paper: 0.15% overhead)
        if self._addr.size > 1_000_000:
            thr = np.percentile(self._val, 10)
            keep = self._val > thr
            self._addr, self._val = self._addr[keep], self._val[keep]

    def score(self, addr: int) -> float:
        return float(self.scores_for(np.asarray([addr]))[0])

    def scores_for(self, addrs: np.ndarray) -> np.ndarray:
        addrs = np.asarray(addrs, np.int64)
        out = np.zeros(addrs.shape, np.float32)
        if self._addr.size and addrs.size:
            pos = np.searchsorted(self._addr, addrs)
            in_range = pos < self._addr.size
            hit = in_range.copy()
            hit[in_range] = self._addr[pos[in_range]] == addrs[in_range]
            out[hit] = self._val[pos[hit]]
        return out

    def most_popular(self, candidates: np.ndarray, frac: float,
                     limit: int | None = None) -> np.ndarray:
        """Top-``frac`` of ``candidates`` by popularity (promotion queue).
        ``limit`` widens the queue up to the free space available — the
        paper drains the promotion queue "only when there is free space
        in SSD", so a mostly-empty cache admits more than the 5% floor."""
        candidates = np.asarray(candidates)
        if candidates.size == 0:
            return candidates
        s = self.scores_for(candidates)
        k = max(int(np.ceil(np.float32(frac) * np.float32(candidates.size))),
                1)
        if limit is not None:
            k = min(max(k, limit), candidates.size)
        order = np.argsort(-s, kind="stable")
        top = order[:k]
        return candidates[top[s[top] > 0]]

    def top_known(self, exclude: np.ndarray, limit: int) -> np.ndarray:
        """Highest-scored blocks the tracker knows about that are not in
        ``exclude`` — the paper's promotion queue draws from the full
        popularity table of disk-resident blocks, not only the current
        window's accesses."""
        if limit <= 0 or not self._addr.size:
            return np.empty(0, np.int64)
        cand = self._val > 0
        exclude = np.asarray(exclude)
        if exclude.size:
            cand &= ~np.isin(self._addr, exclude)
        addrs, vals = self._addr[cand], self._val[cand]
        # score desc, address desc on ties (the historical ordering)
        order = np.lexsort((-addrs, -vals))
        return addrs[order[:limit]]

    def least_popular(self, candidates: np.ndarray, frac: float) -> np.ndarray:
        """Bottom-``frac`` of ``candidates`` (eviction queue)."""
        candidates = np.asarray(candidates)
        if candidates.size == 0:
            return candidates
        s = self.scores_for(candidates)
        k = max(int(np.ceil(np.float32(frac) * np.float32(candidates.size))),
                1)
        order = np.argsort(s, kind="stable")
        return candidates[order[:k]]


# ---------------------------------------------------------------------------
# device-resident popularity: one [V, K] table, batched jitted ops
# ---------------------------------------------------------------------------

class PopularityTable(NamedTuple):
    """All VMs' popularity tables as one device-resident pytree.

    ``addr`` is int32 ``[V, K]``, sorted ascending per row with
    :data:`TABLE_EMPTY` marking free slots; ``val`` is float32 ``[V, K]``
    aligned with it. ``K`` (the per-VM capacity) is static; entries that
    a merge would push past slot ``K`` fall off the end (the analogue of
    the tracker's 1M-entry trim, kept branch-free so updates stay O(K)).
    Size ``K`` so each VM's distinct-block working set fits
    (:func:`table_len` reports per-row occupancy) and the table is a
    bit-exact device twin of :class:`PopularityTracker`.
    """

    addr: jax.Array  # int32  [V, K]
    val: jax.Array   # float32 [V, K]

    @property
    def capacity(self) -> int:
        return self.addr.shape[-1]


def table_init(num_vms: int, capacity: int) -> PopularityTable:
    return PopularityTable(
        addr=jnp.full((num_vms, capacity), TABLE_EMPTY, jnp.int32),
        val=jnp.zeros((num_vms, capacity), jnp.float32),
    )


@jax.jit
def table_len(table: PopularityTable) -> jax.Array:
    """Occupied entries per row (``[V]`` int32) — overflow telemetry."""
    return jnp.sum(table.addr != TABLE_EMPTY, axis=-1).astype(jnp.int32)


def _compact_runs(a: jax.Array, v: jax.Array):
    """Sum runs of equal sorted keys into their first slot.

    ``a`` must be sorted. Returns (addr, val) where each distinct key
    occupies one slot (its run head position in segment order) and the
    tail is ``TABLE_EMPTY`` — the scatter-add applies the run's values
    left to right, which is what keeps the float32 sums identical to the
    tracker's in-order ``np.add.at`` accumulation.
    """
    n = a.shape[0]
    head = jnp.concatenate([jnp.ones(1, bool), a[1:] != a[:-1]])
    seg = jnp.cumsum(head) - 1
    caddr = jnp.full(n, TABLE_EMPTY, jnp.int32).at[seg].set(a)
    cval = jnp.zeros(n, jnp.float32).at[seg].add(v)
    cval = jnp.where(caddr == TABLE_EMPTY, 0.0, cval)
    return caddr, cval


def _row_update(addr, val, waddr, contrib, n_valid, live, decay):
    """One row of :func:`table_update` (vmapped over VMs).

    Sort-free in ``K``: only the ``[N]`` window is sorted; the merge
    into the (already sorted) table is a rank computation — two
    ``searchsorted`` passes and unique-destination scatters — so one
    update costs O(N log N + K) instead of O((K+N) log (K+N)).
    """
    k = addr.shape[0]
    n = waddr.shape[0]
    addr0, val0 = addr, val          # untouched row for non-live VMs
    valid = jnp.arange(n, dtype=jnp.int32) < n_valid
    wa = jnp.where(valid, waddr.astype(jnp.int32), TABLE_EMPTY)
    wc = jnp.where(valid, contrib.astype(jnp.float32), 0.0)

    # per-window per-block sums, partials in access order (= tracker's
    # block_scores): stable sort groups a block's accesses in time order
    order = jnp.argsort(wa, stable=True)
    uaddr, uval = _compact_runs(wa[order], wc[order])

    val = val * jnp.float32(decay)

    # existing blocks: one combining add per block, table + score — the
    # tracker's `_val[pos] += scores` (ordering and rounding identical)
    pos = jnp.searchsorted(addr, uaddr)
    pos_c = jnp.minimum(pos, k - 1)
    found = (pos < k) & (addr[pos_c] == uaddr)
    val = val.at[jnp.where(found, pos_c, k)].add(
        jnp.where(found, uval, 0.0), mode="drop")

    # new blocks: merge by rank. new_sorted = the not-found window
    # uniques, compacted (still ascending); each table slot shifts right
    # by the number of new addresses before it, each new address lands
    # at its insertion point plus its own rank.
    newm = ~found & (uaddr != TABLE_EMPTY)
    newm_i = newm.astype(jnp.int32)
    rank_new = jnp.cumsum(newm_i) - newm_i
    new_sorted = jnp.full(n, TABLE_EMPTY, jnp.int32).at[
        jnp.where(newm, rank_new, n)].set(uaddr, mode="drop")
    new_val = jnp.zeros(n, jnp.float32).at[
        jnp.where(newm, rank_new, n)].set(uval, mode="drop")
    shift = jnp.searchsorted(new_sorted, addr)          # [K]
    dest_table = jnp.arange(k, dtype=jnp.int32) + shift
    dest_new = jnp.searchsorted(addr, new_sorted) + jnp.arange(
        n, dtype=jnp.int32)
    # destinations are disjoint and strictly increasing per stream; any
    # entry pushed past K falls off the end — counted below as the row's
    # merge-overflow drops (surfaced via Stats.pop_drops)
    out_addr = jnp.full(k, TABLE_EMPTY, jnp.int32)
    out_val = jnp.zeros(k, jnp.float32)
    out_addr = out_addr.at[dest_table].set(addr, mode="drop")
    out_val = out_val.at[dest_table].set(val, mode="drop")
    keep_new = new_sorted != TABLE_EMPTY
    out_addr = out_addr.at[jnp.where(keep_new, dest_new, k)].set(
        new_sorted, mode="drop")
    out_val = out_val.at[jnp.where(keep_new, dest_new, k)].set(
        new_val, mode="drop")
    drops = (jnp.sum((addr != TABLE_EMPTY) & (dest_table >= k))
             + jnp.sum(keep_new & (dest_new >= k))).astype(jnp.int32)
    return (jnp.where(live, out_addr, addr0),
            jnp.where(live, out_val, val0),
            jnp.where(live, drops, 0))


@jax.jit
def table_update(table: PopularityTable, waddr, contrib, n_valid,
                 live, decay):
    """Merge one window of Eq. 1 contributions into every VM's table.

    ``waddr``/``contrib`` are ``[V, N]`` (entries at positions >=
    ``n_valid[v]`` are padding and ignored); ``live`` is a ``[V]`` bool —
    rows with ``live=False`` are untouched (no decay), exactly like the
    sequential path skipping a VM with an empty window. Bit-identical to
    calling :meth:`PopularityTracker.update` per live VM.

    Returns ``(table, drops)`` where ``drops`` is the ``[V]`` int32 count
    of entries pushed past the row's ``K`` slots by this merge (the
    previously-silent overflow, surfaced as ``Stats.pop_drops``).
    """
    addr, val, drops = jax.vmap(
        _row_update, in_axes=(0, 0, 0, 0, 0, 0, None)
    )(table.addr, table.val, waddr, contrib,
      jnp.asarray(n_valid, jnp.int32), jnp.asarray(live, bool),
      jnp.float32(decay))
    return PopularityTable(addr, val), drops


def _row_scores(addr_row, val_row, queries):
    """Table lookup for one row: score of each query address (0 if absent)."""
    k = addr_row.shape[0]
    pos = jnp.searchsorted(addr_row, queries)
    pos_c = jnp.minimum(pos, k - 1)
    hit = (pos < k) & (addr_row[pos_c] == queries)
    return jnp.where(hit, val_row[pos_c], 0.0)


@jax.jit
def table_scores(table: PopularityTable, addrs) -> jax.Array:
    """``[V, M]`` scores for ``[V, M]`` query addresses (0 when unknown)."""
    return jax.vmap(_row_scores)(table.addr, table.val,
                                 jnp.asarray(addrs, jnp.int32))


def _row_least_popular(addr_row, val_row, tags, ways, alloc, live, frac):
    """Eviction queue for one VM (vmapped): the bottom-``frac`` of the
    resident blocks, only when the partition is >= 90% full."""
    s, w = tags.shape
    flat = tags.reshape(s * w)
    validc = (jnp.broadcast_to(jnp.arange(w, dtype=jnp.int32),
                               (s, w)).reshape(s * w) < ways) & (flat >= 0)
    n_res = jnp.sum(validc, dtype=jnp.int32)
    # near-full gate, exact in integers (both controller paths use this)
    do = live & (n_res > 0) & (n_res * 10 >= alloc * 9)
    scores = _row_scores(addr_row, val_row, flat)
    order = jnp.argsort(jnp.where(validc, scores, jnp.inf), stable=True)
    k = jnp.maximum(
        jnp.ceil(jnp.float32(frac) * n_res.astype(jnp.float32)), 1.0
    ).astype(jnp.int32)
    take = do & (jnp.arange(s * w, dtype=jnp.int32) < k)
    return jnp.where(take, flat[order], -1), jnp.where(do, k, 0)


@jax.jit
def table_least_popular(table: PopularityTable, tags, ways, alloc,
                        live, frac):
    """Batched eviction queues: ``( [V, S*W] queue, [V] queue length )``.

    ``tags`` is the stacked ``[V, S, W]`` SSD tag array; candidates are
    the resident blocks of the first ``ways[v]`` ways, in ``(set, way)``
    scan order — the order :func:`repro.core.simulator.resident_blocks`
    yields, so stable ties break exactly like the tracker path. Queue
    entries beyond the per-VM length are ``-1`` no-ops.
    """
    return jax.vmap(
        _row_least_popular, in_axes=(0, 0, 0, 0, 0, 0, None)
    )(table.addr, table.val, tags, jnp.asarray(ways, jnp.int32),
      jnp.asarray(alloc, jnp.int32), jnp.asarray(live, bool),
      jnp.float32(frac))


def _row_top_known(addr_row, val_row, tags, ways, limit, live, width):
    """Promotion queue for one VM (vmapped): the highest-scored known
    blocks without an SSD copy, best first, up to ``limit`` entries."""
    k = addr_row.shape[0]
    s, w = tags.shape
    # residency = membership in the sorted resident set (binary search;
    # exactly the tracker's `isin(residents)` exclusion)
    flat = tags.reshape(s * w)
    activef = (jnp.broadcast_to(jnp.arange(w, dtype=jnp.int32),
                                (s, w)).reshape(s * w) < ways) & (flat >= 0)
    res_sorted = jnp.sort(jnp.where(activef, flat, TABLE_EMPTY))
    rpos = jnp.minimum(jnp.searchsorted(res_sorted, addr_row), s * w - 1)
    resident = res_sorted[rpos] == addr_row
    cand = (val_row > 0) & (addr_row != TABLE_EMPTY) & ~resident
    # lexsort((-addr, -val)) via top_k on the REVERSED row: top_k breaks
    # value ties toward the lower index, which after the reversal is the
    # higher address — the tracker's exact tie order. Only the top
    # `width` can ever be drained (limit <= S*W), so no full-K sort.
    key = jnp.where(cand, val_row, -jnp.inf)[::-1]
    topv, topi = jax.lax.top_k(key, min(width, k))
    qa = addr_row[::-1][topi]
    take = ((topv > -jnp.inf) & live
            & (jnp.arange(topv.shape[0], dtype=jnp.int32) < limit))
    queue = jnp.where(take, qa, -1)
    if width > k:
        queue = jnp.concatenate(
            [queue, jnp.full(width - k, -1, jnp.int32)])
    return queue, jnp.sum(take, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("width",))
def table_top_known(table: PopularityTable, tags, ways, limit, live,
                    width: int | None = None):
    """Batched promotion queues: ``( [V, width] queue, [V] length )``.

    Per VM: table entries with positive score and no copy in the first
    ``ways[v]`` ways of ``tags``, ordered by (score desc, address desc)
    — :meth:`PopularityTracker.top_known`'s exact ordering (residency
    via binary search over the sorted resident set, the tracker's
    ``isin(residents)`` exclusion) — truncated to ``limit[v]`` entries,
    ``-1``-padded. ``width`` (static, default the table capacity) bounds
    the queue; callers must keep ``limit <= width``.
    """
    width = table.capacity if width is None else width
    return jax.vmap(
        functools.partial(_row_top_known, width=width)
    )(table.addr, table.val, tags, jnp.asarray(ways, jnp.int32),
      jnp.asarray(limit, jnp.int32), jnp.asarray(live, bool))


@functools.partial(jax.jit, static_argnames=("width",))
def truncate_queue(queue: jax.Array, width: int) -> jax.Array:
    """Static truncation/padding of a ``[V, Q]`` queue to ``width``."""
    v, q = queue.shape
    if q >= width:
        return queue[:, :width]
    return jnp.concatenate(
        [queue, jnp.full((v, width - q), -1, queue.dtype)], axis=1)
