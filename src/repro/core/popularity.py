"""Popularity detection (paper §4.2.1, Eq. 1).

    popularity(B_i) = sum_t exp(-POD(i, t) / cacheSize)

Per-access contributions are computed in JAX (``contributions`` is what
``repro.kernels.popularity`` fuses on TPU); the running per-block scores
live in a host-side tracker updated asynchronously at maintenance points,
exactly as the paper computes popularity off the I/O path. Cold accesses
(no finite POD) contribute 0 — a block becomes popular only through
re-references, which encodes both temporal locality (small POD) and
frequency (the sum over accesses).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def contributions(dist: jax.Array, served: jax.Array, cache_size) -> jax.Array:
    """Eq. 1 per-access popularity contribution."""
    cs = jnp.maximum(jnp.float32(cache_size), 1.0)
    d = dist.astype(jnp.float32)
    return jnp.where(served & (dist >= 0), jnp.exp(-d / cs), 0.0)


def block_scores(addr: np.ndarray, contrib: np.ndarray):
    """Aggregate per-access contributions into per-block scores."""
    addr = np.asarray(addr)
    uniq, inv = np.unique(addr, return_inverse=True)
    scores = np.zeros(uniq.shape[0], np.float64)
    np.add.at(scores, inv, np.asarray(contrib, np.float64))
    return uniq, scores


class PopularityTracker:
    """Running per-block popularity with exponential aging across windows.

    8 bytes/page in the paper; here a host dict keyed by block address —
    the same asymptotic overhead, kept off the datapath.
    """

    def __init__(self, decay: float = 0.5):
        self.decay = float(decay)
        self._scores: dict[int, float] = {}

    def update(self, addr: np.ndarray, contrib: np.ndarray) -> None:
        for k in list(self._scores):
            self._scores[k] *= self.decay
        uniq, scores = block_scores(addr, contrib)
        for a, s in zip(uniq.tolist(), scores.tolist()):
            self._scores[a] = self._scores.get(a, 0.0) + s
        # drop negligible entries to bound memory (paper: 0.15% overhead)
        if len(self._scores) > 1_000_000:
            thr = np.percentile(list(self._scores.values()), 10)
            self._scores = {k: v for k, v in self._scores.items() if v > thr}

    def score(self, addr: int) -> float:
        return self._scores.get(int(addr), 0.0)

    def scores_for(self, addrs: np.ndarray) -> np.ndarray:
        return np.array([self._scores.get(int(a), 0.0) for a in np.asarray(addrs)])

    def most_popular(self, candidates: np.ndarray, frac: float,
                     limit: int | None = None) -> np.ndarray:
        """Top-``frac`` of ``candidates`` by popularity (promotion queue).
        ``limit`` widens the queue up to the free space available — the
        paper drains the promotion queue "only when there is free space
        in SSD", so a mostly-empty cache admits more than the 5% floor."""
        candidates = np.asarray(candidates)
        if candidates.size == 0:
            return candidates
        s = self.scores_for(candidates)
        k = max(int(np.ceil(frac * candidates.size)), 1)
        if limit is not None:
            k = min(max(k, limit), candidates.size)
        order = np.argsort(-s, kind="stable")
        top = order[:k]
        return candidates[top[s[top] > 0]]

    def top_known(self, exclude: np.ndarray, limit: int) -> np.ndarray:
        """Highest-scored blocks the tracker knows about that are not in
        ``exclude`` — the paper's promotion queue draws from the full
        popularity table of disk-resident blocks, not only the current
        window's accesses."""
        if limit <= 0 or not self._scores:
            return np.empty(0, np.int64)
        excl = set(int(a) for a in np.asarray(exclude))
        items = [(s, a) for a, s in self._scores.items()
                 if s > 0 and a not in excl]
        items.sort(reverse=True)
        return np.array([a for _, a in items[:limit]], np.int64)

    def least_popular(self, candidates: np.ndarray, frac: float) -> np.ndarray:
        """Bottom-``frac`` of ``candidates`` (eviction queue)."""
        candidates = np.asarray(candidates)
        if candidates.size == 0:
            return candidates
        s = self.scores_for(candidates)
        k = max(int(np.ceil(frac * candidates.size)), 1)
        order = np.argsort(s, kind="stable")
        return candidates[order[:k]]
