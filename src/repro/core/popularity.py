"""Popularity detection (paper §4.2.1, Eq. 1).

    popularity(B_i) = sum_t exp(-POD(i, t) / cacheSize)

Per-access contributions are computed in JAX (``contributions`` is what
``repro.kernels.popularity`` fuses on TPU); the running per-block scores
live in a host-side tracker updated asynchronously at maintenance points,
exactly as the paper computes popularity off the I/O path. Cold accesses
(no finite POD) contribute 0 — a block becomes popular only through
re-references, which encodes both temporal locality (small POD) and
frequency (the sum over accesses).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def contributions(dist: jax.Array, served: jax.Array, cache_size) -> jax.Array:
    """Eq. 1 per-access popularity contribution.

    ``cache_size`` may be a scalar or any shape broadcastable against
    ``dist`` (e.g. ``[V, 1]`` per-VM sizes against ``[V, N]`` windows).
    """
    cs = jnp.maximum(jnp.asarray(cache_size, jnp.float32), 1.0)
    d = dist.astype(jnp.float32)
    return jnp.where(served & (dist >= 0), jnp.exp(-d / cs), 0.0)


def block_scores(addr: np.ndarray, contrib: np.ndarray):
    """Aggregate per-access contributions into per-block scores."""
    addr = np.asarray(addr)
    uniq, inv = np.unique(addr, return_inverse=True)
    scores = np.zeros(uniq.shape[0], np.float64)
    np.add.at(scores, inv, np.asarray(contrib, np.float64))
    return uniq, scores


class PopularityTracker:
    """Running per-block popularity with exponential aging across windows.

    8 bytes/page in the paper; here a sorted (address, score) numpy table
    — the same asymptotic overhead, kept off the datapath, with every
    operation (aging, merge, lookup, top/bottom-k) vectorized instead of
    per-key dict loops.
    """

    def __init__(self, decay: float = 0.5):
        self.decay = float(decay)
        self._addr = np.empty(0, np.int64)   # sorted block addresses
        self._val = np.empty(0, np.float64)  # scores, aligned with _addr

    def __len__(self) -> int:
        return int(self._addr.size)

    def update(self, addr: np.ndarray, contrib: np.ndarray) -> None:
        self._val *= self.decay
        uniq, scores = block_scores(addr, contrib)
        uniq = uniq.astype(np.int64)
        found = np.zeros(uniq.size, bool)
        if self._addr.size and uniq.size:
            pos = np.searchsorted(self._addr, uniq)
            in_range = pos < self._addr.size
            found[in_range] = self._addr[pos[in_range]] == uniq[in_range]
            self._val[pos[found]] += scores[found]
        if (~found).any():
            merged_a = np.concatenate([self._addr, uniq[~found]])
            merged_v = np.concatenate([self._val, scores[~found]])
            order = np.argsort(merged_a, kind="stable")
            self._addr, self._val = merged_a[order], merged_v[order]
        # drop negligible entries to bound memory (paper: 0.15% overhead)
        if self._addr.size > 1_000_000:
            thr = np.percentile(self._val, 10)
            keep = self._val > thr
            self._addr, self._val = self._addr[keep], self._val[keep]

    def score(self, addr: int) -> float:
        return float(self.scores_for(np.asarray([addr]))[0])

    def scores_for(self, addrs: np.ndarray) -> np.ndarray:
        addrs = np.asarray(addrs, np.int64)
        out = np.zeros(addrs.shape, np.float64)
        if self._addr.size and addrs.size:
            pos = np.searchsorted(self._addr, addrs)
            in_range = pos < self._addr.size
            hit = in_range.copy()
            hit[in_range] = self._addr[pos[in_range]] == addrs[in_range]
            out[hit] = self._val[pos[hit]]
        return out

    def most_popular(self, candidates: np.ndarray, frac: float,
                     limit: int | None = None) -> np.ndarray:
        """Top-``frac`` of ``candidates`` by popularity (promotion queue).
        ``limit`` widens the queue up to the free space available — the
        paper drains the promotion queue "only when there is free space
        in SSD", so a mostly-empty cache admits more than the 5% floor."""
        candidates = np.asarray(candidates)
        if candidates.size == 0:
            return candidates
        s = self.scores_for(candidates)
        k = max(int(np.ceil(frac * candidates.size)), 1)
        if limit is not None:
            k = min(max(k, limit), candidates.size)
        order = np.argsort(-s, kind="stable")
        top = order[:k]
        return candidates[top[s[top] > 0]]

    def top_known(self, exclude: np.ndarray, limit: int) -> np.ndarray:
        """Highest-scored blocks the tracker knows about that are not in
        ``exclude`` — the paper's promotion queue draws from the full
        popularity table of disk-resident blocks, not only the current
        window's accesses."""
        if limit <= 0 or not self._addr.size:
            return np.empty(0, np.int64)
        cand = self._val > 0
        exclude = np.asarray(exclude)
        if exclude.size:
            cand &= ~np.isin(self._addr, exclude)
        addrs, vals = self._addr[cand], self._val[cand]
        # score desc, address desc on ties (the historical ordering)
        order = np.lexsort((-addrs, -vals))
        return addrs[order[:limit]]

    def least_popular(self, candidates: np.ndarray, frac: float) -> np.ndarray:
        """Bottom-``frac`` of ``candidates`` (eviction queue)."""
        candidates = np.asarray(candidates)
        if candidates.size == 0:
            return candidates
        s = self.scores_for(candidates)
        k = max(int(np.ceil(frac * candidates.size)), 1)
        order = np.argsort(s, kind="stable")
        return candidates[order[:k]]
