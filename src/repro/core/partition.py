"""Cache-space partitioning across VMs (paper §4.3.2).

Default allocation is each VM's demand (max POD + 1 blocks). When the
summed demand exceeds physical capacity, sizes are reduced to maximize

    PPC = sum_i H(VM_i, c_i) / c_i            (paper Eq. 3)

subject to ``sum_i c_i <= C`` and ``c_i <= demand_i``. Because miss-ratio
curves are steppy, the PPC optimum parks each VM at its best knee; any
leftover capacity is then waterfilled by marginal hit gain (this is the
"ETICA increases the allocated cache to VM0 since other VMs' demand is
low" behavior of paper Fig. 15).

The knapsack DP is exact over a discretized size grid (grid must include
0 so a VM can be given no cache). The grid unit defaults to the smallest
nonzero grid step so every size maps to whole cache ways.
"""
from __future__ import annotations

import dataclasses

import numpy as np

NEG = -1e30


def size_grid(capacity: int, points: int = 16) -> np.ndarray:
    """Ascending candidate-size grid ``0..capacity`` INCLUSIVE.

    ``np.arange(0, capacity + 1, step)`` silently drops the ``capacity``
    endpoint whenever ``capacity % step != 0``, which forbids the
    partitioner from ever granting a tenant the whole pool; this helper
    always appends the endpoint. ``points`` bounds the grid resolution
    (``step = max(capacity // points, 1)``).
    """
    capacity = int(capacity)
    step = max(capacity // max(points, 1), 1)
    grid = np.arange(0, capacity + 1, step, dtype=np.int64)
    if grid.size == 0 or grid[-1] != capacity:
        grid = np.append(grid, np.int64(capacity))
    return grid


@dataclasses.dataclass
class PartitionResult:
    alloc: np.ndarray       # int64 [V] blocks
    ppc: float              # achieved PPC objective (nan when unsaturated)
    saturated: bool         # demand exceeded capacity


def partition(demands: np.ndarray, hit_curves: np.ndarray, sizes: np.ndarray,
              capacity: int, unit: int | None = None) -> PartitionResult:
    """Allocate ``capacity`` blocks across VMs.

    Args:
      demands:    [V] demand (max POD + 1) per VM, blocks.
      hit_curves: [V, G] hit ratio of each VM at each grid size.
      sizes:      [G] ascending grid of candidate sizes (blocks), incl. 0.
      capacity:   total blocks available at this cache level.
      unit:       DP quantization (default: smallest nonzero grid step).
    """
    demands = np.asarray(demands, np.int64)
    sizes = np.asarray(sizes, np.int64)
    V, G = hit_curves.shape
    assert sizes.shape == (G,)

    if demands.sum() <= capacity:
        return PartitionResult(demands.copy(), float("nan"), False)

    if unit is None:
        steps = np.diff(np.unique(sizes))
        unit = int(steps.min()) if steps.size else 1
    cap_u = int(capacity // unit)
    size_u = (sizes // unit).astype(np.int64)

    # PPC term per (vm, grid point); infeasible above demand; 0 at c=0.
    with np.errstate(divide="ignore", invalid="ignore"):
        ppc = np.where(sizes[None, :] > 0,
                       hit_curves / np.maximum(sizes, 1)[None, :], 0.0)
    ppc = np.where(sizes[None, :] <= np.maximum(demands, 0)[:, None], ppc, NEG)
    ppc[:, sizes == 0] = 0.0

    # layered knapsack DP: layers[v][c] = best PPC of first v VMs using
    # exactly c units (0-size option keeps every layer reachable).
    layer = np.full(cap_u + 1, NEG)
    layer[0] = 0.0
    layers = [layer]
    for v in range(V):
        nxt = np.full(cap_u + 1, NEG)
        for g in range(G):
            s = int(size_u[g])
            if s > cap_u or ppc[v, g] <= NEG / 2:
                continue
            cand = np.full(cap_u + 1, NEG)
            cand[s:] = layers[-1][: cap_u + 1 - s] + ppc[v, g]
            nxt = np.maximum(nxt, cand)
        layers.append(nxt)

    # backtrack from the best final budget
    c = int(np.argmax(layers[-1]))
    best = layers[-1][c]
    alloc = np.zeros(V, np.int64)
    for v in range(V - 1, -1, -1):
        for g in range(G):
            s = int(size_u[g])
            if s > c or ppc[v, g] <= NEG / 2:
                continue
            prev = layers[v][c - s]
            if prev > NEG / 2 and abs(prev + ppc[v, g] - best) <= 1e-12 + 1e-9 * abs(best):
                alloc[v] = sizes[g]
                c -= s
                best = prev
                break

    # waterfill leftover capacity by marginal hit gain per block
    left = capacity - int(alloc.sum())
    if left > 0:
        alloc = _waterfill(alloc, demands, hit_curves, sizes, left, unit)

    return PartitionResult(alloc, _ppc_value(alloc, hit_curves, sizes), True)


def _interp_hit(hit_curve: np.ndarray, sizes: np.ndarray, c: float) -> float:
    return float(np.interp(c, sizes, hit_curve))


def _ppc_value(alloc, hit_curves, sizes) -> float:
    v = 0.0
    for i, c in enumerate(alloc):
        if c > 0:
            v += _interp_hit(hit_curves[i], sizes, c) / c
    return v


def _waterfill(alloc, demands, hit_curves, sizes, left, unit):
    alloc = alloc.copy()
    while left >= unit:
        gains = np.full(len(alloc), -np.inf)
        for i in range(len(alloc)):
            if alloc[i] + unit > demands[i]:
                continue
            h0 = _interp_hit(hit_curves[i], sizes, alloc[i])
            h1 = _interp_hit(hit_curves[i], sizes, alloc[i] + unit)
            gains[i] = h1 - h0
        best = int(np.argmax(gains))
        if not np.isfinite(gains[best]) or gains[best] <= 0:
            # no VM benefits; still spread capacity up to demand
            under = np.nonzero(alloc < demands)[0]
            if under.size == 0:
                break
            best = int(under[np.argmax(demands[under] - alloc[under])])
        alloc[best] += unit
        left -= unit
    return alloc
