"""Block-I/O trace representation.

A trace is a pair of equal-length arrays: block addresses and a write flag.
Multi-VM traces additionally carry a ``vm`` id per request. Everything is a
plain pytree of arrays so traces flow through ``jax.jit``/``lax.scan``
unchanged; host-side code uses the same container with numpy arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Trace:
    addr: np.ndarray        # int32 [N] block addresses
    is_write: np.ndarray    # bool  [N]
    vm: np.ndarray | None = None  # int32 [N] (optional)
    size: np.ndarray | None = None  # int32 [N] request size in blocks
                                    # (optional; absent means 1 block each)

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.addr, self.is_write, self.vm, self.size), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- conveniences ------------------------------------------------------
    def __len__(self) -> int:
        return int(np.shape(self.addr)[0])

    def __getitem__(self, sl) -> "Trace":
        return Trace(
            addr=self.addr[sl],
            is_write=self.is_write[sl],
            vm=None if self.vm is None else self.vm[sl],
            size=None if self.size is None else self.size[sl],
        )

    def sizes(self) -> np.ndarray:
        """Request sizes in blocks; all-ones when no size channel."""
        if self.size is None:
            return np.ones(len(self), np.int32)
        return np.asarray(self.size, np.int32)

    @property
    def n_reads(self) -> int:
        return int(np.sum(~np.asarray(self.is_write)))

    @property
    def n_writes(self) -> int:
        return int(np.sum(np.asarray(self.is_write)))

    def for_vm(self, vm_id: int) -> "Trace":
        """Reference per-VM demux: one boolean-mask scan per VM. The
        controllers use :func:`split_by_vm` (one stable sort for all VMs,
        bit-identical to calling this per VM); this stays as its oracle."""
        assert self.vm is not None
        m = np.asarray(self.vm) == vm_id
        return Trace(np.asarray(self.addr)[m], np.asarray(self.is_write)[m],
                     size=None if self.size is None
                     else np.asarray(self.size)[m])

    def intervals(self, interval: int) -> Iterator["Trace"]:
        """Yield consecutive fixed-size request windows (paper: 10k reqs)."""
        for start in range(0, len(self), interval):
            yield self[start : start + interval]

    @staticmethod
    def concat(traces: list["Trace"]) -> "Trace":
        vm = None
        if all(t.vm is not None for t in traces):
            vm = np.concatenate([np.asarray(t.vm) for t in traces])
        size = None
        if any(t.size is not None for t in traces):
            size = np.concatenate([t.sizes() for t in traces])
        return Trace(
            addr=np.concatenate([np.asarray(t.addr) for t in traces]),
            is_write=np.concatenate([np.asarray(t.is_write) for t in traces]),
            vm=vm,
            size=size,
        )

    @staticmethod
    def from_ops(ops: list[tuple[str, int]]) -> "Trace":
        """Build a trace from [('R', sector), ('W', sector), ...] tuples.

        Used by the unit tests to transcribe the paper's worked examples
        (Figs. 5, 8, 9) verbatim.
        """
        addr = np.array([a for _, a in ops], dtype=np.int32)
        is_write = np.array([op.upper() == "W" for op, _ in ops], dtype=bool)
        return Trace(addr=addr, is_write=is_write)


def split_by_vm(window: Trace, num_vms: int) -> list[Trace]:
    """Demux a multi-VM window into per-VM sub-traces with ONE stable sort.

    Replaces ``[window.for_vm(v) for v in range(num_vms)]`` — which scans
    the window with a fresh boolean mask per VM (O(V·N)) — with a single
    ``np.argsort(vm, kind="stable")`` (O(N log N)): stable sort groups
    requests by VM while preserving each VM's arrival order, so every
    sub-trace is bit-identical to the mask-based reference
    (:meth:`Trace.for_vm`). Windows without a ``vm`` channel keep the
    single-trace-shared-by-all-VMs convention the controllers use.
    """
    if window.vm is None:
        return [window] * num_vms
    vm = np.asarray(window.vm)
    order = np.argsort(vm, kind="stable")
    addr = np.asarray(window.addr)[order]
    is_write = np.asarray(window.is_write)[order]
    size = None if window.size is None else np.asarray(window.size)[order]
    bounds = np.searchsorted(vm[order], np.arange(num_vms + 1))
    return [Trace(addr[bounds[v]:bounds[v + 1]],
                  is_write[bounds[v]:bounds[v + 1]],
                  size=None if size is None
                  else size[bounds[v]:bounds[v + 1]])
            for v in range(num_vms)]


def pad_batch(chunks: list[Trace | None], n: int):
    """Stack per-VM request chunks into rectangular ``[V, n]`` arrays,
    padding ragged tails (and VMs with no chunk) with ``addr = -1``
    no-ops — the shape contract of the batched datapath simulators."""
    v = len(chunks)
    addr = np.full((v, n), -1, np.int32)
    is_write = np.zeros((v, n), bool)
    for i, c in enumerate(chunks):
        if c is None or len(c) == 0:
            continue
        k = min(len(c), n)
        addr[i, :k] = np.asarray(c.addr, np.int32)[:k]
        is_write[i, :k] = np.asarray(c.is_write)[:k]
    return addr, is_write


def interleave(traces: list[Trace], seed: int = 0) -> Trace:
    """Randomly interleave per-VM traces into one multi-VM trace,
    preserving each VM's internal request order (hypervisor arrival order).
    """
    rng = np.random.default_rng(seed)
    lengths = [len(t) for t in traces]
    vm_stream = np.repeat(np.arange(len(traces)), lengths)
    rng.shuffle(vm_stream)
    cursors = [0] * len(traces)
    has_size = any(t.size is not None for t in traces)
    sizes = [t.sizes() for t in traces] if has_size else None
    addr = np.empty(sum(lengths), dtype=np.int32)
    is_write = np.empty(sum(lengths), dtype=bool)
    size = np.empty(sum(lengths), dtype=np.int32) if has_size else None
    for i, v in enumerate(vm_stream):
        t = traces[v]
        addr[i] = t.addr[cursors[v]]
        is_write[i] = t.is_write[cursors[v]]
        if has_size:
            size[i] = sizes[v][cursors[v]]
        cursors[v] += 1
    return Trace(addr=addr, is_write=is_write, vm=vm_stream.astype(np.int32),
                 size=size)
