"""Interval-driven cache controllers (the hypervisor-side brain).

:class:`EticaCache` is the paper's full system: every ``resize_interval``
requests it recomputes POD(RO)/POD(WBWO) per VM, re-partitions both cache
levels via PPC, and resizes the per-VM caches; every ``promo_interval``
requests it refreshes popularity scores and executes the
promotion/eviction queues (pull-mode SSD maintenance, §4.2).

:class:`PartitionedSingleLevelCache` is the shared chassis for the
one-level baselines (ECI-Cache, Centaur, S-CAVE, vCacheShare) — they
differ only in the sizing metric and the per-VM write-policy chooser (see
``repro.core.baselines``).

All datapath simulation happens in fixed-shape jitted ``lax.scan`` windows
(padded with addr = -1 no-ops). With ``batched=True`` (the default) the
per-VM cache states are stacked into one pytree with a leading ``[V]``
axis and each window simulates **all VMs in one vmapped dispatch**; POD
sizing and the one-level baselines' sizing metrics (URD/TRD/WSS/reuse
intensity via ``SizingMetric.batch``) batch across VMs the same way.
ETICA's promotion/eviction maintenance goes further: the whole interval
— Eq. 1 popularity refresh into a device-resident ``[V, K]`` table,
queue building, and the Pallas evict/promote scatters — is ONE fused
jitted dispatch with no host round-trips between stages
(``repro.kernels.maintenance``; ``fused_maintenance=False`` keeps the
staged tracker-based path as the intermediate oracle). Per-VM ways —
and, for the one-level chassis, per-VM write policies — are traced
operands, so heterogeneous allocations and ECI-style dynamic policies
share one compiled executable. ``batched=False`` preserves the
sequential per-VM architecture (separate per-VM states, V dispatches
per window, host-side numpy maintenance) as the bit-identical reference
oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np

from . import popularity as pop
from .partition import partition as _partition
from . import reuse, simulator
from .policies import Policy
from .simulator import (CacheState, Stats, capacity_to_ways, make_cache,
                        make_cache_batch, policy_flags, resize_batch)
from .trace import Trace


def _window_source(trace, num_vms: int, window: int, chunk: int,
                   prefetch: bool, prefetch_depth: int = 2,
                   pad_vms: int = 0, sharding=None):
    """Normalize ``run``'s input (Trace | TraceStore |
    StreamingTraceSource) into a resize-window iterator. Imported lazily
    so ``repro.core`` does not depend on ``repro.traces`` at import
    time."""
    from repro.traces.stream import window_source
    return window_source(trace, num_vms, window, chunk, prefetch,
                         prefetch_depth, pad_vms, sharding)


def _mesh_setup(mesh, num_vms: int, batched: bool, classifier):
    """Validate a controller's mesh config; returns ``(num_rows,
    sharding)`` — the dead-VM-padded row count the device state carries
    and the ``NamedSharding`` that places ``[V_pad, ...]`` arrays one row
    block per device. Dead rows (``ways = 0``, ``addr = -1`` blocks) are
    exact no-ops, so results stay bit-identical to the unpadded run."""
    if mesh is None:
        return num_vms, None
    if not batched:
        raise ValueError(
            "mesh sharding requires batched=True — the sequential "
            "per-VM oracle has no [V] axis to shard")
    if classifier is not None:
        raise ValueError(
            "mesh sharding does not support an IO classifier yet — the "
            "classified datapath dispatches have no sharded variants")
    from jax.sharding import NamedSharding

    from repro.launch.mesh import vm_spec
    d = mesh.size
    num_rows = -(-num_vms // d) * d
    return num_rows, NamedSharding(mesh, vm_spec(mesh))


@dataclasses.dataclass
class Geometry:
    num_sets: int = 64
    max_ways: int = 64

    @property
    def capacity(self) -> int:
        return self.num_sets * self.max_ways


@dataclasses.dataclass
class IntervalLog:
    """Per-interval record for the Fig. 10/15-style plots."""
    demands: np.ndarray          # [V] blocks requested by the metric
    alloc: np.ndarray            # [V] blocks granted
    policies: list[str] | None = None


@dataclasses.dataclass
class VMResult:
    stats: dict[str, float]
    alloc_history: np.ndarray    # [intervals]

    @property
    def hit_ratio(self) -> float:
        s = self.stats
        return (s["read_hits_l1"] + s["read_hits_l2"] + s["write_hits_l2"]) / max(
            s["reads"] + s["writes"], 1)

    @property
    def mean_latency(self) -> float:
        return self.stats["latency_sum"] / max(
            self.stats["reads"] + self.stats["writes"], 1)

    def contended_latency(self, beta: float = 8.0) -> float:
        """Mean latency under an SSD write-contention model.

        Sustained writes trigger SSD garbage collection that inflates the
        latency of *every* SSD access (well documented for NAND devices;
        the paper's own premise is that performance degrades with
        committed writes). Modeled as
        ``t_ssd_eff = T_SSD * (1 + beta * write_share)`` applied to all
        SSD accesses, with write_share = SSD writes / SSD accesses.
        This couples the endurance win to a latency win — the regime the
        paper's real-hardware numbers reflect."""
        from .policies import T_SSD
        s = self.stats
        ssd_accesses = (s["read_hits_l2"] + s["write_hits_l2"]
                        + s["cache_writes_l2"])
        if ssd_accesses <= 0:
            return self.mean_latency
        write_share = s["cache_writes_l2"] / ssd_accesses
        extra = ssd_accesses * T_SSD * beta * write_share
        return (s["latency_sum"] + extra) / max(
            s["reads"] + s["writes"], 1)

    @property
    def ssd_writes(self) -> float:
        return self.stats["cache_writes_l2"]


def _pad(addr: np.ndarray, is_write: np.ndarray, n: int):
    k = n - addr.shape[0]
    if k <= 0:
        return addr[:n], is_write[:n]
    return (np.concatenate([addr, np.full(k, -1, addr.dtype)]),
            np.concatenate([is_write, np.zeros(k, bool)]))


def _vm_slice(state: CacheState, v: int) -> CacheState:
    """View VM ``v``'s cache out of a stacked [V, S, W] state."""
    return jax.tree_util.tree_map(lambda x: x[v], state)


def _stats_to_dict(st: Stats) -> dict[str, float]:
    return {k: float(v) for k, v in zip(Stats._fields, st)}


def _acc(d: dict[str, float], st: Stats) -> None:
    for k, v in zip(Stats._fields, st):
        d[k] = d.get(k, 0.0) + float(v)


def _cls_chunk(cls_subs: list[np.ndarray], k: int, chunk: int) -> np.ndarray:
    """The ``[V, chunk]`` class-id block matching datapath chunk ``k``
    (padding positions are class 0 — masked no-ops either way)."""
    out = np.zeros((len(cls_subs), chunk), np.int32)
    for v, cs in enumerate(cls_subs):
        seg = cs[k * chunk:(k + 1) * chunk]
        out[v, :len(seg)] = seg
    return out


def _class_policy_flags(pol_vc: list[list[Policy]]) -> "simulator.PolicyFlags":
    """``[V, C]`` :class:`~repro.core.simulator.PolicyFlags` from per-
    (VM, class) policies (classifier override or the VM's own policy)."""
    f = lambda attr: np.asarray(
        [[getattr(p, attr) for p in row] for row in pol_vc], bool)
    return simulator.PolicyFlags(f("allocates_reads"), f("write_invalidates"),
                                 f("holds_dirty"), f("write_through"))


def _strip_bypass(chunks: list[Trace | None], cls_subs: list[np.ndarray],
                  k: int, chunk: int, byp: np.ndarray) -> list[Trace | None]:
    """Drop bypass-class requests from a maintenance chunk list: bypassed
    requests never touch the cache, so they must not feed popularity
    either. Chunks without bypassed requests pass through unchanged."""
    out = []
    for v, c in enumerate(chunks):
        if c is None or len(c) == 0:
            out.append(c)
            continue
        m = ~byp[cls_subs[v][k * chunk:(k + 1) * chunk]]
        out.append(c if m.all() else c[m])
    return out


def _mrc_grid(geom: Geometry, points: int = 17) -> np.ndarray:
    ways = np.unique(np.round(np.linspace(0, geom.max_ways, points)).astype(int))
    return (ways * geom.num_sets).astype(np.int64)


def _expand_to_capacity(alloc: np.ndarray, counts: np.ndarray,
                        capacity: int, geom: Geometry) -> np.ndarray:
    """Distribute surplus capacity beyond instantaneous demand.

    Paper Fig. 15/16: "ETICA increases the allocated cache to VM0, since
    other VMs' demand is low" — spare space goes to VMs in proportion to
    their request share (bounded by the per-VM geometry), so promotion
    has room to build each VM's popular set beyond the strict POD demand.
    """
    left = capacity - int(alloc.sum())
    if left <= 0 or counts.sum() == 0:
        return alloc
    share = counts / counts.sum()
    extra = np.floor(left * share).astype(np.int64)
    return np.minimum(alloc + extra, geom.capacity)


# ---------------------------------------------------------------------------
# ETICA (two-level)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EticaConfig:
    dram_capacity: int               # total DRAM-level blocks across VMs
    ssd_capacity: int                # total SSD-level blocks across VMs
    geometry_dram: Geometry = dataclasses.field(default_factory=Geometry)
    geometry_ssd: Geometry = dataclasses.field(default_factory=Geometry)
    resize_interval: int = 10_000    # paper §5.1
    promo_interval: int = 1_000      # paper §5.3
    promo_frac: float = 0.05         # paper §4.2.1: top/bottom 5%
    evict_frac: float = 0.05
    popularity_decay: float = 0.5
    mode: str = "full"               # "full" | "npe"
    mrc_points: int = 17
    batched: bool = True             # one vmapped dispatch for all VMs
    prefetch: bool = True            # pipeline host->device blocks
    prefetch_depth: int = 2          # blocks in flight beyond the consumed
    mesh: object | None = None       # launch.mesh.make_vm_mesh: shard the
    #                                  VM axis across devices (requires
    #                                  batched + fused_maintenance; VM
    #                                  count padded with dead VMs to a
    #                                  multiple of the mesh size)
    fused_maintenance: bool = True   # one fused jitted maintenance dispatch
    pop_capacity: int = 8192         # per-VM device popularity-table slots
    classifier: object | None = None  # repro.classify.Classifier | None
    clean_quota: int = 0             # background cleaner: max dirty-block
    #                                  flushes per VM per maintenance
    #                                  interval (0 disables the stage)
    telemetry: object | None = None  # repro.runtime.telemetry
    #                                  .TelemetryRecorder; None gets a
    #                                  default bounded recorder (same
    #                                  results either way — the recorder
    #                                  only reads already-fetched host
    #                                  values)


class EticaCache:
    """The proposed system: DRAM(RO) + SSD(WBWO), POD sizing, PPC
    partitioning, popularity-driven promotion/eviction.

    With ``cfg.batched`` the per-VM states live stacked in one
    ``[V, S, W]`` pytree (``self.dram`` / ``self.ssd``); without it they
    are lists of per-VM states. Use :meth:`vm_dram` / :meth:`vm_ssd` for a
    single VM's view in either layout.
    """

    def __init__(self, cfg: EticaConfig, num_vms: int):
        self.cfg = cfg
        self.num_vms = num_vms
        if cfg.mesh is not None and not cfg.fused_maintenance:
            raise ValueError(
                "EticaCache mesh sharding requires fused_maintenance=True "
                "— the staged maintenance path round-trips through host "
                "trackers and cannot stay shard-local")
        # device state carries V_pad rows when a mesh is configured; the
        # pad rows are dead VMs (ways 0, addr -1 blocks) that every
        # dispatch treats as exact no-ops. Host-side structures (stats,
        # logs, trackers) stay at the real VM count.
        self._rows, self._sharding = _mesh_setup(
            cfg.mesh, num_vms, cfg.batched, cfg.classifier)
        rows = self._rows
        gd, gs = cfg.geometry_dram, cfg.geometry_ssd
        if cfg.batched:
            self.dram = make_cache_batch(rows, gd.num_sets, gd.max_ways)
            self.ssd = make_cache_batch(rows, gs.num_sets, gs.max_ways)
        else:
            self.dram = [make_cache(gd.num_sets, gd.max_ways)
                         for _ in range(num_vms)]
            self.ssd = [make_cache(gs.num_sets, gs.max_ways)
                        for _ in range(num_vms)]
        self.ways_dram = np.zeros(rows, np.int32)
        self.ways_ssd = np.zeros(rows, np.int32)
        self.t = np.zeros(rows, np.int32)
        # popularity state: the fused batched path keeps ONE [V, K]
        # device-resident table; the staged/sequential paths use the
        # host trackers (the table's bit-exact oracle)
        self.pop_table = (pop.table_init(rows, cfg.pop_capacity)
                          if cfg.batched and cfg.fused_maintenance else None)
        self.trackers = [pop.PopularityTracker(cfg.popularity_decay)
                         for _ in range(num_vms)]
        self.stats = [dict() for _ in range(num_vms)]
        self.logs_dram: list[IntervalLog] = []
        self.logs_ssd: list[IntervalLog] = []
        # interval telemetry: one bounded journal row per promo-interval
        # chunk, fed exclusively from host values the interval already
        # fetched (zero extra device→host syncs). The maintenance temps
        # below carry this interval's promote/evict/clean counts from
        # the maintenance step to the sampler.
        if cfg.telemetry is not None:
            self.telemetry = cfg.telemetry
        else:
            from repro.runtime.telemetry import TelemetryRecorder
            self.telemetry = TelemetryRecorder()
        self._m_promoted = np.zeros(num_vms, np.int64)
        self._m_evicted = np.zeros(num_vms, np.int64)
        self._m_cleaned = np.zeros(num_vms, np.int64)
        self._m_dirty = np.zeros(num_vms, np.int64)
        self._m_clean_ran = False
        # IO classification (repro.classify): per-VM sequential-run carry
        # plus the per-class tables the classified simulators consume
        self.classifier = cfg.classifier
        if self.classifier is not None:
            self._cls_end, self._cls_len = self.classifier.init_carry(num_vms)
            self._byp = np.asarray(self.classifier.bypass, bool)
            c = self.classifier.num_classes
            self._lo_d = self._hi_d = np.zeros((num_vms, c), np.int32)
            self._lo_s = self._hi_s = np.zeros((num_vms, c), np.int32)
            # per-(VM, class) served hit/miss counters (telemetry export)
            self.cls_hits = np.zeros((num_vms, c), np.int64)
            self.cls_miss = np.zeros((num_vms, c), np.int64)

    def vm_dram(self, v: int) -> CacheState:
        return _vm_slice(self.dram, v) if self.cfg.batched else self.dram[v]

    def vm_ssd(self, v: int) -> CacheState:
        return _vm_slice(self.ssd, v) if self.cfg.batched else self.ssd[v]

    # -- telemetry ----------------------------------------------------------
    # Pre-PR-9 cleaner telemetry (`clean_log`/`dirty_log`) was a pair of
    # unbounded Python lists growing one [V] vector per maintenance
    # interval forever. They are now bounded-journal views: the rows
    # where the batched cleaner actually ran — same entries the lists
    # held (the sequential oracle never recorded them, and still
    # doesn't), capped at the journal window.
    @property
    def clean_log(self) -> list[np.ndarray]:
        return self.telemetry.cache_clean_log()

    @property
    def dirty_log(self) -> list[np.ndarray]:
        return self.telemetry.cache_dirty_log()

    def _sample_interval(self) -> None:
        """Append one journal row for the chunk just simulated — per-VM
        deltas from the cumulative stats plus the maintenance counts the
        interval's existing device_get already brought to host."""
        gd, gs = self.cfg.geometry_dram, self.cfg.geometry_ssd
        cls = self.classifier is not None
        self.telemetry.sample_cache(
            self.stats,
            alloc_l1=self.ways_dram[:self.num_vms].astype(np.int64)
            * gd.num_sets,
            alloc_l2=self.ways_ssd[:self.num_vms].astype(np.int64)
            * gs.num_sets,
            promoted=self._m_promoted, evict_queue=self._m_evicted,
            cleaned=self._m_cleaned, dirty=self._m_dirty,
            clean_ran=self._m_clean_ran,
            cls_hits=self.cls_hits if cls else None,
            cls_miss=self.cls_miss if cls else None)
        self._m_promoted = np.zeros(self.num_vms, np.int64)
        self._m_evicted = np.zeros(self.num_vms, np.int64)
        self._m_cleaned = np.zeros(self.num_vms, np.int64)
        self._m_clean_ran = False          # _m_dirty is a gauge: carries

    # -- sizing -----------------------------------------------------------
    def _size_level(self, subs: list[Trace], policy: Policy, geom: Geometry,
                    capacity: int, cls_subs: list[np.ndarray] | None = None):
        grid = _mrc_grid(geom, self.cfg.mrc_points)
        demands = np.zeros(self.num_vms, np.int64)
        curves = np.zeros((self.num_vms, grid.size))
        addrs = [np.asarray(s.addr) for s in subs]
        writes = [np.asarray(s.is_write) for s in subs]
        wts = None
        if cls_subs is not None:
            # per-class sizing weights: weight-0 (bypass) requests never
            # reach the cache, so they are cut from the sizing sub-traces;
            # the rest weight the hit curves per class
            cw = self.classifier.weights
            wts = []
            for v, cs in enumerate(cls_subs):
                w_req = cw[cs]
                keep = w_req > 0
                if not keep.all():
                    addrs[v] = addrs[v][keep]
                    writes[v] = writes[v][keep]
                    w_req = w_req[keep]
                wts.append(w_req)
        if self.cfg.batched:
            # all VMs' POD decompositions in one vmapped dispatch (with a
            # mesh: dead-VM rows pad to the sharded row count and each
            # device decomposes its own block)
            with self.telemetry.span("sizing") as sp:
                if self.cfg.mesh is not None:
                    pad = self._rows - self.num_vms
                    dists = reuse.pod_distances_batch(
                        addrs + [np.empty(0, np.int32)] * pad,
                        writes + [np.empty(0, bool)] * pad,
                        policy, mesh=self.cfg.mesh)[: self.num_vms]
                else:
                    dists = reuse.pod_distances_batch(addrs, writes, policy)
                sp.ready(dists)
        else:
            dists = [reuse.pod_distances(a, w, policy) if a.size else None
                     for a, w in zip(addrs, writes)]
        for v, r in enumerate(dists):
            if r is None:
                continue
            demands[v] = min(reuse.demand_blocks(int(r.max)), geom.capacity)
            if wts is None:
                hits = reuse.hit_counts_at_sizes(r.dist, r.served, grid)
                curves[v] = np.asarray(hits, np.float64) / max(len(subs[v]), 1)
            else:
                hits = reuse.hit_counts_at_sizes_weighted(
                    r.dist, r.served, grid, wts[v])
                curves[v] = hits / max(wts[v].sum(), 1)
        res = _partition(demands, curves, grid, capacity)
        if wts is None:
            counts = np.array([len(s) for s in subs], np.float64)
        else:
            counts = np.array([w.sum() for w in wts], np.float64)
        alloc = _expand_to_capacity(res.alloc, counts, capacity, geom)
        return alloc, demands, dists

    # -- maintenance --------------------------------------------------------
    def _alloc_blocks(self, v: int) -> int:
        return int(self.ways_ssd[v]) * self.cfg.geometry_ssd.num_sets

    def _refresh_tracker(self, v: int, window: Trace, r) -> None:
        # Eq. 1 sums over ALL re-references (paper: "POD(i,t) is the POD of
        # B_i in the t-th access") — write re-references included, so
        # write-hot blocks (usr_0-style workloads) become popular and get
        # promoted into the WBWO SSD where subsequent writes hit.
        contrib = pop.contributions(r.dist, r.served,
                                    max(self._alloc_blocks(v), 1))
        self.trackers[v].update(np.asarray(window.addr), np.asarray(contrib))

    def _maintain_seq(self, v: int, window: Trace) -> None:
        """Per-VM popularity refresh + promotion/eviction (paper §4.2) —
        the pre-batching host-side numpy path (reference oracle)."""
        cfg = self.cfg
        if len(window) == 0:
            return
        alloc_blocks = self._alloc_blocks(v)
        r = reuse.trd_distances(window.addr, window.is_write)
        self._refresh_tracker(v, window, r)

        ssd_res = simulator.resident_blocks(self.ssd[v], int(self.ways_ssd[v]))
        # eviction queue: least popular 5% of SSD-resident blocks — only
        # once the partition is near-full (an empty cache has nothing
        # worth churning; paper evicts to make room for promotions). The
        # 90% gate is integer arithmetic so every path (host and device)
        # agrees at the boundary.
        if ssd_res.size and ssd_res.size * 10 >= alloc_blocks * 9:
            evict = self.trackers[v].least_popular(ssd_res, cfg.evict_frac)
            if evict.size:
                self._m_evicted[v] += int(evict.size)
                self.ssd[v], flushed = simulator.evict_blocks_ref(
                    self.ssd[v], evict)
                self.stats[v]["disk_writes"] = (
                    self.stats[v].get("disk_writes", 0.0) + flushed)
                self.stats[v]["evict_flushes"] = (
                    self.stats[v].get("evict_flushes", 0.0) + flushed)
        # promotion queue: the most popular blocks known to the tracker
        # that lack an SSD copy (paper: "the most popular 5% of the data
        # blocks in disk subsystem"), drained up to the free space
        residents = simulator.resident_blocks(self.ssd[v],
                                              int(self.ways_ssd[v]))
        free = max(alloc_blocks - residents.size, 0)
        if free:
            promote = self.trackers[v].top_known(residents, free)
            if promote.size:
                self.ssd[v], n = simulator.promote_blocks_ref(
                    self.ssd[v], promote, int(self.ways_ssd[v]),
                    int(self.t[v]))
                self._m_promoted[v] += int(n)
                # each promotion = 1 disk read + 1 SSD write (endurance cost)
                self.stats[v]["cache_writes_l2"] = (
                    self.stats[v].get("cache_writes_l2", 0.0) + n)
                self.stats[v]["disk_reads"] = (
                    self.stats[v].get("disk_reads", 0.0) + n)
        # background cleaner (third stage): flush the quota oldest dirty
        # blocks so evictions later in the run hit clean blocks
        if cfg.clean_quota > 0:
            self.ssd[v], n_fl, left = simulator.clean_blocks_ref(
                self.ssd[v], int(self.ways_ssd[v]), cfg.clean_quota)
            self.stats[v]["flushes"] = (
                self.stats[v].get("flushes", 0.0) + n_fl)
            self.stats[v]["disk_writes"] = (
                self.stats[v].get("disk_writes", 0.0) + n_fl)
            self.stats[v]["dirty_resident"] = float(left)
            self._m_cleaned[v] += int(n_fl)
            self._m_dirty[v] = int(left)

    def _residents(self, tags_np: np.ndarray, v: int) -> np.ndarray:
        t = tags_np[v, :, : max(int(self.ways_ssd[v]), 0)]
        return t[t >= 0]

    def _maintain_all(self, chunks: list[Trace | None]) -> None:
        """All VMs' maintenance for one window, batched.

        With ``cfg.fused_maintenance`` (default) the whole interval —
        popularity refresh into the device table, queue building, the
        eviction scatter, and the promotion scatter — runs as ONE fused
        jitted dispatch through the Pallas maintenance kernels
        (:func:`repro.kernels.maintenance.ops.maintenance_interval`);
        the state never visits the host between stages. Without it, the
        staged path keeps host trackers and separate vmapped dispatches
        (the intermediate oracle). Per-VM semantics are identical to
        :meth:`_maintain_seq` either way.
        """
        if self.cfg.fused_maintenance:
            self._maintain_fused(chunks)
        else:
            self._maintain_staged(chunks)

    def _maintain_fused(self, chunks: list[Trace | None]) -> None:
        """One fused jitted dispatch for the whole interval's maintenance
        (device popularity table + Pallas promote/evict kernels)."""
        from repro.kernels.maintenance import ops as maint_ops
        cfg = self.cfg
        empty = np.empty(0, np.int32)
        addrs = [empty if c is None else np.asarray(c.addr) for c in chunks]
        writes = [empty.astype(bool) if c is None else np.asarray(c.is_write)
                  for c in chunks]
        # dead-VM pad rows (mesh only): zero-length like idle VMs
        addrs += [empty] * (self._rows - self.num_vms)
        writes += [empty.astype(bool)] * (self._rows - self.num_vms)
        lens = [int(a.shape[0]) for a in addrs]
        live = [v for v, n in enumerate(lens) if n > 0]
        if not live:
            return
        # batched TRD decomposition (same bucketing as trd_distances_batch)
        # — results stay on device and feed the fused dispatch directly.
        # ALL VMs ride as rows (idle ones zero-length) so the fused
        # executable is keyed only by the window bucket, not by which
        # subset of VMs is live. With a mesh both the decomposition and
        # the fused maintenance run one row block per device.
        amat, wmat = reuse._pad_rows(addrs, writes, list(range(self._rows)),
                                     lens)
        if cfg.mesh is not None:
            r = reuse._decompose_sharded(cfg.mesh, amat, wmat, Policy.WB,
                                         False, 256)
        else:
            r = reuse._decompose_vmapped(amat, wmat, policy=Policy.WB,
                                         sizing_reads_only=False, chunk=256)
        with self.telemetry.span("maintenance") as sp:
            (self.ssd, self.pop_table, flushed, promoted, eqlen, pqlen,
             pdrops, cleaned, dirty_left) = maint_ops.maintenance_interval(
                    self.ssd, self.pop_table, r.dist, r.served, amat,
                    np.asarray(lens, np.int32), self.ways_ssd, self.t,
                    evict_frac=cfg.evict_frac, decay=cfg.popularity_decay,
                    clean_quota=cfg.clean_quota, mesh=cfg.mesh)
            sp.ready((self.ssd, self.pop_table, flushed))
        # ONE host transfer for all per-VM counters — the cleaner's two
        # vectors ride the sync the interval already paid for
        flushed, promoted, eqlen, pqlen, pdrops, cleaned, dirty_left = \
            jax.device_get((flushed, promoted, eqlen, pqlen, pdrops,
                            cleaned, dirty_left))
        # drop the dead-VM pad rows (all-zero: wlen == 0 skips them)
        flushed, promoted, eqlen, pqlen, pdrops, cleaned, dirty_left = (
            np.asarray(x)[: self.num_vms]
            for x in (flushed, promoted, eqlen, pqlen, pdrops, cleaned,
                      dirty_left))
        for v in live:
            if pdrops[v]:
                # merge-overflow: popularity entries pushed past the [V, K]
                # table's capacity this interval (device-table path only —
                # the host trackers are effectively unbounded)
                self.stats[v]["pop_drops"] = (
                    self.stats[v].get("pop_drops", 0.0) + int(pdrops[v]))
            if eqlen[v]:
                self.stats[v]["disk_writes"] = (
                    self.stats[v].get("disk_writes", 0.0) + int(flushed[v]))
                self.stats[v]["evict_flushes"] = (
                    self.stats[v].get("evict_flushes", 0.0)
                    + int(flushed[v]))
            if pqlen[v]:
                # each promotion = 1 disk read + 1 SSD write (endurance)
                self.stats[v]["cache_writes_l2"] = (
                    self.stats[v].get("cache_writes_l2", 0.0)
                    + int(promoted[v]))
                self.stats[v]["disk_reads"] = (
                    self.stats[v].get("disk_reads", 0.0) + int(promoted[v]))
            if cfg.clean_quota > 0:
                self.stats[v]["flushes"] = (
                    self.stats[v].get("flushes", 0.0) + int(cleaned[v]))
                self.stats[v]["disk_writes"] = (
                    self.stats[v].get("disk_writes", 0.0) + int(cleaned[v]))
                self.stats[v]["dirty_resident"] = float(dirty_left[v])
        # same masking as the stats credits above: the kernel outputs are
        # only meaningful where the corresponding queue was non-empty
        self._m_promoted += np.where(np.asarray(pqlen) > 0,
                                     np.asarray(promoted, np.int64), 0)
        self._m_evicted += np.asarray(eqlen, np.int64)
        if cfg.clean_quota > 0:
            self._m_cleaned += np.asarray(cleaned, np.int64)
            self._m_dirty = np.asarray(dirty_left, np.int64)
            self._m_clean_ran = True

    def _maintain_staged(self, chunks: list[Trace | None]) -> None:
        """Staged batched maintenance (host trackers + separate vmapped
        dispatches with host syncs between stages) — kept as the
        intermediate oracle between :meth:`_maintain_fused` and
        :meth:`_maintain_seq`, and as the fused path's benchmark
        baseline."""
        cfg = self.cfg
        live = [v for v, c in enumerate(chunks) if c is not None and len(c)]
        if not live:
            return
        rs = reuse.trd_distances_batch(
            [np.asarray(chunks[v].addr) for v in live],
            [np.asarray(chunks[v].is_write) for v in live])
        # Eq. 1 contributions for every VM in one elementwise dispatch
        # (same values as the per-VM calls; padding rows contribute 0)
        lens = [len(chunks[v]) for v in live]
        width = simulator._next_pow2(max(lens))
        dmat = np.full((len(live), width), -1, np.int32)
        smat = np.zeros((len(live), width), bool)
        cs = np.empty((len(live), 1), np.float32)
        for i, v in enumerate(live):
            dmat[i, : lens[i]] = rs[i].dist
            smat[i, : lens[i]] = rs[i].served
            cs[i] = max(self._alloc_blocks(v), 1)
        cmat = np.asarray(pop.contributions(dmat, smat, cs))
        for i, v in enumerate(live):
            self.trackers[v].update(np.asarray(chunks[v].addr),
                                    cmat[i, : lens[i]])

        nothing = np.empty(0, np.int64)
        tags_np = np.asarray(self.ssd.tags)
        evict_qs = [nothing] * self.num_vms
        for v in live:
            res = self._residents(tags_np, v)
            if res.size and res.size * 10 >= self._alloc_blocks(v) * 9:
                evict_qs[v] = self.trackers[v].least_popular(
                    res, cfg.evict_frac)
        if any(q.size for q in evict_qs):
            self._m_evicted += np.asarray([q.size for q in evict_qs],
                                          np.int64)
            self.ssd, flushed = simulator.evict_blocks_batch(
                self.ssd, evict_qs)
            flushed = np.asarray(flushed)
            for v in live:
                if evict_qs[v].size:
                    self.stats[v]["disk_writes"] = (
                        self.stats[v].get("disk_writes", 0.0)
                        + int(flushed[v]))
                    self.stats[v]["evict_flushes"] = (
                        self.stats[v].get("evict_flushes", 0.0)
                        + int(flushed[v]))
            tags_np = np.asarray(self.ssd.tags)

        promo_qs = [nothing] * self.num_vms
        for v in live:
            res = self._residents(tags_np, v)
            free = max(self._alloc_blocks(v) - res.size, 0)
            if free:
                promo_qs[v] = self.trackers[v].top_known(res, free)
        if any(q.size for q in promo_qs):
            self.ssd, n = simulator.promote_blocks_batch(
                self.ssd, promo_qs, self.ways_ssd, self.t)
            n = np.asarray(n)
            for v in live:
                if promo_qs[v].size:
                    self._m_promoted[v] += int(n[v])
                    self.stats[v]["cache_writes_l2"] = (
                        self.stats[v].get("cache_writes_l2", 0.0)
                        + int(n[v]))
                    self.stats[v]["disk_reads"] = (
                        self.stats[v].get("disk_reads", 0.0) + int(n[v]))

        # background cleaner (third stage): one vmapped dispatch flushes
        # the quota oldest dirty blocks per live VM
        if cfg.clean_quota > 0:
            quota = np.zeros(self.num_vms, np.int32)
            quota[live] = cfg.clean_quota
            self.ssd, cleaned, dirty_left = simulator.clean_batch(
                self.ssd, self.ways_ssd, quota)
            cleaned, dirty_left = jax.device_get((cleaned, dirty_left))
            for v in live:
                self.stats[v]["flushes"] = (
                    self.stats[v].get("flushes", 0.0) + int(cleaned[v]))
                self.stats[v]["disk_writes"] = (
                    self.stats[v].get("disk_writes", 0.0) + int(cleaned[v]))
                self.stats[v]["dirty_resident"] = float(dirty_left[v])
            self._m_cleaned += np.asarray(cleaned, np.int64)
            self._m_dirty = np.asarray(dirty_left, np.int64)
            self._m_clean_ran = True

    # -- datapath ----------------------------------------------------------
    def _run_chunk_batched(self, a, w, chunks: list[Trace | None],
                           cmat: np.ndarray | None = None) -> None:
        """One vmapped dispatch simulates this window for every VM.

        ``a``/``w`` are the rectangular ``[V, chunk]`` request block (host
        numpy or already-transferred device arrays from the streaming
        prefetcher); ``chunks`` the ragged per-VM views for stats
        attribution. ``cmat`` is the matching ``[V, chunk]`` class-id
        block when a classifier is configured."""
        cfg = self.cfg
        with self.telemetry.span("datapath") as sp:
            if cmat is None and cfg.mesh is not None:
                self.dram, self.ssd, st, t_end = \
                    simulator.simulate_two_level_sharded(
                        a, w, self.dram, self.ssd, self.ways_dram,
                        self.ways_ssd, cfg.mesh, mode=cfg.mode, t0=self.t)
            elif cmat is None:
                self.dram, self.ssd, st, t_end = \
                    simulator.simulate_two_level_batch(
                        a, w, self.dram, self.ssd, self.ways_dram,
                        self.ways_ssd, mode=cfg.mode, t0=self.t)
            else:
                self.dram, self.ssd, st, t_end, ch, cm = \
                    simulator.simulate_two_level_classified_batch(
                        a, w, cmat, self.dram, self.ssd, self.ways_dram,
                        self.ways_ssd, self._byp, self._lo_d, self._hi_d,
                        self._lo_s, self._hi_s, mode=cfg.mode, t0=self.t)
                ch, cm = jax.device_get((ch, cm))
                self.cls_hits += np.asarray(ch, np.int64)
                self.cls_miss += np.asarray(cm, np.int64)
            sp.ready(st)
        self.t = np.asarray(t_end)
        st = jax.device_get(st)
        for v, chunk in enumerate(chunks):
            if chunk is not None:
                _acc(self.stats[v], Stats(*[f[v] for f in st]))

    def _run_chunk_sequential(self, chunks: list[Trace | None],
                              cls_subs: list[np.ndarray] | None = None,
                              k: int = 0) -> None:
        """Reference oracle: V sequential per-VM dispatches."""
        cfg = self.cfg
        for v, chunk in enumerate(chunks):
            if chunk is None:
                continue
            a, w = _pad(np.asarray(chunk.addr, np.int32),
                        np.asarray(chunk.is_write), cfg.promo_interval)
            if cls_subs is None:
                self.dram[v], self.ssd[v], st, t_end = \
                    simulator.simulate_two_level(
                        a, w, self.dram[v], self.ssd[v],
                        int(self.ways_dram[v]), int(self.ways_ssd[v]),
                        mode=cfg.mode, t0=int(self.t[v]))
            else:
                seg = cls_subs[v][k * cfg.promo_interval:
                                  (k + 1) * cfg.promo_interval]
                cpad = np.zeros(cfg.promo_interval, np.int32)
                cpad[:len(seg)] = seg
                self.dram[v], self.ssd[v], st, t_end, ch, cm = \
                    simulator.simulate_two_level_classified(
                        a, w, cpad, self.dram[v], self.ssd[v],
                        int(self.ways_dram[v]), int(self.ways_ssd[v]),
                        self._byp, self._lo_d[v], self._hi_d[v],
                        self._lo_s[v], self._hi_s[v],
                        mode=cfg.mode, t0=int(self.t[v]))
                self.cls_hits[v] += np.asarray(ch, np.int64)
                self.cls_miss[v] += np.asarray(cm, np.int64)
            self.t[v] = int(t_end)
            _acc(self.stats[v], st)

    # -- main loop ----------------------------------------------------------
    def run(self, trace) -> list[VMResult]:
        """Drive the controller over a whole trace.

        ``trace`` may be an in-memory :class:`Trace`, an on-disk
        :class:`repro.traces.store.TraceStore`, or a pre-built
        :class:`repro.traces.stream.StreamingTraceSource` — all three
        produce bit-identical results; the store/stream paths never hold
        more than one resize window (plus the in-flight ``[V, chunk]``
        blocks) in host memory."""
        cfg = self.cfg
        gd, gs = cfg.geometry_dram, cfg.geometry_ssd
        alloc_hist = [[] for _ in range(self.num_vms)]
        source = _window_source(trace, self.num_vms, cfg.resize_interval,
                                cfg.promo_interval, cfg.prefetch,
                                cfg.prefetch_depth,
                                self._rows - self.num_vms, self._sharding)
        for win in source.windows():
            subs = win.subs
            # 0) IO classification: one fused dispatch per window, the
            # sequential-run carry threaded across windows per VM
            cls_subs = None
            if self.classifier is not None:
                cls_subs, self._cls_end, self._cls_len = \
                    self.classifier.classify_subs(subs, self._cls_end,
                                                  self._cls_len)
            # 1) POD sizing + PPC partitioning at both levels (§4.3)
            alloc_d, dem_d, _ = self._size_level(
                subs, Policy.RO, cfg.geometry_dram, cfg.dram_capacity,
                cls_subs)
            alloc_s, dem_s, _ = self._size_level(
                subs, Policy.WBWO, cfg.geometry_ssd, cfg.ssd_capacity,
                cls_subs)
            self.logs_dram.append(IntervalLog(dem_d, alloc_d))
            self.logs_ssd.append(IntervalLog(dem_s, alloc_s))
            # 2) resize both levels (shrinking flushes dirty blocks)
            wd = np.asarray(capacity_to_ways(alloc_d, gd.num_sets,
                                             gd.max_ways))
            ws = np.asarray(capacity_to_ways(alloc_s, gs.num_sets,
                                             gs.max_ways))
            # dead-VM pad rows keep zero ways forever
            wd = np.pad(wd, (0, self._rows - self.num_vms))
            ws = np.pad(ws, (0, self._rows - self.num_vms))
            if cfg.batched:
                # both levels resized in ONE jitted dispatch (sharded:
                # every device resizes its own row block)
                if cfg.mesh is not None:
                    self.dram, self.ssd, _, flushed = \
                        simulator.resize_levels_sharded(
                            self.dram, self.ssd, self.ways_dram, wd,
                            self.ways_ssd, ws, cfg.mesh)
                else:
                    self.dram, self.ssd, _, flushed = \
                        simulator.resize_levels(
                            self.dram, self.ssd, self.ways_dram, wd,
                            self.ways_ssd, ws)
                flushed = np.asarray(flushed)
                for v in range(self.num_vms):
                    self.stats[v]["disk_writes"] = (
                        self.stats[v].get("disk_writes", 0.0)
                        + int(flushed[v]))
                    self.stats[v]["evict_flushes"] = (
                        self.stats[v].get("evict_flushes", 0.0)
                        + int(flushed[v]))
            else:
                for v in range(self.num_vms):
                    self.dram[v], _ = simulator.resize_ref(
                        self.dram[v], int(self.ways_dram[v]), int(wd[v]))
                    self.ssd[v], fl = simulator.resize_ref(
                        self.ssd[v], int(self.ways_ssd[v]), int(ws[v]))
                    self.stats[v]["disk_writes"] = (
                        self.stats[v].get("disk_writes", 0.0) + fl)
                    self.stats[v]["evict_flushes"] = (
                        self.stats[v].get("evict_flushes", 0.0) + fl)
            for v in range(self.num_vms):
                alloc_hist[v].append(int(alloc_d[v] + alloc_s[v]))
            self.ways_dram, self.ways_ssd = wd, ws
            # class -> sub-partition way ranges for the new allocations
            if self.classifier is not None:
                self._lo_d, self._hi_d = self.classifier.way_bounds(wd)
                self._lo_s, self._hi_s = self.classifier.way_bounds(ws)
            # 3) datapath simulation in promo-interval chunks + maintenance
            if cfg.batched:
                # [V, chunk] blocks from the source (device-put one block
                # ahead of the simulator when prefetch is on)
                for k, (a, w, kth) in enumerate(win.blocks()):
                    cmat = (None if cls_subs is None else
                            _cls_chunk(cls_subs, k, cfg.promo_interval))
                    self._run_chunk_batched(a, w, kth, cmat)
                    if cfg.mode == "full":
                        mth = (kth if cls_subs is None else _strip_bypass(
                            kth, cls_subs, k, cfg.promo_interval, self._byp))
                        self._maintain_all(mth)
                    self._sample_interval()
            else:
                chunk_lists = win.chunk_lists()
                for k in range(max(map(len, chunk_lists), default=0)):
                    kth = [c[k] if k < len(c) else None for c in chunk_lists]
                    self._run_chunk_sequential(kth, cls_subs, k)
                    if cfg.mode == "full":
                        mth = (kth if cls_subs is None else _strip_bypass(
                            kth, cls_subs, k, cfg.promo_interval, self._byp))
                        for v, chunk in enumerate(mth):
                            if chunk is not None:
                                self._maintain_seq(v, chunk)
                    self._sample_interval()
        return [VMResult(dict(self.stats[v]),
                         np.asarray(alloc_hist[v], np.int64))
                for v in range(self.num_vms)]


# ---------------------------------------------------------------------------
# shared chassis for one-level partitioned baselines
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SingleLevelConfig:
    capacity: int
    geometry: Geometry = dataclasses.field(default_factory=Geometry)
    resize_interval: int = 10_000
    sim_chunk: int = 1_000
    mrc_points: int = 17
    batched: bool = True             # one vmapped dispatch for all VMs
    prefetch: bool = True            # pipeline host->device blocks
    prefetch_depth: int = 2          # blocks in flight beyond the consumed
    mesh: object | None = None       # launch.mesh.make_vm_mesh: shard the
    #                                  VM axis across devices (requires
    #                                  batched; VM count padded with dead
    #                                  VMs to a multiple of the mesh size)
    classifier: object | None = None  # repro.classify.Classifier | None
    telemetry: object | None = None  # TelemetryRecorder | None (default
    #                                  bounded recorder when None)


MetricFn = Callable[[Trace], tuple[int, np.ndarray, np.ndarray]]
# returns (demand_blocks, grid_sizes, hit_curve)
PolicyFn = Callable[[Trace], Policy]


@dataclasses.dataclass(frozen=True)
class PolicyChooser:
    """A per-VM write-policy chooser in batched and sequential forms.

    ECI-Cache picks each VM's policy from its read ratio every resize
    interval. With a batched :class:`~repro.core.baselines.SizingMetric`
    the per-VM read counts already ride the vmapped sizing dispatch
    (``reuse.sizing_metrics_batch``), so :meth:`batch` turns those counts
    into policies with zero extra per-VM work; ``ref`` is the original
    host-loop closure kept as the sequential oracle
    (``batched=False``). Instances are themselves callable as a plain
    :data:`PolicyFn`.
    """

    from_read_ratio: Callable[[float], Policy]
    ref: PolicyFn                     # sequential per-VM oracle

    def __call__(self, sub: Trace) -> Policy:
        return self.ref(sub)

    def batch(self, read_counts, lens) -> list[Policy]:
        """Policies for all VMs from the sizing dispatch's read counts.

        Bit-identical to calling ``ref`` per VM: the ratio is the same
        integer division, and empty VMs keep the chassis' ``Policy.WB``
        default."""
        return [self.from_read_ratio(int(r) / max(int(n), 1))
                if n else Policy.WB
                for r, n in zip(read_counts, lens)]


class PartitionedSingleLevelCache:
    """One SSD cache level, partitioned across VMs per a sizing metric.

    ECI-Cache = URD metric + dynamic WB/RO policy; Centaur = TRD + WB;
    S-CAVE = WSS + WT; vCacheShare = reuse-intensity + RO. Push-mode
    datapath (allocates on every miss the policy admits) — exactly the
    behavior the paper criticizes in §2.1. With ``cfg.batched`` the
    per-VM states are stacked (``[V, S, W]``) and each window runs all
    VMs — including heterogeneous per-VM policies — in one vmapped
    dispatch; otherwise states are per-VM lists driven sequentially.

    ``metric`` may be a plain per-VM closure (``MetricFn``) or a
    :class:`repro.core.baselines.SizingMetric`. With a ``SizingMetric``
    and ``cfg.batched``, every resize interval sizes *all* VMs in one
    vmapped jitted reduction over the stacked reuse-distance histograms
    (zero per-VM Python-loop metric calls) — mirroring how the datapath
    and maintenance already batch. ``batched=False`` (or a plain closure)
    evaluates the sequential per-VM oracle, bit-identically.
    """

    def __init__(self, cfg: SingleLevelConfig, num_vms: int,
                 metric, policy_fn: PolicyFn):
        self.cfg = cfg
        self.num_vms = num_vms
        self.metric = metric
        self.policy_fn = policy_fn
        # device state carries dead-VM-padded rows with a mesh (see
        # EticaCache) — host structures stay at the real VM count
        self._rows, self._sharding = _mesh_setup(
            cfg.mesh, num_vms, cfg.batched, cfg.classifier)
        g = cfg.geometry
        if cfg.batched:
            self.caches = make_cache_batch(self._rows, g.num_sets,
                                           g.max_ways)
        else:
            self.caches = [make_cache(g.num_sets, g.max_ways)
                           for _ in range(num_vms)]
        self.ways = np.zeros(self._rows, np.int32)
        self.t = np.zeros(self._rows, np.int32)
        self.stats = [dict() for _ in range(num_vms)]
        self.logs: list[IntervalLog] = []
        if cfg.telemetry is not None:
            self.telemetry = cfg.telemetry
        else:
            from repro.runtime.telemetry import TelemetryRecorder
            self.telemetry = TelemetryRecorder()
        self.classifier = cfg.classifier
        if self.classifier is not None:
            self._cls_end, self._cls_len = self.classifier.init_carry(num_vms)
            self._byp = np.asarray(self.classifier.bypass, bool)
            c = self.classifier.num_classes
            self.cls_hits = np.zeros((num_vms, c), np.int64)
            self.cls_miss = np.zeros((num_vms, c), np.int64)

    def vm_cache(self, v: int) -> CacheState:
        return _vm_slice(self.caches, v) if self.cfg.batched else self.caches[v]

    def _sample_interval(self) -> None:
        """One journal row per sim chunk — same host-side delta sampling
        as :meth:`EticaCache._sample_interval`, minus the two-level
        maintenance channels this chassis doesn't have."""
        cls = self.classifier is not None
        self.telemetry.sample_cache(
            self.stats,
            alloc_l2=self.ways[:self.num_vms].astype(np.int64)
            * self.cfg.geometry.num_sets,
            cls_hits=self.cls_hits if cls else None,
            cls_miss=self.cls_miss if cls else None)

    def run(self, trace) -> list[VMResult]:
        """Drive the chassis over a :class:`Trace`, an on-disk
        :class:`repro.traces.store.TraceStore`, or a pre-built
        :class:`repro.traces.stream.StreamingTraceSource` — bit-identical
        results either way (the streamed paths hold one resize window at
        a time)."""
        cfg = self.cfg
        alloc_hist = [[] for _ in range(self.num_vms)]
        source = _window_source(trace, self.num_vms, cfg.resize_interval,
                                cfg.sim_chunk, cfg.prefetch,
                                cfg.prefetch_depth,
                                self._rows - self.num_vms, self._sharding)
        for win in source.windows():
            subs = win.subs
            # IO classification: bypass-class requests never reach the
            # cache, so they are cut from the sizing/policy sub-traces
            cls_subs = None
            subs_sz = subs
            if self.classifier is not None:
                cls_subs, self._cls_end, self._cls_len = \
                    self.classifier.classify_subs(subs, self._cls_end,
                                                  self._cls_len)
                wts = self.classifier.weights
                keep = [wts[c] > 0 for c in cls_subs]
                subs_sz = [s if m.all() else s[m]
                           for s, m in zip(subs, keep)]
            demands = np.zeros(self.num_vms, np.int64)
            grid = _mrc_grid(cfg.geometry, cfg.mrc_points)
            curves = np.zeros((self.num_vms, grid.size))
            batched_metric = cfg.batched and hasattr(self.metric, "batch")
            if batched_metric:
                # all VMs' metrics in ONE vmapped reduction over the
                # stacked reuse-distance histograms (empty rows stay 0);
                # the dynamic policy choosers' read counts ride the same
                # dispatch
                with self.telemetry.span("sizing") as sp:
                    pad = self._rows - self.num_vms
                    dem, g_, cur, reads = self.metric.batch(
                        [np.asarray(s.addr) for s in subs_sz]
                        + [np.empty(0, np.int32)] * pad,
                        [np.asarray(s.is_write) for s in subs_sz]
                        + [np.empty(0, bool)] * pad,
                        with_reads=True, mesh=cfg.mesh)
                    dem, cur, reads = (dem[:self.num_vms],
                                       cur[:self.num_vms],
                                       reads[:self.num_vms])
                    sp.ready((dem, cur))
                same_grid = np.array_equal(g_, grid)
                for v, sub in enumerate(subs_sz):
                    if len(sub) == 0:
                        continue
                    demands[v] = min(int(dem[v]), cfg.geometry.capacity)
                    curves[v] = cur[v] if same_grid else np.interp(
                        grid, g_, cur[v])
            else:
                metric_fn = getattr(self.metric, "ref", self.metric)
                for v, sub in enumerate(subs_sz):
                    if len(sub) == 0:
                        continue
                    d, g_, c_ = metric_fn(sub)
                    demands[v] = min(d, cfg.geometry.capacity)
                    curves[v] = np.interp(grid, g_, c_)
            if batched_metric and isinstance(self.policy_fn, PolicyChooser):
                policies = self.policy_fn.batch(reads,
                                                [len(s) for s in subs_sz])
            else:
                policies = [self.policy_fn(sub) if len(sub) else Policy.WB
                            for sub in subs_sz]
            res = _partition(demands, curves, grid, cfg.capacity)
            if cls_subs is None:
                counts = np.array([len(s) for s in subs], np.float64)
            else:
                counts = np.array([wts[c].sum() for c in cls_subs],
                                  np.float64)
            alloc = _expand_to_capacity(res.alloc, counts, cfg.capacity,
                                        cfg.geometry)
            self.logs.append(IntervalLog(demands, alloc,
                                         [p.value for p in policies]))
            w_new = np.asarray(capacity_to_ways(
                alloc, cfg.geometry.num_sets, cfg.geometry.max_ways))
            # dead-VM pad rows keep zero ways forever
            w_new = np.pad(w_new, (0, self._rows - self.num_vms))
            if cfg.batched:
                if cfg.mesh is not None:
                    self.caches, flushed = simulator.resize_batch_sharded(
                        self.caches, self.ways, w_new, cfg.mesh)
                else:
                    self.caches, flushed = resize_batch(
                        self.caches, self.ways, w_new)
                flushed = np.asarray(flushed)
                for v in range(self.num_vms):
                    self.stats[v]["disk_writes"] = (
                        self.stats[v].get("disk_writes", 0.0)
                        + int(flushed[v]))
                    self.stats[v]["evict_flushes"] = (
                        self.stats[v].get("evict_flushes", 0.0)
                        + int(flushed[v]))
            else:
                for v in range(self.num_vms):
                    self.caches[v], fl = simulator.resize_ref(
                        self.caches[v], int(self.ways[v]), int(w_new[v]))
                    self.stats[v]["disk_writes"] = (
                        self.stats[v].get("disk_writes", 0.0) + fl)
                    self.stats[v]["evict_flushes"] = (
                        self.stats[v].get("evict_flushes", 0.0) + fl)
            for v in range(self.num_vms):
                alloc_hist[v].append(int(alloc[v]))
            self.ways = w_new
            # pad rows get the WB default — dead VMs (0 ways, addr -1
            # blocks) never touch their cache whatever the policy says
            flags = policy_flags(
                policies + [Policy.WB] * (self._rows - self.num_vms))
            if cls_subs is not None:
                # per-(VM, class) policy flags + insertion way ranges
                flags_vc = _class_policy_flags(
                    self.classifier.vm_policies(policies))
                lo, hi = self.classifier.way_bounds(w_new)
            if cfg.batched:
                # [V, chunk] blocks from the source (device-put one block
                # ahead of the simulator when prefetch is on)
                for k, (a, wr, kth) in enumerate(win.blocks()):
                    with self.telemetry.span("datapath") as sp:
                        if cls_subs is None and cfg.mesh is not None:
                            self.caches, st, t_end = \
                                simulator.simulate_single_level_sharded(
                                    a, wr, self.caches, self.ways, flags,
                                    cfg.mesh, t0=self.t)
                        elif cls_subs is None:
                            self.caches, st, t_end = \
                                simulator.simulate_single_level_batch(
                                    a, wr, self.caches, self.ways, flags,
                                    t0=self.t)
                        else:
                            cmat = _cls_chunk(cls_subs, k, cfg.sim_chunk)
                            self.caches, st, t_end, ch, cm = simulator.\
                                simulate_single_level_classified_batch(
                                    a, wr, cmat, self.caches, self.ways,
                                    flags_vc, lo, hi, self._byp, t0=self.t)
                            ch, cm = jax.device_get((ch, cm))
                            self.cls_hits += np.asarray(ch, np.int64)
                            self.cls_miss += np.asarray(cm, np.int64)
                        sp.ready(st)
                    self.t = np.asarray(t_end)
                    st = jax.device_get(st)
                    for v, chunk in enumerate(kth):
                        if chunk is not None:
                            _acc(self.stats[v], Stats(*[f[v] for f in st]))
                    self._sample_interval()
            else:
                chunk_lists = win.chunk_lists()
                for k in range(max(map(len, chunk_lists), default=0)):
                    kth = [c[k] if k < len(c) else None for c in chunk_lists]
                    for v, chunk in enumerate(kth):
                        if chunk is None:
                            continue
                        a, wr = _pad(np.asarray(chunk.addr, np.int32),
                                     np.asarray(chunk.is_write),
                                     cfg.sim_chunk)
                        if cls_subs is None:
                            self.caches[v], st, t_end = \
                                simulator.simulate_single_level(
                                    a, wr, self.caches[v], int(self.ways[v]),
                                    policies[v], t0=int(self.t[v]))
                        else:
                            seg = cls_subs[v][k * cfg.sim_chunk:
                                              (k + 1) * cfg.sim_chunk]
                            cpad = np.zeros(cfg.sim_chunk, np.int32)
                            cpad[:len(seg)] = seg
                            fv = simulator.PolicyFlags(
                                *[np.asarray(f[v]) for f in flags_vc])
                            self.caches[v], st, t_end, ch, cm = \
                                simulator.simulate_single_level_classified(
                                    a, wr, cpad, self.caches[v],
                                    int(self.ways[v]), fv, lo[v], hi[v],
                                    self._byp, t0=int(self.t[v]))
                            self.cls_hits[v] += np.asarray(ch, np.int64)
                            self.cls_miss[v] += np.asarray(cm, np.int64)
                        self.t[v] = int(t_end)
                        _acc(self.stats[v], st)
                    self._sample_interval()
        return [VMResult(dict(self.stats[v]),
                         np.asarray(alloc_hist[v], np.int64))
                for v in range(self.num_vms)]
