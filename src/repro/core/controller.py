"""Interval-driven cache controllers (the hypervisor-side brain).

:class:`EticaCache` is the paper's full system: every ``resize_interval``
requests it recomputes POD(RO)/POD(WBWO) per VM, re-partitions both cache
levels via PPC, and resizes the per-VM caches; every ``promo_interval``
requests it refreshes popularity scores and executes the
promotion/eviction queues (pull-mode SSD maintenance, §4.2).

:class:`PartitionedSingleLevelCache` is the shared chassis for the
one-level baselines (ECI-Cache, Centaur, S-CAVE, vCacheShare) — they
differ only in the sizing metric and the per-VM write-policy chooser (see
``repro.core.baselines``).

All datapath simulation happens in fixed-shape jitted ``lax.scan`` windows
(padded with addr = -1 no-ops), so re-running 12 VMs x hundreds of
intervals reuses one compiled executable per geometry.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from . import popularity as pop
from .partition import partition as _partition
from . import reuse, simulator
from .policies import Policy
from .simulator import CacheState, Stats, capacity_to_ways, make_cache
from .trace import Trace


@dataclasses.dataclass
class Geometry:
    num_sets: int = 64
    max_ways: int = 64

    @property
    def capacity(self) -> int:
        return self.num_sets * self.max_ways


@dataclasses.dataclass
class IntervalLog:
    """Per-interval record for the Fig. 10/15-style plots."""
    demands: np.ndarray          # [V] blocks requested by the metric
    alloc: np.ndarray            # [V] blocks granted
    policies: list[str] | None = None


@dataclasses.dataclass
class VMResult:
    stats: dict[str, float]
    alloc_history: np.ndarray    # [intervals]

    @property
    def hit_ratio(self) -> float:
        s = self.stats
        return (s["read_hits_l1"] + s["read_hits_l2"] + s["write_hits_l2"]) / max(
            s["reads"] + s["writes"], 1)

    @property
    def mean_latency(self) -> float:
        return self.stats["latency_sum"] / max(
            self.stats["reads"] + self.stats["writes"], 1)

    def contended_latency(self, beta: float = 8.0) -> float:
        """Mean latency under an SSD write-contention model.

        Sustained writes trigger SSD garbage collection that inflates the
        latency of *every* SSD access (well documented for NAND devices;
        the paper's own premise is that performance degrades with
        committed writes). Modeled as
        ``t_ssd_eff = T_SSD * (1 + beta * write_share)`` applied to all
        SSD accesses, with write_share = SSD writes / SSD accesses.
        This couples the endurance win to a latency win — the regime the
        paper's real-hardware numbers reflect."""
        from .policies import T_SSD
        s = self.stats
        ssd_accesses = (s["read_hits_l2"] + s["write_hits_l2"]
                        + s["cache_writes_l2"])
        if ssd_accesses <= 0:
            return self.mean_latency
        write_share = s["cache_writes_l2"] / ssd_accesses
        extra = ssd_accesses * T_SSD * beta * write_share
        return (s["latency_sum"] + extra) / max(
            s["reads"] + s["writes"], 1)

    @property
    def ssd_writes(self) -> float:
        return self.stats["cache_writes_l2"]


def _pad(addr: np.ndarray, is_write: np.ndarray, n: int):
    k = n - addr.shape[0]
    if k <= 0:
        return addr[:n], is_write[:n]
    return (np.concatenate([addr, np.full(k, -1, addr.dtype)]),
            np.concatenate([is_write, np.zeros(k, bool)]))


def _stats_to_dict(st: Stats) -> dict[str, float]:
    return {k: float(v) for k, v in zip(Stats._fields, st)}


def _acc(d: dict[str, float], st: Stats) -> None:
    for k, v in zip(Stats._fields, st):
        d[k] = d.get(k, 0.0) + float(v)


def _mrc_grid(geom: Geometry, points: int = 17) -> np.ndarray:
    ways = np.unique(np.round(np.linspace(0, geom.max_ways, points)).astype(int))
    return (ways * geom.num_sets).astype(np.int64)


def _expand_to_capacity(alloc: np.ndarray, counts: np.ndarray,
                        capacity: int, geom: Geometry) -> np.ndarray:
    """Distribute surplus capacity beyond instantaneous demand.

    Paper Fig. 15/16: "ETICA increases the allocated cache to VM0, since
    other VMs' demand is low" — spare space goes to VMs in proportion to
    their request share (bounded by the per-VM geometry), so promotion
    has room to build each VM's popular set beyond the strict POD demand.
    """
    left = capacity - int(alloc.sum())
    if left <= 0 or counts.sum() == 0:
        return alloc
    share = counts / counts.sum()
    extra = np.floor(left * share).astype(np.int64)
    return np.minimum(alloc + extra, geom.capacity)


# ---------------------------------------------------------------------------
# ETICA (two-level)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EticaConfig:
    dram_capacity: int               # total DRAM-level blocks across VMs
    ssd_capacity: int                # total SSD-level blocks across VMs
    geometry_dram: Geometry = dataclasses.field(default_factory=Geometry)
    geometry_ssd: Geometry = dataclasses.field(default_factory=Geometry)
    resize_interval: int = 10_000    # paper §5.1
    promo_interval: int = 1_000      # paper §5.3
    promo_frac: float = 0.05         # paper §4.2.1: top/bottom 5%
    evict_frac: float = 0.05
    popularity_decay: float = 0.5
    mode: str = "full"               # "full" | "npe"
    mrc_points: int = 17


class EticaCache:
    """The proposed system: DRAM(RO) + SSD(WBWO), POD sizing, PPC
    partitioning, popularity-driven promotion/eviction."""

    def __init__(self, cfg: EticaConfig, num_vms: int):
        self.cfg = cfg
        self.num_vms = num_vms
        gd, gs = cfg.geometry_dram, cfg.geometry_ssd
        self.dram = [make_cache(gd.num_sets, gd.max_ways) for _ in range(num_vms)]
        self.ssd = [make_cache(gs.num_sets, gs.max_ways) for _ in range(num_vms)]
        self.ways_dram = np.zeros(num_vms, np.int32)
        self.ways_ssd = np.zeros(num_vms, np.int32)
        self.t = np.zeros(num_vms, np.int64)
        self.trackers = [pop.PopularityTracker(cfg.popularity_decay)
                         for _ in range(num_vms)]
        self.stats = [dict() for _ in range(num_vms)]
        self.logs_dram: list[IntervalLog] = []
        self.logs_ssd: list[IntervalLog] = []

    # -- sizing -----------------------------------------------------------
    def _size_level(self, subs: list[Trace], policy: Policy, geom: Geometry,
                    capacity: int):
        grid = _mrc_grid(geom, self.cfg.mrc_points)
        demands = np.zeros(self.num_vms, np.int64)
        curves = np.zeros((self.num_vms, grid.size))
        dists = []
        for v, sub in enumerate(subs):
            if len(sub) == 0:
                dists.append(None)
                continue
            r = reuse.pod_distances(sub.addr, sub.is_write, policy)
            dists.append(r)
            demands[v] = min(reuse.demand_blocks(int(r.max)), geom.capacity)
            hits = reuse.hit_counts_at_sizes(r.dist, r.served, grid)
            curves[v] = np.asarray(hits, np.float64) / max(len(sub), 1)
        res = _partition(demands, curves, grid, capacity)
        counts = np.array([len(s) for s in subs], np.float64)
        alloc = _expand_to_capacity(res.alloc, counts, capacity, geom)
        return alloc, demands, dists

    # -- maintenance --------------------------------------------------------
    def _maintain(self, v: int, window: Trace) -> None:
        """Popularity refresh + promotion/eviction queues (paper §4.2)."""
        cfg = self.cfg
        if len(window) == 0:
            return
        alloc_blocks = int(self.ways_ssd[v]) * cfg.geometry_ssd.num_sets
        # Eq. 1 sums over ALL re-references (paper: "POD(i,t) is the POD of
        # B_i in the t-th access") — write re-references included, so
        # write-hot blocks (usr_0-style workloads) become popular and get
        # promoted into the WBWO SSD where subsequent writes hit.
        r = reuse.trd_distances(window.addr, window.is_write)
        contrib = pop.contributions(r.dist, r.served, max(alloc_blocks, 1))
        self.trackers[v].update(np.asarray(window.addr), np.asarray(contrib))

        ssd_res = simulator.resident_blocks(self.ssd[v], int(self.ways_ssd[v]))
        # eviction queue: least popular 5% of SSD-resident blocks — only
        # once the partition is near-full (an empty cache has nothing
        # worth churning; paper evicts to make room for promotions)
        if ssd_res.size and ssd_res.size >= 0.9 * alloc_blocks:
            evict = self.trackers[v].least_popular(ssd_res, cfg.evict_frac)
            if evict.size:
                self.ssd[v], flushed = simulator.evict_blocks(self.ssd[v], evict)
                self.stats[v]["disk_writes"] = (
                    self.stats[v].get("disk_writes", 0.0) + flushed)
        # promotion queue: the most popular blocks known to the tracker
        # that lack an SSD copy (paper: "the most popular 5% of the data
        # blocks in disk subsystem"), drained up to the free space
        residents = simulator.resident_blocks(self.ssd[v],
                                              int(self.ways_ssd[v]))
        free = max(alloc_blocks - residents.size, 0)
        if free:
            promote = self.trackers[v].top_known(residents, free)
            if promote.size:
                self.ssd[v], n = simulator.promote_blocks(
                    self.ssd[v], promote, int(self.ways_ssd[v]), int(self.t[v]))
                # each promotion = 1 disk read + 1 SSD write (endurance cost)
                self.stats[v]["cache_writes_l2"] = (
                    self.stats[v].get("cache_writes_l2", 0.0) + n)
                self.stats[v]["disk_reads"] = (
                    self.stats[v].get("disk_reads", 0.0) + n)

    # -- main loop ----------------------------------------------------------
    def run(self, trace: Trace) -> list[VMResult]:
        cfg = self.cfg
        alloc_hist = [[] for _ in range(self.num_vms)]
        for window in trace.intervals(cfg.resize_interval):
            subs = [window.for_vm(v) if window.vm is not None else window
                    for v in range(self.num_vms)]
            # 1) POD sizing + PPC partitioning at both levels (§4.3)
            alloc_d, dem_d, _ = self._size_level(
                subs, Policy.RO, cfg.geometry_dram, cfg.dram_capacity)
            alloc_s, dem_s, _ = self._size_level(
                subs, Policy.WBWO, cfg.geometry_ssd, cfg.ssd_capacity)
            self.logs_dram.append(IntervalLog(dem_d, alloc_d))
            self.logs_ssd.append(IntervalLog(dem_s, alloc_s))
            # 2) resize (flushing dirty blocks on shrink)
            for v in range(self.num_vms):
                wd = int(capacity_to_ways(int(alloc_d[v]),
                                          cfg.geometry_dram.num_sets,
                                          cfg.geometry_dram.max_ways))
                ws = int(capacity_to_ways(int(alloc_s[v]),
                                          cfg.geometry_ssd.num_sets,
                                          cfg.geometry_ssd.max_ways))
                self.dram[v], _ = simulator.resize(
                    self.dram[v], int(self.ways_dram[v]), wd)
                self.ssd[v], flushed = simulator.resize(
                    self.ssd[v], int(self.ways_ssd[v]), ws)
                self.stats[v]["disk_writes"] = (
                    self.stats[v].get("disk_writes", 0.0) + flushed)
                self.ways_dram[v], self.ways_ssd[v] = wd, ws
                alloc_hist[v].append(int(alloc_d[v] + alloc_s[v]))
            # 3) datapath simulation in promo-interval chunks + maintenance
            for v in range(self.num_vms):
                sub = subs[v]
                for chunk in sub.intervals(cfg.promo_interval):
                    a, w = _pad(np.asarray(chunk.addr, np.int32),
                                np.asarray(chunk.is_write), cfg.promo_interval)
                    self.dram[v], self.ssd[v], st, t_end = \
                        simulator.simulate_two_level(
                            a, w, self.dram[v], self.ssd[v],
                            int(self.ways_dram[v]), int(self.ways_ssd[v]),
                            mode=cfg.mode, t0=int(self.t[v]))
                    self.t[v] = int(t_end)
                    _acc(self.stats[v], st)
                    if cfg.mode == "full":
                        self._maintain(v, chunk)
        return [VMResult(dict(self.stats[v]),
                         np.asarray(alloc_hist[v], np.int64))
                for v in range(self.num_vms)]


# ---------------------------------------------------------------------------
# shared chassis for one-level partitioned baselines
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SingleLevelConfig:
    capacity: int
    geometry: Geometry = dataclasses.field(default_factory=Geometry)
    resize_interval: int = 10_000
    sim_chunk: int = 1_000
    mrc_points: int = 17


MetricFn = Callable[[Trace], tuple[int, np.ndarray, np.ndarray]]
# returns (demand_blocks, grid_sizes, hit_curve)
PolicyFn = Callable[[Trace], Policy]


class PartitionedSingleLevelCache:
    """One SSD cache level, partitioned across VMs per a sizing metric.

    ECI-Cache = URD metric + dynamic WB/RO policy; Centaur = TRD + WB;
    S-CAVE = WSS + WT; vCacheShare = reuse-intensity + RO. Push-mode
    datapath (allocates on every miss the policy admits) — exactly the
    behavior the paper criticizes in §2.1.
    """

    def __init__(self, cfg: SingleLevelConfig, num_vms: int,
                 metric: MetricFn, policy_fn: PolicyFn):
        self.cfg = cfg
        self.num_vms = num_vms
        self.metric = metric
        self.policy_fn = policy_fn
        g = cfg.geometry
        self.caches = [make_cache(g.num_sets, g.max_ways) for _ in range(num_vms)]
        self.ways = np.zeros(num_vms, np.int32)
        self.t = np.zeros(num_vms, np.int64)
        self.stats = [dict() for _ in range(num_vms)]
        self.logs: list[IntervalLog] = []

    def run(self, trace: Trace) -> list[VMResult]:
        cfg = self.cfg
        alloc_hist = [[] for _ in range(self.num_vms)]
        for window in trace.intervals(cfg.resize_interval):
            subs = [window.for_vm(v) if window.vm is not None else window
                    for v in range(self.num_vms)]
            demands = np.zeros(self.num_vms, np.int64)
            grid = _mrc_grid(cfg.geometry, cfg.mrc_points)
            curves = np.zeros((self.num_vms, grid.size))
            policies = []
            for v, sub in enumerate(subs):
                policies.append(self.policy_fn(sub) if len(sub) else Policy.WB)
                if len(sub) == 0:
                    continue
                d, g_, c_ = self.metric(sub)
                demands[v] = min(d, cfg.geometry.capacity)
                curves[v] = np.interp(grid, g_, c_)
            res = _partition(demands, curves, grid, cfg.capacity)
            counts = np.array([len(s) for s in subs], np.float64)
            alloc = _expand_to_capacity(res.alloc, counts, cfg.capacity,
                                        cfg.geometry)
            self.logs.append(IntervalLog(demands, alloc,
                                         [p.value for p in policies]))
            for v in range(self.num_vms):
                w = int(capacity_to_ways(int(alloc[v]),
                                         cfg.geometry.num_sets,
                                         cfg.geometry.max_ways))
                self.caches[v], flushed = simulator.resize(
                    self.caches[v], int(self.ways[v]), w)
                self.stats[v]["disk_writes"] = (
                    self.stats[v].get("disk_writes", 0.0) + flushed)
                self.ways[v] = w
                alloc_hist[v].append(int(alloc[v]))
                sub = subs[v]
                for chunk in sub.intervals(cfg.sim_chunk):
                    a, wr = _pad(np.asarray(chunk.addr, np.int32),
                                 np.asarray(chunk.is_write), cfg.sim_chunk)
                    self.caches[v], st, t_end = simulator.simulate_single_level(
                        a, wr, self.caches[v], int(self.ways[v]),
                        policies[v], t0=int(self.t[v]))
                    self.t[v] = int(t_end)
                    _acc(self.stats[v], st)
        return [VMResult(dict(self.stats[v]),
                         np.asarray(alloc_hist[v], np.int64))
                for v in range(self.num_vms)]
