"""Cache write-policy semantics (paper §3).

Five policies appear in the paper; their datapath semantics are summarized
by three predicates used uniformly by the reuse-distance engine and the
simulators:

  * ``allocates_reads``  — does a read miss insert the block into the cache?
  * ``allocates_writes`` — does a write (miss) insert the block into the cache?
  * ``write_invalidates`` — does a write remove/invalidate a cached copy
    (instead of updating it in place)?

====== ================= ================== =================
policy allocates_reads   allocates_writes   write_invalidates
====== ================= ================== =================
WB     yes               yes                no
WT     yes               yes                no
RO     yes               no                 yes
WO     no                yes                no
WBWO   no                yes                no
====== ================= ================== =================

WT differs from WB only in that writes are *also* committed to the backing
store immediately (reliability), which the simulators account for in the
latency/endurance model, not in the content model. WBWO ("WB and WO") is
the paper's name for the write-only-allocating write-back cache used at
ETICA's SSD level; WO is retained as an alias with identical content
semantics.
"""
from __future__ import annotations

import enum


class Policy(enum.Enum):
    WB = "WB"
    WT = "WT"
    RO = "RO"
    WO = "WO"
    WBWO = "WBWO"

    # ---- content-model predicates -------------------------------------
    @property
    def allocates_reads(self) -> bool:
        return self in (Policy.WB, Policy.WT, Policy.RO)

    @property
    def allocates_writes(self) -> bool:
        return self in (Policy.WB, Policy.WT, Policy.WO, Policy.WBWO)

    @property
    def write_invalidates(self) -> bool:
        return self is Policy.RO

    # ---- reliability/latency-model predicates -------------------------
    @property
    def write_through(self) -> bool:
        """Writes are synchronously committed to the backing store."""
        return self in (Policy.WT, Policy.RO)

    @property
    def holds_dirty(self) -> bool:
        """The cache may hold write-pending (dirty) blocks."""
        return self in (Policy.WB, Policy.WO, Policy.WBWO)


# Device latency model (paper Fig. 1 device ratios: HDD:SSD:DRAM IOPS of
# roughly 1 : 500 : 10,000 for 4KB random accesses). Units: seconds/block.
# Disk WRITES are absorbed by the RAID controller's battery-backed write
# cache (the paper's testbed uses an LSI9361i), so they cost far less
# than a random-read seek — still ~50x slower than the SSD tier.
T_DRAM = 0.5e-6
T_SSD = 10e-6
T_HDD = 5e-3          # random read (seek-bound)
T_HDD_WRITE = 0.5e-3  # controller-buffered write


class Level(enum.IntEnum):
    """Where a request was served from."""
    DRAM = 0
    SSD = 1
    DISK = 2


LEVEL_LATENCY = {Level.DRAM: T_DRAM, Level.SSD: T_SSD, Level.DISK: T_HDD}
