from .adamw import OptConfig, apply_updates, clip_by_global_norm, \
    global_norm, init_opt_state, schedule
from .compress import (compressed_psum, dequantize_int8, ef_compress_update,
                       init_error_buf, quantize_int8)
