"""AdamW with warmup+cosine schedule and global-norm clipping.

Functional: ``opt_state`` is a pytree mirroring params (first/second
moments + step counter). Moment dtype is configurable — bf16 moments are
one of the §Perf memory levers for the ≥100B configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"     # "float32" | "bfloat16"


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params, cfg: OptConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def apply_updates(params, grads, opt_state, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m_new.astype(mdt), v_new.astype(mdt))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
