"""Gradient compression for the data-parallel all-reduce.

int8 row-wise quantization with error feedback: grads are quantized to
int8 (per-row absmax scale) before the cross-replica ``psum``, cutting DP
collective bytes 4x; the quantization residual is carried in an error
buffer and added to the next step's gradient, which keeps convergence
unbiased in expectation (standard EF-SGD argument).

The collective itself runs under ``shard_map`` so the int8 tensors are
what actually travels the links; everything composes with jit/GSPMD.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def quantize_int8(x):
    """Row-wise (leading-axis) absmax int8 quantization."""
    xf = x.astype(jnp.float32)
    flat = xf.reshape(x.shape[0] if x.ndim > 1 else 1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale


def dequantize_int8(q, scale, shape):
    flat = q.reshape(shape[0] if len(shape) > 1 else 1, -1)
    return (flat.astype(jnp.float32) * scale).reshape(shape)


def compressed_psum(grads, mesh, axis_names=("data",)):
    """All-reduce a gradient pytree with int8 on-the-wire compression."""
    specs = jax.tree_util.tree_map(lambda _: P(), grads)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(specs,), out_specs=specs,
        check_rep=False)
    def reduce_fn(g):
        def one(x):
            q, scale = quantize_int8(x)
            total = jax.lax.psum(q.astype(jnp.int32), axis_names)
            scale_sum = jax.lax.psum(scale, axis_names)
            n = 1
            for a in axis_names:
                n *= mesh.shape[a]
            # average of dequantized replicas (shared mean scale)
            return (total.astype(jnp.float32).reshape(
                x.shape[0] if x.ndim > 1 else 1, -1)
                * (scale_sum / n / n)).reshape(x.shape).astype(x.dtype)
        return jax.tree_util.tree_map(one, g)

    return reduce_fn(grads)


def ef_compress_update(grads, error_buf):
    """Error-feedback: returns (quantized-dequantized grads, new error)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale, corrected.shape)
        return deq.astype(g.dtype), (corrected - deq)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_buf)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error_buf(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
