"""Pallas TPU kernels: maintenance scatters over stacked ``[V, S, W]``.

Both kernels run on a ``(V, num_set_tiles)`` grid — one VM x one strip
of ``TS`` sets per step — with the VM's whole maintenance queue ``[Q]``
resident in VMEM for every strip (the queue is the small operand: 5% of
the partition, -1-padded to a power of two). The set dimension is
innermost, so the per-VM count output block accumulates across set
strips, the same reduction pattern as the other kernels in this repo.

  * **evict**: membership mask (``tags in queue``) per strip, clearing
    matched ways and counting dirty flushes. The ``[TS*W, Q]`` equality
    mask is evaluated in ``QC``-column chunks to bound VMEM.
  * **clean**: the background dirty-block cleaner. The expensive part —
    ranking dirty blocks by age — is a per-VM (lru, flat-index) cutoff
    pair precomputed in the fused dispatch (``ops._clean_cutoffs``); the
    kernel applies the cutoff per strip: a candidate flushes iff its
    lexicographic (lru, flat-index) key is <= the cutoff, clearing only
    the dirty bit (flushed blocks stay resident and clean) and
    accumulating per-VM flush counts.
  * **promote**: the full queue contract of
    ``repro.core.simulator.promote_blocks_ref`` — first occurrence of an
    address wins (optional O(Q^2/QC) in-kernel dedupe, skippable when
    the caller guarantees unique queues), addresses already resident are
    skipped, and the k-th eligible address of a set lands in the set's
    k-th free active way (queue order), so a full set starves later
    entries exactly like the sequential oracle.

VMEM per step: O(TS*W + Q) vectors plus a transient ``TS x QC x W``
selection block (default 16 x 128 x 64 = 128K lanes, 512KB of f32 —
well inside a core's 16MB). Per-VM scalars
(active ways, promote timestamp) ride ``(1,)`` blocks like the
popularity kernel's cache-size scalar. ``dirty`` travels as int32 (VPU
lane-friendly); the ops wrapper converts from/to bool.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TS = 16    # sets per grid step
DEFAULT_QC = 128   # queue chunk streamed against each strip


# ---------------------------------------------------------------------------
# evict
# ---------------------------------------------------------------------------

def _evict_kernel(tags_ref, lru_ref, dirty_ref, q_ref,
                  otags_ref, olru_ref, odirty_ref, flush_ref, *, qc: int):
    s_blk = pl.program_id(1)
    tags = tags_ref[0]          # [TS, W]
    dirty = dirty_ref[0]        # [TS, W] int32 (0/1)
    queue = q_ref[0]            # [Q], -1 = padding
    nq = queue.shape[0]

    def body(c, m):
        blk = jax.lax.dynamic_slice(queue, (c * qc,), (qc,))
        return m | jnp.any(tags[:, :, None] == blk[None, None, :], axis=2)

    mask = jax.lax.fori_loop(0, nq // qc, body,
                             jnp.zeros(tags.shape, bool))
    mask = mask & (tags >= 0)   # -1 queue padding never matches a block

    otags_ref[0] = jnp.where(mask, -1, tags)
    olru_ref[0] = jnp.where(mask, -1, lru_ref[0])
    odirty_ref[0] = jnp.where(mask, 0, dirty)

    @pl.when(s_blk == 0)
    def _init():
        flush_ref[...] = jnp.zeros_like(flush_ref)

    flush_ref[...] += jnp.sum(mask & (dirty > 0)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("ts", "qc", "interpret"))
def evict_scatter(tags, lru, dirty, queue, *, ts: int = DEFAULT_TS,
                  qc: int = DEFAULT_QC, interpret: bool = True):
    """Evict queued blocks from stacked states.

    ``tags``/``lru``/``dirty`` are ``[V, S, W]`` int32 (``S`` a multiple
    of ``ts``); ``queue`` is ``[V, Q]`` int32 with ``Q`` a multiple of
    ``qc`` and ``-1`` padding. Returns ``(tags, lru, dirty, flushed[V])``.
    """
    v, s, w = tags.shape
    nq = queue.shape[1]
    grid = (v, s // ts)
    strip = pl.BlockSpec((1, ts, w), lambda i, j: (i, j, 0))
    per_vm = pl.BlockSpec((1,), lambda i, j: (i,))
    return pl.pallas_call(
        functools.partial(_evict_kernel, qc=qc),
        grid=grid,
        in_specs=[strip, strip, strip,
                  pl.BlockSpec((1, nq), lambda i, j: (i, 0))],
        out_specs=[strip, strip, strip, per_vm],
        out_shape=[jax.ShapeDtypeStruct(tags.shape, jnp.int32),
                   jax.ShapeDtypeStruct(tags.shape, jnp.int32),
                   jax.ShapeDtypeStruct(tags.shape, jnp.int32),
                   jax.ShapeDtypeStruct((v,), jnp.int32)],
        interpret=interpret,
    )(tags, lru, dirty, queue)


# ---------------------------------------------------------------------------
# clean (background dirty-block flush)
# ---------------------------------------------------------------------------

def _clean_kernel(dirty_ref, lru_ref, ways_ref, lcut_ref, icut_ref,
                  odirty_ref, flush_ref, *, ts: int):
    s_blk = pl.program_id(1)
    dirty = dirty_ref[0]        # [TS, W] int32 (0/1)
    lru = lru_ref[0]            # [TS, W]
    ways = ways_ref[0]          # scalar: active ways for this VM
    lcut = lcut_ref[0]          # scalar: lru of the last block to flush
    icut = icut_ref[0]          # scalar: its flat set*W+way index
    n_ts, w = dirty.shape

    widx = jnp.arange(w, dtype=jnp.int32)
    sidx = s_blk * ts + jnp.arange(n_ts, dtype=jnp.int32)
    flat = sidx[:, None] * w + widx[None, :]           # global (set, way) id
    cand = (dirty > 0) & (widx[None, :] < ways)
    # the (lru, flat) keys are unique, so the lexicographic cutoff selects
    # exactly the `take` oldest candidates ranked by ops._clean_cutoffs
    flush = cand & ((lru < lcut) | ((lru == lcut) & (flat <= icut)))
    odirty_ref[0] = jnp.where(flush, 0, dirty)

    @pl.when(s_blk == 0)
    def _init():
        flush_ref[...] = jnp.zeros_like(flush_ref)

    flush_ref[...] += jnp.sum(flush).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("ts", "interpret"))
def clean_scatter(dirty, lru, ways, lru_cut, idx_cut, *,
                  ts: int = DEFAULT_TS, interpret: bool = True):
    """Flush (clear dirty) every dirty active block at or below the
    per-VM age cutoff.

    ``dirty``/``lru`` are ``[V, S, W]`` int32 (``S`` a multiple of
    ``ts``); ``ways``/``lru_cut``/``idx_cut`` are ``[V]`` int32 — the
    cutoff pair is the (lru, flat set*W+way index) key of the last block
    to flush (``(INT32_MIN, -1)`` = flush nothing). Returns ``(dirty,
    flushed[V])``.
    """
    v, s, w = dirty.shape
    grid = (v, s // ts)
    strip = pl.BlockSpec((1, ts, w), lambda i, j: (i, j, 0))
    per_vm = pl.BlockSpec((1,), lambda i, j: (i,))
    return pl.pallas_call(
        functools.partial(_clean_kernel, ts=ts),
        grid=grid,
        in_specs=[strip, strip, per_vm, per_vm, per_vm],
        out_specs=[strip, per_vm],
        out_shape=[jax.ShapeDtypeStruct(dirty.shape, jnp.int32),
                   jax.ShapeDtypeStruct((v,), jnp.int32)],
        interpret=interpret,
    )(dirty, lru, ways, lru_cut, idx_cut)


# ---------------------------------------------------------------------------
# promote
# ---------------------------------------------------------------------------

def _promote_kernel(tags_ref, lru_ref, dirty_ref, q_ref, ways_ref, t_ref,
                    otags_ref, olru_ref, odirty_ref, n_ref, *,
                    num_sets: int, ts: int, qc: int, dedupe: bool):
    s_blk = pl.program_id(1)
    tags = tags_ref[0]          # [TS, W]
    queue = q_ref[0]            # [Q]
    ways = ways_ref[0]          # scalar: active ways for this VM
    tstamp = t_ref[0]           # scalar: promote timestamp
    n_ts, w = tags.shape
    nq = queue.shape[0]

    qidx = jnp.arange(nq, dtype=jnp.int32)
    valid = queue >= 0
    qa = jnp.where(valid, queue, 0)
    local = qa % num_sets - s_blk * ts          # set index within strip
    in_tile = valid & (local >= 0) & (local < ts)

    if dedupe:
        # first occurrence of each address wins: dup[i] = any j < i with
        # the same address, evaluated in QC-column chunks
        def dbody(c, dup):
            blk = jax.lax.dynamic_slice(queue, (c * qc,), (qc,))
            bidx = c * qc + jnp.arange(qc, dtype=jnp.int32)
            m = ((qa[:, None] == blk[None, :]) & (blk[None, :] >= 0)
                 & (bidx[None, :] < qidx[:, None]))
            return dup | jnp.any(m, axis=1)

        dup = jax.lax.fori_loop(0, nq // qc, dbody, jnp.zeros(nq, bool))
        valid = valid & ~dup

    active = jnp.arange(w, dtype=jnp.int32) < ways     # [W]
    set_ids = jnp.arange(ts, dtype=jnp.int32)          # [TS]

    # residency check against this strip (a block only maps to one set)
    def pbody(c, present):
        lblk = jax.lax.dynamic_slice(local, (c * qc,), (qc,))
        ablk = jax.lax.dynamic_slice(qa, (c * qc,), (qc,))
        sel = (lblk[:, None, None] == set_ids[None, :, None]) \
            & (tags[None, :, :] == ablk[:, None, None]) \
            & active[None, None, :]                    # [QC, TS, W]
        return jax.lax.dynamic_update_slice(
            present, jnp.any(sel, axis=(1, 2)), (c * qc,))

    present = jax.lax.fori_loop(0, nq // qc, pbody, jnp.zeros(nq, bool))

    elig = valid & in_tile & ~present & (ways > 0)
    # rank of each eligible entry among its set's eligible entries, in
    # queue order; the k-th one lands in the set's k-th free active way
    eligm = (local[None, :] == set_ids[:, None]) & elig[None, :]  # [TS, Q]
    eligm_i = eligm.astype(jnp.int32)
    rank = jnp.cumsum(eligm_i, axis=1) - eligm_i
    free = active[None, :] & (tags < 0)                           # [TS, W]
    freerank = jnp.cumsum(free.astype(jnp.int32), axis=1) - 1
    nfree = jnp.sum(free.astype(jnp.int32), axis=1)               # [TS]
    prom = eligm & (rank < nfree[:, None])                        # [TS, Q]

    # scatter: one-hot (promoted entry -> its free way), QC chunks
    def sbody(c, carry):
        acc, hit = carry
        pblk = jax.lax.dynamic_slice(prom, (0, c * qc), (ts, qc))
        rblk = jax.lax.dynamic_slice(rank, (0, c * qc), (ts, qc))
        ablk = jax.lax.dynamic_slice(qa, (c * qc,), (qc,))
        sel = pblk[:, :, None] & (rblk[:, :, None] == freerank[:, None, :]) \
            & free[:, None, :]                         # [TS, QC, W]
        acc = acc + jnp.sum(sel * ablk[None, :, None], axis=1)
        return acc, hit | jnp.any(sel, axis=1)

    acc, hit = jax.lax.fori_loop(
        0, nq // qc, sbody,
        (jnp.zeros(tags.shape, jnp.int32), jnp.zeros(tags.shape, bool)))

    otags_ref[0] = jnp.where(hit, acc, tags)
    olru_ref[0] = jnp.where(hit, tstamp, lru_ref[0])
    odirty_ref[0] = jnp.where(hit, 0, dirty_ref[0])

    @pl.when(s_blk == 0)
    def _init():
        n_ref[...] = jnp.zeros_like(n_ref)

    n_ref[...] += jnp.sum(prom).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("num_sets", "ts", "qc", "interpret",
                                    "dedupe"))
def promote_scatter(tags, lru, dirty, queue, ways, t, *, num_sets: int,
                    ts: int = DEFAULT_TS, qc: int = DEFAULT_QC,
                    dedupe: bool = True, interpret: bool = True):
    """Promote queued blocks into free active ways of stacked states.

    Shapes as :func:`evict_scatter` plus per-VM ``ways``/``t`` ``[V]``
    int32. ``num_sets`` is the REAL set count (tiles may pad ``S``
    beyond it; padded sets are never addressed since ``addr %% num_sets
    < num_sets``). ``dedupe=False`` skips the O(Q^2) first-occurrence
    pass when the caller guarantees unique queue entries (the popularity
    table's queues are unique by construction). Returns ``(tags, lru,
    dirty, promoted[V])``.
    """
    v, s, w = tags.shape
    nq = queue.shape[1]
    grid = (v, s // ts)
    strip = pl.BlockSpec((1, ts, w), lambda i, j: (i, j, 0))
    per_vm = pl.BlockSpec((1,), lambda i, j: (i,))
    return pl.pallas_call(
        functools.partial(_promote_kernel, num_sets=num_sets, ts=ts, qc=qc,
                          dedupe=dedupe),
        grid=grid,
        in_specs=[strip, strip, strip,
                  pl.BlockSpec((1, nq), lambda i, j: (i, 0)),
                  per_vm, per_vm],
        out_specs=[strip, strip, strip, per_vm],
        out_shape=[jax.ShapeDtypeStruct(tags.shape, jnp.int32),
                   jax.ShapeDtypeStruct(tags.shape, jnp.int32),
                   jax.ShapeDtypeStruct(tags.shape, jnp.int32),
                   jax.ShapeDtypeStruct((v,), jnp.int32)],
        interpret=interpret,
    )(tags, lru, dirty, queue, ways, t)
