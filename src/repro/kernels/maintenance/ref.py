"""Sequential numpy oracles for the maintenance kernels.

Same semantics as ``repro.core.simulator.evict_blocks_ref`` /
``promote_blocks_ref``, lifted to stacked ``[V, S, W]`` states with one
(possibly empty, possibly ``-1``-padded) queue per VM — the contract the
Pallas kernels are property-tested against bit for bit.
"""
from __future__ import annotations

import numpy as np


def evict_ref(tags, lru, dirty, queues):
    """Per-VM eviction over stacked states.

    ``tags``/``lru``/``dirty`` are ``[V, S, W]`` numpy arrays; ``queues``
    is one 1-D address array per VM (``-1`` entries ignored). Returns
    ``(tags, lru, dirty, flushed[V])`` copies.
    """
    tags = np.asarray(tags).copy()
    lru = np.asarray(lru).copy()
    dirty = np.asarray(dirty).copy()
    flushed = np.zeros(tags.shape[0], np.int32)
    for v, q in enumerate(queues):
        q = np.asarray(q).reshape(-1)
        q = q[q >= 0]
        mask = np.isin(tags[v], q) & (tags[v] >= 0)
        flushed[v] = int((dirty[v].astype(bool) & mask).sum())
        tags[v][mask] = -1
        lru[v][mask] = -1
        dirty[v][mask] = 0
    return tags, lru, dirty, flushed


def clean_ref(tags, lru, dirty, ways, quota):
    """Per-VM background cleaning over stacked states (third stage).

    Flush candidates are the dirty blocks in active ways; age order is
    (``lru`` ascending, flat ``set * W + way`` index ascending) — a total
    order because flat indices are unique. The first ``quota[v]``
    candidates flush: the dirty bit clears, tags/lru stay untouched (a
    flushed block remains resident and clean). Returns ``(tags, lru,
    dirty, flushed[V])`` copies.
    """
    tags = np.asarray(tags).copy()
    lru = np.asarray(lru).copy()
    dirty = np.asarray(dirty).copy()
    ways = np.asarray(ways).reshape(-1)
    quota = np.asarray(quota).reshape(-1)
    num_vms, num_sets, num_ways = tags.shape
    flushed = np.zeros(num_vms, np.int32)
    for v in range(num_vms):
        wa = min(max(int(ways[v]), 0), num_ways)
        cand = [(int(lru[v, s, w]), s * num_ways + w, s, w)
                for s in range(num_sets) for w in range(wa)
                if dirty[v, s, w]]
        cand.sort()
        for _, _, s, w in cand[: max(int(quota[v]), 0)]:
            dirty[v, s, w] = 0
            flushed[v] += 1
    return tags, lru, dirty, flushed


def promote_ref(tags, lru, dirty, queues, ways, t):
    """Per-VM promotion over stacked states (sequential queue drain).

    First occurrence of an address wins; addresses already resident in
    an active way are skipped; each promotion fills the lowest free
    active way of the block's set; a full set starves later entries.
    Returns ``(tags, lru, dirty, promoted[V])`` copies.
    """
    tags = np.asarray(tags).copy()
    lru = np.asarray(lru).copy()
    dirty = np.asarray(dirty).copy()
    ways = np.asarray(ways).reshape(-1)
    t = np.asarray(t).reshape(-1)
    num_sets = tags.shape[1]
    promoted = np.zeros(tags.shape[0], np.int32)
    for v, q in enumerate(queues):
        wa = int(ways[v])
        for a in np.asarray(q).reshape(-1):
            if a < 0 or wa <= 0:
                continue
            s = int(a) % num_sets
            if (tags[v, s, :wa] == a).any():
                continue
            free = np.nonzero(tags[v, s, :wa] < 0)[0]
            if free.size == 0:
                continue
            w = free[0]
            tags[v, s, w] = a
            lru[v, s, w] = int(t[v])
            dirty[v, s, w] = 0
            promoted[v] += 1
    return tags, lru, dirty, promoted
