"""Jitted wrappers for the maintenance kernels + the fused interval op.

``evict`` / ``promote`` mirror the contracts of
``repro.core.simulator.evict_blocks_batch`` / ``promote_blocks_batch``
but run the scatters through the Pallas kernels (interpret mode on CPU,
compiled on TPU — ``interpret=None`` picks by backend, overridable with
``ETICA_PALLAS_INTERPRET=0|1``).

``maintenance_interval`` is the whole between-interval maintenance of
the batched :class:`~repro.core.controller.EticaCache` as ONE jitted
dispatch: Eq. 1 contributions -> device popularity-table update ->
eviction-queue build -> evict kernel -> free-space recount ->
promotion-queue build -> promote kernel -> background cleaner (age
cutoff + clean kernel, when ``clean_quota > 0``). The post-eviction
state feeds the promotion stage on device and the post-promotion state
feeds the cleaner — there is no ``np.asarray(state)`` sync anywhere
between stages; only the final per-VM counts ever reach the host.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import popularity as pop
from repro.core.simulator import CacheState, _next_pow2, _pad_addrs_batch
from repro.kernels import use_interpret

from .kernel import (DEFAULT_QC, DEFAULT_TS, clean_scatter, evict_scatter,
                     promote_scatter)


def _tiles(s: int, ts: int) -> tuple[int, int]:
    """(effective set-tile, padded S) — S padded up to a tile multiple."""
    ts = min(ts, _next_pow2(s))
    return ts, -(-s // ts) * ts


def _pad_sets(x, s_pad: int, fill):
    v, s, w = x.shape
    if s == s_pad:
        return x
    return jnp.concatenate(
        [x, jnp.full((v, s_pad - s, w), fill, x.dtype)], axis=1)


@functools.partial(jax.jit, static_argnames=("ts", "qc", "interpret"))
def _evict_state(state: CacheState, queue, *, ts, qc, interpret):
    v, s, w = state.tags.shape
    ts, s_pad = _tiles(s, ts)
    tags, lru, dirty, flushed = evict_scatter(
        _pad_sets(state.tags, s_pad, -1),
        _pad_sets(state.lru, s_pad, -1),
        _pad_sets(state.dirty.astype(jnp.int32), s_pad, 0),
        queue, ts=ts, qc=qc, interpret=interpret)
    return CacheState(tags[:, :s], lru[:, :s],
                      dirty[:, :s].astype(bool)), flushed


@functools.partial(jax.jit,
                   static_argnames=("ts", "qc", "dedupe", "interpret"))
def _promote_state(state: CacheState, queue, ways, t, *, ts, qc, dedupe,
                   interpret):
    v, s, w = state.tags.shape
    ts, s_pad = _tiles(s, ts)
    tags, lru, dirty, n = promote_scatter(
        _pad_sets(state.tags, s_pad, -1),
        _pad_sets(state.lru, s_pad, -1),
        _pad_sets(state.dirty.astype(jnp.int32), s_pad, 0),
        queue, jnp.asarray(ways, jnp.int32), jnp.asarray(t, jnp.int32),
        num_sets=s, ts=ts, qc=qc, dedupe=dedupe, interpret=interpret)
    return CacheState(tags[:, :s], lru[:, :s],
                      dirty[:, :s].astype(bool)), n


def _clean_cutoffs(dirty, lru, ways, quota):
    """Per-VM age cutoffs for the background cleaner.

    Candidates are the dirty blocks in active ways, aged by the unique
    lexicographic key (lru ascending, flat ``set * W + way`` index
    ascending). Returns ``(lru_cut[V], idx_cut[V], take[V], n_cand[V])``
    where the cutoff pair is the key of the ``take``-th oldest candidate
    (``take = min(quota, n_cand)``); sentinel ``(INT32_MIN, -1)`` when
    nothing flushes. The kernel then flushes exactly the candidates with
    key <= cutoff.

    The two-pass stable argsort is an int32-safe lexsort: sorting by lru
    first and then (stably) by not-candidate yields candidates first, in
    (lru, index) order — no composite 64-bit keys, no sentinel values
    that could collide with real lru timestamps.
    """
    v, s, w = dirty.shape
    active = jnp.arange(w, dtype=jnp.int32)[None, None, :] < ways[:, None, None]
    cflat = (dirty & active).reshape(v, s * w)
    lflat = lru.reshape(v, s * w)
    ord1 = jnp.argsort(lflat, axis=1, stable=True)
    c1 = jnp.take_along_axis(cflat, ord1, axis=1)
    order = jnp.take_along_axis(ord1, jnp.argsort(~c1, axis=1, stable=True),
                                axis=1)
    n_cand = jnp.sum(cflat, axis=1).astype(jnp.int32)
    take = jnp.minimum(jnp.asarray(quota, jnp.int32), n_cand)
    kth = jnp.maximum(take - 1, 0)
    idx_k = jnp.take_along_axis(order, kth[:, None], axis=1)[:, 0]
    lru_k = jnp.take_along_axis(lflat, idx_k[:, None], axis=1)[:, 0]
    has = take > 0
    return (jnp.where(has, lru_k, jnp.int32(-2**31)).astype(jnp.int32),
            jnp.where(has, idx_k, -1).astype(jnp.int32), take, n_cand)


@functools.partial(jax.jit, static_argnames=("ts", "interpret"))
def _clean_state(state: CacheState, ways, quota, *, ts, interpret):
    v, s, w = state.tags.shape
    lcut, icut, take, n_cand = _clean_cutoffs(state.dirty, state.lru, ways,
                                              quota)
    ts, s_pad = _tiles(s, ts)
    dirty, cleaned = clean_scatter(
        _pad_sets(state.dirty.astype(jnp.int32), s_pad, 0),
        _pad_sets(state.lru, s_pad, -1),
        ways, lcut, icut, ts=ts, interpret=interpret)
    return (CacheState(state.tags, state.lru, dirty[:, :s].astype(bool)),
            cleaned, n_cand - take)


def clean(state: CacheState, ways, quota, *, ts: int = DEFAULT_TS,
          interpret: bool | None = None):
    """Kernel-backed background cleaner over stacked states.

    Flushes (clears the dirty bit of) up to ``quota[v]`` of VM ``v``'s
    oldest dirty active blocks — age order (lru, flat index) ascending;
    flushed blocks stay resident and clean. ``ways``/``quota`` are
    ``[V]`` (scalars broadcast). Returns ``(state, flushed[V],
    dirty_left[V])``, oracle-identical to ``ref.clean_ref``.
    """
    v = state.tags.shape[0]
    ways = jnp.broadcast_to(jnp.asarray(ways, jnp.int32), (v,))
    quota = jnp.broadcast_to(jnp.asarray(quota, jnp.int32), (v,))
    interpret = use_interpret() if interpret is None else interpret
    return _clean_state(state, ways, quota, ts=ts, interpret=interpret)


def _queue_matrix(queues) -> np.ndarray:
    """Ragged per-VM queues -> one [V, Q] -1-padded rectangle, Q a
    power-of-two multiple of the chunk width."""
    q = _pad_addrs_batch(queues)
    width = _next_pow2(q.shape[1])
    out = np.full((q.shape[0], width), -1, np.int32)
    out[:, : q.shape[1]] = q
    return out


def _pow2_queue(queue) -> jax.Array:
    """Pad a [V, Q] queue to a power-of-two width (with -1 no-ops) so
    the kernels' chunked loops cover every column — a non-multiple tail
    would otherwise be silently skipped."""
    queue = jnp.asarray(queue, jnp.int32)
    width = _next_pow2(max(queue.shape[1], 1))
    if width == queue.shape[1]:
        return queue
    return jnp.concatenate(
        [queue, jnp.full((queue.shape[0], width - queue.shape[1]), -1,
                         jnp.int32)], axis=1)


def evict(state: CacheState, queues, *, ts: int = DEFAULT_TS,
          qc: int = DEFAULT_QC, interpret: bool | None = None):
    """Kernel-backed :func:`repro.core.simulator.evict_blocks_batch`.

    ``queues`` is one (possibly empty) address array per VM, or an
    already-rectangular ``[V, Q]`` array with ``-1`` padding. Returns
    ``(state, flushed[V])`` with identical states/counts to the numpy
    oracle (``ref.evict_ref``).
    """
    if not isinstance(queues, (np.ndarray, jax.Array)):
        queues = _queue_matrix(queues)
    queues = _pow2_queue(queues)
    qc = min(qc, queues.shape[1])
    interpret = use_interpret() if interpret is None else interpret
    return _evict_state(state, queues, ts=ts, qc=qc, interpret=interpret)


def promote(state: CacheState, queues, ways, t, *, ts: int = DEFAULT_TS,
            qc: int = DEFAULT_QC, assume_unique: bool = False,
            interpret: bool | None = None):
    """Kernel-backed :func:`repro.core.simulator.promote_blocks_batch`.

    ``ways``/``t`` are ``[V]``. ``assume_unique=True`` skips the
    in-kernel first-occurrence dedupe (valid when the caller guarantees
    unique addresses per queue, as the popularity table does). Returns
    ``(state, promoted[V])``, oracle-identical (``ref.promote_ref``).
    """
    if not isinstance(queues, (np.ndarray, jax.Array)):
        queues = _queue_matrix(queues)
    queues = _pow2_queue(queues)
    qc = min(qc, queues.shape[1])
    interpret = use_interpret() if interpret is None else interpret
    return _promote_state(state, queues, jnp.asarray(ways, jnp.int32),
                          jnp.asarray(t, jnp.int32), ts=ts, qc=qc,
                          dedupe=not assume_unique, interpret=interpret)


# ---------------------------------------------------------------------------
# the fused per-interval dispatch
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("evict_frac", "decay", "clean_quota", "ts",
                              "qc", "interpret"))
def _maintenance_impl(ssd: CacheState, table: pop.PopularityTable,
                      dist, served, waddr, wlen, ways, t, *,
                      evict_frac: float, decay: float, clean_quota: int,
                      ts: int, qc: int, interpret: bool):
    v, s, w = ssd.tags.shape
    nval = jnp.asarray(wlen, jnp.int32)
    live = nval > 0
    ways = jnp.asarray(ways, jnp.int32)
    alloc = ways * s

    # 1) Eq. 1 popularity refresh, straight into the [V, K] device table
    contrib = pop.contributions(dist, served,
                                jnp.maximum(alloc, 1)[:, None])
    table, drops = pop.table_update(table, waddr, contrib, nval, live, decay)

    # 2) eviction queue (bottom-frac of residents when >= 90% full) ->
    #    evict kernel
    equeue, eqlen = pop.table_least_popular(table, ssd.tags, ways, alloc,
                                            live, evict_frac)
    equeue = pop.truncate_queue(equeue, _next_pow2(s * w))
    ssd, flushed = _evict_state(ssd, equeue, ts=ts,
                                qc=min(qc, equeue.shape[1]),
                                interpret=interpret)

    # 3) free space from the POST-eviction state (no host sync) ->
    #    promotion queue -> promote kernel
    active = jnp.arange(w, dtype=jnp.int32)[None, None, :] < ways[:, None, None]
    n_res = jnp.sum((ssd.tags >= 0) & active, axis=(1, 2)).astype(jnp.int32)
    free = jnp.maximum(alloc - n_res, 0)
    pqueue, pqlen = pop.table_top_known(
        table, ssd.tags, ways, free, live,
        width=_next_pow2(min(table.capacity, s * w)))
    ssd, promoted = _promote_state(ssd, pqueue, ways,
                                   jnp.asarray(t, jnp.int32), ts=ts,
                                   qc=min(qc, pqueue.shape[1]),
                                   dedupe=False, interpret=interpret)

    # 4) background cleaner (third stage): age-ranked scan over the
    #    post-promotion dirty blocks, flushing up to `clean_quota` per
    #    live VM. Rides the same dispatch — the per-VM counts join the
    #    others in the single end-of-interval host sync.
    if clean_quota > 0:
        quota_v = jnp.where(live, jnp.int32(clean_quota), 0)
        ssd, cleaned, dirty_left = _clean_state(ssd, ways, quota_v, ts=ts,
                                                interpret=interpret)
    else:
        cleaned = jnp.zeros(v, jnp.int32)
        dirty_left = jnp.sum(ssd.dirty & active, axis=(1, 2)).astype(jnp.int32)
    return (ssd, table, flushed, promoted, eqlen, pqlen, drops, cleaned,
            dirty_left)


@functools.lru_cache(maxsize=None)
def _maintenance_sharded(mesh, evict_frac, decay, clean_quota, ts, qc,
                         interpret):
    """``shard_map`` of :func:`_maintenance_impl` over a VM mesh: each
    device runs the full three-stage maintenance on its own ``[V/d, ...]``
    block of states/queues. Queue widths depend only on geometry and
    window bucket (never on V), so per-shard shapes line up and the
    compiled HLO is collective-free (asserted by the sharding tests)."""
    from jax.experimental import shard_map

    from repro.launch.mesh import vm_spec
    spec = vm_spec(mesh)

    def body(ssd, table, dist, served, waddr, wlen, ways, t):
        return _maintenance_impl(
            ssd, table, dist, served, waddr, wlen, ways, t,
            evict_frac=evict_frac, decay=decay, clean_quota=clean_quota,
            ts=ts, qc=qc, interpret=interpret)

    return jax.jit(shard_map.shard_map(
        body, mesh=mesh, in_specs=(spec,) * 8, out_specs=(spec,) * 9,
        check_rep=False))


def maintenance_interval(ssd: CacheState, table: pop.PopularityTable,
                         dist, served, waddr, wlen, ways, t, *,
                         evict_frac: float, decay: float,
                         clean_quota: int = 0,
                         ts: int = DEFAULT_TS, qc: int = DEFAULT_QC,
                         interpret: bool | None = None, mesh=None):
    """One interval of ETICA maintenance for all VMs, fused.

    Args:
      ssd: stacked ``[V, S, W]`` SSD-level :class:`CacheState`.
      table: the ``[V, K]`` :class:`~repro.core.popularity.PopularityTable`.
      dist/served/waddr: ``[V, N]`` TRD distance channels + addresses of
        the VMs' windows (pad tails masked by ``wlen``). Rows are kept
        rectangular across ALL VMs — idle VMs ride along as zero-length
        rows (``wlen == 0`` -> untouched) — so the executable is keyed
        only by the window's power-of-two bucket, never by which subset
        of VMs happens to be live.
      wlen: ``[V]`` valid window lengths (0 = idle VM, no maintenance).
      ways/t: ``[V]`` active SSD ways and per-VM clocks.
      evict_frac/decay: §4.2.1 bottom-fraction and aging factor.
      clean_quota: background-cleaner flush budget per live VM per
        interval (0 disables the third stage entirely).

    Returns ``(ssd, table, flushed[V], promoted[V], evict_qlen[V],
    promo_qlen[V], pop_drops[V], cleaned[V], dirty_left[V])`` — states
    and table stay on device; the count vectors are the only thing a
    caller needs to sync for Stats. ``pop_drops`` is the number of
    popularity entries pushed past the table's ``K`` slots by this merge
    (``Stats.pop_drops``); ``cleaned`` is the cleaner's flush count and
    ``dirty_left`` the dirty blocks still resident in active ways after
    the interval (``Stats.flushes`` / ``Stats.dirty_resident``).

    ``mesh`` splits the VM axis over a 1-d device mesh (V divisible by
    the mesh size; pad with dead ``wlen == 0`` VMs first): the whole
    dispatch runs shard-local with bit-identical per-VM results.
    """
    interpret = use_interpret() if interpret is None else interpret
    args = (ssd, table, jnp.asarray(dist, jnp.int32),
            jnp.asarray(served, bool), jnp.asarray(waddr, jnp.int32),
            jnp.asarray(wlen, jnp.int32), jnp.asarray(ways, jnp.int32),
            jnp.asarray(t, jnp.int32))
    if mesh is not None:
        from repro.launch.mesh import require_vm_divisible
        require_vm_divisible(int(ssd.tags.shape[0]), mesh)
        return _maintenance_sharded(
            mesh, float(evict_frac), float(decay), int(clean_quota), ts, qc,
            interpret)(*args)
    return _maintenance_impl(
        *args, evict_frac=float(evict_frac), decay=float(decay),
        clean_quota=int(clean_quota), ts=ts, qc=qc, interpret=interpret)


# ---------------------------------------------------------------------------
# the fused per-interval dispatch for the two-tier KV serving workload
# ---------------------------------------------------------------------------
#
# Serving sessions play the role of blocks (popularity is per session id)
# and tenants play the role of VMs; the "cache state" is the HBM page
# tables — per-tenant lists of resident sessions with their page counts —
# rather than a [V, S, W] tag array. One maintenance interval is one
# fused dispatch: Eq. 1 contributions over the mixed activation window,
# per-tenant demux, the [T, K] popularity-table merge, candidate scoring
# against the post-update table, and the cold-first eviction ranking that
# turns per-tenant over-quota page counts into per-session release
# counts. Only the final (order, take) queues and the updated table ever
# reach the host, which applies the releases to its page-table dicts.

@functools.partial(jax.jit,
                   static_argnames=("num_tenants", "decay", "clean_quota"))
def _serving_impl(table: pop.PopularityTable, dist, served, waddr, wtenant,
                  cand_sid, cand_pages, over, cache_size, dirty_age, *,
                  num_tenants: int, decay: float, clean_quota: int):
    t_axis, n = num_tenants, waddr.shape[0]

    # 1) Eq. 1 contributions over the MIXED window (distances were
    #    computed on the interleaved activation stream, exactly like the
    #    sequential oracle's single pod_distances call)
    contrib = pop.contributions(dist, served,
                                jnp.maximum(cache_size, 1))

    # 2) demux to [T, N] per-tenant rows, arrival order preserved: a
    #    stable sort by tenant groups each tenant's entries, and each
    #    entry's column is its rank within the group. Pad entries
    #    (tenant = -1) route to row T and are dropped.
    tn = jnp.where(wtenant >= 0, wtenant, t_axis).astype(jnp.int32)
    order = jnp.argsort(tn, stable=True)
    tn_sorted = tn[order]
    starts = jnp.searchsorted(tn_sorted,
                              jnp.arange(t_axis + 1, dtype=jnp.int32))
    col = jnp.arange(n, dtype=jnp.int32) - starts[tn_sorted]
    rows_addr = jnp.zeros((t_axis, n), jnp.int32).at[
        tn_sorted, col].set(waddr[order], mode="drop")
    rows_contrib = jnp.zeros((t_axis, n), jnp.float32).at[
        tn_sorted, col].set(contrib[order], mode="drop")
    n_valid = starts[1:] - starts[:-1]
    live = n_valid > 0

    # 3) [T, K] popularity merge (bit-identical to per-tenant
    #    PopularityTracker.update, incl. the live-row-only decay)
    table, drops = pop.table_update(table, rows_addr, rows_contrib,
                                    n_valid, live, decay)

    # 4) eviction ranking against the POST-update table: candidates are
    #    the resident sessions per tenant in page-table (slot-insertion)
    #    order; stable ascending argsort on their scores reproduces the
    #    oracle's `sorted(resident, key=score)` cold-first order, and the
    #    running page total turns the tenant's over-quota count into
    #    per-session release counts (partial last session allowed).
    valid = cand_sid >= 0
    scores = pop.table_scores(table, jnp.where(valid, cand_sid, 0))
    key = jnp.where(valid, scores, jnp.inf)
    eorder = jnp.argsort(key, axis=1, stable=True)
    pages_sorted = jnp.take_along_axis(
        jnp.where(valid, cand_pages, 0), eorder, axis=1)
    cum_before = jnp.cumsum(pages_sorted, axis=1) - pages_sorted
    take = jnp.clip(over[:, None] - cum_before, 0, pages_sorted)

    # 5) background cleaner: age-rank each tenant's dirty pages (ages are
    #    unique global append sequence numbers, so the order is total)
    #    and pick the oldest `clean_quota` to flush this interval
    if clean_quota > 0:
        dvalid = dirty_age >= 0
        dkey = jnp.where(dvalid, dirty_age, jnp.int32(2**31 - 1))
        ranks = jnp.argsort(jnp.argsort(dkey, axis=1, stable=True), axis=1)
        n_dirty = jnp.sum(dvalid, axis=1).astype(jnp.int32)
        dtake = jnp.minimum(jnp.int32(clean_quota), n_dirty)
        fpick = (dvalid & (ranks < dtake[:, None])).astype(jnp.int32)
    else:
        fpick = jnp.zeros(dirty_age.shape, jnp.int32)
    return (table, drops, eorder.astype(jnp.int32), take.astype(jnp.int32),
            fpick)


def serving_maintenance(table: pop.PopularityTable, dist, served, waddr,
                        wtenant, cand_sid, cand_pages, over, cache_size,
                        *, decay: float, dirty_age=None,
                        clean_quota: int = 0):
    """One fused serving-maintenance interval for all tenants.

    Args:
      table: the ``[T, K]`` session-popularity
        :class:`~repro.core.popularity.PopularityTable`.
      dist/served: the mixed activation window's POD(RO) channels
        (``[N]``, from ``reuse.pod_distances`` — the controller computes
        them once for the interleaved stream, as the oracle does).
      waddr: ``[N]`` session ids of the window, arrival order.
      wtenant: ``[N]`` tenant of each entry (recorded at request time;
        ``-1`` = padding).
      cand_sid/cand_pages: ``[T, Smax]`` eviction candidates — resident
        sessions per tenant in page-table insertion order with their
        resident-page counts (``-1``/0 padding). The active session must
        already be excluded by the caller.
      over: ``[T]`` pages over quota per tenant (<= 0 -> no eviction).
      cache_size: Eq. 1 normalizer (the controller passes the summed
        tenant quotas).
      decay: popularity aging factor.
      dirty_age: optional ``[T, Dmax]`` ages (unique append sequence
        numbers, ``-1`` = padding) of each tenant's dirty pages for the
        background cleaner; required when ``clean_quota > 0``.
      clean_quota: dirty pages flushed per tenant per interval (0
        disables the cleaner stage).

    Returns ``(table, pop_drops[T], order[T, Smax], take[T, Smax],
    fpick[T, Dmax])``: the updated device table, per-tenant
    merge-overflow drops, the eviction queue — ``order[t, i]`` indexes
    into ``cand_sid[t]`` coldest-first, ``take[t, i]`` is how many of
    that session's resident pages to release (0 past the quota point) —
    and the cleaner's 0/1 flush picks over ``dirty_age``'s columns
    (all-zero when the cleaner is off). Inputs are padded to
    power-of-two buckets so executables key on bucket sizes only.
    """
    n = int(np.shape(waddr)[0])
    nb = _next_pow2(max(n, 64))
    t_axis, smax = np.shape(cand_sid)
    sb = _next_pow2(max(smax, 8))
    if dirty_age is None:
        dirty_age = np.full((t_axis, 1), -1, np.int32)
    dmax = int(np.shape(dirty_age)[1])
    db = _next_pow2(max(dmax, 8))

    def padn(x, fill, dtype):
        x = jnp.asarray(x, dtype)
        return jnp.pad(x, (0, nb - n), constant_values=fill)

    cand_sid = jnp.pad(jnp.asarray(cand_sid, jnp.int32),
                       ((0, 0), (0, sb - smax)), constant_values=-1)
    cand_pages = jnp.pad(jnp.asarray(cand_pages, jnp.int32),
                         ((0, 0), (0, sb - smax)), constant_values=0)
    dirty_age = jnp.pad(jnp.asarray(dirty_age, jnp.int32),
                        ((0, 0), (0, db - dmax)), constant_values=-1)
    table, drops, eorder, take, fpick = _serving_impl(
        table, padn(dist, -1, jnp.int32), padn(served, False, bool),
        padn(waddr, 0, jnp.int32), padn(wtenant, -1, jnp.int32),
        cand_sid, cand_pages, jnp.asarray(over, jnp.int32),
        jnp.asarray(cache_size, jnp.float32), dirty_age,
        num_tenants=t_axis, decay=float(decay),
        clean_quota=int(clean_quota))
    return table, drops, eorder, take, fpick[:, :dmax]
