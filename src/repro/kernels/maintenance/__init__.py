"""Pallas kernels for ETICA's between-interval maintenance (paper §4.2).

The three maintenance scatters over stacked ``[V, S, W]`` cache states —
eviction (membership mask + dirty-flush count), promotion
(first-occurrence dedupe + per-set free-way ranking + scatter), and the
background cleaner (age-cutoff dirty flush) — tiled over ``(V, S)`` with
the per-VM queue streamed through VMEM, plus the fused per-interval
dispatch that chains popularity refresh, queue building, eviction,
promotion and cleaning into ONE jitted executable with no host
round-trips between stages (``ops.maintenance_interval``).
"""
from .ops import (clean, evict, promote, maintenance_interval,  # noqa: F401
                  serving_maintenance)
