"""Pallas TPU kernel: blocked causal flash-attention forward.

Grid (B, H, n_q_blocks, n_kv_blocks), kv innermost. Per step a
[TQ, D] query tile (MXU-aligned, D = head_dim is a multiple of 128 for
every assigned arch) attends a [TK, D] KV tile; the online-softmax
running (m, l, acc) state lives in VMEM scratch and survives across the
kv grid dimension; the output tile is written once on the last kv step.
GQA is native: the KV BlockSpec index-maps the query head h to its KV
head h // groups, so KV tiles are fetched once per group, not expanded
in HBM. Causal + sliding-window masking is applied in-tile.

VMEM per step ~ TQ*D (q) + 2*TK*D (kv) + TQ*TK (scores) + TQ*D (acc):
default 128/128 tiles with D=128 ≈ 200KB — comfortably inside 16MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_TQ = 128
DEFAULT_TK = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            tq: int, tk: int, causal: bool, window: int, scale: float,
            n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale      # [TQ, D]
    k = k_ref[0, 0].astype(jnp.float32)              # [TK, D]
    v = v_ref[0, 0].astype(jnp.float32)              # [TK, D]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [TQ, TK]
    q_pos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # [TQ, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                           # [TQ, TK]
    alpha = jnp.exp(m_prev - m_new)                  # [TQ, 1]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "tq", "tk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    tq: int = DEFAULT_TQ, tk: int = DEFAULT_TK,
                    interpret: bool = True):
    """q: [B, H, Sq, D]; k, v: [B, Hkv, Skv, D]. Returns [B, H, Sq, D]."""
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    groups = h // hkv
    tq = min(tq, sq)
    tk = min(tk, skv)
    assert sq % tq == 0 and skv % tk == 0, (sq, tq, skv, tk)
    grid = (b, h, sq // tq, skv // tk)
    scale = d ** -0.5

    return pl.pallas_call(
        functools.partial(_kernel, tq=tq, tk=tk, causal=causal,
                          window=window, scale=scale, n_kv=skv // tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tq, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, tk, d),
                         lambda b_, h_, q_, k_: (b_, h_ // groups, k_, 0)),
            pl.BlockSpec((1, 1, tk, d),
                         lambda b_, h_, q_, k_: (b_, h_ // groups, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, d),
                               lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, d), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
