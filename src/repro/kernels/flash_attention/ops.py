"""Jitted wrapper for blocked flash attention.

``attention(q, k, v, layout="BSHD")`` accepts model-layout tensors
([B, S, H, D], KV un-expanded GQA) and dispatches to the Pallas kernel
(interpret=True on CPU; compiled on TPU). The jnp scan in
``repro.models.attention.blocked_attention`` is the equivalent XLA path
and this kernel's oracle at the model level.
"""
from __future__ import annotations

import jax

from .kernel import flash_attention


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              interpret: bool = True, tq: int = 128, tk: int = 128):
    """q: [B, Sq, H, D]; k, v: [B, Skv, Hkv, D] -> [B, Sq, H, D]."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          tq=tq, tk=tk, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
