"""Pure-jnp oracle: exact causal/windowed attention (fp32 softmax).

q: [B, H, Sq, D]; k, v: [B, Hkv, Skv, D] with H = Hkv * groups.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    groups = h // hkv
    k = jnp.repeat(k, groups, axis=1)
    v = jnp.repeat(v, groups, axis=1)
    skv = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
