"""Pallas TPU kernels for the framework's compute hot spots.

Each subpackage ships the kernel (`kernel.py`: pl.pallas_call + explicit
BlockSpec VMEM tiling), a jitted wrapper (`ops.py`), and a pure-jnp
oracle (`ref.py`) the kernel is allclose-tested against
(tests/test_kernels.py sweeps shapes and dtypes; interpret=True executes
the kernel bodies on CPU).

  * reuse_distance   — tiled windowed distinct-count (POD/URD/TRD), the
                       paper's PARDA hot path on the TPU VPU
  * popularity       — fused Eq. 1 exp + segment reduction
  * maintenance      — ETICA's between-interval promote/evict scatters
                       over stacked [V, S, W] states + the fused
                       per-interval maintenance dispatch
  * flash_attention  — blocked causal/windowed attention fwd (GQA-native)
  * decode_attention — paged flash-decode over the two-tier KV pool
                       (scalar-prefetched page tables)
"""
from __future__ import annotations

import os


def env_flag(name: str) -> bool | None:
    """Tri-state env override: unset -> None, ``0``/``false`` (any
    case) / empty -> False, anything else -> True."""
    env = os.environ.get(name)
    if env is None:
        return None
    return env.lower() not in ("0", "false", "")


def use_interpret() -> bool:
    """Pallas interpret mode unless running on a real TPU backend.

    ``ETICA_PALLAS_INTERPRET=1`` forces the interpreter (CI's
    kernels-interpret job runs the whole suite this way on CPU), ``=0``
    forces compiled Pallas.
    """
    forced = env_flag("ETICA_PALLAS_INTERPRET")
    if forced is not None:
        return forced
    import jax
    return jax.default_backend() != "tpu"
