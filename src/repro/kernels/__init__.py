"""Pallas TPU kernels for the framework's compute hot spots.

Each subpackage ships the kernel (`kernel.py`: pl.pallas_call + explicit
BlockSpec VMEM tiling), a jitted wrapper (`ops.py`), and a pure-jnp
oracle (`ref.py`) the kernel is allclose-tested against
(tests/test_kernels.py sweeps shapes and dtypes; interpret=True executes
the kernel bodies on CPU).

  * reuse_distance   — tiled windowed distinct-count (POD/URD/TRD), the
                       paper's PARDA hot path on the TPU VPU
  * popularity       — fused Eq. 1 exp + segment reduction
  * flash_attention  — blocked causal/windowed attention fwd (GQA-native)
  * decode_attention — paged flash-decode over the two-tier KV pool
                       (scalar-prefetched page tables)
"""
