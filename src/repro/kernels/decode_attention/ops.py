"""Jitted wrapper for paged decode attention over the two-tier KV pool.

Used by `repro.kvcache`: the controller maintains the page table (which
pages are HBM-resident per the POD/popularity policy); this op consumes
it directly — no contiguous KV copy is ever materialized.
"""
from __future__ import annotations

from .kernel import paged_decode_attention


def decode_attention(q, kv_pool, page_table, lengths, *,
                     interpret: bool = True):
    """q: [B, H, D]; kv_pool: (k_pages, v_pages) [NP, PS, Hkv, D]."""
    k_pages, v_pages = kv_pool
    return paged_decode_attention(q, k_pages, v_pages, page_table, lengths,
                                  interpret=interpret)
