"""Pure-jnp oracle: paged single-token decode attention.

q: [B, H, D]; k_pages/v_pages: [NP, PS, Hkv, D] (global page pool);
page_table: [B, n_pages] int32 (pool page id per logical page);
lengths: [B] int32 (valid tokens per sequence).
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_ref(q, k_pages, v_pages, page_table, lengths):
    b, h, d = q.shape
    np_, ps, hkv, _ = k_pages.shape
    n_pages = page_table.shape[1]
    groups = h // hkv

    k = k_pages[page_table]          # [B, n_pages, PS, Hkv, D]
    v = v_pages[page_table]
    k = k.reshape(b, n_pages * ps, hkv, d)
    v = v.reshape(b, n_pages * ps, hkv, d)

    qh = q.reshape(b, hkv, groups, d).astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bhgd,bkhd->bhgk", qh, k.astype(jnp.float32))
    pos = jnp.arange(n_pages * ps)[None, None, None, :]
    s = jnp.where(pos < lengths[:, None, None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
