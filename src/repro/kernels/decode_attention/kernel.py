"""Pallas TPU kernel: paged flash-decode over the two-tier KV pool.

This is the ETICA-integrated serving hot spot (DESIGN.md §2): decode
reads KV *pages* whose HBM residency is decided by the POD/popularity
controller; the page table indirection is resolved with Pallas *scalar
prefetch* — the page_table (and per-sequence lengths) are prefetched to
SMEM, and the KV BlockSpec index_map dereferences them so each grid step
DMAs exactly the page it needs from the pool (no gather materialization,
the vLLM-on-TPU pattern).

Grid (B, Hkv, n_pages), pages innermost; online-softmax state for the
`groups` query heads of one KV head lives in VMEM scratch; output
written on the final page step. Invalid (beyond-length) slots are masked
in-tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_table_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, ps: int, n_pages: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # [G, D]
    k = k_ref[0, :, 0].astype(jnp.float32)             # [PS, D]
    v = v_ref[0, :, 0].astype(jnp.float32)             # [PS, D]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, PS]
    tok = p * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = tok < lengths_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    pexp = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           interpret: bool = True):
    """q: [B, H, D]; k_pages/v_pages: [NP, PS, Hkv, D];
    page_table: [B, n_pages]; lengths: [B]. Returns [B, H, D]."""
    b, h, d = q.shape
    np_, ps, hkv, _ = k_pages.shape
    n_pages = page_table.shape[1]
    groups = h // hkv
    qg = q.reshape(b, hkv, groups, d)
    scale = d ** -0.5

    grid = (b, hkv, n_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            # q: one (b, kv-head) group of G query heads
            pl.BlockSpec((1, 1, groups, d),
                         lambda b_, h_, p_, pt, ln: (b_, h_, 0, 0)),
            # k/v: the pool page named by the page table (scalar prefetch)
            pl.BlockSpec((1, ps, 1, d),
                         lambda b_, h_, p_, pt, ln: (pt[b_, p_], 0, h_, 0)),
            pl.BlockSpec((1, ps, 1, d),
                         lambda b_, h_, p_, pt, ln: (pt[b_, p_], 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, groups, d),
                               lambda b_, h_, p_, pt, ln: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((groups, d), jnp.float32),
            pltpu.VMEM((groups, 1), jnp.float32),
            pltpu.VMEM((groups, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, ps=ps, n_pages=n_pages, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, groups, d), q.dtype),
        interpret=interpret,
    )(page_table, lengths, qg, k_pages, v_pages)
    return out.reshape(b, h, d)
