"""Jitted wrapper: per-block popularity from a window's DistResult.

Host side maps block addresses to dense segment ids (np.unique), the
kernel does the fused exp + segment reduction; mirrors
``repro.core.popularity.{contributions, block_scores}``.
"""
from __future__ import annotations

import numpy as np

from .kernel import popularity


def block_popularity(addr, dist, served, cache_size, *,
                     interpret: bool = True):
    """Returns (unique_addrs, scores) for one maintenance window."""
    addr = np.asarray(addr)
    uniq, seg = np.unique(addr, return_inverse=True)
    scores = popularity(dist, served, seg.astype(np.int32),
                        num_blocks=int(uniq.size), cache_size=cache_size,
                        interpret=interpret)
    return uniq, np.asarray(scores)
