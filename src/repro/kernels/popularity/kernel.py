"""Pallas TPU kernel: fused Eq. 1 popularity scoring + segment reduction.

One pass over the access stream computes the per-access contribution
``exp(-dist/cacheSize)`` (VPU transcendental) and reduces it into
per-block scores without materializing the contribution vector in HBM.
The reduction is a tiled one-hot accumulation: for an access tile of TI
and a block-id tile of TB, ``acc[b] += sum_i contrib[i] * [seg[i] == b]``
— an outer-product-shaped reduction that maps onto the VPU (and the MXU
for f32 when TB = 128k lanes align).

Grid: (num_block_tiles, num_access_tiles); the access dimension is
innermost so each output tile accumulates across access tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TI = 1024
DEFAULT_TB = 512


def _kernel(dist_ref, served_ref, seg_ref, cs_ref, out_ref, *,
            ti: int, tb: int):
    b_blk = pl.program_id(0)
    i_blk = pl.program_id(1)

    @pl.when(i_blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dist = dist_ref[...].astype(jnp.float32)       # [TI]
    served = served_ref[...] > 0                   # [TI]
    seg = seg_ref[...]                             # [TI]
    cs = jnp.maximum(cs_ref[0], 1.0)

    contrib = jnp.where(served & (dist >= 0), jnp.exp(-dist / cs), 0.0)

    b_idx = b_blk * tb + jax.lax.broadcasted_iota(jnp.int32, (ti, tb), 1)
    onehot = (seg[:, None] == b_idx).astype(jnp.float32)   # [TI, TB]
    out_ref[...] += jnp.sum(contrib[:, None] * onehot, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("num_blocks", "ti", "tb", "interpret"))
def popularity(dist, served, seg, num_blocks: int, cache_size,
               *, ti: int = DEFAULT_TI, tb: int = DEFAULT_TB,
               interpret: bool = True):
    """Per-block popularity scores. seg[i] in [0, num_blocks)."""
    n = dist.shape[0]
    ti = min(ti, max(8, 1 << (n - 1).bit_length()))
    n_pad = ((n + ti - 1) // ti) * ti
    tb = min(tb, max(128, 1 << (num_blocks - 1).bit_length()))
    nb_pad = ((num_blocks + tb - 1) // tb) * tb

    dist = jnp.pad(jnp.asarray(dist, jnp.int32), (0, n_pad - n),
                   constant_values=-1)
    served = jnp.pad(jnp.asarray(served).astype(jnp.int32), (0, n_pad - n))
    seg = jnp.pad(jnp.asarray(seg, jnp.int32), (0, n_pad - n),
                  constant_values=nb_pad)  # out of every block tile
    cs = jnp.asarray([cache_size], jnp.float32)

    grid = (nb_pad // tb, n_pad // ti)
    out = pl.pallas_call(
        functools.partial(_kernel, ti=ti, tb=tb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti,), lambda b, i: (i,)),
            pl.BlockSpec((ti,), lambda b, i: (i,)),
            pl.BlockSpec((ti,), lambda b, i: (i,)),
            pl.BlockSpec((1,), lambda b, i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda b, i: (b,)),
        out_shape=jax.ShapeDtypeStruct((nb_pad,), jnp.float32),
        interpret=interpret,
    )(dist, served, seg, cs)
    return out[:num_blocks]
