"""Pure-jnp oracle for the fused popularity kernel (paper Eq. 1).

popularity[b] = sum over accesses i with seg[i] == b of
                exp(-dist[i]/cacheSize) * [served[i] and dist[i] >= 0]
"""
from __future__ import annotations

import jax.numpy as jnp


def popularity_ref(dist, served, seg, num_blocks: int, cache_size: float):
    cs = jnp.maximum(jnp.float32(cache_size), 1.0)
    contrib = jnp.where(served & (dist >= 0),
                        jnp.exp(-dist.astype(jnp.float32) / cs), 0.0)
    return jnp.zeros(num_blocks, jnp.float32).at[seg].add(contrib)
