"""Pallas TPU kernel: tiled windowed distinct-count (stack distance).

PARDA-on-TPU (DESIGN.md §4): the O(N^2) pairwise predicate

    count[i] = sum_j [prev[i] < j < i] * touch[j] * [nt[j] >= i]

is tiled into (TI x TJ) blocks. Each grid step loads a TI-row strip of
(prev, i-index) and a TJ-column strip of (touch, nt) into VMEM, evaluates
the mask on the VPU, and accumulates row sums into the int32 output
block. The j grid dimension is innermost, so the output block (indexed
by i only) accumulates across j steps — the standard Pallas reduction
pattern. VMEM footprint per step: TI*TJ mask + O(TI + TJ) vectors;
default 256 x 512 = 512KB of pred, well inside a v5e core's 16MB VMEM,
with the mask dims multiples of the 8x128 VPU lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TI = 256
DEFAULT_TJ = 512


def _kernel(prev_ref, touch_ref, nt_ref, out_ref, *, ti: int, tj: int):
    i_blk = pl.program_id(0)
    j_blk = pl.program_id(1)

    @pl.when(j_blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    i_idx = i_blk * ti + jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 0)
    j_idx = j_blk * tj + jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 1)

    prev = prev_ref[...][:, None]          # [TI, 1]
    touch = touch_ref[...][None, :]        # [1, TJ] int32 (0/1)
    nt = nt_ref[...][None, :]              # [1, TJ]

    m = ((j_idx > prev) & (j_idx < i_idx) & (touch > 0) & (nt >= i_idx))
    out_ref[...] += jnp.sum(m.astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("ti", "tj", "interpret"))
def count_between(prev, touch, nt, *, ti: int = DEFAULT_TI,
                  tj: int = DEFAULT_TJ, interpret: bool = True):
    """count[i] = #{ j in (prev[i], i) : touch[j] and nt[j] >= i }.

    Inputs are 1-D int32 arrays of equal length; length is padded up to a
    tile multiple internally (padded j entries have touch = 0, padded i
    rows are discarded).
    """
    n = prev.shape[0]
    ti = min(ti, max(8, 1 << (n - 1).bit_length()))
    tj = min(tj, max(128, 1 << (n - 1).bit_length()))
    n_pad = ((n + max(ti, tj) - 1) // max(ti, tj)) * max(ti, tj)
    pad = n_pad - n
    prev = jnp.pad(prev.astype(jnp.int32), (0, pad))
    touch = jnp.pad(touch.astype(jnp.int32), (0, pad))  # pad -> not touched
    nt = jnp.pad(nt.astype(jnp.int32), (0, pad), constant_values=-1)

    grid = (n_pad // ti, n_pad // tj)
    out = pl.pallas_call(
        functools.partial(_kernel, ti=ti, tj=tj),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti,), lambda i, j: (i,)),
            pl.BlockSpec((tj,), lambda i, j: (j,)),
            pl.BlockSpec((tj,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((ti,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(prev, touch, nt)
    return out[:n]
