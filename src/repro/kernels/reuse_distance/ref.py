"""Pure-jnp oracle for the windowed distinct-count (stack distance) kernel.

Given, per access i:
  * prev[i]  — index of the previous policy-touch of addr[i] (-1 if none),
  * touch[j] — whether access j occupies/refreshes a cache block,
  * nt[j]    — index of the next policy-touch of addr[j] (N if none),

the policy-filtered stack distance is

  count[i] = #{ j : prev[i] < j < i, touch[j], nt[j] >= i }

(each qualifying j is the last touch of its address inside the window, so
the count equals the number of distinct addresses touched between the
two references). This is exactly `repro.core.reuse._count_between`; the
kernel tiles it over (i, j) blocks for the TPU VPU.
"""
from __future__ import annotations

import jax.numpy as jnp


def count_between_ref(prev, touch, nt):
    n = touch.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)[:, None]
    j = jnp.arange(n, dtype=jnp.int32)[None, :]
    m = ((j > prev[:, None]) & (j < i) & touch[None, :].astype(bool)
         & (nt[None, :] >= i))
    return jnp.sum(m, axis=1, dtype=jnp.int32)
