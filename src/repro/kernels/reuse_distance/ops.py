"""Jitted wrappers: policy-filtered reuse distances via the Pallas kernel.

``reuse_distances`` mirrors ``repro.core.reuse.pod_distances`` but runs
the O(N^2) distinct-count through the TPU kernel (interpret=True executes
the same kernel body on CPU for validation). The prev/next-touch
bookkeeping stays in regular jnp (sort-based, O(N log N)) — it is not the
hot spot. ``sizing_reduction`` additionally reduces the kernel-computed
distance channels into the one-level baselines' sizing metrics.

Metric definitions (ETICA §2.1 / §4.3.1; see ``repro.core.reuse`` for the
oracle engine these wrappers are tested against):

  * **TRD** — classic Mattson stack distance: distinct blocks between
    consecutive accesses to the same block, any re-access counting
    (Centaur's sizing metric).
  * **URD** — Useful Reuse Distance (ECI-Cache, arXiv:1805.00976): TRD
    restricted to read re-references (RAR + RAW).
  * **POD** — Policy Optimized reuse Distance (ETICA Eq. 2): URD further
    filtered by the cache write policy, so only requests the policy would
    serve occupy blocks or earn distances; ``demand = max POD + 1``.
  * **WSS** — working-set size (S-CAVE): distinct blocks touched, no
    distance filtering.

All of them reduce over the same decomposed distance channels: one
all-touch (read+write) distance pass serves URD/TRD/WSS, one read-only
touch pass serves POD(RO), and the served masks select the read, write,
or total re-reference populations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import Policy
from repro.core import reuse as core_reuse
from .kernel import count_between


def reuse_distances(addr, is_write, policy: Policy, *,
                    sizing_reads_only: bool = True,
                    interpret: bool = True,
                    ti: int = 256, tj: int = 512):
    """DistResult with the pairwise count computed by the Pallas kernel.

    ``sizing_reads_only=False`` widens the served set to write
    re-references too (the TRD convention), matching
    ``core.reuse._decompose``.
    """
    addr = jnp.asarray(addr, jnp.int32)
    is_write = jnp.asarray(is_write)
    is_read = ~is_write
    all_mask = jnp.ones_like(is_write)

    prev_any = core_reuse._prev_same(addr, all_mask)
    has_prev = prev_any >= 0
    if policy in (Policy.WB, Policy.WT):
        touch = all_mask
        served = is_read & has_prev
    elif policy is Policy.RO:
        touch = is_read
        prev_is_read = jnp.where(has_prev,
                                 ~is_write[jnp.maximum(prev_any, 0)], False)
        served = is_read & prev_is_read
    elif policy in (Policy.WBWO, Policy.WO):
        prev_write = core_reuse._prev_same(addr, is_write)
        served = is_read & (prev_write >= 0)
        touch = is_write | served
    else:  # pragma: no cover
        raise ValueError(policy)

    prev_touch = core_reuse._prev_same(addr, touch)
    next_touch = core_reuse._next_same(addr, touch)
    dist = count_between(prev_touch, touch.astype(jnp.int32), next_touch,
                         ti=ti, tj=tj, interpret=interpret)
    if not sizing_reads_only:
        served = served | (is_write & has_prev)
    dist = jnp.where(served, dist, core_reuse.COLD)
    return core_reuse.DistResult(dist=dist, served=served, touch=touch)


def sizing_reduction(addr, is_write, kind: str, grid, *, n_valid=None,
                     with_reads: bool = False,
                     interpret: bool = True, ti: int = 256, tj: int = 512):
    """``(demand, hit_counts[G])`` for one trace, kernel-backed.

    The kernel analogue of the batched jnp sizing path: the O(N^2)
    distance channel comes from the Pallas ``count_between`` kernel and
    the metric reduction is the SAME shared ``core.reuse``
    ``sizing_from_dists`` code; used when the sizing path runs next to
    the datapath on TPU. ``kind`` is one of ``core.reuse.SIZING_KINDS``;
    ``n_valid`` (default: full length) masks a pad tail out of the WSS
    distinct-count when the caller hands in bucket-padded rows. With
    ``with_reads`` the per-VM read count (the dynamic write-policy
    choosers' input, ``core.reuse.read_count``) is appended, mirroring
    ``sizing_metrics_batch``.
    """
    if kind not in core_reuse.SIZING_KINDS:
        raise ValueError(
            f"kind must be one of {core_reuse.SIZING_KINDS}, got {kind!r}")
    addr = jnp.asarray(addr, jnp.int32)
    is_write = jnp.asarray(is_write)
    grid = jnp.asarray(grid, jnp.int32)
    if n_valid is None:
        n_valid = addr.shape[0]
    policy, reads_only = core_reuse.sizing_policy(kind)
    r = reuse_distances(addr, is_write, policy, sizing_reads_only=reads_only,
                        interpret=interpret, ti=ti, tj=tj)
    demand, hits = core_reuse.sizing_from_dists(addr, is_write, r, n_valid,
                                                grid, kind)
    if with_reads:
        return demand, hits, core_reuse.read_count(is_write, n_valid)
    return demand, hits


# ---------------------------------------------------------------------------
# batched kernel-backed sizing (the TPU route of SizingMetric.batch)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("kind", "interpret", "ti", "tj"))
def _sizing_reduce_vmapped(amat, wmat, nvec, grid, kind, interpret, ti, tj):
    policy, reads_only = core_reuse.sizing_policy(kind)

    def one(addr, is_write, n_valid):
        r = reuse_distances(addr, is_write, policy,
                            sizing_reads_only=reads_only,
                            interpret=interpret, ti=ti, tj=tj)
        demand, hits = core_reuse.sizing_from_dists(addr, is_write, r,
                                                    n_valid, grid, kind)
        return demand, hits, core_reuse.read_count(is_write, n_valid)

    return jax.vmap(one)(amat, wmat, nvec)


def _sizing_sharded(mesh, amat, wmat, nvec, grid, kind, interpret, ti, tj):
    # Manual per-device dispatch, not shard_map: see core.reuse — the CPU
    # GSPMD partitioner corrupts the decompose body with spurious
    # all-reduces. Each device runs the same single-device jitted
    # executable as the oracle path on its own row block (async dispatch,
    # host-side gather), so this stays bit-identical and collective-free.
    from repro.launch.mesh import device_row_blocks
    parts = []
    for dev, rows in device_row_blocks(amat.shape[0], mesh):
        a = jax.device_put(jnp.asarray(amat[rows]), dev)
        w = jax.device_put(jnp.asarray(wmat[rows]), dev)
        n = jax.device_put(jnp.asarray(nvec[rows]), dev)
        g = jax.device_put(jnp.asarray(grid), dev)
        parts.append(_sizing_reduce_vmapped(a, w, n, g, kind=kind,
                                            interpret=interpret,
                                            ti=ti, tj=tj))
    return tuple(
        np.concatenate([np.asarray(p[i]) for p in parts], axis=0)
        for i in range(3))


def sizing_metrics_batch(addrs, writes, kind: str, grid, *,
                         interpret: bool = True, ti: int = 256,
                         tj: int = 512, mesh=None):
    """Kernel-backed ``core.reuse.sizing_metrics_batch``: same ragged
    contract and ``(demands, hit_counts, read_counts)`` returns, but the
    O(N^2) distance channel of every VM runs through the Pallas
    ``count_between`` kernel, vmapped across the stacked rows (the
    batching rule adds the VM axis to the kernel grid). This is what
    ``SizingMetric.batch`` dispatches to when the backend compiles
    Pallas (TPU) — bit-identical to the jnp path, which stays the CPU
    fallback and parity oracle (``tests/test_kernels.py``). ``mesh``
    splits the VM rows over a device mesh, shard-local like the jnp
    route (empty rows packed as pure-pad rows that reduce to zeros).
    """
    if kind not in core_reuse.SIZING_KINDS:
        raise ValueError(
            f"kind must be one of {core_reuse.SIZING_KINDS}, got {kind!r}")
    lens = [int(np.shape(a)[0]) for a in addrs]
    grid = np.asarray(grid, np.int32)
    demands = np.zeros(len(lens), np.int64)
    hits = np.zeros((len(lens), grid.size), np.int64)
    reads = np.zeros(len(lens), np.int64)
    live = [v for v, n in enumerate(lens) if n > 0]
    if not live:
        return demands, hits, reads
    if mesh is not None:
        from repro.launch.mesh import require_vm_divisible
        require_vm_divisible(len(lens), mesh)
        rows = list(range(len(lens)))
        amat, wmat = core_reuse._pad_rows(addrs, writes, rows, lens)
        d, h, r = _sizing_sharded(mesh, amat, wmat,
                                  np.array(lens, np.int32),
                                  np.asarray(grid, np.int32),
                                  kind, interpret, ti, tj)
        demands[:] = np.asarray(d, np.int64)
        hits[:] = np.asarray(h, np.int64)
        reads[:] = np.asarray(r, np.int64)
        empty = [v for v, n in enumerate(lens) if n == 0]
        demands[empty] = 0
        hits[empty] = 0
        reads[empty] = 0
        return demands, hits, reads
    amat, wmat = core_reuse._pad_rows(addrs, writes, live, lens)
    nvec = np.array([lens[v] for v in live], np.int32)
    d, h, r = _sizing_reduce_vmapped(amat, wmat, nvec, jnp.asarray(grid),
                                     kind=kind, interpret=interpret,
                                     ti=ti, tj=tj)
    demands[live] = np.asarray(d, np.int64)
    hits[live] = np.asarray(h, np.int64)
    reads[live] = np.asarray(r, np.int64)
    return demands, hits, reads
