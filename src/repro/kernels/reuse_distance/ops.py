"""Jitted wrapper: policy-filtered reuse distances via the Pallas kernel.

``reuse_distances`` mirrors ``repro.core.reuse.pod_distances`` but runs
the O(N^2) distinct-count through the TPU kernel (interpret=True executes
the same kernel body on CPU for validation). The prev/next-touch
bookkeeping stays in regular jnp (sort-based, O(N log N)) — it is not the
hot spot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policies import Policy
from repro.core import reuse as core_reuse
from .kernel import count_between


def reuse_distances(addr, is_write, policy: Policy, *,
                    interpret: bool = True,
                    ti: int = 256, tj: int = 512):
    """DistResult with the pairwise count computed by the Pallas kernel."""
    addr = jnp.asarray(addr, jnp.int32)
    is_write = jnp.asarray(is_write)
    is_read = ~is_write
    all_mask = jnp.ones_like(is_write)

    prev_any = core_reuse._prev_same(addr, all_mask)
    has_prev = prev_any >= 0
    if policy in (Policy.WB, Policy.WT):
        touch = all_mask
        served = is_read & has_prev
    elif policy is Policy.RO:
        touch = is_read
        prev_is_read = jnp.where(has_prev,
                                 ~is_write[jnp.maximum(prev_any, 0)], False)
        served = is_read & prev_is_read
    elif policy in (Policy.WBWO, Policy.WO):
        prev_write = core_reuse._prev_same(addr, is_write)
        served = is_read & (prev_write >= 0)
        touch = is_write | served
    else:  # pragma: no cover
        raise ValueError(policy)

    prev_touch = core_reuse._prev_same(addr, touch)
    next_touch = core_reuse._next_same(addr, touch)
    dist = count_between(prev_touch, touch.astype(jnp.int32), next_touch,
                         ti=ti, tj=tj, interpret=interpret)
    dist = jnp.where(served, dist, core_reuse.COLD)
    return core_reuse.DistResult(dist=dist, served=served, touch=touch)
