"""Sharded checkpointing with atomic commit and elastic restore.

Layout per step:
    <dir>/step_<n>.tmp/...   (write)
    <dir>/step_<n>/          (atomic rename on completion)
        manifest.json        treedef, shapes, dtypes, step, extra metadata
        arr_<k>.npy          one file per leaf (host-gathered)

Properties required at 1000+-node scale and kept here:
  * atomicity — a crash mid-save never corrupts the latest checkpoint
    (tmp dir + rename; restore picks the newest *committed* step);
  * async save — a background thread serializes device-get + write so
    the train loop only blocks on the previous save;
  * elastic restore — leaves are loaded as host arrays and re-placed with
    whatever shardings the *new* mesh prescribes, so restoring onto a
    different topology (scale up/down) is the same code path;
  * retention — keep the newest ``keep`` checkpoints.

In a real multi-host deployment each host writes only its address-local
shards; on this single-host runtime the full arrays are written, but the
API (save/restore against shardings) is the multi-host one.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _leaf_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, state, *, keep: int = 3,
         extra: dict | None = None) -> str:
    leaves, treedef = _leaf_paths(state)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(state).serialize_using_proto().hex(),
        "num_leaves": len(leaves),
        "time": time.time(),
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"arr_{i}.npy"), np.asarray(leaf))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return out


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, state_like, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``state_like``; device placement per
    ``shardings`` (pytree of NamedSharding) enables elastic remesh."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _leaf_paths(state_like)
    assert manifest["num_leaves"] == len(leaves_like), "structure mismatch"
    arrs = [np.load(os.path.join(d, f"arr_{i}.npy"))
            for i in range(len(leaves_like))]
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
        placed = [jax.device_put(a, s) for a, s in zip(arrs, sh_leaves)]
    else:
        placed = [jax.device_put(a) for a in arrs]
    return treedef.unflatten(placed), step, manifest.get("extra", {})


class AsyncCheckpointer:
    """Overlap checkpoint serialization with training."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def save(self, step: int, state, extra: dict | None = None):
        self.wait()
        # device_get on the caller thread (consistent snapshot), IO async
        host_state = jax.tree_util.tree_map(np.asarray, state)

        def work():
            self.last_path = save(self.ckpt_dir, step, host_state,
                                  keep=self.keep, extra=extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
