"""IO-class assignment + class→sub-partition mapping for the controllers.

:class:`Classifier` wraps an ordered :class:`~repro.classify.rules.IOClass`
list into everything the datapath needs:

* :meth:`classify_subs` — assign every request of a window's per-VM
  sub-traces a class id in one fused ``jnp`` dispatch (rows padded to a
  power-of-two bucket to bound recompiles), threading the per-VM
  sequential-run carry across windows;
* :meth:`way_bounds` — map classes to sub-partitions inside each VM's
  way allocation: classes with an explicit ``ways_frac`` get exclusive
  way slices carved from the top of the VM's active ways (in class
  order), everything else shares the remaining common pool. Lookups stay
  global — classes partition *insertion*, not residency;
* :attr:`bypass` / :attr:`weights` — the ``[C]`` bypass mask for the
  classified simulators and the ``[C]`` POD-sizing weights.

With the single default class (:func:`match_all`) every request is class
0, the common pool is the whole allocation and nothing bypasses — the
controllers produce Stats bit-identical to ``classifier=None``.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .rules import ClassRule, IOClass, RulePlan, compile_rules, \
    classify_block, classify_ref


def _bucket(n: int, floor: int = 256) -> int:
    return max(1 << max(n - 1, 0).bit_length(), floor)


class Classifier:
    """Ordered IO classes compiled to one vectorized rule plan.

    ``classes[0]`` is the default class (unmatched requests land there);
    later classes take priority in order. The exclusive ``ways_frac``
    reservations may sum to at most 1.
    """

    def __init__(self, classes: Sequence[IOClass]):
        classes = tuple(classes)
        if not classes:
            raise ValueError("need at least one (default) class")
        if classes[0].bypass:
            raise ValueError("the default class cannot bypass the cache")
        fracs = [c.ways_frac for c in classes if c.ways_frac is not None]
        if sum(fracs) > 1.0 + 1e-9:
            raise ValueError(f"exclusive ways_frac reservations sum to "
                             f"{sum(fracs)} > 1")
        self.classes = classes
        self.plan: RulePlan = compile_rules(classes)
        self.bypass = np.asarray([c.bypass for c in classes], bool)
        # a bypass class never caches, so it must not drive sizing either
        self.weights = np.asarray(
            [0.0 if c.bypass else c.weight for c in classes], np.float64)

    @property
    def num_classes(self) -> int:
        return len(self.classes)

    def init_carry(self, num_vms: int):
        """Fresh per-VM sequential-run carry: ``(prev_end, run_len)``."""
        return (np.full(num_vms, -1, np.int32),
                np.zeros(num_vms, np.int32))

    # -- request -> class --------------------------------------------------
    def classify_subs(self, subs, carry_end, carry_len):
        """Classify a window's per-VM sub-traces in one dispatch.

        ``subs`` is the window's ``list[Trace]`` (ragged); returns
        (``list[np.ndarray int32]`` class ids per VM, new carries).
        """
        v = len(subs)
        lens = np.asarray([len(s) for s in subs], np.int32)
        n = _bucket(int(lens.max()) if v else 0)
        amat = np.zeros((v, n), np.int32)
        wmat = np.zeros((v, n), bool)
        smat = np.zeros((v, n), np.int32)
        for i, sub in enumerate(subs):
            k = lens[i]
            amat[i, :k] = np.asarray(sub.addr, np.int32)
            wmat[i, :k] = np.asarray(sub.is_write)
            smat[i, :k] = sub.sizes()
        cls, ce, cl = classify_block(amat, wmat, smat, lens,
                                     np.asarray(carry_end, np.int32),
                                     np.asarray(carry_len, np.int32),
                                     self.plan)
        cls = np.asarray(cls)
        return ([cls[i, :lens[i]] for i in range(v)],
                np.asarray(ce), np.asarray(cl))

    def classify_trace_ref(self, trace, carry_end: int = -1,
                           carry_len: int = 0):
        """Scalar oracle over one sub-trace (see :func:`classify_ref`)."""
        return classify_ref(np.asarray(trace.addr), np.asarray(trace.is_write),
                            trace.sizes(), self.plan, carry_end, carry_len)

    # -- class -> sub-partition --------------------------------------------
    def way_bounds(self, ways):
        """Per-(VM, class) insertion way ranges ``(lo, hi)``, ``[V, C]``.

        Explicit-``ways_frac`` classes get exclusive ``floor(frac * ways)``
        slices stacked from the top of the VM's active ways (class order);
        all other classes share the remaining common pool ``[0, cursor)``.
        Bypass classes get the empty range.
        """
        w = np.atleast_1d(np.asarray(ways, np.int32))
        v, c = len(w), self.num_classes
        lo = np.zeros((v, c), np.int32)
        hi = np.zeros((v, c), np.int32)
        cursor = w.copy()
        for ci, cls in enumerate(self.classes):
            if cls.ways_frac is not None:
                width = np.floor(cls.ways_frac * w).astype(np.int32)
                hi[:, ci] = cursor
                lo[:, ci] = cursor - width
                cursor = cursor - width
        for ci, cls in enumerate(self.classes):
            if cls.bypass:
                lo[:, ci] = hi[:, ci] = 0
            elif cls.ways_frac is None:
                lo[:, ci] = 0
                hi[:, ci] = cursor
        return lo, hi

    def vm_policies(self, policies) -> list:
        """``[V][C]`` write policies: class override or the VM's policy."""
        return [[c.policy if c.policy is not None else p
                 for c in self.classes] for p in policies]


# -- convenience constructors ------------------------------------------------

def match_all(name: str = "default", **attrs) -> Classifier:
    """Single default class — behaves bit-identically to no classifier."""
    return Classifier([IOClass(name, **attrs)])


def seq_cutoff(threshold_blocks: int,
               extra: Sequence[IOClass] = ()) -> Classifier:
    """Default class + a sequential-cutoff bypass class (big-scan
    protection): requests whose sequential run reaches
    ``threshold_blocks`` go straight to disk instead of flushing the
    cache's working set — Open-CAS's ``seq_cutoff``, expressed as an
    ordinary run-length rule."""
    cutoff = IOClass("seq_bypass",
                     rules=(ClassRule(run_len=(threshold_blocks, None)),),
                     bypass=True)
    return Classifier([IOClass("default"), *extra, cutoff])
