"""IO-classification subsystem: rule-driven sub-partitions, per-class
write policies, and sequential-cutoff bypass (Open-CAS io_class model).

See :mod:`repro.classify.rules` for the vectorized rule engine and
:mod:`repro.classify.classifier` for the class→sub-partition mapping the
controllers consume (``EticaConfig.classifier`` /
``SingleLevelConfig.classifier``).
"""
from .rules import (ClassRule, IOClass, RulePlan, compile_rules,
                    classify_block, classify_ref)
from .classifier import Classifier, match_all, seq_cutoff

__all__ = [
    "ClassRule",
    "IOClass",
    "RulePlan",
    "compile_rules",
    "classify_block",
    "classify_ref",
    "Classifier",
    "match_all",
    "seq_cutoff",
]
