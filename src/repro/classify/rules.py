"""Vectorized IO-classification rule engine (Open-CAS io_class model).

A :class:`ClassRule` is a *conjunction* of per-request conditions over
the four request fields the datapath exposes:

==============  ============================================================
``size``        request size in blocks — half-open ``(lo, hi)`` interval
``lba``         block address — half-open ``(lo, hi)`` interval
``run_len``     sequential run length in blocks *including this request*
                (a request continues a run iff its address equals the
                previous request's ``addr + size``) — half-open interval
``direction``   ``"read"`` / ``"write"`` / ``None`` (either)
==============  ============================================================

An :class:`IOClass` owns a tuple of rules — a *disjunction*: the class
matches when any of its rules matches. ``classes[0]`` is the default
class every unmatched request falls back to. When several classes match,
the first matching rule in ``(class order, rule order)`` wins — the same
priority convention Open-CAS uses for its io_class table.

The engine compiles the whole rule set to a flat :class:`RulePlan` of
``[G]`` arrays (one row per conjunction group) so a ``[V, N]`` block is
classified by one fused ``jnp`` broadcast — no Python in the hot path.
:func:`classify_ref` is the scalar per-request oracle the property tests
hold the vectorized path bit-identical to.

Sequential-run state crosses window boundaries through a per-VM carry
``(prev_end, run_len)``; ``prev_end = -1`` is the no-run sentinel (safe
because addresses are non-negative, so ``addr + size >= 1``).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

INT_MAX = np.int32(2**31 - 1)

Bound = "tuple[int | None, int | None] | None"


@dataclasses.dataclass(frozen=True)
class ClassRule:
    """Conjunction of vectorized conditions; ``None`` = unconstrained.

    ``size``/``lba``/``run_len`` are half-open ``(lo, hi)`` intervals
    where either end may be ``None`` (open). ``direction`` restricts the
    request type. An all-``None`` rule matches everything.
    """
    size: tuple | None = None      # (lo, hi) request size in blocks
    lba: tuple | None = None       # (lo, hi) block address range
    run_len: tuple | None = None   # (lo, hi) sequential run length, blocks
    direction: str | None = None   # "read" | "write" | None

    def __post_init__(self):
        if self.direction not in (None, "read", "write"):
            raise ValueError(f"direction must be 'read', 'write' or None, "
                             f"got {self.direction!r}")
        for name in ("size", "lba", "run_len"):
            iv = getattr(self, name)
            if iv is None:
                continue
            lo, hi = iv
            if lo is not None and hi is not None and not lo < hi:
                raise ValueError(f"{name} interval {iv} is empty")


@dataclasses.dataclass(frozen=True)
class IOClass:
    """One IO class: a disjunction of rules plus its cache treatment.

    ``policy`` overrides the VM's write policy for this class on the
    single-level chassis (``None`` = inherit). ``ways_frac`` reserves an
    exclusive fraction of the VM's active ways for the class (``None`` =
    share the common pool). ``weight`` scales the class's contribution to
    POD sizing (0 excludes it). ``bypass`` routes the class straight to
    disk — never cached, never sized, never maintained.
    """
    name: str
    rules: tuple = ()              # tuple[ClassRule, ...] (OR-ed)
    policy: object | None = None   # repro.core.policies.Policy | None
    ways_frac: float | None = None
    weight: float = 1.0
    bypass: bool = False

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        if self.ways_frac is not None and not 0.0 <= self.ways_frac <= 1.0:
            raise ValueError(f"ways_frac must be in [0, 1], "
                             f"got {self.ways_frac}")
        if self.weight < 0:
            raise ValueError(f"weight must be >= 0, got {self.weight}")
        if self.bypass and self.ways_frac is not None:
            raise ValueError("a bypass class cannot reserve ways")


class RulePlan(NamedTuple):
    """Compiled rule set: one row per conjunction group, ``[G]`` each."""
    group_class: np.ndarray  # int32 — owning class id
    size_lo: np.ndarray      # int32 half-open bounds (INT_MAX-open)
    size_hi: np.ndarray
    lba_lo: np.ndarray
    lba_hi: np.ndarray
    run_lo: np.ndarray
    run_hi: np.ndarray
    dir_read: np.ndarray     # bool — rule matches reads
    dir_write: np.ndarray    # bool — rule matches writes


def compile_rules(classes: Sequence[IOClass]) -> RulePlan:
    """Flatten ``classes`` into a :class:`RulePlan`.

    Group order is (class order, rule order), so ``argmax`` over the
    match matrix picks the highest-priority matching rule. A rule set
    with no rules at all compiles to one never-matching group so the
    plan arrays are never empty.
    """
    rows = []
    for ci, cls in enumerate(classes):
        for rule in cls.rules:
            lo = lambda iv: 0 if iv is None or iv[0] is None else int(iv[0])
            hi = lambda iv: (int(INT_MAX) if iv is None or iv[1] is None
                             else int(iv[1]))
            rows.append((ci, lo(rule.size), hi(rule.size),
                         lo(rule.lba), hi(rule.lba),
                         lo(rule.run_len), hi(rule.run_len),
                         rule.direction != "write",
                         rule.direction != "read"))
    if not rows:
        rows.append((0, 0, 0, 0, 0, 0, 0, False, False))
    cols = list(zip(*rows))
    return RulePlan(
        group_class=np.asarray(cols[0], np.int32),
        size_lo=np.asarray(cols[1], np.int32),
        size_hi=np.asarray(cols[2], np.int32),
        lba_lo=np.asarray(cols[3], np.int32),
        lba_hi=np.asarray(cols[4], np.int32),
        run_lo=np.asarray(cols[5], np.int32),
        run_hi=np.asarray(cols[6], np.int32),
        dir_read=np.asarray(cols[7], bool),
        dir_write=np.asarray(cols[8], bool),
    )


# ---------------------------------------------------------------------------
# vectorized engine
# ---------------------------------------------------------------------------

def _row_run_lengths(addr, size, n_valid, carry_end, carry_len):
    """Sequential run lengths (in blocks) for one VM's ``[N]`` row.

    A request continues the current run iff ``addr == prev_addr +
    prev_size``. Run starts are recovered with the cummax trick (index
    where ``new_run`` last held, else carried run), so the whole row
    vectorizes: ``run_len[i] = csum[i] - csum_excl[last_start]`` for
    in-window runs and ``csum[i] + carry_len`` for the carried one.
    """
    n = addr.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    valid = idx < n_valid
    size = jnp.where(valid, size, 0)
    end = addr + size                               # run-continuation key
    prev_end = jnp.concatenate([carry_end[None], end[:-1]])
    new_run = valid & (addr != prev_end)
    csum = jnp.cumsum(size, dtype=jnp.int32)
    csum_excl = csum - size
    start = jnp.where(new_run, idx, jnp.int32(-1))
    last_start = jax.lax.associative_scan(jnp.maximum, start)
    base = jnp.where(last_start >= 0,
                     csum_excl[jnp.maximum(last_start, 0)],
                     -carry_len)
    run = jnp.where(valid, csum - base, 0).astype(jnp.int32)
    last = jnp.maximum(n_valid - 1, 0)
    has = n_valid > 0
    return (run,
            jnp.where(has, end[last], carry_end).astype(jnp.int32),
            jnp.where(has, run[last], carry_len).astype(jnp.int32))


@jax.jit
def classify_block(addr, is_write, size, n_valid, carry_end, carry_len,
                   plan: RulePlan):
    """Classify a ``[V, N]`` block in one fused dispatch.

    ``addr``/``is_write``/``size`` are ``[V, N]`` (positions >=
    ``n_valid[v]`` are padding, classified 0), carries are ``[V]``.
    Returns ``(cls [V, N] int32, carry_end' [V], carry_len' [V])``.
    """
    addr = jnp.asarray(addr, jnp.int32)
    size = jnp.asarray(size, jnp.int32)
    if addr.shape[1] == 0:      # static: empty window, carries unchanged
        return (jnp.zeros(addr.shape, jnp.int32),
                jnp.asarray(carry_end, jnp.int32),
                jnp.asarray(carry_len, jnp.int32))
    run, ce, cl = jax.vmap(_row_run_lengths)(
        addr, size, jnp.asarray(n_valid, jnp.int32),
        jnp.asarray(carry_end, jnp.int32), jnp.asarray(carry_len, jnp.int32))
    # [V, G, N] match matrix -> argmax over G = first matching group
    a = addr[:, None, :]
    sz = size[:, None, :]
    rl = run[:, None, :]
    w = jnp.asarray(is_write)[:, None, :]
    g = lambda x: jnp.asarray(x)[None, :, None]
    m = ((sz >= g(plan.size_lo)) & (sz < g(plan.size_hi))
         & (a >= g(plan.lba_lo)) & (a < g(plan.lba_hi))
         & (rl >= g(plan.run_lo)) & (rl < g(plan.run_hi))
         & jnp.where(w, g(plan.dir_write), g(plan.dir_read)))
    matched = m.any(axis=1)
    first = jnp.argmax(m, axis=1)
    cls = jnp.where(matched, jnp.asarray(plan.group_class)[first], 0)
    valid = jnp.arange(addr.shape[1])[None, :] < jnp.asarray(
        n_valid, jnp.int32)[:, None]
    return jnp.where(valid, cls, 0).astype(jnp.int32), ce, cl


# ---------------------------------------------------------------------------
# scalar reference oracle
# ---------------------------------------------------------------------------

def classify_ref(addr, is_write, size, plan: RulePlan,
                 carry_end: int = -1, carry_len: int = 0):
    """Per-request Python evaluator — the oracle :func:`classify_block`
    must match bit-identically (hypothesis-tested in test_classify.py).

    Returns ``(cls [N] int32, carry_end', carry_len')``.
    """
    addr = np.asarray(addr, np.int64)
    is_write = np.asarray(is_write, bool)
    size = np.asarray(size, np.int64)
    n = len(addr)
    g_cnt = len(plan.group_class)
    cls = np.zeros(n, np.int32)
    end, run = int(carry_end), int(carry_len)
    for i in range(n):
        a, s, w = int(addr[i]), int(size[i]), bool(is_write[i])
        run = run + s if a == end else s
        end = a + s
        for g in range(g_cnt):
            if not (plan.dir_write[g] if w else plan.dir_read[g]):
                continue
            if not plan.size_lo[g] <= s < plan.size_hi[g]:
                continue
            if not plan.lba_lo[g] <= a < plan.lba_hi[g]:
                continue
            if not plan.run_lo[g] <= run < plan.run_hi[g]:
                continue
            cls[i] = plan.group_class[g]
            break
    return cls, end, run
