"""Roofline-term analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` has two gaps for our purposes: it reports no
collective traffic at all, and it counts ``while`` bodies (scan-over-
layers, chunked loss) once instead of trip-count times. This module
parses the scheduled HLO module directly:

  * two-pass per computation: first a symbol table (%name -> shape), then
    metrics per op line with operand shapes resolved through the table
    (scheduled HLO prints operands by name only);
  * ``while`` bodies are multiplied by XLA's recorded
    ``known_trip_count`` (fallback: the constant in the loop condition);
  * dot FLOPs = 2 * prod(result dims) * prod(contracting dims);
  * HBM bytes = result + operand bytes of materializing top-level ops
    (fusion internals excluded — a fusion reads its operands and writes
    its result once);
  * collective bytes = max(result, largest operand) per all-reduce /
    all-gather / reduce-scatter / all-to-all / collective-permute.

All numbers are per-device (the SPMD module is the per-device program);
the dry-run driver scales by chip count.
"""
from __future__ import annotations

import collections
import re

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute")

_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{\s*$")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OPNAME_RE = re.compile(r"^\s*([a-z0-9\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALL_RE = re.compile(
    r"\b(to_apply|body|condition|called_computations|calls)=%?([\w\.\-]+)")
_CALL_LIST_RE = re.compile(r"\b(branch_computations)={([^}]*)}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims={([0-9,]*)}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n"\s*:\s*"(\d+)"')
_CONST_RE = re.compile(r"constant\((\d+)\)")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id", "iota",
             "copy-start", "copy-done", "while", "call", "conditional",
             "custom-call", "opt-barrier"}

# ops that touch only a window of their (possibly huge) operands: count
# bytes moved, not operand size
_WINDOW_OPS = {"dynamic-slice", "slice", "gather"}


def _dims(dim_str: str) -> list[int]:
    return [int(d) for d in dim_str.split(",") if d]


def _nbytes(dtype: str, dim_str: str) -> int:
    n = 1
    for d in _dims(dim_str):
        n *= d
    return n * DTYPE_BYTES.get(dtype, 4)


class _Op:
    __slots__ = ("name", "kind", "result_bytes", "result_dims", "operands",
                 "line", "result_elems")

    def __init__(self, name, kind, result_bytes, result_dims, operands,
                 line, result_elems=()):
        self.name = name
        self.kind = kind
        self.result_bytes = result_bytes
        self.result_dims = result_dims
        self.operands = operands
        self.line = line
        self.result_elems = result_elems  # per-tuple-element byte sizes


def _parse_module(text: str):
    """-> (comps: {name: [_Op]}, entry_name)."""
    comps: dict[str, list[_Op]] = {}
    entry = None
    cur: list[_Op] | None = None
    for line in text.splitlines():
        hm = _COMP_HDR.match(line)
        if hm and "{" in line:
            name = hm.group(1)
            comps[name] = []
            cur = comps[name]
            if line.lstrip().startswith("ENTRY"):
                entry = name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_LINE.match(line)
        if not om:
            continue
        name, rhs = om.group(1), om.group(2)
        # split off the result type; tuple types contain parens, so walk
        # to the matching close paren when the type starts with '('
        rest = rhs
        if rhs.startswith("("):
            depth = 0
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        rest = rhs[i + 1:]
                        break
            type_part = rhs[: len(rhs) - len(rest)]
            paren = rest.find("(")
            pre = rest[:paren] if paren > 0 else rest
        else:
            paren = rhs.find("(")
            pre = rhs[:paren] if paren > 0 else rhs
            type_part = pre
            rest = rhs
        kind_m = re.search(r"([a-z0-9\-]+)$", pre.strip())
        kind = kind_m.group(1) if kind_m else "?"
        shapes = _SHAPE_RE.findall(type_part)
        elems = tuple(_nbytes(d, s) for d, s in shapes)
        rbytes = sum(elems)
        rdims = _dims(shapes[0][1]) if len(shapes) == 1 else []
        # operands: %names inside the call parens (cut at attrs)
        operand_str = rest[paren:] if paren > 0 else ""
        attr_cut = operand_str.find("), ")
        if attr_cut >= 0:
            operand_str = operand_str[: attr_cut + 1]
        operands = _OPERAND_RE.findall(operand_str)
        cur.append(_Op(name, kind, rbytes, rdims, operands, rhs, elems))
    return comps, entry


def _inplace_fusion_bytes(op: _Op, operand_bytes: list) -> int:
    """Traffic of a fusion wrapping dynamic-update-slice: operands that
    size-match a result (tuple) element are aliased in place — only the
    unmatched operands and unmatched result elements move."""
    import collections as _c
    elems = _c.Counter(op.result_elems)
    moved = 0
    for ob in sorted(operand_bytes, reverse=True):
        if elems.get(ob, 0) > 0:
            elems[ob] -= 1          # aliased: passes through in place
        else:
            moved += ob
    moved += sum(sz * n for sz, n in elems.items())
    return moved


def analyze(text: str) -> dict:
    comps, entry = _parse_module(text)
    if entry is None and comps:
        entry = next(iter(comps))

    # symbol tables: per computation, name -> (_Op)
    sym: dict[str, dict[str, _Op]] = {
        c: {op.name: op for op in ops} for c, ops in comps.items()}

    # fusion bodies: flops counted, bytes not (they materialize as a unit)
    fusion_bodies: set[str] = set()
    # fusions that wrap a dynamic-update-slice over a same-sized operand
    # run IN PLACE (XLA aliases input/output): charging full operand +
    # result bytes would bill a whole KV-cache copy per decoded token.
    inplace_bodies: set[str] = set()
    for ops in comps.values():
        for op in ops:
            if op.kind == "fusion":
                m = _CALL_RE.search(op.line)
                if m and m.group(1) == "calls":
                    fusion_bodies.add(m.group(2))
    for body in fusion_bodies:
        for op in comps.get(body, []):
            if op.kind == "dynamic-update-slice":
                inplace_bodies.add(body)
                break

    memo: dict[str, tuple] = {}

    def dot_flops(op: _Op, table) -> float:
        res = 1
        for d in op.result_dims:
            res *= d
        k = 1
        mc = _CONTRACT_RE.search(op.line)
        if mc and op.operands:
            lhs = table.get(op.operands[0])
            if lhs is not None:
                for idx in _dims(mc.group(1)):
                    if idx < len(lhs.result_dims):
                        k *= lhs.result_dims[idx]
        return 2.0 * res * k

    def walk(name: str, stack: frozenset):
        if name in memo:
            return memo[name]
        zero = (collections.Counter(), collections.Counter(), 0.0, 0.0)
        if name not in comps or name in stack:
            return zero
        stack = stack | {name}
        table = sym[name]
        by = collections.Counter()
        cnt = collections.Counter()
        flops = 0.0
        hbm = 0.0
        in_fusion = name in fusion_bodies
        for op in comps[name]:
            base = op.kind.removesuffix("-start")
            operand_bytes = [table[o].result_bytes for o in op.operands
                             if o in table]
            if base in COLL_OPS:
                by[base] += max(op.result_bytes,
                                max(operand_bytes, default=0))
                cnt[base] += 1
            if op.kind == "dot":
                flops += dot_flops(op, table)
            if not in_fusion and op.kind not in _FREE_OPS:
                if op.kind in _WINDOW_OPS:
                    hbm += 2 * op.result_bytes
                elif op.kind == "dynamic-update-slice":
                    upd = (table[op.operands[1]].result_bytes
                           if len(op.operands) > 1 and op.operands[1] in table
                           else op.result_bytes)
                    hbm += 2 * upd
                elif op.kind == "scatter":
                    upd = (table[op.operands[2]].result_bytes
                           if len(op.operands) > 2 and op.operands[2] in table
                           else op.result_bytes)
                    hbm += 2 * upd
                elif op.kind == "fusion":
                    m = _CALL_RE.search(op.line)
                    if m and m.group(2) in inplace_bodies:
                        hbm += _inplace_fusion_bytes(op, operand_bytes)
                    else:
                        hbm += op.result_bytes + sum(operand_bytes)
                else:
                    hbm += op.result_bytes + sum(operand_bytes)
            # nested computations
            calls = [(m.group(1), m.group(2))
                     for m in _CALL_RE.finditer(op.line)]
            for m in _CALL_LIST_RE.finditer(op.line):
                calls += [(m.group(1), s.strip().lstrip("%"))
                          for s in m.group(2).split(",") if s.strip()]
            for attr, sub in calls:
                sb, sc, sf, sh = walk(sub, stack)
                mult = 1
                if op.kind == "while" and attr == "body":
                    mt = _TRIP_RE.search(op.line)
                    if mt:
                        mult = int(mt.group(1))
                    else:
                        cm = re.search(r"condition=%?([\w\.\-]+)", op.line)
                        if cm and cm.group(1) in comps:
                            best = 1
                            for o2 in comps[cm.group(1)]:
                                for c in _CONST_RE.findall(o2.line):
                                    best = max(best, int(c))
                            mult = best
                for k_, v in sb.items():
                    by[k_] += v * mult
                for k_, v in sc.items():
                    cnt[k_] += v * mult
                flops += sf * mult
                hbm += sh * mult
        memo[name] = (by, cnt, flops, hbm)
        return memo[name]

    by, cnt, flops, hbm = walk(entry, frozenset()) if entry else (
        collections.Counter(), collections.Counter(), 0.0, 0.0)
    return {"per_op": dict(by), "total": int(sum(by.values())),
            "count": dict(cnt), "dot_flops": flops, "hbm_bytes": hbm}


def collective_bytes(text: str) -> dict:
    """Back-compat wrapper around :func:`analyze`."""
    r = analyze(text)
    return {"per_op": r["per_op"], "total": r["total"], "count": r["count"]}


def byte_census(text: str, top: int = 15) -> dict:
    """Trip-expanded byte attribution: per op kind, and the top individual
    op sites (with their jax op_name metadata) — the §Perf profile."""
    comps, entry = _parse_module(text)
    sym = {c: {op.name: op for op in ops} for c, ops in comps.items()}
    fusion_bodies = set()
    for ops in comps.values():
        for op in ops:
            if op.kind == "fusion":
                m = _CALL_RE.search(op.line)
                if m and m.group(1) == "calls":
                    fusion_bodies.add(m.group(2))
    per_kind: collections.Counter = collections.Counter()
    sites: collections.Counter = collections.Counter()
    colls: collections.Counter = collections.Counter()

    def op_bytes(op, table):
        operand_bytes = [table[o].result_bytes for o in op.operands
                         if o in table]
        if op.kind in _WINDOW_OPS:
            return 2 * op.result_bytes
        if op.kind == "dynamic-update-slice":
            return 2 * (table[op.operands[1]].result_bytes
                        if len(op.operands) > 1 and op.operands[1] in table
                        else op.result_bytes)
        return op.result_bytes + sum(operand_bytes)

    def meta(op):
        m = re.search(r'op_name="([^"]+)"', op.line)
        return (m.group(1)[:90] if m else op.name[:60])

    def walk(name, stack, mult):
        if name not in comps or name in stack:
            return
        stack = stack | {name}
        table = sym[name]
        in_fusion = name in fusion_bodies
        for op in comps[name]:
            base = op.kind.removesuffix("-start")
            if base in COLL_OPS:
                b = max(op.result_bytes,
                        max((table[o].result_bytes for o in op.operands
                             if o in table), default=0))
                colls[f"{base} | {meta(op)}"] += b * mult
            if not in_fusion and op.kind not in _FREE_OPS:
                b = op_bytes(op, table)
                per_kind[op.kind] += b * mult
                sites[f"{op.kind} | {meta(op)}"] += b * mult
            calls = [(m.group(1), m.group(2))
                     for m in _CALL_RE.finditer(op.line)]
            for attr, sub in calls:
                m2 = 1
                if op.kind == "while" and attr == "body":
                    mt = _TRIP_RE.search(op.line)
                    m2 = int(mt.group(1)) if mt else 1
                walk(sub, stack, mult * m2)

    if entry:
        walk(entry, frozenset(), 1)
    return {
        "per_kind": dict(per_kind.most_common()),
        "top_sites": dict(sites.most_common(top)),
        "top_collectives": dict(colls.most_common(top)),
    }
