"""Step functions (train / prefill / decode) + abstract input specs.

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for every
input of the step implied by the shape kind — weak-type-correct,
shardable, and allocation-free, so ``jit(step).lower(**specs).compile()``
exercises the full distribution plan without touching device memory
(MULTI-POD DRY-RUN step 2).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig, ShapeSpec
from repro.optim import OptConfig, apply_updates, init_opt_state

ENC_DECODE_LEN = 4_096   # encoder memory length used for decode shapes


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig | None = None,
                    grad_dtype: str | None = None):
    """grad_dtype="bfloat16" casts gradients before the optimizer — the
    cross-replica all-reduce then moves half the bytes (§Perf lever)."""
    opt_cfg = opt_cfg or OptConfig()

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            M.forward_train, has_aux=True)(params, cfg, batch)
        if grad_dtype:
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.dtype(grad_dtype)), grads)
        params, opt_state, stats = apply_updates(params, grads, opt_state,
                                                 opt_cfg)
        metrics = dict(metrics, **stats)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        logits, cache = M.prefill(params, cfg, batch, cache_len=cache_len)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tokens, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        logits, cache = M.decode_step(params, cfg, tokens, cache, pos)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tokens[:, None], cache

    return serve_step


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def _sds(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))


def abstract_opt_state(cfg: ModelConfig, params_shape,
                       opt_cfg: OptConfig | None = None):
    opt_cfg = opt_cfg or OptConfig()
    return jax.eval_shape(lambda: init_opt_state(
        jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), params_shape),
        opt_cfg))


def cache_len_for(cfg: ModelConfig, shape: ShapeSpec) -> int:
    if cfg.sliding_window:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """Training/prefill batch stand-ins for this (arch, shape)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    if cfg.is_encdec:
        return {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
            "dec_tokens": jax.ShapeDtypeStruct((b, s), i32),
        }
    if cfg.frontend == "vision":
        p = cfg.frontend_tokens
        return {
            "tokens": jax.ShapeDtypeStruct((b, s - p), i32),
            "patches": jax.ShapeDtypeStruct((b, p, cfg.d_model), f32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}


def decode_specs(cfg: ModelConfig, shape: ShapeSpec):
    """(cache, tokens, pos) stand-ins for the decode step."""
    b = shape.global_batch
    clen = cache_len_for(cfg, shape)
    enc_len = ENC_DECODE_LEN if cfg.is_encdec else 0
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, b, clen, enc_len=enc_len))
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, pos


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                opt_cfg: OptConfig | None = None) -> dict[str, Any]:
    """All abstract inputs for the step this shape lowers."""
    params = abstract_params(cfg)
    if shape.kind == "train":
        return {
            "params": params,
            "opt_state": abstract_opt_state(cfg, params, opt_cfg),
            "batch": batch_specs(cfg, shape),
        }
    if shape.kind == "prefill":
        return {"params": params, "batch": batch_specs(cfg, shape)}
    if shape.kind == "decode":
        cache, tokens, pos = decode_specs(cfg, shape)
        return {"params": params, "cache": cache, "tokens": tokens,
                "pos": pos}
    raise ValueError(shape.kind)
