"""Sharding rules: param/optimizer/cache/batch PartitionSpecs per mesh.

Policy (DESIGN.md §5):
  * tensor parallelism over 'model' — attention heads, MLP hidden, MoE
    experts (expert-parallel when num_experts divides the axis, otherwise
    tensor-parallel inside each expert), vocab;
  * batch over ('pod','data');
  * FSDP ('data'-axis weight sharding) automatically for configs whose
    TP-sharded fp32 params would exceed ``fsdp_threshold_bytes`` per
    device; otherwise only optimizer moments are 'data'-sharded (ZeRO-1);
  * KV caches: batch over data when divisible, KV heads over 'model' when
    divisible else KV sequence over 'model' (GQA kv=8 < 16-way axis).

Everything is divisibility-checked against the actual mesh, so the same
rules serve the 16x16 pod, the 2x16x16 multi-pod, and the 1-device test
mesh.
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from .mesh import axis_size, dp_axes


def _divides(n: int, size: int) -> bool:
    return size > 0 and n % size == 0 and n >= size


def _axes_size(mesh, axes) -> int:
    return int(np.prod([axis_size(mesh, a) for a in axes]))


def _greedy(shape, mesh, prefs):
    """Assign mesh axes to dims by preference order with divisibility.

    prefs: list of (dim, axes) where axes is a str or tuple of axis names
    (tried as a combined product). Later prefs skip used axes/dims.
    """
    spec = [None] * len(shape)
    used: set[str] = set()
    for dim, axes in prefs:
        if dim >= len(shape) or spec[dim] is not None:
            continue
        axes_t = axes if isinstance(axes, tuple) else (axes,)
        axes_t = tuple(a for a in axes_t
                       if a in mesh.axis_names and a not in used)
        if not axes_t:
            continue
        if _divides(shape[dim], _axes_size(mesh, axes_t)):
            spec[dim] = axes_t if len(axes_t) > 1 else axes_t[0]
            used.update(axes_t)
    return P(*spec)


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        out.append(getattr(p, "key", getattr(p, "name", str(p))))
    return out


def param_spec(path, leaf, cfg: ModelConfig, mesh, fsdp: bool) -> P:
    names = _path_names(path)
    shape = leaf.shape
    stacked = "layers" in names  # leading superlayer axis
    off = 1 if stacked else 0
    m = axis_size(mesh, "model")
    d = axis_size(mesh, "data")

    def pad(*spec):
        full = (None,) * off + spec
        full = full + (None,) * (len(shape) - len(full))
        return list(full[: len(shape)])

    spec: list = pad()
    if "table" in names:  # embeddings [V, D]
        spec = [None] * len(shape)
        if _divides(shape[0], m):
            spec[0] = "model"
    elif names[-1] == "w":
        site = names[-2]
        if site in ("wq", "wk", "wv"):
            if _divides(shape[off + 1], m):
                spec = pad(None, "model")
        elif site == "wo":
            if _divides(shape[off + 0], m):
                spec = pad("model", None)
        elif site in ("w_up", "w_gate", "in_proj"):
            if _divides(shape[off + 1], m):
                spec = pad(None, "model")
        elif site in ("w_down", "out_proj"):
            if _divides(shape[off + 0], m):
                spec = pad("model", None)
        # router stays replicated
    elif names[-1] in ("w_up", "w_gate") and len(shape) - off == 3:
        # MoE expert weights [E, D, F]
        e, ff = shape[off], shape[off + 2]
        if _divides(e, m):
            spec = pad("model", None, None)        # expert parallel
        elif _divides(ff, m):
            spec = pad(None, None, "model")        # TP inside experts
    elif names[-1] == "w_down" and len(shape) - off == 3:
        e, ff = shape[off], shape[off + 1]
        if _divides(e, m):
            spec = pad("model", None, None)
        elif _divides(ff, m):
            spec = pad(None, "model", None)
    elif names[-1] in ("conv_w", "conv_b", "A_log", "D", "dt_bias",
                       "norm_scale", "scale"):
        spec = [None] * len(shape)  # small/replicated

    # FSDP: shard the largest still-unsharded non-stacked dim over 'data'
    if fsdp and len(shape) - off >= 2:
        cands = sorted(
            (i for i in range(off, len(shape))
             if spec[i] is None and _divides(shape[i], d)),
            key=lambda i: -shape[i])
        if cands:
            spec[cands[0]] = "data"
    return P(*spec)


def should_fsdp(cfg: ModelConfig, mesh,
                threshold_bytes: float = 4e9) -> bool:
    total, _ = cfg.param_counts()
    m = axis_size(mesh, "model")
    return total * 4 / m > threshold_bytes


def param_shardings(cfg: ModelConfig, params_shape, mesh, fsdp=None):
    fsdp = should_fsdp(cfg, mesh) if fsdp is None else fsdp
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, cfg, mesh, fsdp)),
        params_shape)


def opt_shardings(cfg: ModelConfig, params_shape, mesh, fsdp=None):
    """Moments get 'data' sharding even without FSDP (ZeRO-1)."""
    fsdp = should_fsdp(cfg, mesh) if fsdp is None else fsdp
    moments = jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, cfg, mesh, True)),
        params_shape)
    return {"m": moments, "v": moments,
            "step": NamedSharding(mesh, P())}


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def batch_sharding(shape, mesh) -> NamedSharding:
    """Token-like arrays [B, ...]: batch over ('pod','data')."""
    dp = dp_axes(mesh)
    return NamedSharding(mesh, _greedy(shape, mesh, [(0, dp)]))


def cache_leaf_spec(path, leaf, mesh) -> P:
    names = _path_names(path)
    shape = leaf.shape
    dp = dp_axes(mesh)
    if names[-1] in ("k", "v"):
        if len(shape) == 5:    # [R, B, S, Hkv, Dh]
            return _greedy(shape, mesh,
                           [(1, dp), (3, "model"), (2, "model"),
                            (2, dp), (2, ("data", "model"))])
        if len(shape) == 4:    # [B, S, Hkv, Dh] (prefix layer)
            return _greedy(shape, mesh,
                           [(0, dp), (2, "model"), (1, "model")])
    if names[-1] == "ssd":     # [R, B, H, P, N] or [B, H, P, N]
        off = len(shape) - 4
        return _greedy(shape, mesh,
                       [(off + 0, dp), (off + 1, "model")])
    if names[-1] == "conv":    # [R, B, W-1, conv_dim]
        off = len(shape) - 3
        return _greedy(shape, mesh,
                       [(off + 0, dp), (off + 2, "model")])
    if names and names[0] == "memory_kv":  # [R, B, S_enc, Hkv, Dh]
        return _greedy(shape, mesh,
                       [(1, dp), (3, "model"), (2, "model")])
    return P()


def cache_shardings(cache_shape, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_leaf_spec(path, leaf, mesh)),
        cache_shape)


def batch_shardings(batch_shape, mesh):
    return jax.tree_util.tree_map(
        lambda leaf: batch_sharding(leaf.shape, mesh), batch_shape)
