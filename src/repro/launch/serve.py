"""Churn-driven serving with the ETICA two-tier KV manager.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --events 2000 --tenants 4 --live 256 [--manager lru]

A session arrival/churn stream (`repro.traces.generate_sessions`: zipf
popularity, bursty batch residency, bounded lifetimes) drives the
manager's full lifecycle — arrivals, activations (tier-1 residency via
the POD/popularity controller), KV-page appends (WBWO commits), and
retirements — at serving population sizes, not a fixed handful of
sessions. KV pages are *real*: one prefill of the reduced model fills a
bank of pages from its first attention layer's cache, and decode steps
run real paged attention against the HBM pool. Prints hit ratio / DMA
traffic / latency — the serving analogs of the paper's hit-ratio /
SSD-write / latency metrics.

Managers: ``etica`` (batched controller), ``etica-seq`` (the host-dict
sequential oracle — same decisions, slower), ``lru`` (global LRU +
write-back baseline).

Observability: ``--metrics-port N`` starts the stdlib scrape endpoint
(`repro.runtime.http.MetricsServer`; 0 picks an ephemeral port, printed
at startup) serving live ``/metrics`` from the manager's counters and
telemetry journal; ``--journal PATH`` spills one JSONL row per
maintenance interval (read it back with ``tools/run_report.py``);
``--spans`` enables the dispatch wall-clock histograms (adds
``block_until_ready`` syncs — off by default).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.kernels.decode_attention.ops import decode_attention
from repro.kvcache import GlobalLRUManager, TwoTierConfig, TwoTierKVManager
from repro.models import model as M
from repro.traces import (SESSION_ACTIVATE, SESSION_APPEND, SESSION_END,
                          SESSION_NEW, SessionSpec, generate_sessions)


def kv_page_bank(cfg, kv_cfg: TwoTierConfig, bank: int, seed: int):
    """A bank of real KV pages: prefill the reduced model once over
    ``bank`` pages' worth of random tokens and slice its first attention
    layer's cache into ``[1, page_size, heads, dim]`` pages. Falls back
    to gaussian pages for frontends whose prefill needs extra modalities
    (encdec/vision) — the manager only moves bytes either way."""
    ps = kv_cfg.page_size
    rng = np.random.default_rng(seed)
    if cfg.is_encdec or getattr(cfg, "frontend", None) == "vision":
        pages = rng.normal(size=(bank, 1, ps, kv_cfg.num_kv_heads,
                                 kv_cfg.head_dim)).astype(np.float32)
        return pages, pages
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1),
                              (1, bank * ps), 0, cfg.vocab_size)
    _, cache = M.prefill(params, cfg, {"tokens": toks}, cache_len=bank * ps)
    k_leaf = v_leaf = None
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            cache["layers"])[0]:
        name = getattr(path[-1], "key", getattr(path[-1], "name", ""))
        if np.ndim(leaf) == 5 and np.shape(leaf)[2] == bank * ps:
            if name == "k" and k_leaf is None:
                k_leaf = np.asarray(leaf[0], np.float32)   # [1, S, Hkv, D]
            elif name == "v" and v_leaf is None:
                v_leaf = np.asarray(leaf[0], np.float32)
    assert k_leaf is not None and v_leaf is not None, "no attention cache"
    if (k_leaf.shape[2], k_leaf.shape[3]) != (kv_cfg.num_kv_heads,
                                              kv_cfg.head_dim):
        raise ValueError("kv geometry mismatch between model and pool")
    split = lambda a: np.stack([a[:, i * ps:(i + 1) * ps]
                                for i in range(bank)])
    return split(k_leaf), split(v_leaf)


def run_events(mgr, trace, k_bank, v_bank, *, decode_every: int = 0,
               seed: int = 0):
    """Replay a SessionTrace through a manager; optionally run a real
    paged-attention decode step every ``decode_every``-th activation."""
    rng = np.random.default_rng(seed)
    bank = k_bank.shape[0]
    n_act = 0
    for i in range(len(trace)):
        kind, sid = int(trace.kind[i]), int(trace.sid[i])
        if kind == SESSION_NEW:
            mgr.new_session(sid, int(trace.tenant[i]))
        elif kind == SESSION_APPEND:
            j = sid % bank
            mgr.append_page(sid, k_bank[j], v_bank[j])
        elif kind == SESSION_ACTIVATE:
            pt = mgr.activate(sid)
            n_act += 1
            if decode_every and n_act % decode_every == 0:
                h, d = mgr.cfg.num_kv_heads, mgr.cfg.head_dim
                q = jnp.asarray(rng.normal(size=(1, h, d)), jnp.float32)
                lengths = jnp.asarray([mgr.sessions[sid].length], jnp.int32)
                out = decode_attention(
                    q, (mgr.k_pool[0], mgr.v_pool[0]),
                    jnp.asarray(pt[None, :]), lengths)
                assert bool(jnp.all(jnp.isfinite(out)))
            mgr.deactivate(sid)
        elif kind == SESSION_END:
            mgr.end_session(sid)
    return mgr.stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--events", type=int, default=2000)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--live", type=int, default=256,
                    help="target concurrent sessions")
    ap.add_argument("--hbm-pages", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-pages", type=int, default=6,
                    help="per-session KV budget (pages)")
    ap.add_argument("--manager", choices=["etica", "etica-seq", "lru"],
                    default="etica")
    ap.add_argument("--decode-every", type=int, default=8,
                    help="real paged-attention decode each Nth activation "
                         "(0 = controller only)")
    ap.add_argument("--no-materialize", action="store_true",
                    help="skip device page pools (implies no decode) — "
                         "controller-scale runs")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve live /metrics + /healthz on this port "
                         "(0 = ephemeral; off when omitted)")
    ap.add_argument("--journal", default=None,
                    help="spill the per-interval telemetry journal to "
                         "this JSONL path")
    ap.add_argument("--spans", action="store_true",
                    help="time the fused dispatches into the "
                         "etica_dispatch_seconds histogram (adds "
                         "block_until_ready syncs)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch)
    recorder = None
    if args.metrics_port is not None or args.journal or args.spans:
        from repro.runtime.telemetry import TelemetryRecorder
        recorder = TelemetryRecorder(spill=args.journal,
                                     span_timing=args.spans)
    kv_cfg = TwoTierConfig(
        page_size=args.page_size, hbm_pages=args.hbm_pages,
        num_kv_heads=max(cfg.num_kv_heads, 1),
        head_dim=max(cfg.head_dim, 8), num_layers=1, dtype="float32",
        materialize=not args.no_materialize, telemetry=recorder)
    if args.manager == "lru":
        mgr = GlobalLRUManager(kv_cfg, args.tenants)
    else:
        mgr = TwoTierKVManager(kv_cfg, args.tenants,
                               batched=args.manager == "etica")

    server = None
    if args.metrics_port is not None:
        from repro.runtime import metrics as metrics_mod
        from repro.runtime.http import MetricsServer

        def _collect():
            out = []
            if isinstance(mgr, TwoTierKVManager):
                out += metrics_mod.collect_serving(mgr)
                out += metrics_mod.collect_telemetry(
                    mgr.telemetry, prefix="etica_serving", label="tenant")
            return out

        server = MetricsServer(_collect, port=args.metrics_port)
        host, port = server.start()
        print(f"metrics: http://{host}:{port}/metrics")

    spec = SessionSpec(num_tenants=args.tenants, target_live=args.live,
                       max_pages=args.max_pages)
    trace = generate_sessions(spec, args.events, seed=args.seed)
    k_bank, v_bank = kv_page_bank(cfg, kv_cfg, bank=8, seed=args.seed)

    t0 = time.time()
    decode_every = 0 if args.no_materialize else args.decode_every
    stats = run_events(mgr, trace, k_bank, v_bank,
                       decode_every=decode_every, seed=args.seed)
    wall = time.time() - t0
    s = stats.as_dict()
    print(f"manager={args.manager} events={args.events} "
          f"sessions={trace.num_sessions} max_live={trace.max_live} "
          f"wall={wall:.1f}s")
    for k, v in s.items():
        print(f"  {k:18s} {v:,.3f}" if isinstance(v, float) else
              f"  {k:18s} {v:,}")
    if recorder is not None and recorder.journal.total:
        last = recorder.journal.last_row()
        flagged = [str(t) for t, f in enumerate(last["overloaded"]) if f]
        print(f"  telemetry: {recorder.journal.total} interval rows"
              + (f", journal -> {args.journal}" if args.journal else "")
              + (f", overloaded tenants: {','.join(flagged)}"
                 if flagged else ""))
    if server is not None:
        # interactive runs keep the endpoint alive for a final scrape;
        # programmatic callers (argv passed in) get it shut down cleanly
        if argv is None:
            print(f"scrape still live at {server.url} (ctrl-c to exit)")
            try:
                import signal
                signal.pause()
            except (KeyboardInterrupt, AttributeError):
                pass
        server.stop()
    return s


if __name__ == "__main__":
    main()
