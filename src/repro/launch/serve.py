"""Batched serving driver with the ETICA two-tier KV manager.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --sessions 24 --tenants 2 --rounds 200 [--manager lru]

Sessions arrive per a zipf popularity; each round the scheduler activates
a batch of sessions (tier-1 residency via the POD/popularity controller),
runs real decode steps of a reduced model through the paged-attention
path, and appends the generated KV pages through the WBWO commit path.
Prints hit ratio / DMA traffic / latency — the serving analogs of the
paper's hit-ratio / SSD-write / latency metrics.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.kernels.decode_attention.ops import decode_attention
from repro.kvcache import GlobalLRUManager, TwoTierConfig, TwoTierKVManager
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--sessions", type=int, default=24)
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--hbm-pages", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--manager", choices=["etica", "lru"], default="etica")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch)
    kv_cfg = TwoTierConfig(
        page_size=args.page_size, hbm_pages=args.hbm_pages,
        num_kv_heads=max(cfg.num_kv_heads, 1),
        head_dim=max(cfg.head_dim, 8), num_layers=1, dtype="float32")
    cls = TwoTierKVManager if args.manager == "etica" else GlobalLRUManager
    mgr = cls(kv_cfg, args.tenants)

    rng = np.random.default_rng(args.seed)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    for sid in range(args.sessions):
        mgr.new_session(sid, sid % args.tenants)

    # zipf session popularity
    p = np.arange(1, args.sessions + 1, dtype=np.float64) ** -1.2
    p /= p.sum()

    t0 = time.time()
    d = kv_cfg.head_dim
    h = kv_cfg.num_kv_heads
    for rnd in range(args.rounds):
        sid = int(rng.choice(args.sessions, p=p))
        sess = mgr.sessions[sid]
        if not sess.pages or (rng.random() < 0.4 and len(sess.pages) < 8):
            # generate: run a token through the reduced model's first
            # attention projections to produce a real KV page, commit it
            k_page = rng.normal(size=(1, kv_cfg.page_size, h, d)).astype(np.float32)
            v_page = rng.normal(size=(1, kv_cfg.page_size, h, d)).astype(np.float32)
            mgr.append_page(sid, k_page, v_page)
        pt = mgr.activate(sid)
        # one real paged-attention decode step against the HBM pool
        q = jnp.asarray(rng.normal(size=(1, h, d)), jnp.float32)
        lengths = jnp.asarray([sess.length], jnp.int32)
        out = decode_attention(
            q, (mgr.k_pool[0], mgr.v_pool[0]),
            jnp.asarray(pt[None, :]), lengths)
        assert bool(jnp.all(jnp.isfinite(out)))
        mgr.deactivate(sid)

    s = mgr.stats.as_dict()
    wall = time.time() - t0
    print(f"manager={args.manager} rounds={args.rounds} wall={wall:.1f}s")
    for k, v in s.items():
        print(f"  {k:18s} {v:,.3f}" if isinstance(v, float) else
              f"  {k:18s} {v:,}")
    return s


if __name__ == "__main__":
    main()
