import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import: jax locks the device
count at first init, and the production meshes need 512 placeholder host
devices. Do not replicate this env var anywhere global — tests and
benches must see the single real CPU device.

Per cell this driver:
  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  2. builds the step implied by the shape kind (train / prefill / decode)
     and its ShapeDtypeStruct input specs + NamedShardings,
  3. ``jit(...).lower(...).compile()`` — success proves the sharding plan
     is coherent (no mismatched collectives, no impossible layouts),
  4. records cost_analysis (FLOPs/bytes), collective traffic parsed from
     the compiled HLO (see hlo_analysis), memory_analysis when the
     backend provides it, and analytic per-device state bytes,
  5. writes one JSON under experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --list   # all cells
"""
import argparse
import json
import sys
import time
import traceback


HW = {  # TPU v5e-class hardware constants (per chip)
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,
}


def _sharded_bytes(tree, shardings) -> int:
    import jax
    import numpy as np

    def per_leaf(leaf, sh):
        n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize if leaf.shape else leaf.dtype.itemsize
        spec = sh.spec
        shards = 1
        for i, s in enumerate(spec):
            if s is None:
                continue
            axes = s if isinstance(s, tuple) else (s,)
            for a in axes:
                shards *= sh.mesh.shape[a]
        return n // max(shards, 1)

    leaves = jax.tree_util.tree_leaves(tree)
    shard_leaves = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
    return sum(per_leaf(l, s) for l, s in zip(leaves, shard_leaves))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None, sites: str = "none",
             grad_dtype: str | None = None, census: bool = False,
             bf16_params: bool = False) -> dict:
    import contextlib

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import configs
    from repro.launch import sharding as SH
    from repro.launch import steps as ST
    from repro.launch.hlo_analysis import analyze, byte_census
    from repro.launch.mesh import dp_axes, make_production_mesh
    from repro.models.config import SHAPES, shape_applicable
    from repro.models.sharding_hooks import sharding_site_specs

    cfg = configs.get(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "kind": shape.kind,
    }
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    specs = ST.input_specs(cfg, shape)
    if bf16_params:
        # serving lever: weights pre-cast to bf16 at load time — halves
        # weight-read traffic and FSDP gather bytes for decode/prefill
        import jax.numpy as jnp
        specs["params"] = jax.tree_util.tree_map(
            lambda s: (jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
                       if s.dtype == jnp.float32 else s),
            specs["params"])
        rec["bf16_params"] = True
    params_sh = SH.param_shardings(cfg, specs["params"], mesh)
    fsdp = SH.should_fsdp(cfg, mesh)
    rec["fsdp"] = fsdp

    # optional explicit activation shardings (§Perf levers): "attn" pins
    # the attention head axis to 'model' only when the head count divides
    # it, and replicates heads otherwise — avoiding GSPMD's fallback of
    # per-chunk masked all-reduces for non-divisible head counts.
    site_specs = {}
    if sites == "attn":
        dp = dp_axes(mesh)
        m = mesh.shape["model"]
        h_spec = "model" if cfg.num_heads % m == 0 else None
        kv_spec = "model" if cfg.num_kv_heads % m == 0 else None
        site_specs = {
            "attn_q": P(dp, None, h_spec, None),
            "attn_kv": P(dp, None, kv_spec, None),
        }
    rec["sites"] = sites
    if grad_dtype:
        rec["grad_dtype"] = grad_dtype

    if shape.kind == "train":
        step = ST.make_train_step(cfg, grad_dtype=grad_dtype)
        opt_sh = SH.opt_shardings(cfg, specs["params"], mesh)
        batch_sh = SH.batch_shardings(specs["batch"], mesh)
        args = (specs["params"], specs["opt_state"], specs["batch"])
        in_sh = (params_sh, opt_sh, batch_sh)
        out_sh = (params_sh, opt_sh, None)
        donate = (0, 1)
        tokens = shape.global_batch * shape.seq_len
        rec["model_flops"] = cfg.model_flops(tokens, decode=False)
        state_bytes = (_sharded_bytes(specs["params"], params_sh)
                       + _sharded_bytes(specs["opt_state"]["m"], opt_sh["m"])
                       + _sharded_bytes(specs["opt_state"]["v"], opt_sh["v"]))
    elif shape.kind == "prefill":
        clen = ST.cache_len_for(cfg, shape)
        step = ST.make_prefill_step(cfg, clen)
        batch_sh = SH.batch_shardings(specs["batch"], mesh)
        args = (specs["params"], specs["batch"])
        in_sh = (params_sh, batch_sh)
        out_sh = None
        donate = ()
        tokens = shape.global_batch * shape.seq_len
        rec["model_flops"] = cfg.model_flops(tokens, decode=True)
        state_bytes = _sharded_bytes(specs["params"], params_sh)
    else:  # decode
        step = ST.make_decode_step(cfg)
        cache_sh = SH.cache_shardings(specs["cache"], mesh)
        tok_sh = SH.batch_sharding((shape.global_batch, 1), mesh)
        pos_sh = NamedSharding(mesh, P())
        args = (specs["params"], specs["cache"], specs["tokens"],
                specs["pos"])
        in_sh = (params_sh, cache_sh, tok_sh, pos_sh)
        out_sh = (None, cache_sh)
        donate = (1,)
        tokens = shape.global_batch  # one new token per sequence
        rec["model_flops"] = cfg.model_flops(tokens, decode=True)
        state_bytes = (_sharded_bytes(specs["params"], params_sh)
                       + _sharded_bytes(specs["cache"], cache_sh))

    ctx = (sharding_site_specs(site_specs) if site_specs
           else contextlib.nullcontext())
    with mesh, ctx:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0] if ca else {}
    rec["xla_cost_flops"] = float(ca.get("flops", 0.0))
    rec["xla_cost_bytes"] = float(ca.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for field in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes"):
                v = getattr(ma, field, None)
                if v is not None:
                    rec[f"mem_{field}"] = int(v)
    except Exception as e:  # CPU backend may not implement it
        rec["memory_analysis_error"] = str(e)
    rec["state_bytes_per_device"] = int(state_bytes)

    hlo = analyze(compiled.as_text())
    # The compiled SPMD module is the per-device program (shard shapes),
    # so parsed numbers are per device; globals scale by chip count. The
    # parser also expands while (scan) bodies by trip count, which XLA's
    # own cost_analysis does not.
    chips = rec["chips"]
    rec["collectives"] = hlo["per_op"]
    rec["collective_counts"] = hlo["count"]
    rec["collective_bytes_per_device"] = hlo["total"]
    rec["collective_bytes"] = hlo["total"] * chips
    rec["flops_per_device"] = max(hlo["dot_flops"], rec["xla_cost_flops"])
    rec["flops"] = rec["flops_per_device"] * chips
    rec["hlo_bytes_per_device"] = max(hlo["hbm_bytes"], rec["xla_cost_bytes"])
    rec["hlo_bytes"] = rec["hlo_bytes_per_device"] * chips

    rec["t_compute_s"] = rec["flops"] / (chips * HW["peak_flops_bf16"])
    rec["t_memory_s"] = rec["hlo_bytes"] / (chips * HW["hbm_bw"])
    rec["t_collective_s"] = rec["collective_bytes"] / (chips * HW["ici_bw"])
    terms = {"compute": rec["t_compute_s"], "memory": rec["t_memory_s"],
             "collective": rec["t_collective_s"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["useful_flops_ratio"] = (rec["model_flops"] / rec["flops"]
                                 if rec["flops"] else 0.0)
    if census:
        rec["census"] = byte_census(compiled.as_text())
    rec["status"] = "ok"
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=False)
    ap.add_argument("--shape", required=False)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--list", action="store_true",
                    help="print all cells (arch shape) and exit")
    ap.add_argument("--override", default="",
                    help="comma list k=v ModelConfig overrides (perf loop)")
    ap.add_argument("--sites", default="none", choices=["none", "attn"],
                    help="explicit activation sharding sites (perf lever)")
    ap.add_argument("--grad-dtype", default="",
                    help="cast grads before optimizer (e.g. bfloat16)")
    ap.add_argument("--census", action="store_true",
                    help="include a byte/collective census in the JSON")
    ap.add_argument("--bf16-params", action="store_true",
                    help="serve with bf16 weights (perf lever)")
    args = ap.parse_args(argv)

    from repro import configs
    from repro.models.config import SHAPES

    if args.list:
        for a in configs.ARCH_IDS:
            for s in SHAPES:
                print(a, s)
        return 0

    overrides = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        overrides[k] = type(getattr(configs.get(args.arch), k))(eval(v))

    rec = run_cell(args.arch, args.shape, args.multi_pod, overrides,
                   sites=args.sites, grad_dtype=args.grad_dtype or None,
                   census=args.census, bf16_params=args.bf16_params)
    os.makedirs(args.out, exist_ok=True)
    name = f"{args.arch}__{args.shape}__{rec['mesh']}__{args.tag}.json"
    path = os.path.join(args.out, name)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))
    print("wrote", path, file=sys.stderr)
    return 0 if rec["status"] in ("ok", "skip") else 1


if __name__ == "__main__":
    try:
        sys.exit(main())
    except Exception:
        traceback.print_exc()
        sys.exit(1)
