"""Run the full dry-run sweep: every (arch x shape x mesh) cell in its own
subprocess (fresh XLA + device-count init per cell), resumable — cells
with an existing JSON are skipped.

Usage:
  PYTHONPATH=src python -m repro.launch.sweep [--out experiments/dryrun]
      [--multi-pod-only|--single-pod-only] [--timeout 2400]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def cells():
    from repro import configs
    from repro.models.config import SHAPES
    for arch in configs.ARCH_IDS:
        for shape in SHAPES:
            yield arch, shape


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args(argv)

    meshes = []
    if not args.multi_pod_only:
        meshes.append(("16x16", []))
    if not args.single_pod_only:
        meshes.append(("2x16x16", ["--multi-pod"]))

    os.makedirs(args.out, exist_ok=True)
    todo = [(a, s, m, extra) for (a, s) in cells() for (m, extra) in meshes]
    t_start = time.time()
    n_ok = n_skip = n_fail = n_cached = 0
    for i, (arch, shape, mesh_name, extra) in enumerate(todo):
        path = os.path.join(
            args.out, f"{arch}__{shape}__{mesh_name}__{args.tag}.json")
        if os.path.exists(path):
            n_cached += 1
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out,
               "--tag", args.tag] + extra
        t0 = time.time()
        print(f"[{i+1}/{len(todo)}] {arch} {shape} {mesh_name} ...",
              flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            status = "?"
            if os.path.exists(path):
                with open(path) as f:
                    status = json.load(f).get("status", "?")
            if r.returncode == 0 and status in ("ok", "skip"):
                if status == "skip":
                    n_skip += 1
                else:
                    n_ok += 1
                print(f"    {status} in {time.time()-t0:.0f}s", flush=True)
            else:
                n_fail += 1
                tail = (r.stderr or r.stdout or "")[-2000:]
                print(f"    FAIL rc={r.returncode}\n{tail}", flush=True)
                with open(path + ".fail", "w") as f:
                    f.write(tail)
        except subprocess.TimeoutExpired:
            n_fail += 1
            print("    TIMEOUT", flush=True)
            with open(path + ".fail", "w") as f:
                f.write("timeout")
    print(f"done in {time.time()-t_start:.0f}s: ok={n_ok} skip={n_skip} "
          f"fail={n_fail} cached={n_cached}", flush=True)
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
