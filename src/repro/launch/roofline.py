"""Aggregate dry-run JSONs into the §Dry-run / §Roofline tables.

    PYTHONPATH=src python -m repro.launch.roofline \
        [--dir experiments/dryrun] [--mesh 16x16] [--tag baseline] [--md]

Per (arch x shape): the three roofline terms (seconds), the dominant
term, MODEL_FLOPS/HLO_FLOPs, and a one-line "what would move the
dominant term" note derived from the collective/byte mix.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str, mesh: str, tag: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}__{tag}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def advice(rec: dict) -> str:
    """One sentence: what would move the dominant term down."""
    b = rec.get("bottleneck")
    coll = rec.get("collectives", {})
    if rec.get("status") != "ok":
        return rec.get("reason", "")
    if b == "memory":
        if rec["kind"] == "decode":
            return ("KV reads dominate: shrink cache dtype/window or batch "
                    "more queries per KV pass")
        return ("activation traffic dominates: fuse attention (Pallas flash "
                "kernel keeps S^2 tiles in VMEM) / stronger remat")
    if b == "collective":
        top = max(coll, key=coll.get) if coll else "?"
        if top == "all-to-all":
            return "MoE dispatch all-to-all: cut capacity factor or shard tokens with experts"
        if top == "all-gather":
            return "FSDP weight gathers: overlap with compute or widen model axis"
        return "gradient all-reduce: reduce-scatter + bf16/int8 compression"
    return "compute-bound: good — push MXU utilization (tiling/dtype)"


def fraction(rec: dict) -> float:
    """Roofline fraction = useful-compute time / dominant-term time."""
    t_useful = rec["model_flops"] / (rec["chips"] * 197e12)
    t_dom = max(rec["t_compute_s"], rec["t_memory_s"], rec["t_collective_s"])
    return t_useful / t_dom if t_dom else 0.0


def table(recs: list[dict], md: bool = True) -> str:
    hdr = ["arch", "shape", "status", "t_compute", "t_memory", "t_coll",
           "bottleneck", "MF/HLO", "roofline_frac", "note"]
    lines = []
    if md:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    for r in recs:
        if r.get("status") == "skip":
            row = [r["arch"], r["shape"], "SKIP", "-", "-", "-", "-", "-",
                   "-", r.get("reason", "")[:60]]
        else:
            row = [r["arch"], r["shape"], "ok",
                   f"{r['t_compute_s']:.3g}", f"{r['t_memory_s']:.3g}",
                   f"{r['t_collective_s']:.3g}", r["bottleneck"],
                   f"{r['useful_flops_ratio']:.2f}",
                   f"{fraction(r):.3f}", advice(r)]
        lines.append(("| " + " | ".join(row) + " |") if md
                     else ",".join(row))
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--md", action="store_true", default=True)
    args = ap.parse_args(argv)
    recs = load(args.dir, args.mesh, args.tag)
    print(table(recs))
    ok = [r for r in recs if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=fraction)
        coll = max(ok, key=lambda r: r["t_collective_s"]
                   / max(r["t_compute_s"] + r["t_memory_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']} {worst['shape']} "
              f"({fraction(worst):.4f})")
        print(f"most collective-bound:  {coll['arch']} {coll['shape']} "
              f"(t_coll {coll['t_collective_s']:.3g}s)")


if __name__ == "__main__":
    main()
