"""End-to-end training driver (runs for real on the host mesh).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt \
        [--inject-failure-at 20] [--compress-grads]

Wires together every substrate: model zoo, AdamW, deterministic data
pipeline, async atomic checkpointing, straggler monitoring, bounded-retry
recovery (with exact replay), and optional int8 gradient compression.
The same step function lowers unchanged on the production meshes (that
path is exercised by launch.dryrun).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint.store import AsyncCheckpointer, latest_step, restore
from repro.data.pipeline import TokenPipeline
from repro.launch import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import OptConfig, init_opt_state
from repro.runtime.fault import StragglerMonitor, run_with_recovery


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get(args.arch))
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 10, 1))
    mesh = make_host_mesh()
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} "
          f"params~{cfg.param_counts()[0]/1e6:.1f}M")

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    opt_state = init_opt_state(params, opt_cfg)
    start_step = 0

    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start_step, _ = restore(
            args.ckpt_dir, (params, opt_state))
        print(f"restored from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    pipe = TokenPipeline(cfg, args.batch, args.seq, seed=args.seed)
    monitor = StragglerMonitor()

    def snapshot(state):
        # committed state must be host-resident: device buffers are
        # donated by subsequent steps (restoring them would hand the
        # runtime deleted buffers) — mirroring a real restore-from-disk.
        return jax.tree_util.tree_map(np.asarray, state)

    def restore_committed():
        return jax.tree_util.tree_map(jax.device_put, committed)

    committed = snapshot((params, opt_state))
    failed_once = False
    losses = []

    for step in range(start_step, args.steps):
        batch = pipe.batch_at(step)
        t0 = time.time()

        def thunk(state, b):
            nonlocal failed_once
            if step == args.inject_failure_at and not failed_once:
                failed_once = True
                raise RuntimeError("injected device failure")
            p, o = state
            return step_fn(p, o, b)

        params, opt_state, metrics = run_with_recovery(
            thunk, (params, opt_state), batch,
            restore_fn=restore_committed)
        dt = time.time() - t0
        straggler = monitor.observe(step, dt)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or straggler:
            flag = " STRAGGLER" if straggler else ""
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"{dt*1e3:7.1f}ms{flag}")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state))
            committed = snapshot((params, opt_state))
    if ckpt:
        ckpt.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"stragglers flagged: {len(monitor.flagged)}")
    assert np.isfinite(losses[-1])
    return losses


if __name__ == "__main__":
    main()
