"""Production meshes.

Single pod: 16x16 = 256 chips, axes ('data', 'model').
Multi-pod:  2x16x16 = 512 chips, axes ('pod', 'data', 'model') — the
'pod' axis only ever carries batch (pure data parallelism across pods,
so cross-pod traffic is one gradient reduction per step / none when
serving).

Defined as functions (never module-level) so importing this module never
touches jax device state — required because the dry-run process forces
``xla_force_host_platform_device_count=512`` before first jax init while
tests/benches must see the single real CPU device.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    # dry-run host platform exposes 512 placeholder devices; the
    # single-pod mesh uses the first 256 of them.
    assert len(devices) >= n, (
        f"need {n} devices for mesh {shape}, have {len(devices)} — run "
        "under launch/dryrun.py which forces "
        "xla_force_host_platform_device_count=512")
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over whatever devices exist (tests on 1 CPU)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The batch-carrying axes of a mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
