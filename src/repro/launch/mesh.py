"""Production meshes.

Single pod: 16x16 = 256 chips, axes ('data', 'model').
Multi-pod:  2x16x16 = 512 chips, axes ('pod', 'data', 'model') — the
'pod' axis only ever carries batch (pure data parallelism across pods,
so cross-pod traffic is one gradient reduction per step / none when
serving).

Defined as functions (never module-level) so importing this module never
touches jax device state — required because the dry-run process forces
``xla_force_host_platform_device_count=512`` before first jax init while
tests/benches must see the single real CPU device.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    # dry-run host platform exposes 512 placeholder devices; the
    # single-pod mesh uses the first 256 of them.
    if len(devices) < n:
        raise ValueError(
            f"need {n} devices for mesh shape {shape} with axes {axes}, "
            f"have {len(devices)} — run under launch/dryrun.py which "
            "forces xla_force_host_platform_device_count=512")
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over whatever devices exist (tests on 1 CPU)."""
    n = len(jax.devices())
    if n % model == 0:
        return jax.make_mesh((n // model, model), ("data", "model"))
    raise ValueError(
        f"host mesh needs the device count ({n}) divisible by the "
        f"requested model-axis size ({model}) for shape "
        f"({n // model}, {model})")


def make_vm_mesh(num_shards: int | None = None):
    """1-d VM-axis mesh over available devices, axis name ``'vm'``.

    The consolidation meshes: batched ``[V, S, W]`` controller state is
    split over this axis by ``shard_map``, one block of VMs per device.
    ``num_shards=None`` takes every device. On CPU CI, force placeholder
    devices first — ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    before the first jax init (same trick as launch/dryrun.py).
    """
    devices = jax.devices()
    n = len(devices) if num_shards is None else num_shards
    if n > len(devices):
        raise ValueError(
            f"VM mesh wants {n} shards but only {len(devices)} devices "
            "exist — on CPU, set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=<n> before "
            "jax initializes")
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("vm",))


def vm_spec(mesh):
    """``PartitionSpec`` over a VM mesh's single axis (prefix spec: the
    leading VM dimension of any-rank arrays is the sharded one)."""
    from jax.sharding import PartitionSpec
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"VM-axis sharding needs a 1-d mesh, got axes {mesh.axis_names}")
    return PartitionSpec(mesh.axis_names[0])


def require_vm_divisible(num_vms: int, mesh) -> None:
    """Reject VM counts the mesh cannot split evenly (callers pad first)."""
    if num_vms % mesh.size != 0:
        raise ValueError(
            f"sharded dispatch needs the VM count ({num_vms}) divisible by "
            f"the mesh size ({mesh.size}); pad with dead VMs (addr=-1 / "
            f"empty sub-traces) first")


def device_row_blocks(num_rows: int, mesh):
    """``[(device, row_slice), ...]`` splitting ``num_rows`` evenly over
    the mesh's devices, in mesh order.

    The manual-dispatch analogue of ``vm_spec``: routes that cannot trust
    ``shard_map`` (the CPU GSPMD partitioner wraps some row-local bodies
    in spurious cross-shard all-reduces, corrupting every device but the
    first — see ``core.reuse``) instead run one single-device executable
    per block and concatenate on the host. Zero collectives by
    construction, and each block runs the *same* jitted program as the
    single-device oracle, so results stay bit-identical.
    """
    require_vm_divisible(num_rows, mesh)
    devices = list(mesh.devices.flat)
    per = num_rows // len(devices)
    return [(dev, slice(i * per, (i + 1) * per))
            for i, dev in enumerate(devices)]


def dp_axes(mesh) -> tuple[str, ...]:
    """The batch-carrying axes of a mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
