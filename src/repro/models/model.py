"""Top-level models: causal LM (dense/MoE/SSM/hybrid/VLM) and enc-dec.

Functional API over nested-dict params:

  * :func:`init_params`   — jittable (works under ``jax.eval_shape`` for
    the allocation-free dry-run).
  * :func:`forward_train` — loss over a batch (scan over superlayers with
    rematerialization).
  * :func:`prefill`       — run the prompt, return (last-position logits,
    cache pytree) for decoding.
  * :func:`decode_step`   — one token against the cache.
  * :func:`init_cache`    — zero/abstract cache (decode dry-run entry).

Batch dicts:
  LM:      {"tokens": [B,S] int32}                (labels = shifted tokens)
  VLM:     {"tokens": [B,S_text], "patches": [B,P,D]}
  enc-dec: {"frames": [B,S_enc,D], "dec_tokens": [B,S_dec]}
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import blocks
from .attention import _project_kv
from .config import BlockSpec, ModelConfig
from .layers import dense, embed, init_dense, init_embedding, init_mlp, \
    init_rmsnorm, mlp, rmsnorm, unembed
from .sharding_hooks import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    keys = jax.random.split(key, 8)
    p = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model),
        "unembed": init_embedding(keys[1], cfg.vocab_size, cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    reps = cfg.num_superlayers
    layer_keys = jax.random.split(keys[2], reps)
    cross = cfg.is_encdec
    p["layers"] = jax.vmap(
        lambda k: blocks.init_superlayer(k, cfg, cross=cross))(layer_keys)
    if cfg.first_dense_ff:
        kp1, kp2 = jax.random.split(keys[3])
        p["prefix"] = blocks.init_block(kp1, cfg, BlockSpec(kind="attn"))
        # override ffn with the wide dense FFN (deepseek layer 0)
        p["prefix"]["ffn"] = init_mlp(kp2, cfg.d_model, cfg.first_dense_ff,
                                      cfg.mlp_act)
    if cfg.is_encdec:
        enc_keys = jax.random.split(keys[4], cfg.encoder_layers)
        enc_spec = BlockSpec(kind="attn")
        p["encoder"] = {
            "layers": jax.vmap(
                lambda k: blocks.init_block(k, cfg, enc_spec))(enc_keys),
            "final_norm": init_rmsnorm(cfg.d_model),
        }
    if cfg.frontend == "vision":
        p["frontend"] = init_dense(keys[5], cfg.d_model, cfg.d_model)
    elif cfg.frontend == "audio":
        p["frontend"] = init_dense(keys[5], cfg.d_model, cfg.d_model)
    return p


# ---------------------------------------------------------------------------
# backbone scan
# ---------------------------------------------------------------------------

def _scan_train(params, cfg: ModelConfig, x, positions, memory_kv=None,
                collect_cache: bool = False):
    """Scan superlayers; returns (x, aux, stacked_cache|None).

    ``memory_kv`` (enc-dec) is stacked per-superlayer and sliced by the
    scan alongside the layer parameters."""

    def body(carry, xs):
        h, aux = carry
        layer_params, mem = xs
        h, a, cache = blocks.superlayer_train(
            layer_params, cfg, h, positions,
            collect_cache=collect_cache, memory_kv=mem)
        return (h, aux + a), (cache if collect_cache else 0)

    body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), caches = jax.lax.scan(body, (x, 0.0),
                                    (params["layers"], memory_kv))
    return x, aux, (caches if collect_cache else None)


def _scan_decode(params, cfg: ModelConfig, x, cache, pos, memory_kv=None):
    def body(h, xs):
        layer_params, layer_cache = xs
        h, new_cache = blocks.superlayer_decode(
            layer_params, cfg, h, layer_cache, pos, memory_kv=memory_kv)
        return h, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    return x, new_caches


def _encode(params, cfg: ModelConfig, frames):
    """Encoder stack over (stub) frame embeddings [B, S_enc, D]."""
    spec = BlockSpec(kind="attn")
    x = dense(params["frontend"], frames) if "frontend" in params else frames
    positions = jnp.arange(x.shape[1])[None, :]

    def body(h, layer_params):
        h2, _, _ = blocks.block_train(layer_params, cfg, spec, h, positions,
                                      collect_cache=False, causal=False)
        return h2, None

    x, _ = jax.lax.scan(jax.checkpoint(body, prevent_cse=False), x,
                        params["encoder"]["layers"])
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _embed_inputs(params, cfg: ModelConfig, batch):
    """Token (+ modality) embedding -> (x, positions, loss_mask, labels)."""
    if cfg.is_encdec:
        tokens = batch["dec_tokens"]
        x = embed(params["embed"], tokens)
        positions = jnp.arange(x.shape[1])[None, :]
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.ones(tokens.shape, bool).at[:, -1].set(False)
        return x, positions, mask, labels
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens)
    if cfg.frontend == "vision" and "patches" in batch:
        pe = dense(params["frontend"], batch["patches"].astype(x.dtype))
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        text_mask = jnp.concatenate(
            [jnp.zeros(pe.shape[:2], bool), jnp.ones(tokens.shape, bool)],
            axis=1)
    else:
        text_mask = jnp.ones(tokens.shape, bool)
    positions = jnp.arange(x.shape[1])[None, :]
    full_tokens = jnp.concatenate(
        [jnp.zeros((x.shape[0], x.shape[1] - tokens.shape[1]), tokens.dtype),
         tokens], axis=1)
    labels = jnp.pad(full_tokens[:, 1:], ((0, 0), (0, 1)))
    mask = text_mask & jnp.ones(labels.shape, bool).at[:, -1].set(False)
    return x, positions, mask, labels


# ---------------------------------------------------------------------------
# training forward
# ---------------------------------------------------------------------------

def _chunked_ce(params, cfg: ModelConfig, x, labels, mask,
                chunk_tokens: int = 16_384):
    """Cross-entropy without materializing full [T, V] logits.

    Scans over token chunks; each chunk's logits are live only inside one
    loop iteration, bounding logits memory to chunk_tokens x V regardless
    of batch/sequence (big-vocab configs would otherwise blow HBM)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    lf = labels.reshape(t)
    mf = mask.reshape(t)
    chunk = min(chunk_tokens, t)
    pad = (-t) % chunk
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        lf = jnp.pad(lf, (0, pad))
        mf = jnp.pad(mf, (0, pad))
    nc = xf.shape[0] // chunk

    def body(carry, xs):
        xc, lc, mc = xs
        logits = unembed(params["unembed"], xc)
        logits = constrain(logits, "logits")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
        return carry + jnp.sum((logz - gold) * mc), None

    total, _ = jax.lax.scan(
        body, jnp.float32(0.0),
        (xf.reshape(nc, chunk, d), lf.reshape(nc, chunk),
         mf.reshape(nc, chunk)))
    return total


def forward_train(params, cfg: ModelConfig, batch, aux_weight: float = 0.01,
                  loss_chunk: int = 16_384):
    """Returns (loss, metrics)."""
    x, positions, mask, labels = _embed_inputs(params, cfg, batch)
    memory_kv = None
    if cfg.is_encdec:
        memory = _encode(params, cfg, batch["frames"].astype(x.dtype))
        memory_kv = _prepare_memory(params, cfg, memory)
    if "prefix" in params:
        x, _, _ = blocks.block_train(params["prefix"], cfg,
                                     BlockSpec(kind="attn"), x, positions,
                                     collect_cache=False)
    x, aux, _ = _scan_train(params, cfg, x, positions, memory_kv=memory_kv)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    x = constrain(x, "pre_logits")
    nll_sum = _chunked_ce(params, cfg, x, labels, mask, loss_chunk)
    denom = jnp.maximum(jnp.sum(mask), 1)
    loss = nll_sum / denom
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux,
                   "tokens": denom.astype(jnp.float32)}


def _prepare_memory(params, cfg: ModelConfig, memory):
    """Encoder memory is kept raw; cross-attn projects K/V per layer.

    To keep the decode path cheap we precompute per-superlayer K/V once:
    stacked [R, B, S_enc, Hkv, Dh]."""
    def per_layer(layer_params):
        block0 = layer_params["block0"]
        pos = jnp.arange(memory.shape[1])[None, :]
        k, v = _project_kv(block0["cross"], cfg, memory, pos, rope=False)
        return k, v

    ks, vs = jax.vmap(per_layer)(params["layers"])
    return ks, vs


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16, enc_len: int = 0):
    """Zero cache pytree (pass through jax.eval_shape for the dry-run)."""
    one = blocks.init_superlayer_cache(cfg, batch, cache_len, dtype)
    reps = cfg.num_superlayers
    layers = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), one)
    cache = {"layers": layers}
    if cfg.first_dense_ff:
        cache["prefix"] = blocks.init_superlayer_cache(
            cfg, batch, cache_len, dtype)["block0"]
    if cfg.is_encdec:
        cache["memory_kv"] = (
            jnp.zeros((reps, batch, enc_len, cfg.num_kv_heads, cfg.head_dim),
                      dtype),
            jnp.zeros((reps, batch, enc_len, cfg.num_kv_heads, cfg.head_dim),
                      dtype))
    return cache


def prefill(params, cfg: ModelConfig, batch, cache_len: int | None = None):
    """Run the full prompt; returns (last-position logits, cache)."""
    x, positions, _, _ = _embed_inputs(params, cfg, batch)
    s_prompt = x.shape[1]
    cache_len = cache_len or s_prompt
    memory_kv = None
    cache = {}
    if cfg.is_encdec:
        memory = _encode(params, cfg, batch["frames"].astype(x.dtype))
        memory_kv = _prepare_memory(params, cfg, memory)
        cache["memory_kv"] = memory_kv
    if "prefix" in params:
        x, _, pcache = blocks.block_train(
            params["prefix"], cfg, BlockSpec(kind="attn"), x, positions,
            collect_cache=True)
        cache["prefix"] = _pad_kv(pcache, cache_len)
    x, _, caches = _scan_train(params, cfg, x, positions,
                               memory_kv=memory_kv, collect_cache=True)
    cache["layers"] = jax.tree_util.tree_map_with_path(
        lambda path, a: _pad_stacked(path, a, cache_len), caches)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], x[:, -1:])
    return logits, cache


def _fit_kv_seq(a, cache_len, axis):
    """Pad K/V to cache_len, or — for sliding-window ring caches shorter
    than the prompt — keep the trailing window, rolled so each position p
    sits at slot p % cache_len (future ring writes then overwrite the
    oldest entry; stored K carries absolute RoPE so slot order is free).
    """
    s = a.shape[axis]
    pad = cache_len - s
    if pad >= 0:
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        return jnp.pad(a, widths)
    tail = jax.lax.slice_in_dim(a, s - cache_len, s, axis=axis)
    return jnp.roll(tail, shift=s % cache_len, axis=axis)


def _pad_kv(entry, cache_len):
    return {name: (_fit_kv_seq(a, cache_len, axis=1)
                   if name in ("k", "v") else a)
            for name, a in entry.items()}


def _pad_stacked(path, a, cache_len):
    names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    if names and names[-1] in ("k", "v"):
        return _fit_kv_seq(a, cache_len, axis=2)
    return a


def decode_step(params, cfg: ModelConfig, tokens, cache, pos):
    """tokens: [B,1] int32; pos: int32 scalar (next position).

    Returns (logits [B,1,V], new_cache)."""
    x = embed(params["embed"], tokens)
    memory_kv = cache.get("memory_kv")
    new_cache = dict(cache)
    if "prefix" in params:
        x, pc = blocks.block_decode(params["prefix"], cfg,
                                    BlockSpec(kind="attn"),
                                    x, cache["prefix"], pos)
        new_cache["prefix"] = pc

    if memory_kv is not None:
        # per-superlayer memory: slice inside the scan
        def body(h, xs):
            layer_params, layer_cache, mem_k, mem_v = xs
            h, nc = blocks.superlayer_decode(layer_params, cfg, h,
                                             layer_cache, pos,
                                             memory_kv=(mem_k, mem_v))
            return h, nc
        x, layers = jax.lax.scan(
            body, x, (params["layers"], cache["layers"],
                      memory_kv[0], memory_kv[1]))
    else:
        x, layers = _scan_decode(params, cfg, x, cache, pos)
    new_cache["layers"] = layers
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], x)
    return logits, new_cache
