"""Superlayer blocks: pre-norm residual blocks composed per the config's
layer pattern, with train/prefill/decode variants sharing parameters.

A *superlayer* is one period of the pattern (Jamba: 7 mamba + 1 attn with
MoE on every 2nd block; dense models: a single block). Parameter pytrees
for all superlayers are stacked on a leading axis and scanned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_lib
from . import ssm as ssm_lib
from .config import BlockSpec, ModelConfig
from .layers import init_mlp, init_rmsnorm, mlp, rmsnorm


def init_block(key, cfg: ModelConfig, spec: BlockSpec, cross: bool = False):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"norm1": init_rmsnorm(cfg.d_model)}
    if spec.kind == "attn":
        p["mixer"] = attn.init_attention(k1, cfg)
    else:
        p["mixer"] = ssm_lib.init_ssm(k1, cfg)
    if cross:
        p["norm_x"] = init_rmsnorm(cfg.d_model)
        p["cross"] = attn.init_attention(k2, cfg, cross=True)
    if spec.has_mlp:
        p["norm2"] = init_rmsnorm(cfg.d_model)
        if spec.moe:
            p["ffn"] = moe_lib.init_moe(k3, cfg)
        else:
            p["ffn"] = init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_act)
    return p


def _ffn_apply(p, cfg: ModelConfig, spec: BlockSpec, x):
    if not spec.has_mlp:
        return x, 0.0
    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if spec.moe:
        y, aux = moe_lib.moe_mlp(p["ffn"], cfg, h)
    else:
        y, aux = mlp(p["ffn"], h, cfg.mlp_act), 0.0
    return x + y, aux


def block_train(p, cfg: ModelConfig, spec: BlockSpec, x, positions,
                collect_cache: bool, memory_kv=None, causal: bool = True):
    """Returns (x, aux_loss, cache_entry_or_None)."""
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    cache = None
    if spec.kind == "attn":
        if causal:
            y, k, v = attn.attention_train(p["mixer"], cfg, h, positions)
            if collect_cache:
                cache = {"k": k, "v": v}
        else:
            y = attn.attention_encoder(p["mixer"], cfg, h, positions)
    else:
        if collect_cache:
            y, state = ssm_lib.ssm_train(p["mixer"], cfg, h, return_state=True)
            cache = state
        else:
            y = ssm_lib.ssm_train(p["mixer"], cfg, h)
    x = x + y
    if memory_kv is not None:
        hx = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        x = x + attn.attention_cross(p["cross"], cfg, hx, memory_kv, positions)
    x, aux = _ffn_apply(p, cfg, spec, x)
    return x, aux, cache


def block_decode(p, cfg: ModelConfig, spec: BlockSpec, x, cache, pos,
                 memory_kv=None):
    """Returns (x, new_cache_entry)."""
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.kind == "attn":
        y, k, v = attn.attention_decode(p["mixer"], cfg, h,
                                        cache["k"], cache["v"], pos)
        new_cache = {"k": k, "v": v}
    else:
        y, new_cache = ssm_lib.ssm_decode(p["mixer"], cfg, h, cache)
    x = x + y
    if memory_kv is not None:
        hx = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        x = x + attn.attention_cross_decode(p["cross"], cfg, hx, memory_kv, pos)
    x, _ = _ffn_apply(p, cfg, spec, x)
    return x, new_cache


# ---------------------------------------------------------------------------
# superlayers (one pattern period)
# ---------------------------------------------------------------------------

def init_superlayer(key, cfg: ModelConfig, cross: bool = False):
    pattern = cfg.layer_pattern()
    keys = jax.random.split(key, len(pattern))
    return {f"block{i}": init_block(keys[i], cfg, spec, cross=cross)
            for i, spec in enumerate(pattern)}


def superlayer_train(params, cfg: ModelConfig, x, positions,
                     collect_cache: bool = False, memory_kv=None,
                     causal: bool = True):
    pattern = cfg.layer_pattern()
    aux_total = 0.0
    caches = {}
    for i, spec in enumerate(pattern):
        x, aux, cache = block_train(
            params[f"block{i}"], cfg, spec, x, positions, collect_cache,
            memory_kv=memory_kv, causal=causal)
        aux_total = aux_total + aux
        if collect_cache and cache is not None:
            caches[f"block{i}"] = cache
    return x, aux_total, caches


def superlayer_decode(params, cfg: ModelConfig, x, cache, pos, memory_kv=None):
    pattern = cfg.layer_pattern()
    new_cache = {}
    for i, spec in enumerate(pattern):
        entry = cache.get(f"block{i}") if isinstance(cache, dict) else None
        x, ncache = block_decode(params[f"block{i}"], cfg, spec, x,
                                 entry, pos, memory_kv=memory_kv)
        new_cache[f"block{i}"] = ncache
    return x, new_cache


def init_superlayer_cache(cfg: ModelConfig, batch: int, cache_len: int,
                          dtype=jnp.bfloat16):
    """Abstract/zero cache for one superlayer."""
    pattern = cfg.layer_pattern()
    out = {}
    for i, spec in enumerate(pattern):
        if spec.kind == "attn":
            out[f"block{i}"] = {
                "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads,
                                cfg.head_dim), dtype),
            }
        else:
            out[f"block{i}"] = ssm_lib.init_ssm_cache(cfg, batch)
    return out
