"""GQA attention: training (chunked-causal), prefill, and decode paths.

Training/prefill use a blocked online-softmax ("flash") formulation as a
`lax.scan` over KV chunks so the full [S, S] score matrix is never
materialized (required for the 32k-prefill shapes); on TPU the inner
computation is the `repro.kernels.flash_attention` Pallas kernel — the
jnp scan here is also its reference oracle.

Decode attends one query position against a KV cache; for sliding-window
configs only the last `window` positions are attended (the sub-quadratic
path that makes mixtral's long_500k cell tractable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, dense, head_rmsnorm, init_dense, init_rmsnorm
from .sharding_hooks import constrain

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, h, hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, h * dh),
        "wk": init_dense(ks[1], d, hk * dh),
        "wv": init_dense(ks[2], d, hk * dh),
        "wo": init_dense(ks[3], h * dh, d, scale=(h * dh) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh)
        p["k_norm"] = init_rmsnorm(dh)
    return p


def _project_q(params, cfg: ModelConfig, x, positions, rope: bool = True):
    b, s, _ = x.shape
    q = dense(params["wq"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
    q = constrain(q, "attn_q")
    if cfg.qk_norm:
        q = head_rmsnorm(params["q_norm"]["scale"], q, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
    return q


def _project_kv(params, cfg: ModelConfig, x, positions, rope: bool = True):
    b, s, _ = x.shape
    k = dense(params["wk"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = dense(params["wv"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    k = constrain(k, "attn_kv")
    v = constrain(v, "attn_kv")
    if cfg.qk_norm:
        k = head_rmsnorm(params["k_norm"]["scale"], k, cfg.norm_eps)
    if rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def _expand_kv(x, groups: int):
    """[B,S,Hkv,Dh] -> [B,S,Hkv*groups,Dh] (GQA head replication)."""
    b, s, hk, dh = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, hk, groups, dh)
                            ).reshape(b, s, hk * groups, dh)


# ---------------------------------------------------------------------------
# blocked causal attention (training / prefill)
# ---------------------------------------------------------------------------

def blocked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk: int = 1024, q_offset: int = 0):
    """Online-softmax attention scanning KV chunks.

    q: [B,Sq,H,Dh], k/v: [B,Skv,H,Dh] (already GQA-expanded).
    window > 0 restricts attention to the trailing `window` positions
    (sliding-window); q_offset is the absolute position of q[0] relative
    to k[0] (for cached prefill continuation).
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    chunk = min(chunk, skv)
    assert skv % chunk == 0, (skv, chunk)
    scale = dh ** -0.5

    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # [B,H,Sq,Dh]
    kc = k.transpose(0, 2, 1, 3).reshape(b, h, skv // chunk, chunk, dh)
    vc = v.transpose(0, 2, 1, 3).reshape(b, h, skv // chunk, chunk, dh)

    q_pos = q_offset + jnp.arange(sq)

    def step(carry, xs):
        acc, m, l = carry
        kj, vj, j = xs
        k_pos = j * chunk + jnp.arange(chunk)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kj.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vj.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0),
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
         jnp.arange(skv // chunk)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B,Sq,H,Dh]


def attention_train(params, cfg: ModelConfig, x, positions, chunk: int = 1024):
    """Full causal self-attention for training/prefill. Returns (out, k, v)
    so callers can populate a KV cache (prefill)."""
    q = _project_q(params, cfg, x, positions)
    k, v = _project_kv(params, cfg, x, positions)
    groups = cfg.num_heads // cfg.num_kv_heads
    out = blocked_attention(
        q, _expand_kv(k, groups), _expand_kv(v, groups),
        causal=True, window=cfg.sliding_window,
        chunk=min(chunk, x.shape[1]))
    b, s, _, _ = out.shape
    out = dense(params["wo"], out.reshape(b, s, -1))
    return out, k, v


def attention_encoder(params, cfg: ModelConfig, x, positions):
    """Bidirectional (encoder) self-attention."""
    q = _project_q(params, cfg, x, positions)
    k, v = _project_kv(params, cfg, x, positions)
    groups = cfg.num_heads // cfg.num_kv_heads
    out = blocked_attention(q, _expand_kv(k, groups), _expand_kv(v, groups),
                            causal=False, chunk=min(1024, x.shape[1]))
    b, s, _, _ = out.shape
    return dense(params["wo"], out.reshape(b, s, -1))


def attention_cross(params, cfg: ModelConfig, x, memory_kv, positions):
    """Cross-attention against precomputed encoder memory (k, v)."""
    k, v = memory_kv
    q = _project_q(params, cfg, x, positions, rope=False)
    groups = cfg.num_heads // cfg.num_kv_heads
    out = blocked_attention(q, _expand_kv(k, groups), _expand_kv(v, groups),
                            causal=False, chunk=min(1024, k.shape[1]))
    b, s, _, _ = out.shape
    return dense(params["wo"], out.reshape(b, s, -1))


# ---------------------------------------------------------------------------
# decode (one token against a KV cache)
# ---------------------------------------------------------------------------

def attention_decode(params, cfg: ModelConfig, x, cache_k, cache_v, pos):
    """x: [B,1,D]; cache_k/v: [B,Skv,Hkv,Dh].

    Returns (out [B,1,D], new_k, new_v). The new token's K/V is written at
    ``pos % Skv`` — for full-context caches (Skv = seq_len) that is just
    ``pos``; for sliding-window archs the cache is allocated at window
    size and behaves as a ring buffer (K/V are stored post-RoPE with
    absolute positions, so ring order does not affect correctness). This
    is the sub-quadratic path that makes 500k-context decode tractable
    for SWA configs. On TPU the inner loop is the
    `repro.kernels.decode_attention` kernel.
    """
    b, _, _ = x.shape
    skv = cache_k.shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = _project_q(params, cfg, x, positions)              # [B,1,H,Dh]
    k_new, v_new = _project_kv(params, cfg, x, positions)  # [B,1,Hkv,Dh]
    write_idx = pos % skv
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), write_idx, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), write_idx, axis=1)

    groups = cfg.num_heads // cfg.num_kv_heads
    scale = cfg.head_dim ** -0.5
    qh = q[:, 0].reshape(b, cfg.num_kv_heads, groups, cfg.head_dim)
    # Work on the cache's native [B,Skv,Hkv,Dh] layout with bf16 MXU dots
    # (fp32 accumulation). Transposing or up-casting the cache would
    # materialize a full extra copy per layer per token — the dominant
    # byte term in the baseline decode profile (EXPERIMENTS.md §Perf).
    s = jnp.einsum("bhgd,bshd->bhgs", (qh * scale).astype(cache_k.dtype),
                   cache_k, preferred_element_type=jnp.float32)
    k_pos = jnp.arange(skv)
    # slots beyond the number of tokens written so far are invalid; a full
    # ring (pos + 1 >= skv) is entirely valid and entirely in-window.
    valid = k_pos[None, None, None, :] < jnp.minimum(pos + 1, skv)
    if cfg.sliding_window and skv > cfg.sliding_window:
        valid &= k_pos[None, None, None, :] > pos - cfg.sliding_window
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim).astype(x.dtype)
    return dense(params["wo"], out), cache_k, cache_v


def attention_cross_decode(params, cfg: ModelConfig, x, memory_kv, pos):
    """Decode-time cross attention (static encoder memory)."""
    k, v = memory_kv
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = _project_q(params, cfg, x, positions, rope=False)
    groups = cfg.num_heads // cfg.num_kv_heads
    scale = cfg.head_dim ** -0.5
    qh = q[:, 0].reshape(b, cfg.num_kv_heads, groups, cfg.head_dim)
    s = jnp.einsum("bhgd,bhkd->bhgk", qh.astype(jnp.float32) * scale,
                   k.transpose(0, 2, 1, 3).astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p,
                     v.transpose(0, 2, 1, 3).astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim).astype(x.dtype)
    return dense(params["wo"], out)
