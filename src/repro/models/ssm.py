"""Mamba2 (SSD — state-space duality) sequence mixer.

Chunked SSD forward for training/prefill (`lax.scan` over chunks carries
the inter-chunk SSM state, the intra-chunk part is a masked quadratic
form over a small chunk — MXU friendly), plus the O(1) recurrent decode
step. Single B/C group shared across heads.

State carried for serving: (conv_state [B, conv_dim, W-1],
ssd_state [B, H, P, N]). This per-session state is exactly the "cached
object" the ETICA two-tier controller manages for SSM architectures
(DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import init_dense, truncated_normal


def init_ssm(key, cfg: ModelConfig):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": init_dense(ks[0], d, 2 * di + 2 * n + h),
        "conv_w": truncated_normal(ks[1], (cfg.ssm_conv, conv_dim), 0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": init_dense(ks[2], di, d),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xs, B, C, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n],
                                axis=-1)
    return z, xs, B, C, dt


def _gated_norm(scale, y, z, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def _causal_conv(w, b, x):
    """Depthwise causal conv, x: [B, S, C], w: [W, C]."""
    width = w.shape[0]
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return jax.nn.silu(out + b).astype(x.dtype)


def _segsum(x):
    """segsum[..., i, j] = sum_{k in (j, i]} x[..., k] (lower-triangular)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssm_train(params, cfg: ModelConfig, x, return_state: bool = False):
    """Chunked SSD scan. x: [B, S, D] -> [B, S, D] (and the final state
    when ``return_state`` — used by prefill to seed decoding)."""
    b, s_real, _ = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s_real)
    # pad to a chunk multiple; padded positions get dt = 0, which makes
    # them exact no-ops on the SSM state (decay exp(0)=1, no input).
    s = ((s_real + q - 1) // q) * q
    if s != s_real:
        x = jnp.pad(x, ((0, 0), (0, s - s_real), (0, 0)))
    nc = s // q

    proj = jnp.einsum("bsd,de->bse", x.astype(jnp.bfloat16),
                      params["in_proj"]["w"].astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)
    z, xs, B, C, dt = _split_proj(cfg, proj)
    xBC_raw = jnp.concatenate([xs, B, C], axis=-1)
    xBC = _causal_conv(params["conv_w"], params["conv_b"], xBC_raw)
    xs, B, C = jnp.split(xBC, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    if s != s_real:
        valid = (jnp.arange(s) < s_real)[None, :, None]
        dt = jnp.where(valid, dt, 0.0)
    A = -jnp.exp(params["A_log"])                                     # [H]
    xh = xs.reshape(b, s, h, p).astype(jnp.float32)
    dA = dt * A                                                       # [B,S,H]

    # chunk
    xc = xh.reshape(b, nc, q, h, p)
    Bc = B.reshape(b, nc, q, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, q, n).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h)
    dAc = dA.reshape(b, nc, q, h)

    def chunk_step(state, inp):
        xck, Bk, Ck, dtk, dAk = inp            # [b,q,h,p],[b,q,n],...
        # intra-chunk (quadratic within chunk)
        L = jnp.exp(_segsum(dAk.transpose(0, 2, 1)))       # [b,h,q,q]
        scores = jnp.einsum("bqn,bkn->bqk", Ck, Bk)        # [b,q,q]
        M = scores[:, None] * L                            # [b,h,q,q]
        y_intra = jnp.einsum("bhqk,bkh,bkhp->bqhp", M, dtk, xck)
        # contribution of the carried state
        decay0 = jnp.exp(jnp.cumsum(dAk, axis=1))          # [b,q,h]
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", Ck, state, decay0)
        # chunk's new state
        decay_end = jnp.exp(jnp.sum(dAk, axis=1))          # [b,h]
        decay_to_end = jnp.exp(jnp.sum(dAk, axis=1)[:, None] -
                               jnp.cumsum(dAk, axis=1))    # [b,q,h]
        s_new = jnp.einsum("bqn,bqh,bqhp->bhpn", Bk, dtk * decay_to_end, xck)
        state = state * decay_end[..., None, None] + s_new
        return state, y_intra + y_inter

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    state_fin, yc = jax.lax.scan(
        chunk_step, state0,
        (xc.transpose(1, 0, 2, 3, 4), Bc.transpose(1, 0, 2, 3),
         Cc.transpose(1, 0, 2, 3), dtc.transpose(1, 0, 2, 3),
         dAc.transpose(1, 0, 2, 3)))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(b, s, di)
    y = _gated_norm(params["norm_scale"], y, z, cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y.astype(jnp.bfloat16),
                     params["out_proj"]["w"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out[:, :s_real]
    if not return_state:
        return out
    w = cfg.ssm_conv
    tail = xBC_raw[:, :s_real][:, -(w - 1):, :].astype(jnp.float32)
    if s_real < w - 1:
        tail = jnp.pad(tail, ((0, 0), (w - 1 - s_real, 0), (0, 0)))
    return out, {"conv": tail, "ssd": state_fin}


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), dtype),
    }


def ssm_decode(params, cfg: ModelConfig, x, cache):
    """One-token recurrent step. x: [B, 1, D]."""
    b = x.shape[0]
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x.astype(jnp.bfloat16),
                      params["in_proj"]["w"].astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)
    z, xs, B, C, dt = _split_proj(cfg, proj)
    xBC_new = jnp.concatenate([xs, B, C], axis=-1)          # [B,1,conv_dim]
    window = jnp.concatenate([cache["conv"], xBC_new.astype(cache["conv"].dtype)],
                             axis=1)                         # [B,W,conv_dim]
    conv_out = jnp.sum(window * params["conv_w"][None], axis=1) + params["conv_b"]
    xBC = jax.nn.silu(conv_out)[:, None, :]                 # [B,1,conv_dim]
    xs, B, C = jnp.split(xBC, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # [B,H]
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                    # [B,H]
    xh = xs[:, 0].reshape(b, h, p).astype(jnp.float32)
    Bv = B[:, 0].astype(jnp.float32)                        # [B,N]
    Cv = C[:, 0].astype(jnp.float32)
    ssd = cache["ssd"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bv)
    y = jnp.einsum("bhpn,bn->bhp", ssd, Cv) + params["D"][None, :, None] * xh
    y = y.reshape(b, 1, di)
    y = _gated_norm(params["norm_scale"], y, z, cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y.astype(jnp.bfloat16),
                     params["out_proj"]["w"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_cache = {"conv": window[:, 1:], "ssd": ssd.astype(cache["ssd"].dtype)}
    return out, new_cache
