"""Pluggable internal sharding constraints.

Model code calls ``constrain(x, "site-name")`` at collective-critical
activations (MoE dispatch, attention heads, logits). By default this is
the identity; the launch layer registers concrete ``PartitionSpec``s per
site when lowering under a mesh. This keeps model definitions
mesh-agnostic while giving the perf loop (EXPERIMENTS.md §Perf) a clean
lever to re-shard individual sites without touching model code.
"""
from __future__ import annotations

import contextlib
import threading

import jax

_local = threading.local()


def _registry() -> dict:
    if not hasattr(_local, "specs"):
        _local.specs = {}
    return _local.specs


@contextlib.contextmanager
def sharding_site_specs(specs: dict):
    """Register {site-name: PartitionSpec} for the enclosed trace."""
    old = dict(_registry())
    _registry().update(specs)
    try:
        yield
    finally:
        _local.specs = old


def constrain(x, site: str):
    spec = _registry().get(site)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
