"""Primitive layers (pure functions over param pytrees).

Parameters are nested dicts of jnp arrays. Every ``init_*`` is jittable
(usable under ``jax.eval_shape`` for the allocation-free dry-run) and
every ``apply`` is shape-polymorphic in batch/sequence.

Numerics policy: parameters live in fp32; matmuls run in the config
compute dtype (bf16 on TPU) with fp32 accumulation via
``preferred_element_type``; norms and logits stay fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# -- norms -------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


def head_rmsnorm(scale, x, eps: float = 1e-5):
    """qk-norm (per-head RMS norm over head_dim), qwen3-style."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# -- dense -------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": truncated_normal(key, (d_in, d_out), scale)}


def dense(params, x, compute_dtype=jnp.bfloat16):
    w = params["w"].astype(compute_dtype)
    return jax.lax.dot_general(
        x.astype(compute_dtype), w,
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(compute_dtype)


# -- embeddings --------------------------------------------------------------

def init_embedding(key, vocab: int, d: int):
    # d^-0.5 keeps unembed logits O(1) at init (CE starts near ln(vocab))
    return {"table": truncated_normal(key, (vocab, d), d ** -0.5)}


def embed(params, ids, compute_dtype=jnp.bfloat16):
    return jnp.take(params["table"], ids, axis=0).astype(compute_dtype)


def unembed(params, x):
    """Logits in fp32 (vocab typically sharded over the model axis)."""
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32),
        params["table"].astype(jnp.float32),
        preferred_element_type=jnp.float32)


# -- rotary position embeddings ---------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    freqs = rope_frequencies(x.shape[-1], theta)          # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    angles = angles[..., None, :]                         # [..., S, 1, Dh/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- activations --------------------------------------------------------------

def activation(name: str):
    if name == "swiglu":  # handled by the caller (gated)
        return jax.nn.silu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(name)


# -- MLPs ---------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, act: str):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": init_dense(k1, d, ff), "w_down": init_dense(k2, ff, d)}
    if act == "swiglu":
        p["w_gate"] = init_dense(k3, d, ff)
    return p


def mlp(params, x, act: str, compute_dtype=jnp.bfloat16):
    h = dense(params["w_up"], x, compute_dtype)
    if act == "swiglu":
        h = jax.nn.silu(dense(params["w_gate"], x, compute_dtype)) * h
    else:
        h = activation(act)(h)
    return dense(params["w_down"], h, compute_dtype)
