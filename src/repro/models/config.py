"""Model configuration schema for the assigned architecture pool.

One :class:`ModelConfig` describes any member of the zoo: dense GQA
transformers, MoE (incl. fine-grained + shared experts), pure SSM
(Mamba2/SSD), hybrid SSM+attention (Jamba), encoder-decoder (Seamless),
and VLM/audio backbones with stub modality frontends.

The layer stack is expressed as a repeating *superlayer pattern* so that
heterogeneous stacks (Jamba's 1:7 attn:mamba interleave with MoE every
2nd layer) still scan with `jax.lax.scan` over stacked parameters.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

BlockKind = Literal["attn", "ssm"]


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One block inside the superlayer pattern."""
    kind: BlockKind = "attn"          # sequence mixer
    moe: bool = False                 # MoE FFN instead of dense FFN
    has_mlp: bool = True              # SSM blocks carry no separate FFN


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention details ---
    qk_norm: bool = False
    sliding_window: int = 0           # 0 = full attention
    rope_theta: float = 10_000.0
    mlp_act: str = "swiglu"           # swiglu | relu2 | gelu

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0                 # per-expert ffn width
    moe_layer_period: int = 1         # every k-th block uses MoE
    first_dense_ff: int = 0           # deepseek: layer 0 dense FFN width
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    attn_period: int = 0              # hybrid: one attn block per `period`

    # --- encoder-decoder ---
    encoder_layers: int = 0

    # --- modality frontend stub ---
    frontend: str = "none"            # none | audio | vision
    frontend_tokens: int = 256        # vision: image tokens prepended

    # --- numerics ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    # ------------------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid / SWA)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    # --- superlayer pattern -------------------------------------------
    def layer_pattern(self) -> tuple[BlockSpec, ...]:
        """The repeating block pattern (one *superlayer*)."""
        if self.family == "hybrid":
            period = self.attn_period or 8
            blocks = []
            for i in range(period):
                kind = "attn" if i == period - 1 else "ssm"
                moe = (self.moe_num_experts > 0
                       and (i % self.moe_layer_period) == self.moe_layer_period - 1)
                blocks.append(BlockSpec(kind=kind, moe=moe, has_mlp=True))
            return tuple(blocks)
        if self.family == "ssm":
            return (BlockSpec(kind="ssm", has_mlp=False),)
        if self.moe_num_experts > 0:
            return (BlockSpec(kind="attn", moe=True),)
        return (BlockSpec(kind="attn"),)

    @property
    def pattern_len(self) -> int:
        return len(self.layer_pattern())

    @property
    def num_superlayers(self) -> int:
        n = self.num_layers - (1 if self.first_dense_ff else 0)
        assert n % self.pattern_len == 0, (
            f"{self.name}: {n} layers not divisible by pattern "
            f"{self.pattern_len}")
        return n // self.pattern_len

    # --- parameter counts (for roofline MODEL_FLOPS) --------------------
    def _attn_params(self) -> int:
        d, h, hk, dh = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        return d * h * dh + 2 * d * hk * dh + h * dh * d

    def _mlp_params(self, ff: int) -> int:
        mult = 3 if self.mlp_act == "swiglu" else 2
        return mult * self.d_model * ff

    def _moe_params(self) -> tuple[int, int]:
        """(total, active) params of one MoE FFN."""
        e, k, sh = self.moe_num_experts, self.moe_top_k, self.moe_num_shared
        per = self._mlp_params(self.moe_d_ff or self.d_ff)
        router = self.d_model * e
        total = e * per + sh * per + router
        active = k * per + sh * per + router
        return total, active

    def _ssm_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * n + h)   # z, x, B, C, dt
        conv = (di + 2 * n) * self.ssm_conv
        out_proj = di * d
        return in_proj + conv + out_proj + 3 * h  # + A, D, dt_bias

    def param_counts(self) -> tuple[int, int]:
        """(total, active) parameter counts, embeddings included."""
        total = active = self.vocab_size * self.d_model * 2  # in + out embed
        def add(n_total, n_active=None):
            nonlocal total, active
            total += n_total
            active += n_active if n_active is not None else n_total

        stacks = [self.num_layers]
        if self.is_encdec:
            stacks = [self.encoder_layers, self.num_layers]
        # decoder/self stack
        pattern = self.layer_pattern()
        reps = self.num_superlayers
        for spec in pattern:
            if spec.kind == "attn":
                add(reps * self._attn_params())
            else:
                add(reps * self._ssm_params())
            if spec.has_mlp:
                if spec.moe:
                    t, a = self._moe_params()
                    add(reps * t, reps * a)
                else:
                    add(reps * self._mlp_params(self.d_ff))
        if self.first_dense_ff:
            add(self._attn_params() + self._mlp_params(self.first_dense_ff))
        if self.is_encdec:
            # encoder: attn + mlp; decoder adds cross-attention
            add(self.encoder_layers * (self._attn_params()
                                       + self._mlp_params(self.d_ff)))
            add(self.num_layers * self._attn_params())  # cross-attn
        return total, active

    def model_flops(self, tokens: int, decode: bool = False) -> float:
        """6·N·D for training, 2·N_active·D for inference forward."""
        total, active = self.param_counts()
        return (2.0 if decode else 6.0) * active * tokens


# ---------------------------------------------------------------------------
# input shapes assigned to the LM pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs; else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k-context decode requires "
                       "sub-quadratic attention (DESIGN.md §6)")
    return True, ""
