"""Mixture-of-Experts FFN with gather-based capacity dispatch.

Design for TPU/GSPMD (DESIGN.md §5): no [T, E, C] one-hot dispatch tensor
is ever built. Tokens' (token, choice) pairs are sorted by expert id;
slot positions come from a per-expert running count; dispatch is a gather
``x[dispatch_idx]`` into an [E, C, D] buffer sharded over the model axis
(expert parallelism), and the combine is a scatter-add back. Capacity is
``ceil(T·k/E · capacity_factor)``; overflow tokens are dropped from the
expert (their gate mass falls to the shared experts / residual), matching
GShard-style capacity semantics.

Supports DeepSeekMoE fine-grained experts + shared experts, and Mixtral
top-2. When the expert count does not divide the model axis (mixtral: 8
experts on a 16-way axis), expert weights shard over their ffn dim
instead (tensor-parallel experts) — selected by the launch layer via
sharding rules, not here.

Returns an auxiliary load-balancing loss (Switch-style) for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import init_dense, init_mlp, mlp, truncated_normal
from .sharding_hooks import constrain


def init_moe(key, cfg: ModelConfig):
    e = cfg.moe_num_experts
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    k_router, k_up, k_gate, k_down, k_shared = jax.random.split(key, 5)
    p = {
        "router": {"w": truncated_normal(k_router, (d, e), d ** -0.5)},
        "w_up": truncated_normal(k_up, (e, d, ff), d ** -0.5),
        "w_down": truncated_normal(k_down, (e, ff, d), ff ** -0.5),
    }
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = truncated_normal(k_gate, (e, d, ff), d ** -0.5)
    if cfg.moe_num_shared:
        p["shared"] = init_mlp(k_shared, d, ff * cfg.moe_num_shared,
                               cfg.mlp_act)
    return p


def _expert_ffn(p, xe, act: str, compute_dtype=jnp.bfloat16):
    """xe: [E, C, D] -> [E, C, D] (per-expert MLP via batched einsum)."""
    up = jnp.einsum("ecd,edf->ecf", xe.astype(compute_dtype),
                    p["w_up"].astype(compute_dtype),
                    preferred_element_type=jnp.float32).astype(compute_dtype)
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xe.astype(compute_dtype),
                       p["w_gate"].astype(compute_dtype),
                       preferred_element_type=jnp.float32).astype(compute_dtype)
        up = jax.nn.silu(g) * up
    elif act == "relu2":
        up = jnp.square(jax.nn.relu(up))
    else:
        up = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", up, p["w_down"].astype(compute_dtype),
                      preferred_element_type=jnp.float32).astype(compute_dtype)


def moe_mlp(params, cfg: ModelConfig, x):
    """x: [B, S, D] -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    t = b * s
    # capacity: GShard-style for large T; for small T (decode) admit the
    # worst case (all tokens to one expert) so decoding is drop-free.
    cap = int(max((t * k * cfg.moe_capacity_factor) // e, min(t, 256), 1))
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # [T, E]
    gate_vals, expert_idx = jax.lax.top_k(probs, k)         # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss
    density = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux_loss = jnp.sum(density * density_proxy) * e

    # ---- dispatch: sort (token, choice) pairs by expert --------------
    e_flat = expert_idx.reshape(-1)                         # [T*k]
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    counts = jnp.bincount(e_flat, length=e)                 # [E]
    starts = jnp.cumsum(counts) - counts
    slot = jnp.arange(t * k, dtype=jnp.int32) - starts[e_sorted].astype(jnp.int32)
    keep = slot < cap

    # dispatch indices [E, C]; sentinel t = zero row
    disp = jnp.full((e, cap), t, jnp.int32)
    disp = disp.at[e_sorted, slot].set(tok_sorted, mode="drop")
    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = xpad[disp]                                         # [E, C, D]
    xe = constrain(xe, "moe_dispatch")

    ye = _expert_ffn(params, xe, cfg.mlp_act)               # [E, C, D]
    ye = constrain(ye, "moe_expert_out")

    # ---- combine: gather back per pair, weight, scatter-add ----------
    val = ye[e_sorted, jnp.minimum(slot, cap - 1)]          # [T*k, D]
    val = jnp.where(keep[:, None], val, 0)
    gate_sorted = gate_vals.reshape(-1)[order].astype(val.dtype)
    out = jnp.zeros((t, d), val.dtype).at[tok_sorted].add(val * gate_sorted[:, None])

    if cfg.moe_num_shared:
        out = out + mlp(params["shared"], xf, cfg.mlp_act)
    return out.reshape(b, s, d).astype(x.dtype), aux_loss
