from .generators import SPECS, WorkloadSpec, generate, make, names

__all__ = ["SPECS", "WorkloadSpec", "generate", "make", "names"]
