from .generators import (SCAN_HEAVY_MIX, SPECS, WorkloadSpec, generate,
                         generate_to_store, make, make_store, names)
from .store import TraceStore, parse_blktrace, parse_msr_csv
from .stream import StreamingTraceSource, StreamWindow, window_source

__all__ = [
    "SCAN_HEAVY_MIX", "SPECS", "WorkloadSpec", "generate",
    "generate_to_store", "make", "make_store", "names",
    "TraceStore", "parse_blktrace", "parse_msr_csv",
    "StreamingTraceSource", "StreamWindow", "window_source",
]
