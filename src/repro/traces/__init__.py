from .generators import (SCAN_HEAVY_MIX, SESSION_ACTIVATE, SESSION_APPEND,
                         SESSION_END, SESSION_NEW, SPECS, SessionSpec,
                         SessionTrace, WorkloadSpec, generate,
                         generate_sessions, generate_to_store, make,
                         make_store, names)
from .store import TraceStore, parse_blktrace, parse_msr_csv
from .stream import StreamingTraceSource, StreamWindow, window_source

__all__ = [
    "SCAN_HEAVY_MIX", "SPECS", "WorkloadSpec", "generate",
    "generate_to_store", "make", "make_store", "names",
    "SESSION_NEW", "SESSION_ACTIVATE", "SESSION_APPEND", "SESSION_END",
    "SessionSpec", "SessionTrace", "generate_sessions",
    "TraceStore", "parse_blktrace", "parse_msr_csv",
    "StreamingTraceSource", "StreamWindow", "window_source",
]
