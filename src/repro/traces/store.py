"""Chunked, columnar, on-disk trace store.

ETICA is evaluated on multi-million-request MSR Cambridge and
FIO/Filebench traces (§5.1); holding such traces as one in-memory
:class:`~repro.core.trace.Trace` is the scalability wall this module
removes. A :class:`TraceStore` is a directory of fixed-size **shards**,
one column file per channel:

    store/
      meta.json                  # version, shard_size, per-shard lengths,
                                 # total length, num_vms
      shard_00000.addr.npy       # int32  [n]  block addresses
      shard_00000.w.npy          # bool   [n]  write flags
      shard_00000.vm.npy         # int32  [n]  vm ids (multi-VM stores only)
      shard_00000.sz.npy         # int32  [n]  request sizes in blocks
                                 #             (sized stores only)
      shard_00001.addr.npy
      ...

Shards are plain ``.npy`` files opened with ``np.load(mmap_mode="r")``,
so iterating a store touches one shard of host memory at a time no
matter how long the trace is. Appends are buffered and flushed one full
shard at a time; ``flush()``/``close()`` persist a partial tail shard
and the metadata, and re-opening with ``mode="a"`` re-absorbs that tail
so appends can resume.

Ingestion paths:

  * :meth:`TraceStore.from_trace` — deterministic conversion of any
    in-memory :class:`Trace` (exact round-trip, asserted in tests);
  * :func:`parse_msr_csv` / :meth:`TraceStore.from_msr_csv` —
    MSR-Cambridge-style CSV
    (``Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime``);
  * :func:`parse_blktrace` / :meth:`TraceStore.from_blktrace` —
    blktrace/blkparse text logs (the format FIO's ``blktrace`` backend
    and ``blkparse`` emit).

Both parsers stream their input line-by-line and yield bounded
chunk-:class:`Trace`\\ s, so importing a 100M-request trace never holds
more than one chunk in memory. A small CLI covers the common ops::

    PYTHONPATH=src python -m repro.traces.store import --format msr \\
        trace.csv store_dir
    PYTHONPATH=src python -m repro.traces.store info store_dir

Consumption at bounded memory is the job of
:class:`repro.traces.stream.StreamingTraceSource`, which both
controllers accept directly (``cache.run(store)``).
"""
from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import Iterable, Iterator

import numpy as np

from repro.core.trace import Trace

META_NAME = "meta.json"
VERSION = 1
DEFAULT_SHARD_SIZE = 1 << 18        # 256k requests (= 3 MiB of columns)
SECTOR = 512                        # blktrace sector size (bytes)
DEFAULT_BLOCK = 4096                # cache block size (bytes), paper §5.1

_COLS = (("addr", np.int32), ("w", np.bool_), ("vm", np.int32))


def _shard_file(path: Path, i: int, col: str) -> Path:
    return path / f"shard_{i:05d}.{col}.npy"


@dataclasses.dataclass
class _Meta:
    shard_size: int
    shards: list[int]               # per-shard lengths
    has_vm: bool
    num_vms: int | None             # max vm id + 1 (None for vm-less stores)
    has_size: bool = False          # optional request-size column (blocks)

    @property
    def total(self) -> int:
        return int(sum(self.shards))


class TraceStore:
    """A chunked on-disk multi-VM block-I/O trace (see module docstring).

    Use :meth:`create` / :meth:`open` rather than the constructor.
    Stores are context managers; writers must :meth:`close` (or exit the
    ``with`` block) to persist the tail shard and metadata.
    """

    def __init__(self, path: Path, meta: _Meta, writable: bool):
        self.path = Path(path)
        self._meta = meta
        self._writable = writable
        self._buf_addr: list[np.ndarray] = []
        self._buf_w: list[np.ndarray] = []
        self._buf_vm: list[np.ndarray] = []
        self._buf_sz: list[np.ndarray] = []
        self._buffered = 0

    # -- lifecycle ---------------------------------------------------------
    @classmethod
    def create(cls, path, shard_size: int = DEFAULT_SHARD_SIZE) -> "TraceStore":
        """Create an empty writable store at ``path`` (dir must not hold a
        store already). Whether the store carries a ``vm`` channel is
        fixed by the first :meth:`append`."""
        path = Path(path)
        path.mkdir(parents=True, exist_ok=True)
        if (path / META_NAME).exists():
            raise FileExistsError(f"{path} already contains a trace store")
        if shard_size <= 0:
            raise ValueError("shard_size must be positive")
        return cls(path, _Meta(int(shard_size), [], False, None),
                   writable=True)

    @classmethod
    def open(cls, path, mode: str = "r") -> "TraceStore":
        """Open an existing store: ``"r"`` read-only, ``"a"`` append (a
        partial tail shard is re-absorbed on the first append)."""
        path = Path(path)
        with (path / META_NAME).open() as f:
            raw = json.load(f)
        if raw.get("version") != VERSION:
            raise ValueError(f"unsupported store version {raw.get('version')}")
        meta = _Meta(int(raw["shard_size"]), [int(n) for n in raw["shards"]],
                     bool(raw["has_vm"]),
                     None if raw["num_vms"] is None else int(raw["num_vms"]),
                     bool(raw.get("has_size", False)))
        return cls(path, meta, writable=(mode == "a"))

    def __enter__(self) -> "TraceStore":
        return self

    def __exit__(self, *exc) -> None:
        if self._writable:
            self.close()

    # -- write path --------------------------------------------------------
    def append(self, trace: Trace) -> None:
        """Append a chunk of requests; full shards are flushed to disk
        immediately, so peak memory is O(shard_size) regardless of how
        much is appended."""
        if not self._writable:
            raise PermissionError("store opened read-only")
        n = len(trace)
        if n == 0:
            return
        self._absorb_tail()
        has_vm = trace.vm is not None
        has_size = trace.size is not None
        if self._meta.total == 0 and self._buffered == 0:
            self._meta.has_vm = has_vm
            self._meta.has_size = has_size
        elif has_vm != self._meta.has_vm:
            raise ValueError("cannot mix vm-tagged and vm-less appends")
        elif has_size != self._meta.has_size:
            raise ValueError("cannot mix sized and size-less appends")
        self._buf_addr.append(np.asarray(trace.addr, np.int32))
        self._buf_w.append(np.asarray(trace.is_write, bool))
        if has_size:
            self._buf_sz.append(np.asarray(trace.size, np.int32))
        if has_vm:
            vm = np.asarray(trace.vm, np.int32)
            if vm.size and vm.min() < 0:
                raise ValueError("vm ids must be non-negative")
            self._buf_vm.append(vm)
            hi = int(vm.max()) + 1 if vm.size else 0
            self._meta.num_vms = max(self._meta.num_vms or 0, hi)
        self._buffered += n
        while self._buffered >= self._meta.shard_size:
            self._flush_shard(self._meta.shard_size)

    def _take(self, bufs: list[np.ndarray], k: int) -> np.ndarray:
        out, got = [], 0
        while got < k:
            b = bufs[0]
            take = min(k - got, b.shape[0])
            out.append(b[:take])
            if take == b.shape[0]:
                bufs.pop(0)
            else:
                bufs[0] = b[take:]
            got += take
        return np.concatenate(out) if len(out) != 1 else np.array(out[0])

    def _flush_shard(self, k: int) -> None:
        i = len(self._meta.shards)
        np.save(_shard_file(self.path, i, "addr"),
                self._take(self._buf_addr, k))
        np.save(_shard_file(self.path, i, "w"), self._take(self._buf_w, k))
        if self._meta.has_vm:
            np.save(_shard_file(self.path, i, "vm"),
                    self._take(self._buf_vm, k))
        if self._meta.has_size:
            np.save(_shard_file(self.path, i, "sz"),
                    self._take(self._buf_sz, k))
        self._meta.shards.append(k)
        self._buffered -= k

    def _absorb_tail(self) -> None:
        """Pull a previously flushed partial tail shard back into the
        append buffer so the shard sequence stays [full..., tail]."""
        if (self._buffered == 0 and self._meta.shards
                and self._meta.shards[-1] < self._meta.shard_size):
            tail = self.shard(len(self._meta.shards) - 1)
            self._buf_addr = [np.array(tail.addr, np.int32)]
            self._buf_w = [np.array(tail.is_write, bool)]
            if self._meta.has_vm:
                self._buf_vm = [np.array(tail.vm, np.int32)]
            if self._meta.has_size:
                self._buf_sz = [np.array(tail.size, np.int32)]
            self._buffered = len(tail)
            self._meta.shards.pop()

    def flush(self) -> None:
        """Persist any buffered tail as a (short) final shard + metadata.
        The store remains usable; a later append re-absorbs the tail."""
        if self._buffered:
            self._flush_shard(self._buffered)
        with (self.path / META_NAME).open("w") as f:
            json.dump({"version": VERSION,
                       "shard_size": self._meta.shard_size,
                       "shards": self._meta.shards,
                       "has_vm": self._meta.has_vm,
                       "num_vms": self._meta.num_vms,
                       "has_size": self._meta.has_size,
                       "total": self._meta.total}, f, indent=1)

    def close(self) -> None:
        if self._writable:
            self.flush()
            self._writable = False

    # -- read path ---------------------------------------------------------
    def _check_readable(self) -> None:
        if self._writable and self._buffered:
            raise RuntimeError(
                "store has unflushed appends; call flush() or close() "
                "before reading")

    def __len__(self) -> int:
        return self._meta.total + (self._buffered if self._writable else 0)

    @property
    def num_shards(self) -> int:
        return len(self._meta.shards)

    @property
    def shard_size(self) -> int:
        return self._meta.shard_size

    @property
    def has_vm(self) -> bool:
        return self._meta.has_vm

    @property
    def num_vms(self) -> int | None:
        return self._meta.num_vms

    @property
    def has_size(self) -> bool:
        return self._meta.has_size

    def shard(self, i: int) -> Trace:
        """Shard ``i`` as a Trace of memory-mapped (read-only) arrays."""
        self._check_readable()
        addr = np.load(_shard_file(self.path, i, "addr"), mmap_mode="r")
        w = np.load(_shard_file(self.path, i, "w"), mmap_mode="r")
        vm = (np.load(_shard_file(self.path, i, "vm"), mmap_mode="r")
              if self._meta.has_vm else None)
        sz = (np.load(_shard_file(self.path, i, "sz"), mmap_mode="r")
              if self._meta.has_size else None)
        return Trace(addr=addr, is_write=w, vm=vm, size=sz)

    def iter_shards(self) -> Iterator[Trace]:
        for i in range(self.num_shards):
            yield self.shard(i)

    def read(self, start: int, stop: int) -> Trace:
        """Materialize requests ``[start, stop)`` (crossing shard
        boundaries; out-of-range tails are clipped)."""
        self._check_readable()
        stop = min(stop, self._meta.total)
        parts, base = [], 0
        for i, n in enumerate(self._meta.shards):
            if base + n > start and base < stop:
                sh = self.shard(i)
                parts.append(sh[max(start - base, 0): stop - base])
            base += n
            if base >= stop:
                break
        if not parts:
            return Trace(np.empty(0, np.int32), np.empty(0, bool),
                         np.empty(0, np.int32) if self._meta.has_vm else None,
                         np.empty(0, np.int32) if self._meta.has_size
                         else None)
        return Trace.concat(parts) if len(parts) > 1 else parts[0]

    def iter_windows(self, window: int) -> Iterator[Trace]:
        """Yield consecutive fixed-size request windows (the on-disk
        analogue of :meth:`Trace.intervals`) at O(window) memory."""
        for start in range(0, self._meta.total, window):
            yield self.read(start, start + window)

    def to_trace(self) -> Trace:
        """Materialize the whole store (tests / small stores only)."""
        return self.read(0, self._meta.total)

    # -- conversions -------------------------------------------------------
    @classmethod
    def from_trace(cls, path, trace: Trace,
                   shard_size: int = DEFAULT_SHARD_SIZE) -> "TraceStore":
        """Deterministically convert an in-memory Trace (exact round-trip:
        ``TraceStore.from_trace(p, t).to_trace() == t``)."""
        with cls.create(path, shard_size=shard_size) as store:
            store.append(trace)
        return store

    @classmethod
    def from_chunks(cls, path, chunks: Iterable[Trace],
                    shard_size: int = DEFAULT_SHARD_SIZE) -> "TraceStore":
        with cls.create(path, shard_size=shard_size) as store:
            for chunk in chunks:
                store.append(chunk)
        return store

    @classmethod
    def from_msr_csv(cls, path, csv_path, *, block_size: int = DEFAULT_BLOCK,
                     shard_size: int = DEFAULT_SHARD_SIZE) -> "TraceStore":
        with Path(csv_path).open() as f:
            return cls.from_chunks(path, parse_msr_csv(f, block_size=block_size),
                                   shard_size=shard_size)

    @classmethod
    def from_blktrace(cls, path, log_path, *,
                      block_size: int = DEFAULT_BLOCK,
                      shard_size: int = DEFAULT_SHARD_SIZE) -> "TraceStore":
        with Path(log_path).open() as f:
            return cls.from_chunks(path, parse_blktrace(f, block_size=block_size),
                                   shard_size=shard_size)


# ---------------------------------------------------------------------------
# external-format parsers (streaming, bounded memory)
# ---------------------------------------------------------------------------

class _ChunkBuilder:
    """Accumulates block spans, expanding to per-block requests lazily.

    One Python-level append per *record*; the per-block expansion (a
    64 KiB request touches 16 x 4 KiB blocks) happens vectorized at
    :meth:`pop` time via ``np.repeat`` — the importer stays O(records)
    in interpreter work even for large-request traces."""

    def __init__(self, chunk: int):
        self.chunk = chunk
        self.first: list[int] = []
        self.last: list[int] = []
        self.w: list[bool] = []
        self.vm: list[int] = []
        self._blocks = 0

    def add_span(self, first: int, last: int, is_write: bool, vm: int) -> None:
        self.first.append(first)
        self.last.append(last)
        self.w.append(is_write)
        self.vm.append(vm)
        self._blocks += last - first + 1

    def ready(self) -> bool:
        return self._blocks >= self.chunk

    @property
    def pending(self) -> bool:
        return bool(self.first)

    def pop(self) -> Trace:
        first = np.asarray(self.first, np.int64)
        last = np.asarray(self.last, np.int64)
        hi, lo = int(last.max()), int(first.min())
        if hi >= 2**31 or lo < 0:
            # out-of-range block ids would wrap/land on negative int32
            # addresses — the datapath's pad/no-op convention — silently
            # dropping requests from the simulation
            raise ValueError(
                f"block address {lo if lo < 0 else hi} outside int32 range "
                f"[0, 2^31) — corrupt offset or device region too large; "
                f"check the input or re-import with a larger block size")
        counts = last - first + 1
        # addr = each span's first block + its within-span offset 0..len-1
        offset = np.arange(self._blocks, dtype=np.int64) - np.repeat(
            np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
        t = Trace((np.repeat(first, counts) + offset).astype(np.int32),
                  np.repeat(np.asarray(self.w, bool), counts),
                  np.repeat(np.asarray(self.vm, np.int32), counts))
        self.first, self.last, self.w, self.vm = [], [], [], []
        self._blocks = 0
        return t


def parse_msr_csv(lines: Iterable[str], *, block_size: int = DEFAULT_BLOCK,
                  chunk: int = 1 << 16) -> Iterator[Trace]:
    """Parse MSR-Cambridge-style CSV into bounded Trace chunks.

    Line format (SNIA IOTTA block-I/O release)::

        Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime

    ``Offset``/``Size`` are bytes; each record expands to every
    ``block_size`` block it spans. VM ids are assigned to
    ``(Hostname, DiskNumber)`` pairs in order of first appearance — the
    paper's "one MSR volume = one VM" convention. A header line and
    blank/malformed lines are skipped.
    """
    vm_ids: dict[tuple[str, str], int] = {}
    out = _ChunkBuilder(chunk)
    for line in lines:
        parts = line.strip().split(",")
        if len(parts) < 6:
            continue
        ts, host, disk, typ, off, size = parts[:6]
        typ = typ.strip().lower()
        if typ not in ("read", "write", "r", "w"):
            continue  # header or foreign row
        try:
            off_b, size_b = int(off), int(size)
        except ValueError:
            continue
        key = (host.strip(), disk.strip())
        vm = vm_ids.setdefault(key, len(vm_ids))
        first = off_b // block_size
        last = (off_b + max(size_b - 1, 0)) // block_size
        out.add_span(first, last, typ.startswith("w"), vm)
        if out.ready():
            yield out.pop()
    if out.pending:
        yield out.pop()


# blkparse default line, e.g.:
#   8,16   1   42   0.000104 1234  Q   R 223490 + 8 [fio]
_BLK_RE = re.compile(
    r"^\s*(?P<dev>\d+,\d+)\s+\d+\s+\d+\s+[\d.]+\s+\d+\s+"
    r"(?P<action>[A-Z])\s+(?P<rwbs>[A-Z]+)\s+(?P<sector>\d+)\s*\+\s*"
    r"(?P<count>\d+)")


def parse_blktrace(lines: Iterable[str], *, block_size: int = DEFAULT_BLOCK,
                   actions: str = "Q", chunk: int = 1 << 16) -> Iterator[Trace]:
    """Parse blktrace/blkparse text logs (FIO's blktrace output) into
    bounded Trace chunks.

    Keeps lines whose action is in ``actions`` (default ``Q`` = queued,
    one event per submitted I/O) and whose RWBS field carries ``R`` or
    ``W``. Sectors are 512-byte units; each request expands to every
    ``block_size`` block it spans. VM ids are assigned per device
    (``maj,min``) in order of first appearance. Unparsable lines are
    skipped.
    """
    vm_ids: dict[str, int] = {}
    out = _ChunkBuilder(chunk)
    for line in lines:
        m = _BLK_RE.match(line)
        if m is None or m.group("action") not in actions:
            continue
        rwbs = m.group("rwbs")
        if "R" in rwbs:
            is_write = False
        elif "W" in rwbs:
            is_write = True
        else:
            continue  # barriers / discards
        vm = vm_ids.setdefault(m.group("dev"), len(vm_ids))
        off_b = int(m.group("sector")) * SECTOR
        size_b = int(m.group("count")) * SECTOR
        first = off_b // block_size
        last = (off_b + max(size_b - 1, 0)) // block_size
        out.add_span(first, last, is_write, vm)
        if out.ready():
            yield out.pop()
    if out.pending:
        yield out.pop()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: list[str] | None = None) -> int:
    """``python -m repro.traces.store {import,info} ...``"""
    import argparse

    ap = argparse.ArgumentParser(prog="repro.traces.store",
                                 description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    imp = sub.add_parser("import", help="import an external trace file")
    imp.add_argument("src", help="trace file (CSV or blktrace text)")
    imp.add_argument("dest", help="store directory to create")
    imp.add_argument("--format", choices=("msr", "blktrace"), default="msr")
    imp.add_argument("--block-size", type=int, default=DEFAULT_BLOCK)
    imp.add_argument("--shard-size", type=int, default=DEFAULT_SHARD_SIZE)
    info = sub.add_parser("info", help="describe an existing store")
    info.add_argument("store", help="store directory")
    args = ap.parse_args(argv)

    if args.cmd == "import":
        conv = (TraceStore.from_msr_csv if args.format == "msr"
                else TraceStore.from_blktrace)
        store = conv(args.dest, args.src, block_size=args.block_size,
                     shard_size=args.shard_size)
        print(f"imported {len(store)} requests from {args.src} -> "
              f"{args.dest} ({store.num_shards} shards, "
              f"num_vms={store.num_vms})")
    else:
        store = TraceStore.open(args.store)
        reads = sum(int(np.sum(~np.asarray(s.is_write)))
                    for s in store.iter_shards())
        print(f"{args.store}: {len(store)} requests in {store.num_shards} "
              f"shards of {store.shard_size} "
              f"(num_vms={store.num_vms}, reads={reads}, "
              f"writes={len(store) - reads})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
