"""Streaming ``[V, chunk]`` request-block ingestion for the controllers.

The batched controllers consume multi-VM traces as a sequence of resize
windows, each demuxed per VM and simulated in rectangular ``[V, chunk]``
blocks (``addr = -1`` padded). With an in-memory
:class:`~repro.core.trace.Trace` that demux used to cost V boolean-mask
scans per window; with the million-request traces the paper evaluates on
(§5.1) the trace would not even fit in host memory. This module supplies
both halves of the fix:

* :class:`StreamingTraceSource` — iterates resize windows from an
  on-disk :class:`~repro.traces.store.TraceStore` (or an in-memory
  ``Trace``) and performs the per-VM demux with **one stable sort per
  shard** (``np.argsort(vm, kind="stable")`` groups requests by VM while
  preserving per-VM arrival order), serving each window's per-VM
  sub-traces by binary-searching the sorted global-index segments. Only
  the shards overlapping the current window are resident, so peak host
  memory is O(shard + window + V·chunk) — independent of trace length.

* **Depth-``d`` host→device prefetch** — :meth:`StreamWindow.blocks`
  keeps ``prefetch_depth`` ``[V, chunk]`` blocks in flight beyond the one
  being consumed: while the simulator consumes block *k*, blocks
  *k+1 … k+d* are already being ``jax.device_put`` — the generalized
  pipeline (``d = 1`` is the classic double buffer)::

      host   : | build k | build k+1 | build k+2 |
      xfer   :      | put k | put k+1  | put k+2 |
      device :          | sim k  | sim k+1 | sim k+2 |

  JAX transfers and dispatches are asynchronous, so the copies overlap
  the simulation instead of serializing after it. ``prefetch_depth = 0``
  (or ``prefetch=False`` on the source) disables the pipeline and yields
  host arrays; results are bit-identical at every depth (asserted in
  ``tests/test_trace_store.py``).

* **Sharded feeding** — with ``sharding`` (a ``NamedSharding`` over a VM
  mesh) each prefetched block is placed directly into its per-device
  ``[V/d, chunk]`` layout, and ``pad_vms`` appends that many dead VM rows
  (all ``addr = -1``, the exact-no-op padding contract) so the padded VM
  count divides the mesh size. The demux itself is unchanged — the pad
  rows never exist on the host side beyond the block builder.

Both controllers accept a ``Trace``, a ``TraceStore``, or a pre-built
``StreamingTraceSource`` in :meth:`run` and produce **bit-identical**
results for all three (asserted in ``tests/test_trace_store.py``): the
demux equals the mask-based reference and padding/chunking are shared
with the in-memory path (:func:`repro.core.trace.pad_batch`).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterator

import jax
import numpy as np

from repro.core.trace import Trace, pad_batch, split_by_vm

from .store import TraceStore


@dataclasses.dataclass
class StreamWindow:
    """One resize window: per-VM sub-traces + padded datapath blocks."""

    index: int                  # window ordinal
    subs: list[Trace]           # per-VM demux (sizing / maintenance / oracle)
    chunk: int                  # datapath block width (promo/sim chunk)
    prefetch_depth: int = 2     # blocks in flight beyond the consumed one
    pad_vms: int = 0            # dead VM rows appended to each block
    sharding: object = None     # NamedSharding placing [V, chunk] per shard

    def chunk_lists(self) -> list[list[Trace]]:
        return [list(sub.intervals(self.chunk)) for sub in self.subs]

    def blocks(self) -> Iterator[tuple]:
        """Yield ``(addr [V, chunk], is_write [V, chunk], kth)`` per
        datapath chunk; ``kth`` is the ragged per-VM chunk list the
        maintenance path consumes (real VMs only — never padded). With
        ``prefetch_depth > 0`` the arrays arrive as device buffers, put up
        to that many blocks ahead of consumption; with ``sharding`` each
        transfer lands directly in the per-device row-block layout."""
        lists = self.chunk_lists()
        n_chunks = max(map(len, lists), default=0)
        pad = [None] * self.pad_vms

        def host_block(k: int):
            kth = [c[k] if k < len(c) else None for c in lists]
            a, w = pad_batch(kth + pad, self.chunk)
            return a, w, kth

        if self.prefetch_depth <= 0:
            yield from (host_block(k) for k in range(n_chunks))
            return

        def put(a, w):
            if self.sharding is None:
                return jax.device_put((a, w))
            return jax.device_put((a, w), self.sharding)

        pending: deque = deque()
        for k in range(min(self.prefetch_depth, n_chunks)):
            a, w, kth = host_block(k)
            pending.append((put(a, w), kth))
        k = len(pending)
        while pending:
            dev, kth = pending.popleft()
            if k < n_chunks:        # start the next transfer before the
                a, w, nk = host_block(k)  # consumer dispatches this block
                pending.append((put(a, w), nk))
                k += 1
            yield dev[0], dev[1], kth


@dataclasses.dataclass
class _DemuxedShard:
    """One shard after its single stable sort: requests grouped by VM
    (arrival order preserved within each VM), with global indices."""

    base: int                   # global index of the shard's first request
    length: int
    addr: np.ndarray            # [n] sorted by (vm, arrival)
    is_write: np.ndarray        # [n]
    gidx: np.ndarray            # [n] ascending global index per VM segment
    bounds: np.ndarray          # [num_vms + 1] VM segment boundaries
    size: np.ndarray | None = None  # [n] request sizes (sized stores only)

    @classmethod
    def demux(cls, shard: Trace, base: int, num_vms: int) -> "_DemuxedShard":
        vm = np.asarray(shard.vm)
        order = np.argsort(vm, kind="stable")
        bounds = np.searchsorted(vm[order], np.arange(num_vms + 1))
        return cls(base=base, length=len(shard),
                   addr=np.asarray(shard.addr, np.int32)[order],
                   is_write=np.asarray(shard.is_write, bool)[order],
                   gidx=(base + order).astype(np.int64), bounds=bounds,
                   size=(None if shard.size is None
                         else np.asarray(shard.size, np.int32)[order]))

    def vm_part(self, v: int, start: int, stop: int):
        """This shard's (addr, is_write, size) for VM ``v`` restricted to
        global request range ``[start, stop)`` — a binary search, no
        scan. ``size`` is ``None`` for size-less stores."""
        lo, hi = int(self.bounds[v]), int(self.bounds[v + 1])
        g = self.gidx[lo:hi]
        a = int(np.searchsorted(g, start))
        b = int(np.searchsorted(g, stop))
        return (self.addr[lo + a: lo + b], self.is_write[lo + a: lo + b],
                None if self.size is None else self.size[lo + a: lo + b])


@dataclasses.dataclass
class StreamingTraceSource:
    """Resize-window iterator over a ``TraceStore`` or in-memory ``Trace``.

    Yields :class:`StreamWindow`\\ s whose per-VM sub-traces are
    bit-identical to ``split_by_vm(trace[s:e], num_vms)`` on the
    materialized trace. ``window`` is the controller's resize interval,
    ``chunk`` its datapath block width.
    """

    source: "TraceStore | Trace"
    num_vms: int
    window: int
    chunk: int
    prefetch: bool = True       # master switch (False -> host blocks)
    prefetch_depth: int = 2     # pipeline depth when prefetch is on
    pad_vms: int = 0            # dead VM rows appended to datapath blocks
    sharding: object = None     # NamedSharding for per-shard placement

    @property
    def depth(self) -> int:
        return self.prefetch_depth if self.prefetch else 0

    def _window(self, i: int, subs: list[Trace]) -> StreamWindow:
        return StreamWindow(i, subs, self.chunk, self.depth,
                            self.pad_vms, self.sharding)

    def windows(self) -> Iterator[StreamWindow]:
        if isinstance(self.source, Trace):
            yield from self._windows_from_trace(self.source)
        elif self.source.has_vm:
            yield from self._windows_from_store(self.source)
        else:
            yield from self._windows_from_vmless_store(self.source)

    # -- in-memory ---------------------------------------------------------
    def _windows_from_trace(self, trace: Trace) -> Iterator[StreamWindow]:
        for i, window in enumerate(trace.intervals(self.window)):
            yield self._window(i, split_by_vm(window, self.num_vms))

    # -- on-disk, vm channel ----------------------------------------------
    def _windows_from_store(self, store: TraceStore) -> Iterator[StreamWindow]:
        total = len(store)
        active: deque[_DemuxedShard] = deque()
        shard_idx, loaded = 0, 0
        sized = store.has_size
        empty = (np.empty(0, np.int32), np.empty(0, bool),
                 np.empty(0, np.int32) if sized else None)
        for i, ws in enumerate(range(0, total, self.window)):
            we = min(ws + self.window, total)
            while loaded < we:            # one stable sort per shard, once
                sh = store.shard(shard_idx)
                active.append(_DemuxedShard.demux(sh, loaded, self.num_vms))
                loaded += len(sh)
                shard_idx += 1
            while active and active[0].base + active[0].length <= ws:
                active.popleft()          # shard fully behind this window
            subs = []
            for v in range(self.num_vms):
                parts = [d.vm_part(v, ws, we) for d in active]
                parts = [p for p in parts if p[0].size]
                if not parts:
                    subs.append(Trace(empty[0], empty[1], size=empty[2]))
                elif len(parts) == 1:
                    subs.append(Trace(parts[0][0], parts[0][1],
                                      size=parts[0][2]))
                else:
                    subs.append(Trace(
                        np.concatenate([p[0] for p in parts]),
                        np.concatenate([p[1] for p in parts]),
                        size=(np.concatenate([p[2] for p in parts])
                              if sized else None)))
            yield self._window(i, subs)

    # -- on-disk, no vm channel (single-stream convention) -----------------
    def _windows_from_vmless_store(self, store) -> Iterator[StreamWindow]:
        # mirrors the controllers' Trace(vm=None) convention: every VM
        # sees the whole window
        for i, window in enumerate(store.iter_windows(self.window)):
            yield self._window(i, [window] * self.num_vms)


def window_source(trace, num_vms: int, window: int, chunk: int,
                  prefetch: bool = True, prefetch_depth: int = 2,
                  pad_vms: int = 0, sharding=None) -> StreamingTraceSource:
    """Normalize any accepted trace input into a StreamingTraceSource.

    ``trace`` may be an in-memory :class:`Trace`, an on-disk
    :class:`TraceStore`, or an existing :class:`StreamingTraceSource`
    (re-parameterized to the controller's intervals)."""
    if isinstance(trace, StreamingTraceSource):
        return dataclasses.replace(trace, num_vms=num_vms, window=window,
                                   chunk=chunk, prefetch=prefetch,
                                   prefetch_depth=prefetch_depth,
                                   pad_vms=pad_vms, sharding=sharding)
    if not isinstance(trace, (Trace, TraceStore)):
        raise TypeError(f"expected Trace, TraceStore or "
                        f"StreamingTraceSource, got {type(trace).__name__}")
    return StreamingTraceSource(trace, num_vms, window, chunk, prefetch,
                                prefetch_depth, pad_vms, sharding)
