"""Streaming ``[V, chunk]`` request-block ingestion for the controllers.

The batched controllers consume multi-VM traces as a sequence of resize
windows, each demuxed per VM and simulated in rectangular ``[V, chunk]``
blocks (``addr = -1`` padded). With an in-memory
:class:`~repro.core.trace.Trace` that demux used to cost V boolean-mask
scans per window; with the million-request traces the paper evaluates on
(§5.1) the trace would not even fit in host memory. This module supplies
both halves of the fix:

* :class:`StreamingTraceSource` — iterates resize windows from an
  on-disk :class:`~repro.traces.store.TraceStore` (or an in-memory
  ``Trace``) and performs the per-VM demux with **one stable sort per
  shard** (``np.argsort(vm, kind="stable")`` groups requests by VM while
  preserving per-VM arrival order), serving each window's per-VM
  sub-traces by binary-searching the sorted global-index segments. Only
  the shards overlapping the current window are resident, so peak host
  memory is O(shard + window + V·chunk) — independent of trace length.

* **Double-buffered host→device prefetch** — :meth:`StreamWindow.blocks`
  keeps two ``[V, chunk]`` blocks in flight: while the simulator consumes
  block *k*, block *k+1* is already being ``jax.device_put`` — the
  classic two-slot pipeline::

      host   : | build k | build k+1 | build k+2 |
      xfer   :      | put k | put k+1  | put k+2 |
      device :          | sim k  | sim k+1 | sim k+2 |

  JAX transfers and dispatches are asynchronous, so the copy of block
  *k+1* overlaps the simulation of block *k* instead of serializing
  after it.

Both controllers accept a ``Trace``, a ``TraceStore``, or a pre-built
``StreamingTraceSource`` in :meth:`run` and produce **bit-identical**
results for all three (asserted in ``tests/test_trace_store.py``): the
demux equals the mask-based reference and padding/chunking are shared
with the in-memory path (:func:`repro.core.trace.pad_batch`).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterator

import jax
import numpy as np

from repro.core.trace import Trace, pad_batch, split_by_vm

from .store import TraceStore


@dataclasses.dataclass
class StreamWindow:
    """One resize window: per-VM sub-traces + padded datapath blocks."""

    index: int                  # window ordinal
    subs: list[Trace]           # per-VM demux (sizing / maintenance / oracle)
    chunk: int                  # datapath block width (promo/sim chunk)
    prefetch: bool = True       # double-buffer host->device transfers

    def chunk_lists(self) -> list[list[Trace]]:
        return [list(sub.intervals(self.chunk)) for sub in self.subs]

    def blocks(self) -> Iterator[tuple]:
        """Yield ``(addr [V, chunk], is_write [V, chunk], kth)`` per
        datapath chunk; ``kth`` is the ragged per-VM chunk list the
        maintenance path consumes. With ``prefetch`` the arrays arrive as
        device buffers, put one block ahead of consumption."""
        lists = self.chunk_lists()
        n_chunks = max(map(len, lists), default=0)

        def host_block(k: int):
            kth = [c[k] if k < len(c) else None for c in lists]
            a, w = pad_batch(kth, self.chunk)
            return a, w, kth

        if not self.prefetch:
            yield from (host_block(k) for k in range(n_chunks))
            return
        if n_chunks == 0:
            return
        nxt = host_block(0)
        nxt_dev = jax.device_put((nxt[0], nxt[1]))
        for k in range(n_chunks):
            cur_kth, cur_dev = nxt[2], nxt_dev
            if k + 1 < n_chunks:    # start the next transfer before the
                nxt = host_block(k + 1)   # consumer dispatches this block
                nxt_dev = jax.device_put((nxt[0], nxt[1]))
            yield cur_dev[0], cur_dev[1], cur_kth


@dataclasses.dataclass
class _DemuxedShard:
    """One shard after its single stable sort: requests grouped by VM
    (arrival order preserved within each VM), with global indices."""

    base: int                   # global index of the shard's first request
    length: int
    addr: np.ndarray            # [n] sorted by (vm, arrival)
    is_write: np.ndarray        # [n]
    gidx: np.ndarray            # [n] ascending global index per VM segment
    bounds: np.ndarray          # [num_vms + 1] VM segment boundaries
    size: np.ndarray | None = None  # [n] request sizes (sized stores only)

    @classmethod
    def demux(cls, shard: Trace, base: int, num_vms: int) -> "_DemuxedShard":
        vm = np.asarray(shard.vm)
        order = np.argsort(vm, kind="stable")
        bounds = np.searchsorted(vm[order], np.arange(num_vms + 1))
        return cls(base=base, length=len(shard),
                   addr=np.asarray(shard.addr, np.int32)[order],
                   is_write=np.asarray(shard.is_write, bool)[order],
                   gidx=(base + order).astype(np.int64), bounds=bounds,
                   size=(None if shard.size is None
                         else np.asarray(shard.size, np.int32)[order]))

    def vm_part(self, v: int, start: int, stop: int):
        """This shard's (addr, is_write, size) for VM ``v`` restricted to
        global request range ``[start, stop)`` — a binary search, no
        scan. ``size`` is ``None`` for size-less stores."""
        lo, hi = int(self.bounds[v]), int(self.bounds[v + 1])
        g = self.gidx[lo:hi]
        a = int(np.searchsorted(g, start))
        b = int(np.searchsorted(g, stop))
        return (self.addr[lo + a: lo + b], self.is_write[lo + a: lo + b],
                None if self.size is None else self.size[lo + a: lo + b])


@dataclasses.dataclass
class StreamingTraceSource:
    """Resize-window iterator over a ``TraceStore`` or in-memory ``Trace``.

    Yields :class:`StreamWindow`\\ s whose per-VM sub-traces are
    bit-identical to ``split_by_vm(trace[s:e], num_vms)`` on the
    materialized trace. ``window`` is the controller's resize interval,
    ``chunk`` its datapath block width.
    """

    source: "TraceStore | Trace"
    num_vms: int
    window: int
    chunk: int
    prefetch: bool = True

    def windows(self) -> Iterator[StreamWindow]:
        if isinstance(self.source, Trace):
            yield from self._windows_from_trace(self.source)
        elif self.source.has_vm:
            yield from self._windows_from_store(self.source)
        else:
            yield from self._windows_from_vmless_store(self.source)

    # -- in-memory ---------------------------------------------------------
    def _windows_from_trace(self, trace: Trace) -> Iterator[StreamWindow]:
        for i, window in enumerate(trace.intervals(self.window)):
            yield StreamWindow(i, split_by_vm(window, self.num_vms),
                               self.chunk, self.prefetch)

    # -- on-disk, vm channel ----------------------------------------------
    def _windows_from_store(self, store: TraceStore) -> Iterator[StreamWindow]:
        total = len(store)
        active: deque[_DemuxedShard] = deque()
        shard_idx, loaded = 0, 0
        sized = store.has_size
        empty = (np.empty(0, np.int32), np.empty(0, bool),
                 np.empty(0, np.int32) if sized else None)
        for i, ws in enumerate(range(0, total, self.window)):
            we = min(ws + self.window, total)
            while loaded < we:            # one stable sort per shard, once
                sh = store.shard(shard_idx)
                active.append(_DemuxedShard.demux(sh, loaded, self.num_vms))
                loaded += len(sh)
                shard_idx += 1
            while active and active[0].base + active[0].length <= ws:
                active.popleft()          # shard fully behind this window
            subs = []
            for v in range(self.num_vms):
                parts = [d.vm_part(v, ws, we) for d in active]
                parts = [p for p in parts if p[0].size]
                if not parts:
                    subs.append(Trace(empty[0], empty[1], size=empty[2]))
                elif len(parts) == 1:
                    subs.append(Trace(parts[0][0], parts[0][1],
                                      size=parts[0][2]))
                else:
                    subs.append(Trace(
                        np.concatenate([p[0] for p in parts]),
                        np.concatenate([p[1] for p in parts]),
                        size=(np.concatenate([p[2] for p in parts])
                              if sized else None)))
            yield StreamWindow(i, subs, self.chunk, self.prefetch)

    # -- on-disk, no vm channel (single-stream convention) -----------------
    def _windows_from_vmless_store(self, store) -> Iterator[StreamWindow]:
        # mirrors the controllers' Trace(vm=None) convention: every VM
        # sees the whole window
        for i, window in enumerate(store.iter_windows(self.window)):
            yield StreamWindow(i, [window] * self.num_vms, self.chunk,
                               self.prefetch)


def window_source(trace, num_vms: int, window: int, chunk: int,
                  prefetch: bool = True) -> StreamingTraceSource:
    """Normalize any accepted trace input into a StreamingTraceSource.

    ``trace`` may be an in-memory :class:`Trace`, an on-disk
    :class:`TraceStore`, or an existing :class:`StreamingTraceSource`
    (re-parameterized to the controller's intervals)."""
    if isinstance(trace, StreamingTraceSource):
        return dataclasses.replace(trace, num_vms=num_vms, window=window,
                                   chunk=chunk, prefetch=prefetch)
    if not isinstance(trace, (Trace, TraceStore)):
        raise TypeError(f"expected Trace, TraceStore or "
                        f"StreamingTraceSource, got {type(trace).__name__}")
    return StreamingTraceSource(trace, num_vms, window, chunk, prefetch)
