"""Synthetic workload generators modeled on the paper's trace suite.

The paper evaluates with MSR Cambridge traces (SNIA IOTTA) and
FIO/Filebench workloads. Those traces are not redistributable inside this
container, so each family is modeled as a parameterized generator that
reproduces the *characteristics the paper relies on*: read/write mix,
locality (zipf re-reference), sequentiality, working-set size, and
RAW-vs-RAR structure. Every generator is deterministic given a seed.

Families (paper §5.1 and Table 2):

====================  =========================================================
hm_1                  hardware monitoring — random reads, high locality
mds_0 / mds_1         media server — sequential (streaming) reads, low locality
src2_0 / src1_2       source control — small writes with heavy RAW re-reads
stg_1                 web staging — write-intensive random
ts_0                  terminal server — RAW/RARAW-heavy mixed
wdev_0                test web server — writes followed by repeated reads (RAW)
web_3                 web/SQL server — read-intensive, mostly cold reads
rsrch_0               research projects — write-heavy with moderate RAW
usr_0                 user home dirs — write-dominated, popular written blocks
proj_0                project dirs — mixed, moderate locality
fio_randrw            FIO RandRW 70% read zipf(1.1) (motivational Fig. 3a)
web_server            Filebench Web Server — random cold reads (Fig. 3b)
video_server          Filebench Video Server — pure sequential reads (Fig. 3c)
varmail               Filebench Varmail — 50/50 random read/write (Fig. 3d)
====================  =========================================================
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.trace import Trace


@dataclasses.dataclass
class WorkloadSpec:
    """Knobs shared by all generators."""
    read_ratio: float = 0.7         # fraction of reads
    working_set: int = 4096         # distinct blocks
    zipf_a: float = 1.1             # skew of the re-reference distribution
    sequential: float = 0.0         # fraction of sequential runs
    raw_fraction: float = 0.0       # fraction of reads directed at
                                    # recently-written blocks (RAW structure)
    cold_fraction: float = 0.0      # fraction of reads to never-reused blocks
    write_burst: float = 0.0        # fraction of writes redirected to
                                    # one-shot addresses (scans/installs/log
                                    # writes — the pollution that penalizes
                                    # push-mode caches, paper §4.2)
    run_length: int = 64            # blocks per sequential run
    seq_interleaved: bool = False   # emit the sequential part as contiguous
                                    # runs spliced into the random stream
                                    # (adjacency survives, so run-length
                                    # rules / seq-cutoff can see the scans;
                                    # plain `sequential` permutes arrivals)
    big_fraction: float = 0.0       # fraction of requests issued at
                                    # big_size blocks (mixed-block-size
                                    # workloads -> Trace.size channel)
    big_size: int = 8               # blocks per "big" request


def _zipf_ranks(rng: np.random.Generator, n: int, size: int, a: float):
    """Zipf-distributed ranks in [0, size) (bounded, vectorized)."""
    ranks = np.arange(1, size + 1, dtype=np.float64)
    p = ranks ** (-a)
    p /= p.sum()
    return rng.choice(size, size=n, p=p)


def generate(spec: WorkloadSpec, n: int, seed: int = 0,
             addr_offset: int = 0) -> Trace:
    if spec.seq_interleaved and spec.sequential > 0:
        return _generate_seq_interleaved(spec, n, seed, addr_offset)
    rng = np.random.default_rng(seed)
    addr = np.zeros(n, np.int64)
    is_write = rng.random(n) >= spec.read_ratio

    # permute the working set so zipf-hot blocks are scattered over sets
    perm = rng.permutation(spec.working_set)

    n_seq = int(n * spec.sequential)
    n_rand = n - n_seq

    # random (zipf) part
    hot = perm[_zipf_ranks(rng, n_rand, spec.working_set, spec.zipf_a)]
    addr[:n_rand] = hot

    # sequential runs (streaming) — walk fresh address space
    if n_seq:
        base = spec.working_set
        runs = np.maximum(spec.run_length, 1)
        steps = np.arange(n_seq)
        addr[n_rand:] = base + steps  # one long scan
        is_write[n_rand:] = rng.random(n_seq) >= spec.read_ratio

    # interleave sequential into random positions to avoid phase artifacts
    order = rng.permutation(n)
    addr = addr[order]
    is_write = is_write[order]

    # cold reads: redirect a fraction of reads to one-shot addresses
    if spec.cold_fraction > 0:
        reads = np.nonzero(~is_write)[0]
        k = int(len(reads) * spec.cold_fraction)
        if k:
            pick = rng.choice(reads, size=k, replace=False)
            addr[pick] = spec.working_set + n + np.arange(k)

    # write bursts: one-shot writes with no future references (pollution)
    if spec.write_burst > 0:
        writes = np.nonzero(is_write)[0]
        k = int(len(writes) * spec.write_burst)
        if k:
            pick = rng.choice(writes, size=k, replace=False)
            addr[pick] = spec.working_set + 2 * n + np.arange(k)

    # RAW structure: redirect a fraction of reads to the most recent writes
    if spec.raw_fraction > 0:
        write_pos = np.nonzero(is_write)[0]
        reads = np.nonzero(~is_write)[0]
        k = int(len(reads) * spec.raw_fraction)
        if k and write_pos.size:
            pick = rng.choice(reads, size=k, replace=False)
            for i in pick:
                prev_w = write_pos[write_pos < i]
                if prev_w.size:
                    # read one of the last few written blocks (RAW / RARAW)
                    j = prev_w[-1 - rng.integers(0, min(8, prev_w.size))]
                    addr[i] = addr[j]

    return Trace(addr=(addr + addr_offset).astype(np.int32),
                 is_write=is_write,
                 size=_draw_sizes(spec, n, rng))


def _draw_sizes(spec: WorkloadSpec, n: int,
                rng: np.random.Generator) -> np.ndarray | None:
    """Mixed-block-size channel: ``big_fraction`` of requests at
    ``big_size`` blocks, the rest at 1. ``None`` (no size channel, the
    all-ones convention) when the spec is single-size — existing
    workloads are byte-identical to before."""
    if spec.big_fraction <= 0 or n == 0:
        return None
    size = np.ones(n, np.int32)
    k = int(n * spec.big_fraction)
    if k:
        size[rng.choice(n, size=k, replace=False)] = spec.big_size
    return size


def _generate_seq_interleaved(spec: WorkloadSpec, n: int, seed: int,
                              addr_offset: int) -> Trace:
    """Contiguous sequential runs spliced into the random stream.

    The base generator permutes arrival order, which destroys the
    address adjacency run-length rules key on; here the random part is
    generated as usual (``sequential=0``) and whole runs of
    ``run_length`` contiguous blocks — one direction per run, fresh
    address space, gaps between runs so they never merge — are inserted
    at sorted random cut points, preserving both streams' internal
    order."""
    run_len = max(spec.run_length, 1)
    num_runs = int(n * spec.sequential) // run_len
    n_seq = num_runs * run_len
    n_rand = n - n_seq
    base = dataclasses.replace(spec, sequential=0.0, seq_interleaved=False)
    rnd = generate(base, n_rand, seed=seed, addr_offset=0)
    rng = np.random.default_rng(seed + 1)   # splice stream, decoupled
                                            # from the random part's seed
    scan_base = spec.working_set + 4 * n    # clear of cold/burst ranges
    out_a = [np.asarray(rnd.addr, np.int64)]
    out_w = [np.asarray(rnd.is_write)]
    out_s = [rnd.sizes().astype(np.int32)]
    if num_runs:
        cuts = np.sort(rng.integers(0, n_rand + 1, num_runs))
        run_write = rng.random(num_runs) >= spec.read_ratio
        out_a, out_w, out_s = [], [], []
        prev = 0
        for r in range(num_runs):
            c = int(cuts[r])
            out_a.append(np.asarray(rnd.addr[prev:c], np.int64))
            out_w.append(np.asarray(rnd.is_write[prev:c]))
            out_s.append(rnd.sizes()[prev:c].astype(np.int32))
            start = scan_base + r * (run_len + 64)   # gap: runs never chain
            out_a.append(np.arange(start, start + run_len, dtype=np.int64))
            out_w.append(np.full(run_len, run_write[r]))
            out_s.append(np.ones(run_len, np.int32))
            prev = c
        out_a.append(np.asarray(rnd.addr[prev:], np.int64))
        out_w.append(np.asarray(rnd.is_write[prev:]))
        out_s.append(rnd.sizes()[prev:].astype(np.int32))
    addr = np.concatenate(out_a)
    is_write = np.concatenate(out_w)
    size = np.concatenate(out_s) if rnd.size is not None else None
    return Trace(addr=(addr + addr_offset).astype(np.int32),
                 is_write=is_write, size=size)


# -- named families ---------------------------------------------------------

SPECS: dict[str, WorkloadSpec] = {
    "hm_1": WorkloadSpec(read_ratio=0.95, working_set=2048, zipf_a=1.4,
                         cold_fraction=0.02),
    "mds_0": WorkloadSpec(read_ratio=0.9, working_set=512, sequential=0.9,
                          zipf_a=1.05),
    "mds_1": WorkloadSpec(read_ratio=0.98, working_set=256, sequential=0.97,
                          zipf_a=1.01, cold_fraction=0.5),
    "src2_0": WorkloadSpec(read_ratio=0.4, working_set=1024, zipf_a=1.55,
                           raw_fraction=0.7),
    "src1_2": WorkloadSpec(read_ratio=0.45, working_set=1536, zipf_a=1.15,
                           raw_fraction=0.5),
    "stg_1": WorkloadSpec(read_ratio=0.25, working_set=4096, zipf_a=1.35,
                          write_burst=0.15),
    "ts_0": WorkloadSpec(read_ratio=0.55, working_set=1024, zipf_a=1.6,
                         raw_fraction=0.8),
    "wdev_0": WorkloadSpec(read_ratio=0.5, working_set=768, zipf_a=1.7,
                           raw_fraction=0.85),
    "web_3": WorkloadSpec(read_ratio=0.97, working_set=8192, zipf_a=1.02,
                          cold_fraction=0.6),
    "rsrch_0": WorkloadSpec(read_ratio=0.3, working_set=2048, zipf_a=1.5,
                            raw_fraction=0.3),
    "usr_0": WorkloadSpec(read_ratio=0.2, working_set=1536, zipf_a=1.7,
                          raw_fraction=0.6),
    "proj_0": WorkloadSpec(read_ratio=0.6, working_set=3072, zipf_a=1.15,
                           raw_fraction=0.2, cold_fraction=0.1),
    # motivational (Fig. 3) workloads
    "fio_randrw": WorkloadSpec(read_ratio=0.7, working_set=8192, zipf_a=1.1,
                               raw_fraction=0.5),
    "web_server": WorkloadSpec(read_ratio=0.9, working_set=16384, zipf_a=1.01,
                               cold_fraction=0.7),
    "video_server": WorkloadSpec(read_ratio=1.0, working_set=64,
                                 sequential=1.0, cold_fraction=0.0),
    "varmail": WorkloadSpec(read_ratio=0.5, working_set=4096, zipf_a=1.1,
                            raw_fraction=0.25),
    # scan-heavy / mixed-block families (classification workloads): the
    # sequential part is emitted as contiguous runs (seq_interleaved) so
    # run-length rules and the sequential-cutoff bypass can see the scans
    "scan_mix": WorkloadSpec(read_ratio=0.85, working_set=1024, zipf_a=1.4,
                             sequential=0.6, run_length=96,
                             seq_interleaved=True),
    "backup_scan": WorkloadSpec(read_ratio=0.15, working_set=1024,
                                zipf_a=1.3, sequential=0.7, run_length=128,
                                seq_interleaved=True),
    "mixed_block": WorkloadSpec(read_ratio=0.7, working_set=2048, zipf_a=1.3,
                                sequential=0.3, run_length=64,
                                seq_interleaved=True, big_fraction=0.25,
                                big_size=8),
}

# the classification benchmarks' default multi-VM mix: two scan-heavy
# streams next to two reuse-friendly victims whose working sets the
# scans would otherwise flush
SCAN_HEAVY_MIX = ["scan_mix", "hm_1", "backup_scan", "src2_0"]


def make(name: str, n: int, seed: int = 0, addr_offset: int = 0,
         scale: float = 1.0) -> Trace:
    """Instantiate a named workload; ``scale`` shrinks the working set for
    CPU-friendly benchmark sizes while preserving the mix."""
    spec = SPECS[name]
    if scale != 1.0:
        spec = dataclasses.replace(
            spec, working_set=max(int(spec.working_set * scale), 16))
    return generate(spec, n, seed=seed, addr_offset=addr_offset)


def names() -> list[str]:
    return list(SPECS)


# -- serving session churn --------------------------------------------------

# event kinds of a SessionTrace (the serving analog of a block trace)
SESSION_NEW = 0        # session arrives (sid, tenant)
SESSION_ACTIVATE = 1   # session scheduled into a decode batch (KV read)
SESSION_APPEND = 2     # session generates one KV page (WBWO write)
SESSION_END = 3        # session leaves for good (frees tier-2 state)


@dataclasses.dataclass
class SessionTrace:
    """Arrival/churn event stream driving the two-tier KV serving stack.

    Parallel arrays, one entry per event: ``kind`` (the ``SESSION_*``
    constants), ``sid`` (session id, unique per NEW), ``tenant`` (valid
    on NEW, ``-1`` elsewhere)."""
    kind: np.ndarray     # int8  [N]
    sid: np.ndarray      # int32 [N]
    tenant: np.ndarray   # int8  [N]

    def __len__(self) -> int:
        return int(self.kind.size)

    @property
    def num_sessions(self) -> int:
        return int((self.kind == SESSION_NEW).sum())

    @property
    def max_live(self) -> int:
        delta = np.where(self.kind == SESSION_NEW, 1,
                         np.where(self.kind == SESSION_END, -1, 0))
        return int(np.cumsum(delta).max(initial=0))


@dataclasses.dataclass
class SessionSpec:
    """Knobs of the serving churn generator.

    Models the characteristics the ETICA policy keys on, translated to
    serving: zipf re-reference (a few hot sessions absorb most
    activations), recency bias (new sessions are hotter), bursty
    scheduling (a scheduled session tends to stay in the batch for a few
    consecutive rounds), bounded lifetimes (sessions retire after a
    bounded number of touches, so the population churns instead of
    growing without bound)."""
    num_tenants: int = 4
    target_live: int = 1024     # concurrent-session level after ramp-up
    zipf_a: float = 1.2         # skew of activation popularity over live
                                # sessions (rank 0 = most recent arrival)
    p_new: float = 0.05         # arrival probability per event once ramped
    p_append: float = 0.35      # chance a touch generates a page (vs pure
                                # activation) while below max_pages
    max_pages: int = 8          # per-session KV budget (pages)
    lifetime: int = 40          # touches before a session must retire
    p_end: float = 0.02         # early-retire chance per touch once the
                                # session has written >= 2 pages
    burst_len: float = 4.0      # mean consecutive touches to one session
                                # (geometric) — bursty batch residency
    tenant_weights: tuple | None = None   # arrival mix (default uniform)


def generate_sessions(spec: SessionSpec, n: int, seed: int = 0) -> SessionTrace:
    """Deterministic session arrival/churn stream of ``n`` events.

    O(1) per event: popularity is a precomputed zipf CDF over recency
    ranks, sampled by ``searchsorted`` and folded onto however many
    sessions are currently live."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, max(spec.target_live, 1) + 1, dtype=np.float64)
    cdf = np.cumsum(ranks ** (-spec.zipf_a))
    cdf /= cdf[-1]
    tw = None
    if spec.tenant_weights is not None:
        tw = np.asarray(spec.tenant_weights, np.float64)
        tw = tw / tw.sum()

    kind = np.empty(n, np.int8)
    sid_col = np.empty(n, np.int32)
    ten_col = np.full(n, -1, np.int8)

    live: list[int] = []          # newest last
    pages: dict[int, int] = {}
    touches: dict[int, int] = {}
    next_sid = 0
    burst_sid, burst_left = -1, 0
    # pre-draw the cheap scalars in one block each
    u_new = rng.random(n)
    u_rank = rng.random(n)
    u_act = rng.random(n)
    u_end = rng.random(n)
    mean_burst = max(spec.burst_len, 1.0)

    i = 0
    while i < n:
        ramping = len(live) < spec.target_live // 2
        p_new = max(spec.p_new, 0.0) + (0.5 if ramping else 0.0)
        if not live or (len(live) < spec.target_live and u_new[i] < p_new):
            sid = next_sid
            next_sid += 1
            live.append(sid)
            pages[sid] = 0
            touches[sid] = 0
            t = (int(rng.choice(spec.num_tenants, p=tw)) if tw is not None
                 else int(rng.integers(spec.num_tenants)))
            kind[i] = SESSION_NEW
            sid_col[i] = sid
            ten_col[i] = t
            burst_sid = sid
            burst_left = max(int(rng.geometric(1.0 / mean_burst)), 1)
            i += 1
            continue
        if burst_left > 0 and burst_sid in pages:
            sid = burst_sid
            burst_left -= 1
        else:
            r = int(np.searchsorted(cdf, u_rank[i]))
            sid = live[-1 - (r % len(live))]     # rank 0 = newest arrival
            burst_sid = sid
            burst_left = max(int(rng.geometric(1.0 / mean_burst)) - 1, 0)
        touches[sid] += 1
        if pages[sid] == 0 or (pages[sid] < spec.max_pages
                               and u_act[i] < spec.p_append):
            kind[i] = SESSION_APPEND
            pages[sid] += 1
        else:
            kind[i] = SESSION_ACTIVATE
        sid_col[i] = sid
        retire = (touches[sid] >= spec.lifetime
                  or (pages[sid] >= 2 and u_end[i] < spec.p_end))
        i += 1
        if retire and len(live) > 1 and i < n:
            kind[i] = SESSION_END
            sid_col[i] = sid
            live.remove(sid)
            del pages[sid], touches[sid]
            burst_left = 0
            i += 1
    return SessionTrace(kind=kind, sid=sid_col, tenant=ten_col)


# -- generate-to-store ------------------------------------------------------

def generate_to_store(path, spec: WorkloadSpec, n: int, seed: int = 0,
                      addr_offset: int = 0, shard_size: int | None = None):
    """Generate one workload straight into an on-disk
    :class:`~repro.traces.store.TraceStore` (vm-less single stream).

    The synthetic generator itself is in-memory (its permutations are
    global), but the store is written shard-by-shard, so the result can
    be consumed at bounded memory like any imported trace."""
    from .store import DEFAULT_SHARD_SIZE, TraceStore
    trace = generate(spec, n, seed=seed, addr_offset=addr_offset)
    return TraceStore.from_trace(path, trace,
                                 shard_size=shard_size or DEFAULT_SHARD_SIZE)


def make_store(path, workloads: list[str], reqs_per_vm: int, seed: int = 0,
               scale: float = 1.0, addr_stride: int = 10_000_000,
               interleave_seed: int = 42, shard_size: int | None = None):
    """Generate a consolidated multi-VM mix straight into a TraceStore.

    One named workload per VM (``workloads[i]`` drives VM ``i``, disjoint
    address spaces via ``addr_stride``), randomly interleaved into one
    hypervisor arrival stream — the same recipe the benchmarks use
    in-memory, persisted shard-by-shard for the streaming ingestion
    path."""
    from .store import DEFAULT_SHARD_SIZE, TraceStore
    from repro.core.trace import interleave
    traces = [make(w, reqs_per_vm, seed=seed + i, addr_offset=i * addr_stride,
                   scale=scale)
              for i, w in enumerate(workloads)]
    mixed = interleave(traces, seed=interleave_seed)
    return TraceStore.from_trace(path, mixed,
                                 shard_size=shard_size or DEFAULT_SHARD_SIZE)
