"""The paper's own experiment configuration (ETICA §5.1): 12 VMs running
MSR-family workloads over a DRAM+SSD two-level cache."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class EticaPaperConfig:
    vms: tuple = ("hm_1", "proj_0", "stg_1", "usr_0", "ts_0", "wdev_0",
                  "web_3", "usr_0", "mds_0", "src2_0", "rsrch_0", "mds_1")
    requests_per_vm: int = 20_000
    resize_interval: int = 10_000
    promo_interval: int = 1_000
    dram_fraction: float = 1.0 / 3.0   # DRAM:SSD capacity split


CONFIG = EticaPaperConfig()
