"""internvl2-26b — VLM backbone (InternViT frontend is a stub providing
precomputed patch embeddings) [arXiv:2404.16821; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553, mlp_act="swiglu",
    frontend="vision", frontend_tokens=256,
)

REDUCED = ModelConfig(
    name="internvl2-reduced", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, mlp_act="swiglu",
    frontend="vision", frontend_tokens=16,
)
