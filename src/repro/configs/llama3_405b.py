"""llama3-405b — dense GQA, 128k vocab [arXiv:2407.21783]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    head_dim=128, d_ff=53248, vocab_size=128256, mlp_act="swiglu",
    rope_theta=5e5,
)

REDUCED = ModelConfig(
    name="llama3-reduced", family="dense",
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512, mlp_act="swiglu",
)
