"""seamless-m4t-large-v2 — speech encoder-decoder backbone; the audio
frontend is a stub providing precomputed frame embeddings
[arXiv:2308.11596; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    num_layers=24, encoder_layers=24, d_model=1024, num_heads=16,
    num_kv_heads=16, head_dim=64, d_ff=8192, vocab_size=256206,
    mlp_act="gelu", frontend="audio",
)

REDUCED = ModelConfig(
    name="seamless-reduced", family="audio",
    num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
    mlp_act="gelu", frontend="audio",
)
