"""Architecture registry: one module per assigned architecture.

``get(arch_id)`` returns the full-size ModelConfig; ``get_reduced`` the
CPU-smoke-test variant of the same family.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "jamba-v0.1-52b",
    "nemotron-4-15b",
    "phi4-mini-3.8b",
    "qwen3-4b",
    "llama3-405b",
    "mamba2-370m",
    "seamless-m4t-large-v2",
    "deepseek-moe-16b",
    "mixtral-8x22b",
    "internvl2-26b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get(arch_id: str):
    return _module(arch_id).CONFIG


def get_reduced(arch_id: str):
    return _module(arch_id).REDUCED
