"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE every 2nd
layer, 16 experts top-2 [arXiv:2403.19887; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    moe_num_experts=16, moe_top_k=2, moe_d_ff=14336, moe_layer_period=2,
    attn_period=8,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    mlp_act="swiglu",
)

REDUCED = ModelConfig(
    name="jamba-reduced", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    moe_num_experts=4, moe_top_k=2, moe_d_ff=128, moe_layer_period=2,
    attn_period=8,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=32,
    mlp_act="swiglu",
)
