"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    moe_num_experts=8, moe_top_k=2, moe_d_ff=16384,
    sliding_window=4096, mlp_act="swiglu",
)

REDUCED = ModelConfig(
    name="mixtral-reduced", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    moe_num_experts=4, moe_top_k=2, moe_d_ff=128,
    sliding_window=64, mlp_act="swiglu",
)
