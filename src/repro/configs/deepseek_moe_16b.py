"""deepseek-moe-16b — fine-grained MoE: 64 routed experts top-6 + 2
shared, dense first layer [arXiv:2401.06066; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    moe_num_experts=64, moe_top_k=6, moe_num_shared=2, moe_d_ff=1408,
    first_dense_ff=10944, mlp_act="swiglu",
)

REDUCED = ModelConfig(
    name="deepseek-moe-reduced", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=32, vocab_size=512,
    moe_num_experts=8, moe_top_k=2, moe_num_shared=2, moe_d_ff=32,
    first_dense_ff=128, mlp_act="swiglu",
)
