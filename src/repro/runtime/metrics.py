"""Prometheus text-format telemetry export for the cache controllers.

One module, three layers:

* :class:`Metric` + :func:`render` — a tiny, dependency-free renderer of
  the Prometheus text exposition format v0.0.4 (``# HELP`` / ``# TYPE``
  headers, ``name{label="v"} value`` samples, stable ordering, label
  escaping). No client library exists in the image, and none is needed:
  the format is line-oriented text.
* :func:`collect_cache` / :func:`collect_serving` — adapters that turn a
  controller's per-VM stats dicts (:class:`repro.core.controller
  .EticaCache` / ``PartitionedSingleLevelCache``) or a serving manager's
  :class:`repro.kvcache.manager.Stats` into metric families, including
  the background cleaner's channels (``flushes``, ``evict_flushes``,
  ``dirty_resident``), the popularity-table overflow counter
  (``pop_drops``), the classifier bypass channel, and — when a
  classifier is configured — per-(VM, IO-class) served hit/miss counts.
* :func:`parse_exposition` — a strict parser/validator for the same
  format, used by the golden tests and the fig14 self-check to assert
  the emitted text round-trips.

Metric names are a stable public contract (tests/test_metrics_export.py
pins them); extend, do not rename.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = [
    "Metric", "render", "render_cache", "render_serving",
    "collect_cache", "collect_serving", "parse_exposition",
]

_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*\Z")
_LABEL_RE = re.compile(r"[a-zA-Z_][a-zA-Z0-9_]*\Z")
_SAMPLE_RE = re.compile(
    r"(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?"
    r"\s+(?P<value>\S+)\Z")
_LABEL_PAIR_RE = re.compile(
    r'\s*(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]|\\.)*)"\s*(?:,|\Z)')


@dataclasses.dataclass
class Metric:
    """One metric family: a name, a type, help text, and samples.

    ``samples`` is a list of ``(labels, value)`` pairs where ``labels``
    is a plain ``{label: value}`` dict (may be empty)."""
    name: str
    mtype: str                     # "counter" | "gauge"
    help: str
    samples: list = dataclasses.field(default_factory=list)

    def add(self, labels: dict, value) -> "Metric":
        self.samples.append((dict(labels), value))
        return self


def _escape_label(v) -> str:
    return (str(v).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _escape_help(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n")


def _format_value(v) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 2**53:
        return str(int(f))
    return repr(f)


def render(metrics: list) -> str:
    """Render metric families as Prometheus text exposition v0.0.4.

    Deterministic: families render in list order, samples in insertion
    order, label keys in insertion order — collectors insert in a fixed
    order, so the full text is stable run to run (the golden tests rely
    on this)."""
    out = []
    for m in metrics:
        if not _NAME_RE.match(m.name):
            raise ValueError(f"bad metric name: {m.name!r}")
        if m.mtype not in ("counter", "gauge"):
            raise ValueError(f"bad metric type: {m.mtype!r}")
        out.append(f"# HELP {m.name} {_escape_help(m.help)}")
        out.append(f"# TYPE {m.name} {m.mtype}")
        for labels, value in m.samples:
            for k in labels:
                if not _LABEL_RE.match(k):
                    raise ValueError(f"bad label name: {k!r}")
            lbl = ",".join(f'{k}="{_escape_label(v)}"'
                           for k, v in labels.items())
            lbl = "{" + lbl + "}" if lbl else ""
            out.append(f"{m.name}{lbl} {_format_value(value)}")
    return "\n".join(out) + "\n"


def parse_exposition(text: str) -> dict:
    """Parse (and thereby validate) Prometheus exposition text.

    Returns ``{name: {"type": t, "help": h, "samples": {label_key:
    value}}}`` with ``label_key`` a tuple of sorted ``(k, v)`` pairs.
    Raises ``ValueError`` on malformed lines, samples without a
    preceding ``# TYPE``, or duplicate samples."""
    families: dict = {}
    current = None
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            _, _, rest = line.partition("# HELP ")
            name, _, help_text = rest.partition(" ")
            families.setdefault(name, {"type": None, "help": None,
                                       "samples": {}})
            families[name]["help"] = help_text
            continue
        if line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            name, _, mtype = rest.partition(" ")
            if mtype not in ("counter", "gauge", "histogram", "summary",
                             "untyped"):
                raise ValueError(f"line {ln}: bad TYPE {mtype!r}")
            families.setdefault(name, {"type": None, "help": None,
                                       "samples": {}})
            families[name]["type"] = mtype
            current = name
            continue
        if line.startswith("#"):
            continue                           # comment
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: malformed sample {line!r}")
        name = m.group("name")
        if name not in families or families[name]["type"] is None:
            raise ValueError(f"line {ln}: sample {name!r} without # TYPE")
        if current != name:
            raise ValueError(f"line {ln}: sample {name!r} outside its "
                             f"family block")
        labels = {}
        raw = m.group("labels")
        if raw is not None:
            pos = 0
            while pos < len(raw):
                pm = _LABEL_PAIR_RE.match(raw, pos)
                if not pm:
                    raise ValueError(f"line {ln}: malformed labels {raw!r}")
                labels[pm.group("k")] = pm.group("v")
                pos = pm.end()
        key = tuple(sorted(labels.items()))
        if key in families[name]["samples"]:
            raise ValueError(f"line {ln}: duplicate sample {name}{key}")
        families[name]["samples"][key] = float(m.group("value"))
    return families


# ---------------------------------------------------------------------------
# collectors
# ---------------------------------------------------------------------------

def _stat(d: dict, key: str) -> float:
    return float(d.get(key, 0.0))


def collect_cache(cache, prefix: str = "etica") -> list:
    """Metric families from an interval controller — works for both
    :class:`~repro.core.controller.EticaCache` and the one-level
    :class:`~repro.core.controller.PartitionedSingleLevelCache` (the
    DRAM-level hit family simply stays 0 there).

    Every family emits a sample for every VM even when the count is 0,
    so scrapes are fixed-shape and rate() never sees series appear."""
    stats = cache.stats
    vms = [str(v) for v in range(len(stats))]
    req = Metric(f"{prefix}_requests_total", "counter",
                 "Requests entering the cache datapath, by operation.")
    hits = Metric(f"{prefix}_hits_total", "counter",
                  "Served cache hits, by level and operation.")
    ssd_w = Metric(f"{prefix}_ssd_writes_total", "counter",
                   "Blocks committed to the SSD level (endurance metric).")
    disk_r = Metric(f"{prefix}_disk_reads_total", "counter",
                    "Blocks read from the disk subsystem.")
    disk_w = Metric(f"{prefix}_disk_writes_total", "counter",
                    "Blocks written to the disk subsystem "
                    "(misses, flushes, cleaning).")
    flushes = Metric(f"{prefix}_flushes_total", "counter",
                     "Dirty blocks flushed by the background cleaner.")
    ev_fl = Metric(f"{prefix}_evict_flushes_total", "counter",
                   "Dirty blocks flushed by eviction or resize.")
    dirty = Metric(f"{prefix}_dirty_resident", "gauge",
                   "Dirty SSD blocks resident after the last "
                   "maintenance interval.")
    byp = Metric(f"{prefix}_bypassed_total", "counter",
                 "Requests routed straight to disk by the IO classifier.")
    drops = Metric(f"{prefix}_pop_drops_total", "counter",
                   "Popularity-table merge-overflow drops.")
    lat = Metric(f"{prefix}_latency_seconds_total", "counter",
                 "Modeled service latency, summed over requests.")
    for v, d in zip(vms, stats):
        req.add({"vm": v, "op": "read"}, _stat(d, "reads"))
        req.add({"vm": v, "op": "write"}, _stat(d, "writes"))
        hits.add({"vm": v, "level": "dram", "op": "read"},
                 _stat(d, "read_hits_l1"))
        hits.add({"vm": v, "level": "ssd", "op": "read"},
                 _stat(d, "read_hits_l2"))
        hits.add({"vm": v, "level": "ssd", "op": "write"},
                 _stat(d, "write_hits_l2"))
        ssd_w.add({"vm": v}, _stat(d, "cache_writes_l2"))
        disk_r.add({"vm": v}, _stat(d, "disk_reads"))
        disk_w.add({"vm": v}, _stat(d, "disk_writes"))
        flushes.add({"vm": v}, _stat(d, "flushes"))
        ev_fl.add({"vm": v}, _stat(d, "evict_flushes"))
        dirty.add({"vm": v}, _stat(d, "dirty_resident"))
        byp.add({"vm": v}, _stat(d, "bypassed"))
        drops.add({"vm": v}, _stat(d, "pop_drops"))
        lat.add({"vm": v}, _stat(d, "latency_sum"))
    out = [req, hits, ssd_w, disk_r, disk_w, flushes, ev_fl, dirty, byp,
           drops, lat]
    if getattr(cache, "classifier", None) is not None and \
            hasattr(cache, "cls_hits"):
        names = [c.name for c in cache.classifier.classes]
        cls = Metric(f"{prefix}_class_requests_total", "counter",
                     "Served requests by VM, IO class, and hit/miss "
                     "outcome (bypassed requests excluded).")
        for v in range(len(stats)):
            for ci, cname in enumerate(names):
                cls.add({"vm": str(v), "io_class": cname, "result": "hit"},
                        int(cache.cls_hits[v, ci]))
                cls.add({"vm": str(v), "io_class": cname, "result": "miss"},
                        int(cache.cls_miss[v, ci]))
        out.append(cls)
    return out


def collect_serving(mgr, prefix: str = "etica_serving") -> list:
    """Metric families from a :class:`~repro.kvcache.manager
    .TwoTierKVManager` — the serving analog of :func:`collect_cache`,
    including the deferred write-back channels."""
    s = mgr.stats
    def counter(name, help_, value):
        return Metric(f"{prefix}_{name}", "counter", help_).add({}, value)
    dirty = Metric(f"{prefix}_dirty_resident", "gauge",
                   "Uncommitted (dirty) KV pages resident in HBM.")
    dirty.add({}, s.dirty_resident)
    return [
        counter("activations_total",
                "Session activations (tier-1 reads).", s.activations),
        counter("hits_total",
                "Fully HBM-resident activations.", s.hits),
        counter("appends_total",
                "KV pages generated (WBWO commits).", s.appends),
        counter("dma_read_bytes_total",
                "Host-to-HBM DMA bytes (misses, promotions).",
                s.dma_read_bytes),
        counter("dma_write_bytes_total",
                "HBM-to-host DMA bytes (the wear analog).",
                s.dma_write_bytes),
        counter("latency_seconds_total",
                "Modeled DMA latency, summed.", s.latency_s),
        counter("sessions_ended_total",
                "Retired sessions (churn).", s.sessions_ended),
        counter("pop_drops_total",
                "Popularity-table merge-overflow drops.", s.pop_drops),
        counter("flushes_total",
                "Dirty pages committed by the background cleaner.",
                s.flushes),
        counter("evict_flushes_total",
                "Dirty pages committed on forced slot release.",
                s.evict_flushes),
        counter("dirty_dropped_total",
                "Dirty pages retired with their session (no DMA).",
                s.dirty_dropped),
        dirty,
    ]


def render_cache(cache, prefix: str = "etica") -> str:
    return render(collect_cache(cache, prefix))


def render_serving(mgr, prefix: str = "etica_serving") -> str:
    return render(collect_serving(mgr, prefix))
