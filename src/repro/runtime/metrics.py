"""Prometheus text-format telemetry export for the cache controllers.

One module, three layers:

* :class:`Metric` + :func:`render` — a tiny, dependency-free renderer of
  the Prometheus text exposition format v0.0.4 (``# HELP`` / ``# TYPE``
  headers, ``name{label="v"} value`` samples, stable ordering, label
  escaping). No client library exists in the image, and none is needed:
  the format is line-oriented text. Counter, gauge, and — for the
  dispatch-span timers — histogram families (:class:`HistogramValue`
  renders the standard cumulative ``_bucket{le=...}`` / ``_sum`` /
  ``_count`` triplet).
* :func:`collect_cache` / :func:`collect_serving` — adapters that turn a
  controller's per-VM stats dicts (:class:`repro.core.controller
  .EticaCache` / ``PartitionedSingleLevelCache``) or a serving manager's
  :class:`repro.kvcache.manager.Stats` into metric families, including
  the background cleaner's channels (``flushes``, ``evict_flushes``,
  ``dirty_resident``), the popularity-table overflow counter
  (``pop_drops``), the classifier bypass channel, and — when a
  classifier is configured — per-(VM, IO-class) served hit/miss counts.
* :func:`collect_telemetry` — adapter over a
  :class:`repro.runtime.telemetry.TelemetryRecorder`: the
  ``etica_dispatch_seconds`` span histograms, the journal row counter,
  the last interval's per-VM request/hit deltas, and the LBICA-style
  ``etica_overloaded`` flags.
* :func:`parse_exposition` — a strict parser/validator for the same
  format, used by the golden tests and the fig14 self-check to assert
  the emitted text round-trips. Histogram families accept exactly the
  suffixed sample triplet and are checked for cumulative-monotone
  buckets, a ``+Inf`` bucket, and bucket/count agreement.

Metric names are a stable public contract (tests/test_metrics_export.py
pins them); extend, do not rename.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = [
    "HistogramValue", "Metric", "render", "render_cache", "render_serving",
    "collect_cache", "collect_serving", "collect_telemetry",
    "parse_exposition",
]

_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*\Z")
_LABEL_RE = re.compile(r"[a-zA-Z_][a-zA-Z0-9_]*\Z")
_SAMPLE_RE = re.compile(
    r"(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?"
    r"\s+(?P<value>\S+)\Z")
_LABEL_PAIR_RE = re.compile(
    r'\s*(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]|\\.)*)"\s*(?:,|\Z)')


@dataclasses.dataclass
class Metric:
    """One metric family: a name, a type, help text, and samples.

    ``samples`` is a list of ``(labels, value)`` pairs where ``labels``
    is a plain ``{label: value}`` dict (may be empty). For histogram
    families the value must be a :class:`HistogramValue`; for counters
    and gauges it must be a plain number."""
    name: str
    mtype: str                     # "counter" | "gauge" | "histogram"
    help: str
    samples: list = dataclasses.field(default_factory=list)

    def add(self, labels: dict, value) -> "Metric":
        self.samples.append((dict(labels), value))
        return self


@dataclasses.dataclass(frozen=True)
class HistogramValue:
    """One histogram sample: fixed finite bucket bounds, *per-bucket*
    (non-cumulative) counts with a trailing +Inf overflow slot, and the
    running sum of observations. The renderer emits the standard
    cumulative ``_bucket`` series plus ``_sum`` / ``_count``."""
    le: tuple                      # finite upper bounds, strictly ascending
    counts: tuple                  # len(le) + 1; last slot = +Inf overflow
    sum: float

    def validate(self) -> None:
        if len(self.counts) != len(self.le) + 1:
            raise ValueError(
                f"histogram needs {len(self.le) + 1} bucket counts "
                f"(incl. +Inf), got {len(self.counts)}")
        if any(b >= a for b, a in zip(self.le, self.le[1:])):
            raise ValueError(f"histogram bounds not ascending: {self.le}")
        if any(c < 0 for c in self.counts):
            raise ValueError(f"negative bucket count in {self.counts}")

    @property
    def count(self) -> int:
        return sum(self.counts)


def _escape_label(v) -> str:
    return (str(v).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _escape_help(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n")


def _format_value(v) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 2**53:
        return str(int(f))
    return repr(f)


def render(metrics: list) -> str:
    """Render metric families as Prometheus text exposition v0.0.4.

    Deterministic: families render in list order, samples in insertion
    order, label keys in insertion order — collectors insert in a fixed
    order, so the full text is stable run to run (the golden tests rely
    on this)."""
    out = []
    for m in metrics:
        if not _NAME_RE.match(m.name):
            raise ValueError(f"bad metric name: {m.name!r}")
        if m.mtype not in ("counter", "gauge", "histogram"):
            raise ValueError(f"bad metric type: {m.mtype!r}")
        out.append(f"# HELP {m.name} {_escape_help(m.help)}")
        out.append(f"# TYPE {m.name} {m.mtype}")
        for labels, value in m.samples:
            for k in labels:
                if not _LABEL_RE.match(k):
                    raise ValueError(f"bad label name: {k!r}")
            if m.mtype == "histogram":
                if not isinstance(value, HistogramValue):
                    raise ValueError(
                        f"{m.name}: histogram sample must be a "
                        f"HistogramValue, got {type(value).__name__}")
                if "le" in labels:
                    raise ValueError(f"{m.name}: reserved label 'le'")
                value.validate()
                bounds = tuple(_format_value(b) for b in value.le) + ("+Inf",)
                cum = 0
                for bound, c in zip(bounds, value.counts):
                    cum += int(c)
                    pairs = list(labels.items()) + [("le", bound)]
                    lbl = ",".join(f'{k}="{_escape_label(v)}"'
                                   for k, v in pairs)
                    out.append(f"{m.name}_bucket{{{lbl}}} {cum}")
                lbl = ",".join(f'{k}="{_escape_label(v)}"'
                               for k, v in labels.items())
                lbl = "{" + lbl + "}" if lbl else ""
                out.append(f"{m.name}_sum{lbl} {_format_value(value.sum)}")
                out.append(f"{m.name}_count{lbl} {cum}")
                continue
            if isinstance(value, HistogramValue):
                raise ValueError(
                    f"{m.name}: {m.mtype} sample cannot be a HistogramValue")
            lbl = ",".join(f'{k}="{_escape_label(v)}"'
                           for k, v in labels.items())
            lbl = "{" + lbl + "}" if lbl else ""
            out.append(f"{m.name}{lbl} {_format_value(value)}")
    return "\n".join(out) + "\n"


def parse_exposition(text: str) -> dict:
    """Parse (and thereby validate) Prometheus exposition text.

    Returns ``{name: {"type": t, "help": h, "samples": {label_key:
    value}}}`` with ``label_key`` a tuple of sorted ``(k, v)`` pairs.
    For histogram families the only legal sample names are
    ``name_bucket`` (with an ``le`` label), ``name_sum`` and
    ``name_count``; their keys are prefixed with ``("bucket"|"sum"|
    "count",)`` and the bucket series is validated (cumulative
    non-decreasing, ``+Inf`` present and equal to ``_count``). Raises
    ``ValueError`` on malformed lines, samples without a preceding
    ``# TYPE``, or duplicate samples."""
    families: dict = {}
    current = None
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            _, _, rest = line.partition("# HELP ")
            name, _, help_text = rest.partition(" ")
            families.setdefault(name, {"type": None, "help": None,
                                       "samples": {}})
            families[name]["help"] = help_text
            continue
        if line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            name, _, mtype = rest.partition(" ")
            if mtype not in ("counter", "gauge", "histogram", "summary",
                             "untyped"):
                raise ValueError(f"line {ln}: bad TYPE {mtype!r}")
            families.setdefault(name, {"type": None, "help": None,
                                       "samples": {}})
            families[name]["type"] = mtype
            current = name
            continue
        if line.startswith("#"):
            continue                           # comment
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: malformed sample {line!r}")
        name, suffix = m.group("name"), None
        if name not in families or families[name]["type"] is None:
            for sfx in ("_bucket", "_sum", "_count"):
                base = name[:-len(sfx)]
                if name.endswith(sfx) and \
                        families.get(base, {}).get("type") == "histogram":
                    name, suffix = base, sfx[1:]
                    break
            else:
                raise ValueError(f"line {ln}: sample {m.group('name')!r} "
                                 f"without # TYPE")
        if families[name]["type"] == "histogram" and suffix is None:
            raise ValueError(f"line {ln}: histogram family {name!r} only "
                             f"emits _bucket/_sum/_count samples")
        if current != name:
            raise ValueError(f"line {ln}: sample {name!r} outside its "
                             f"family block")
        labels = {}
        raw = m.group("labels")
        if raw is not None:
            pos = 0
            while pos < len(raw):
                pm = _LABEL_PAIR_RE.match(raw, pos)
                if not pm:
                    raise ValueError(f"line {ln}: malformed labels {raw!r}")
                labels[pm.group("k")] = pm.group("v")
                pos = pm.end()
        if suffix == "bucket" and "le" not in labels:
            raise ValueError(f"line {ln}: _bucket sample without 'le'")
        key = tuple(sorted(labels.items()))
        if suffix is not None:
            key = (suffix,) + key
        if key in families[name]["samples"]:
            raise ValueError(f"line {ln}: duplicate sample {name}{key}")
        families[name]["samples"][key] = float(m.group("value"))
    for name, fam in families.items():
        if fam["type"] == "histogram" and fam["samples"]:
            _validate_histogram_family(name, fam["samples"])
    return families


def _validate_histogram_family(name: str, samples: dict) -> None:
    """Check each label group's bucket series is cumulative
    non-decreasing, carries ``+Inf``, and agrees with ``_count``."""
    groups: dict = {}
    for key, value in samples.items():
        suffix, labels = key[0], dict(key[1:])
        base = tuple(sorted((k, v) for k, v in labels.items() if k != "le"))
        g = groups.setdefault(base, {"buckets": {}, "sum": None,
                                     "count": None})
        if suffix == "bucket":
            g["buckets"][labels["le"]] = value
        else:
            g[suffix] = value
    for base, g in groups.items():
        where = f"{name}{dict(base)}"
        if g["sum"] is None or g["count"] is None:
            raise ValueError(f"{where}: missing _sum/_count")
        if "+Inf" not in g["buckets"]:
            raise ValueError(f"{where}: missing le=\"+Inf\" bucket")
        les = sorted(g["buckets"],
                     key=lambda s: float("inf") if s == "+Inf" else float(s))
        series = [g["buckets"][le] for le in les]
        if any(b < a for a, b in zip(series, series[1:])):
            raise ValueError(f"{where}: bucket series not cumulative")
        if series[-1] != g["count"]:
            raise ValueError(f"{where}: +Inf bucket {series[-1]} != "
                             f"_count {g['count']}")


# ---------------------------------------------------------------------------
# collectors
# ---------------------------------------------------------------------------

def _stat(d: dict, key: str) -> float:
    return float(d.get(key, 0.0))


def collect_cache(cache, prefix: str = "etica") -> list:
    """Metric families from an interval controller — works for both
    :class:`~repro.core.controller.EticaCache` and the one-level
    :class:`~repro.core.controller.PartitionedSingleLevelCache` (the
    DRAM-level hit family simply stays 0 there).

    Every family emits a sample for every VM even when the count is 0,
    so scrapes are fixed-shape and rate() never sees series appear."""
    stats = cache.stats
    vms = [str(v) for v in range(len(stats))]
    req = Metric(f"{prefix}_requests_total", "counter",
                 "Requests entering the cache datapath, by operation.")
    hits = Metric(f"{prefix}_hits_total", "counter",
                  "Served cache hits, by level and operation.")
    ssd_w = Metric(f"{prefix}_ssd_writes_total", "counter",
                   "Blocks committed to the SSD level (endurance metric).")
    disk_r = Metric(f"{prefix}_disk_reads_total", "counter",
                    "Blocks read from the disk subsystem.")
    disk_w = Metric(f"{prefix}_disk_writes_total", "counter",
                    "Blocks written to the disk subsystem "
                    "(misses, flushes, cleaning).")
    flushes = Metric(f"{prefix}_flushes_total", "counter",
                     "Dirty blocks flushed by the background cleaner.")
    ev_fl = Metric(f"{prefix}_evict_flushes_total", "counter",
                   "Dirty blocks flushed by eviction or resize.")
    dirty = Metric(f"{prefix}_dirty_resident", "gauge",
                   "Dirty SSD blocks resident after the last "
                   "maintenance interval.")
    byp = Metric(f"{prefix}_bypassed_total", "counter",
                 "Requests routed straight to disk by the IO classifier.")
    drops = Metric(f"{prefix}_pop_drops_total", "counter",
                   "Popularity-table merge-overflow drops.")
    lat = Metric(f"{prefix}_latency_seconds_total", "counter",
                 "Modeled service latency, summed over requests.")
    for v, d in zip(vms, stats):
        req.add({"vm": v, "op": "read"}, _stat(d, "reads"))
        req.add({"vm": v, "op": "write"}, _stat(d, "writes"))
        hits.add({"vm": v, "level": "dram", "op": "read"},
                 _stat(d, "read_hits_l1"))
        hits.add({"vm": v, "level": "ssd", "op": "read"},
                 _stat(d, "read_hits_l2"))
        hits.add({"vm": v, "level": "ssd", "op": "write"},
                 _stat(d, "write_hits_l2"))
        ssd_w.add({"vm": v}, _stat(d, "cache_writes_l2"))
        disk_r.add({"vm": v}, _stat(d, "disk_reads"))
        disk_w.add({"vm": v}, _stat(d, "disk_writes"))
        flushes.add({"vm": v}, _stat(d, "flushes"))
        ev_fl.add({"vm": v}, _stat(d, "evict_flushes"))
        dirty.add({"vm": v}, _stat(d, "dirty_resident"))
        byp.add({"vm": v}, _stat(d, "bypassed"))
        drops.add({"vm": v}, _stat(d, "pop_drops"))
        lat.add({"vm": v}, _stat(d, "latency_sum"))
    out = [req, hits, ssd_w, disk_r, disk_w, flushes, ev_fl, dirty, byp,
           drops, lat]
    if getattr(cache, "classifier", None) is not None and \
            hasattr(cache, "cls_hits"):
        names = [c.name for c in cache.classifier.classes]
        cls = Metric(f"{prefix}_class_requests_total", "counter",
                     "Served requests by VM, IO class, and hit/miss "
                     "outcome (bypassed requests excluded).")
        for v in range(len(stats)):
            for ci, cname in enumerate(names):
                cls.add({"vm": str(v), "io_class": cname, "result": "hit"},
                        int(cache.cls_hits[v, ci]))
                cls.add({"vm": str(v), "io_class": cname, "result": "miss"},
                        int(cache.cls_miss[v, ci]))
        out.append(cls)
    return out


def collect_serving(mgr, prefix: str = "etica_serving") -> list:
    """Metric families from a :class:`~repro.kvcache.manager
    .TwoTierKVManager` — the serving analog of :func:`collect_cache`,
    including the deferred write-back channels."""
    s = mgr.stats
    def counter(name, help_, value):
        return Metric(f"{prefix}_{name}", "counter", help_).add({}, value)
    dirty = Metric(f"{prefix}_dirty_resident", "gauge",
                   "Uncommitted (dirty) KV pages resident in HBM.")
    dirty.add({}, s.dirty_resident)
    return [
        counter("activations_total",
                "Session activations (tier-1 reads).", s.activations),
        counter("hits_total",
                "Fully HBM-resident activations.", s.hits),
        counter("appends_total",
                "KV pages generated (WBWO commits).", s.appends),
        counter("dma_read_bytes_total",
                "Host-to-HBM DMA bytes (misses, promotions).",
                s.dma_read_bytes),
        counter("dma_write_bytes_total",
                "HBM-to-host DMA bytes (the wear analog).",
                s.dma_write_bytes),
        counter("latency_seconds_total",
                "Modeled DMA latency, summed.", s.latency_s),
        counter("sessions_ended_total",
                "Retired sessions (churn).", s.sessions_ended),
        counter("pop_drops_total",
                "Popularity-table merge-overflow drops.", s.pop_drops),
        counter("flushes_total",
                "Dirty pages committed by the background cleaner.",
                s.flushes),
        counter("evict_flushes_total",
                "Dirty pages committed on forced slot release.",
                s.evict_flushes),
        counter("dirty_dropped_total",
                "Dirty pages retired with their session (no DMA).",
                s.dirty_dropped),
        dirty,
    ]


def _vector(x) -> tuple[bool, list]:
    """(is_vector, values) for a journal cell that may be a numpy array,
    a numpy scalar, or a plain number — without importing numpy."""
    try:
        return True, list(x)
    except TypeError:
        return False, [x]


def collect_telemetry(rec, prefix: str = "etica",
                      label: str = "vm") -> list:
    """Metric families from a :class:`~repro.runtime.telemetry
    .TelemetryRecorder`: the dispatch-span wall-clock histograms, the
    journal row counter, and the *last* recorded interval's request/hit
    deltas and LBICA-style overload flags (``{prefix}_overloaded``).
    ``label`` names the per-entity axis (``vm`` for the block-cache
    controllers, ``tenant`` for the serving manager)."""
    hist = Metric(f"{prefix}_dispatch_seconds", "histogram",
                  "Wall-clock seconds per fused dispatch span "
                  "(opt-in timers; block_until_ready at span close).")
    for name in sorted(rec.spans):
        s = rec.spans[name]
        hist.add({"span": name},
                 HistogramValue(tuple(s.buckets),
                                tuple(int(c) for c in s.counts),
                                float(s.total)))
    ivals = Metric(f"{prefix}_telemetry_intervals_total", "counter",
                   "Interval samples appended to the telemetry journal.")
    ivals.add({}, rec.journal.total)
    i_req = Metric(f"{prefix}_interval_requests", "gauge",
                   "Requests observed in the last telemetry interval.")
    i_hit = Metric(f"{prefix}_interval_hits", "gauge",
                   "Cache hits observed in the last telemetry interval.")
    over = Metric(f"{prefix}_overloaded", "gauge",
                  "LBICA-style overload flag from the last interval "
                  "(windowed hit-ratio collapse or queue pressure).")
    if rec.journal.total:
        row = rec.journal.last_row()
        for metric, col in ((i_req, "requests"), (i_hit, "hits"),
                            (over, "overloaded")):
            if col not in rec.journal:
                continue
            vec, values = _vector(row[col])
            for i, v in enumerate(values):
                metric.add({label: str(i)} if vec else {}, float(v))
    return [hist, ivals, i_req, i_hit, over]


def render_cache(cache, prefix: str = "etica") -> str:
    return render(collect_cache(cache, prefix))


def render_serving(mgr, prefix: str = "etica_serving") -> str:
    return render(collect_serving(mgr, prefix))
