"""Interval-resolution telemetry runtime for the cache controllers.

ETICA's claims are *trajectories over maintenance intervals* (performance
and endurance per §6), so the observability layer records one structured
sample per interval rather than a single end-of-run aggregate. Three
pieces, all dependency-free (numpy + stdlib; jax is imported lazily and
only by the opt-in span timers):

* :class:`Journal` — a bounded columnar ring of per-interval samples
  (O(window) host memory regardless of run length) with an optional
  JSONL *spill*: every appended row is also written as one JSON line, so
  the full trajectory survives on disk while memory stays bounded.
  :func:`load_journal` reads a spill file back into stacked columns.
* :class:`TelemetryRecorder` — the object the controllers thread through
  their interval loops. ``sample_cache`` / ``sample_serving`` turn the
  host-side stats the controller *already fetched* into per-interval
  deltas — the recorder performs no device→host transfers of its own, so
  ``telemetry`` on vs off is bit-identical and sync-count-identical.
  Opt-in extras: ``span_timing`` wall-clock histograms around the fused
  dispatches (``span()`` calls ``jax.block_until_ready`` at close, so it
  IS documented as adding syncs), and a ``jax.profiler.trace`` hook
  (``profile()``).
* :func:`overload_flags` — LBICA-style per-interval overload *detection*
  (PAPERS.md): a VM/tenant is flagged when its windowed hit ratio
  collapses below ``drop × best-recent-baseline`` or its dirty/used
  occupancy presses against its allocation. Detection only — the flags
  are exported (``etica_overloaded``) and journaled; rebalancing actions
  remain a ROADMAP item.

The exporter side lives in :mod:`repro.runtime.metrics`
(``collect_telemetry`` renders the span histograms and the last
interval's flags) and :mod:`repro.runtime.http` (live scrape endpoint).
"""
from __future__ import annotations

import bisect
import collections
import contextlib
import dataclasses
import json
import time

import numpy as np

__all__ = [
    "DISPATCH_BUCKETS", "Journal", "OverloadConfig", "SpanStats",
    "TelemetryRecorder", "load_journal", "overload_flags",
    "summarize_journal",
]

# Golden-pinned histogram bucket bounds (seconds) for the dispatch span
# timers — `etica_dispatch_seconds` renders exactly these `le` edges.
DISPATCH_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
                    0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5)

# Cumulative stats-dict keys sampled as per-interval deltas by
# ``sample_cache`` (the controllers maintain exactly these host-side).
CACHE_DELTA_KEYS = ("reads", "writes", "read_hits_l1", "read_hits_l2",
                    "write_hits_l2", "cache_writes_l2", "disk_reads",
                    "disk_writes", "flushes", "evict_flushes", "bypassed",
                    "pop_drops", "latency_sum")

SERVING_DELTA_KEYS = ("activations", "hits", "appends", "dma_read_bytes",
                      "dma_write_bytes", "latency_s", "sessions_ended",
                      "pop_drops", "flushes", "evict_flushes",
                      "dirty_dropped")


# ---------------------------------------------------------------------------
# bounded columnar journal with JSONL spill
# ---------------------------------------------------------------------------

class Journal:
    """Bounded columnar ring of per-interval rows.

    ``append(row)`` takes a ``{name: scalar | ndarray}`` dict; each column
    keeps the last ``window`` values in a preallocated ``[window, ...]``
    ring (shape and dtype fixed by the column's first appearance), so
    memory is O(window · columns), never O(run length). With ``spill``
    set, every row is additionally written as one JSON line
    (``{"i": <row index>, <column>: <value.tolist()>, ...}``) and flushed
    immediately, so a live scrape/tail sees rows as they land and the
    full trajectory survives the ring.
    """

    def __init__(self, window: int = 512, spill=None):
        if window <= 0:
            raise ValueError("journal window must be positive")
        self.window = int(window)
        self.total = 0                 # rows ever appended
        self._cols: dict[str, np.ndarray] = {}
        self._spill_path = spill
        self._spill_f = None

    def __contains__(self, name: str) -> bool:
        return name in self._cols

    def __len__(self) -> int:
        return self.total

    @property
    def retained(self) -> int:
        """Rows currently held in memory (≤ ``window``)."""
        return min(self.total, self.window)

    @property
    def columns(self) -> tuple[str, ...]:
        return tuple(self._cols)

    def append(self, row: dict) -> None:
        pos = self.total % self.window
        for name, value in row.items():
            a = np.asarray(value)
            buf = self._cols.get(name)
            if buf is None:
                buf = np.zeros((self.window,) + a.shape, a.dtype)
                self._cols[name] = buf
            elif buf.shape[1:] != a.shape:
                raise ValueError(
                    f"journal column {name!r}: shape {a.shape} != "
                    f"established {buf.shape[1:]}")
            buf[pos] = a
        self.total += 1
        if self._spill_path is not None:
            if self._spill_f is None:
                # truncate: one journal owns one spill file (row indices
                # restart at 0, and load_journal expects one schema)
                self._spill_f = open(self._spill_path, "w")
            line = {"i": self.total - 1}
            line.update({k: np.asarray(v).tolist() for k, v in row.items()})
            self._spill_f.write(json.dumps(line) + "\n")
            self._spill_f.flush()

    def _order(self) -> np.ndarray:
        n = self.retained
        if self.total <= self.window:
            return np.arange(n)
        pos = self.total % self.window
        return np.r_[pos:self.window, 0:pos]

    def column(self, name: str) -> np.ndarray:
        """Retained values of one column, oldest first — ``[retained, ...]``."""
        return self._cols[name][self._order()]

    def last_row(self) -> dict:
        """The most recent row as ``{name: ndarray | scalar}``."""
        if self.total == 0:
            raise IndexError("empty journal")
        pos = (self.total - 1) % self.window
        return {k: buf[pos] for k, buf in self._cols.items()}

    def rows(self) -> list[dict]:
        """Retained rows oldest-first (each a plain column dict)."""
        order = self._order()
        return [{k: buf[i] for k, buf in self._cols.items()} for i in order]

    def close(self) -> None:
        if self._spill_f is not None:
            self._spill_f.close()
            self._spill_f = None


def load_journal(path) -> dict[str, np.ndarray]:
    """Read a JSONL spill file back into ``{column: [rows, ...] ndarray}``.

    Inverse of the spill writer: columns stack in row order; the ``"i"``
    row index becomes an int column. Rows missing a column that other
    rows carry are rejected — spills are fixed-schema by construction.
    """
    rows = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            if not line.strip():
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}: line {ln}: {e}") from None
    if not rows:
        return {}
    keys = set(rows[0])
    for ln, r in enumerate(rows, 1):
        if set(r) != keys:
            raise ValueError(f"{path}: row {ln} schema {sorted(r)} != "
                             f"{sorted(keys)}")
    return {k: np.asarray([r[k] for r in rows]) for k in keys}


# ---------------------------------------------------------------------------
# dispatch-span histograms (opt-in: adds block_until_ready syncs)
# ---------------------------------------------------------------------------

class SpanStats:
    """One wall-clock histogram: fixed bucket edges, per-bucket counts
    (the last slot is the +Inf overflow bucket), running sum."""

    __slots__ = ("buckets", "counts", "total", "n")

    def __init__(self, buckets=DISPATCH_BUCKETS):
        self.buckets = tuple(buckets)
        self.counts = np.zeros(len(self.buckets) + 1, np.int64)
        self.total = 0.0
        self.n = 0

    def observe(self, seconds: float) -> None:
        self.counts[bisect.bisect_left(self.buckets, seconds)] += 1
        self.total += float(seconds)
        self.n += 1


class _Span:
    """Times a block and blocks on the value handed to :meth:`ready` at
    close — the explicit sync that makes the measurement mean "dispatch
    complete", and the reason span timing is opt-in."""

    __slots__ = ("_rec", "_name", "_t0", "_val")

    def __init__(self, rec, name):
        self._rec = rec
        self._name = name
        self._val = None

    def ready(self, value) -> None:
        """Register the dispatch output to ``block_until_ready`` on."""
        self._val = value

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        if exc[0] is None:
            if self._val is not None:
                import jax
                jax.block_until_ready(self._val)
            self._rec._observe_span(self._name,
                                    time.perf_counter() - self._t0)
        return False


class _NullSpan:
    """Shared no-op span: zero overhead, zero added syncs."""

    __slots__ = ()

    def ready(self, value) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


# ---------------------------------------------------------------------------
# LBICA-style overload detection (detection only — no rebalancing)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OverloadConfig:
    """Windowed hit-ratio-collapse + queue-pressure detection knobs."""
    window: int = 8          # intervals of baseline history per VM/tenant
    drop: float = 0.6        # flag when ratio < drop * best recent ratio
    min_requests: int = 32   # interval request floor for a verdict
    pressure: float = 0.95   # occupancy/allocation fraction that flags


def overload_flags(prev_hits: np.ndarray, prev_reqs: np.ndarray,
                   hits: np.ndarray, reqs: np.ndarray,
                   pressure: np.ndarray, ocfg: OverloadConfig) -> np.ndarray:
    """Per-entity overload flags for one interval.

    ``prev_hits``/``prev_reqs`` are ``[n, V]`` per-interval deltas of the
    up-to-``ocfg.window`` preceding intervals; ``hits``/``reqs`` the
    current interval's ``[V]`` deltas; ``pressure`` a ``[V]`` bool of
    queue-pressure verdicts the caller computed (e.g. dirty occupancy vs
    allocation). An entity is overloaded when its current hit ratio falls
    below ``drop ×`` the best ratio any *qualified* baseline interval
    (``>= min_requests`` requests) achieved, or when pressure flags it.
    Deterministic and pure — exactness-tested on synthetic collapses.
    """
    hits = np.asarray(hits, np.float64)
    reqs = np.asarray(reqs, np.float64)
    flags = np.zeros(hits.shape, bool)
    prev_reqs = np.asarray(prev_reqs, np.float64).reshape(-1, hits.shape[0])
    prev_hits = np.asarray(prev_hits, np.float64).reshape(-1, hits.shape[0])
    if prev_reqs.shape[0]:
        valid = prev_reqs >= ocfg.min_requests
        ratio_prev = np.where(valid, prev_hits / np.maximum(prev_reqs, 1.0),
                              -1.0)
        base = ratio_prev.max(axis=0)          # -1 when no qualified interval
        ratio = hits / np.maximum(reqs, 1.0)
        flags = ((reqs >= ocfg.min_requests) & (base > 0.0)
                 & (ratio < ocfg.drop * base))
    return flags | np.asarray(pressure, bool)


# ---------------------------------------------------------------------------
# the recorder
# ---------------------------------------------------------------------------

class TelemetryRecorder:
    """Per-interval telemetry sink threaded through the controllers.

    One recorder belongs to one controller: it keeps the previous
    cumulative-stats snapshot to compute interval deltas, so sharing an
    instance between controllers would interleave their deltas.

    Guarantees: ``sample_*`` only reads host-side values the controller
    already fetched (zero added device→host syncs) and never touches
    cache state (telemetry on vs off is bit-identical — asserted in
    ``tests/test_telemetry.py``). ``span_timing`` and ``profile_dir``
    are the opt-in exceptions that DO add synchronization, and say so.
    """

    def __init__(self, window: int = 512, spill=None,
                 span_timing: bool = False,
                 overload: OverloadConfig | None = None,
                 profile_dir=None):
        self.journal = Journal(window=window, spill=spill)
        self.span_timing = bool(span_timing)
        self.spans: dict[str, SpanStats] = {}
        self.overload = overload if overload is not None else OverloadConfig()
        self.profile_dir = profile_dir
        self._prev: dict[str, np.ndarray] = {}
        self._ov_hits = collections.deque(maxlen=self.overload.window)
        self._ov_reqs = collections.deque(maxlen=self.overload.window)

    # -- spans ------------------------------------------------------------
    def span(self, name: str):
        """Context manager timing one dispatch; hand the dispatch output
        to ``.ready(out)`` so close can ``block_until_ready`` it. A
        no-op (and sync-free) unless ``span_timing`` is on."""
        return _Span(self, name) if self.span_timing else _NULL_SPAN

    def _observe_span(self, name: str, seconds: float) -> None:
        s = self.spans.get(name)
        if s is None:
            s = self.spans[name] = SpanStats()
        s.observe(seconds)

    def profile(self):
        """``jax.profiler.trace`` over a region when ``profile_dir`` is
        set; a null context otherwise."""
        if self.profile_dir is None:
            return contextlib.nullcontext()
        import jax
        return jax.profiler.trace(str(self.profile_dir))

    # -- interval samples -------------------------------------------------
    def _deltas(self, cur: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        out = {k: v - self._prev.get(k, np.zeros_like(v))
               for k, v in cur.items()}
        self._prev = cur
        return out

    def _flag(self, hits, reqs, pressure) -> np.ndarray:
        n = len(self._ov_hits)
        prev_h = (np.stack(self._ov_hits) if n
                  else np.zeros((0, len(hits))))
        prev_r = (np.stack(self._ov_reqs) if n
                  else np.zeros((0, len(reqs))))
        flags = overload_flags(prev_h, prev_r, hits, reqs, pressure,
                               self.overload)
        self._ov_hits.append(np.asarray(hits, np.float64))
        self._ov_reqs.append(np.asarray(reqs, np.float64))
        return flags

    def sample_cache(self, stats: list[dict], *, alloc_l1=None, alloc_l2=None,
                     promoted=None, evict_queue=None, cleaned=None,
                     dirty=None, clean_ran: bool = False,
                     cls_hits=None, cls_miss=None) -> dict:
        """One interval sample from a block-cache controller's per-VM
        stats dicts (cumulative, host-side) plus the maintenance counts
        the interval's existing device_get already fetched."""
        num_vms = len(stats)
        cur = {k: np.asarray([float(d.get(k, 0.0)) for d in stats])
               for k in CACHE_DELTA_KEYS}
        d = self._deltas(cur)
        zeros = np.zeros(num_vms, np.int64)
        alloc_l1 = np.asarray(alloc_l1 if alloc_l1 is not None else zeros,
                              np.int64)
        alloc_l2 = np.asarray(alloc_l2 if alloc_l2 is not None else zeros,
                              np.int64)
        dirty = np.asarray(dirty if dirty is not None else zeros, np.int64)
        reqs = d["reads"] + d["writes"]
        hits = d["read_hits_l1"] + d["read_hits_l2"] + d["write_hits_l2"]
        pressure = (alloc_l2 > 0) & (dirty >= self.overload.pressure
                                     * alloc_l2)
        row = {
            "requests": reqs,
            "hits": hits,
            "ssd_writes": d["cache_writes_l2"],
            "disk_reads": d["disk_reads"],
            "disk_writes": d["disk_writes"],
            "flushes": d["flushes"],
            "evict_flushes": d["evict_flushes"],
            "bypassed": d["bypassed"],
            "pop_drops": d["pop_drops"],
            "latency": d["latency_sum"],
            "dirty_resident": dirty,
            "alloc_l1": alloc_l1,
            "alloc_l2": alloc_l2,
            "promoted": np.asarray(promoted if promoted is not None
                                   else zeros, np.int64),
            "evict_queue": np.asarray(evict_queue if evict_queue is not None
                                      else zeros, np.int64),
            "cleaned": np.asarray(cleaned if cleaned is not None else zeros,
                                  np.int64),
            "clean_ran": bool(clean_ran),
            "overloaded": self._flag(hits, reqs, pressure),
        }
        if cls_hits is not None:
            ch = np.asarray(cls_hits, np.int64)
            cm = np.asarray(cls_miss, np.int64)
            prev_ch = self._prev.get("_cls_hits", np.zeros_like(ch))
            prev_cm = self._prev.get("_cls_miss", np.zeros_like(cm))
            row["cls_hits"] = ch - prev_ch
            row["cls_miss"] = cm - prev_cm
            self._prev["_cls_hits"] = ch.copy()
            self._prev["_cls_miss"] = cm.copy()
        self.journal.append(row)
        return row

    def sample_serving(self, stats, *, quota, used) -> dict:
        """One maintenance-tick sample from a serving manager's
        :class:`~repro.kvcache.manager.Stats` plus the per-tenant quota
        state (all host-side already)."""
        cur = {k: np.asarray([float(getattr(stats, k))])
               for k in SERVING_DELTA_KEYS}
        dirty = int(stats.dirty_resident)
        d = self._deltas(cur)
        quota = np.asarray(quota, np.int64)
        used = np.asarray(used, np.int64)
        # queue pressure per tenant: resident pages pressing the quota
        pressure = (quota > 0) & (used >= np.ceil(
            self.overload.pressure * quota).astype(np.int64))
        global_flag = self._flag(d["hits"], d["activations"],
                                 np.zeros(1, bool))
        row = {
            "requests": d["activations"][0],
            "hits": d["hits"][0],
            "appends": d["appends"][0],
            "dma_read_bytes": d["dma_read_bytes"][0],
            "dma_write_bytes": d["dma_write_bytes"][0],
            "latency": d["latency_s"][0],
            "flushes": d["flushes"][0],
            "evict_flushes": d["evict_flushes"][0],
            "dirty_dropped": d["dirty_dropped"][0],
            "sessions_ended": d["sessions_ended"][0],
            "pop_drops": d["pop_drops"][0],
            "dirty_resident": dirty,
            "quota": quota,
            "used": used,
            "overloaded": pressure | bool(global_flag[0]),
        }
        self.journal.append(row)
        return row

    # -- legacy cleaner-log views -----------------------------------------
    # PR 8's EticaCache.clean_log / dirty_log were unbounded Python lists
    # (one [V] array per maintenance interval, forever). They are now
    # views over the bounded journal: the rows where the cleaner actually
    # ran, exactly the intervals the old lists recorded.
    def cache_clean_log(self) -> list[np.ndarray]:
        if "clean_ran" not in self.journal:
            return []
        ran = self.journal.column("clean_ran")
        cl = self.journal.column("cleaned")
        return [cl[i] for i in np.flatnonzero(ran)]

    def cache_dirty_log(self) -> list[np.ndarray]:
        if "clean_ran" not in self.journal:
            return []
        ran = self.journal.column("clean_ran")
        dl = self.journal.column("dirty_resident")
        return [dl[i] for i in np.flatnonzero(ran)]


# ---------------------------------------------------------------------------
# journal summaries (tools/run_report.py + fig17 render from these)
# ---------------------------------------------------------------------------

def summarize_journal(cols: dict[str, np.ndarray]) -> dict:
    """Aggregate a loaded (or in-memory) journal's columns.

    ``cols`` maps column name -> ``[rows, ...]`` arrays (the shape
    :func:`load_journal` returns). Returns per-interval 1-D series
    (requests, hit_ratio, dirty, overloaded count) plus scalar totals.
    """
    if not cols:
        return {"intervals": 0}
    reqs = np.asarray(cols["requests"], np.float64)
    hits = np.asarray(cols["hits"], np.float64)
    if reqs.ndim > 1:                      # per-VM rows -> per-interval sums
        reqs_i, hits_i = reqs.sum(axis=1), hits.sum(axis=1)
    else:
        reqs_i, hits_i = reqs, hits
    dirty = np.asarray(cols.get("dirty_resident", np.zeros_like(reqs)),
                       np.float64)
    dirty_i = dirty.sum(axis=1) if dirty.ndim > 1 else dirty
    over = np.asarray(cols.get("overloaded", np.zeros_like(reqs)), bool)
    over_i = over.sum(axis=1) if over.ndim > 1 else over.astype(np.int64)
    ratio = hits_i / np.maximum(reqs_i, 1.0)
    return {
        "intervals": int(reqs_i.shape[0]),
        "requests": reqs_i,
        "hit_ratio": ratio,
        "dirty": dirty_i,
        "overloaded": over_i,
        "total_requests": float(reqs_i.sum()),
        "mean_hit_ratio": float(hits_i.sum() / max(reqs_i.sum(), 1.0)),
        "peak_dirty": float(dirty_i.max(initial=0.0)),
        "overloaded_intervals": int((over_i > 0).sum()),
    }


def format_report(cols: dict[str, np.ndarray], last: int | None = None,
                  vm: int | None = None) -> list[str]:
    """Human-readable per-interval report lines for a journal."""
    s = summarize_journal(cols)
    if not s["intervals"]:
        return ["empty journal"]
    idx = np.asarray(cols.get("i", np.arange(s["intervals"])), np.int64)
    reqs, ratio = s["requests"], s["hit_ratio"]
    dirty, over = s["dirty"], s["overloaded"]
    if vm is not None:
        r = np.asarray(cols["requests"], np.float64)
        if r.ndim < 2:
            raise ValueError("journal has no per-VM columns (serving run?)")
        h = np.asarray(cols["hits"], np.float64)
        reqs, ratio = r[:, vm], h[:, vm] / np.maximum(r[:, vm], 1.0)
        d = np.asarray(cols["dirty_resident"], np.float64)
        o = np.asarray(cols["overloaded"], bool)
        dirty, over = d[:, vm], o[:, vm].astype(np.int64)
    lines = [f"{'interval':>8} {'requests':>9} {'hit_ratio':>9} "
             f"{'dirty':>7} {'overloaded':>10}"]
    sel = range(s["intervals"]) if last is None else \
        range(max(s["intervals"] - last, 0), s["intervals"])
    for i in sel:
        lines.append(f"{int(idx[i]):>8} {reqs[i]:>9.0f} {ratio[i]:>9.3f} "
                     f"{dirty[i]:>7.0f} {int(over[i]):>10}")
    lines.append(
        f"summary: intervals={s['intervals']} "
        f"requests={s['total_requests']:.0f} "
        f"mean_hit_ratio={s['mean_hit_ratio']:.3f} "
        f"peak_dirty={s['peak_dirty']:.0f} "
        f"overloaded_intervals={s['overloaded_intervals']}")
    return lines
