"""Dependency-free live scrape endpoint for the telemetry registry.

A tiny stdlib ``http.server`` wrapper exposing two routes from a
background daemon thread:

* ``GET /metrics``  — the Prometheus text exposition rendered *live* at
  scrape time from a ``collect`` callable (returning either a list of
  :class:`repro.runtime.metrics.Metric` families or pre-rendered text).
* ``GET /healthz``  — ``ok`` liveness probe.

No third-party HTTP stack exists in the image and none is needed: the
exposition format is plain text and ``ThreadingHTTPServer`` handles
concurrent scrapes. The collector runs on the scrape thread while the
simulation appends journal rows on the main thread; column reads are
snapshot copies, so the worst case is a scrape observing interval N-1
while N lands — acceptable for monitoring, noted here for honesty.

Wired into ``launch/serve.py`` via ``--metrics-port`` (0 picks an
ephemeral port, printed at startup).
"""
from __future__ import annotations

import http.server
import threading

from repro.runtime import metrics as metrics_mod

__all__ = ["MetricsServer"]

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class _Handler(http.server.BaseHTTPRequestHandler):
    # the server instance injects `collect` via the class-per-server
    # subclass created in MetricsServer.start()
    collect = None

    def _send(self, status: int, body: str,
              ctype: str = CONTENT_TYPE) -> None:
        data = body.encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self):  # noqa: N802 (stdlib handler naming)
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            try:
                out = type(self).collect()
                body = out if isinstance(out, str) else \
                    metrics_mod.render(out)
            except Exception as e:  # surface collector bugs to the scraper
                self._send(500, f"collector error: {e}\n",
                           "text/plain; charset=utf-8")
                return
            self._send(200, body)
        elif path == "/healthz":
            self._send(200, "ok\n", "text/plain; charset=utf-8")
        else:
            self._send(404, "not found\n", "text/plain; charset=utf-8")

    def log_message(self, fmt, *args):  # silence per-request stderr spam
        pass


class MetricsServer:
    """Background-thread scrape server over a live collector.

    ``collect`` is called per scrape — pass a closure over the live
    controller/recorder (e.g. ``lambda: collect_serving(mgr) +
    collect_telemetry(rec)``) so every scrape sees current counters.

    Usable as a context manager; ``start()`` returns ``(host, port)``
    with the ephemeral port resolved.
    """

    def __init__(self, collect, host: str = "127.0.0.1", port: int = 0):
        self._collect = collect
        self._host = host
        self._port = port
        self._server = None
        self._thread = None

    def start(self) -> tuple[str, int]:
        if self._server is not None:
            raise RuntimeError("server already started")
        handler = type("_BoundHandler", (_Handler,),
                       {"collect": staticmethod(self._collect)})
        self._server = http.server.ThreadingHTTPServer(
            (self._host, self._port), handler)
        self._server.daemon_threads = True
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="etica-metrics",
            daemon=True)
        self._thread.start()
        return self.address

    @property
    def address(self) -> tuple[str, int]:
        if self._server is None:
            raise RuntimeError("server not started")
        host, port = self._server.server_address[:2]
        return host, port

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}/metrics"

    def stop(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._thread.join(timeout=5)
            self._server = None
            self._thread = None

    def __enter__(self) -> "MetricsServer":
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()
        return False
