"""Fault tolerance & elasticity runtime.

* :class:`StragglerMonitor` — EMA/variance step-time tracker; flags steps
  whose duration z-score exceeds a threshold. On a real fleet the flag
  feeds the scheduler (re-dispatch the slow host's shard / swap in a hot
  spare); here it drives logging and the retry policy, and its decisions
  are unit-tested.
* :func:`run_with_recovery` — wraps a step thunk with bounded retries;
  on failure restores from the last committed checkpoint and replays
  (the data pipeline is pure-functional in step, so replay is exact).
* :func:`remesh` — elastic scaling: re-place a host state pytree onto a
  new mesh's shardings (used with ``checkpoint.restore`` when the device
  count changes between runs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.1
    z_threshold: float = 3.0
    warmup: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.n += 1
        if self.n <= self.warmup:
            # prime the EMA
            self.mean = dt if self.n == 1 else (
                self.mean + (dt - self.mean) / self.n)
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        z = (dt - self.mean) / max(np.sqrt(self.var), 1e-9)
        is_straggler = z > self.z_threshold
        if is_straggler:
            self.flagged.append((step, dt, z))
        else:
            # only track healthy steps so stragglers don't poison the EMA
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = ((1 - self.alpha) * self.var
                        + self.alpha * (dt - self.mean) ** 2)
        return is_straggler


class StepFailure(RuntimeError):
    pass


def run_with_recovery(step_fn: Callable, state, batch, *, max_retries: int = 2,
                      restore_fn: Callable | None = None):
    """Execute one training step with bounded retry + restore.

    ``restore_fn()`` must return a state equivalent to the last committed
    checkpoint. Deterministic data (batch is replayed as-is) keeps the
    result bit-identical to a failure-free run."""
    attempt = 0
    while True:
        try:
            return step_fn(state, batch)
        except Exception as e:  # noqa: BLE001 — any device/step failure
            attempt += 1
            if attempt > max_retries:
                raise StepFailure(
                    f"step failed {attempt} times: {e}") from e
            if restore_fn is not None:
                state = restore_fn()


def remesh(host_state, shardings):
    """Place a host (numpy) state pytree onto new-mesh shardings."""
    sh_leaves = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
    leaves, treedef = jax.tree_util.tree_flatten(host_state)
    return treedef.unflatten(
        [jax.device_put(np.asarray(l), s) for l, s in zip(leaves, sh_leaves)])
