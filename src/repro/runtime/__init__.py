"""Runtime subsystems: fault handling (`fault`), interval telemetry
journals + dispatch spans + overload detection (`telemetry`), Prometheus
text export (`metrics`), and the stdlib live scrape endpoint (`http`).

Submodules are imported explicitly (``from repro.runtime import
metrics``) — nothing is re-exported here, so importing the package stays
free of jax/numpy side effects.
"""
