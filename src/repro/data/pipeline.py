"""Deterministic synthetic token pipeline (host-sharded, prefetched).

Every batch is a pure function of (seed, step, process_index), so replay
after failure/restore is exact — the fault-tolerance contract the train
loop relies on. A background thread keeps ``prefetch`` batches ready.

Produces the batch dicts the models consume (tokens / patches / frames /
dec_tokens), matching ``launch.steps.batch_specs`` shapes.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.models.config import ModelConfig


class TokenPipeline:
    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, process_index: int = 0,
                 process_count: int = 1, prefetch: int = 2):
        assert batch % process_count == 0
        self.cfg = cfg
        self.local_batch = batch // process_count
        self.seq_len = seq_len
        self.seed = seed
        self.process_index = process_index
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._cursor = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def batch_at(self, step: int) -> dict:
        """Pure: the batch for a given global step."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + self.process_index)
        cfg = self.cfg
        b, s = self.local_batch, self.seq_len
        if cfg.is_encdec:
            return {
                "frames": rng.normal(size=(b, s, cfg.d_model)).astype(np.float32),
                "dec_tokens": rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32),
            }
        if cfg.frontend == "vision":
            p = cfg.frontend_tokens
            return {
                "tokens": rng.integers(0, cfg.vocab_size, (b, s - p)).astype(np.int32),
                "patches": rng.normal(size=(b, p, cfg.d_model)).astype(np.float32),
            }
        return {"tokens": rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)}

    # -- prefetching iterator ---------------------------------------------
    def start(self, step: int = 0):
        self._cursor = step

        def work():
            s = step
            while not self._stop.is_set():
                self._q.put((s, self.batch_at(s)))
                s += 1

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return self

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def stop(self):
        self._stop.set()
        if self._thread:
            while not self._q.empty():
                self._q.get_nowait()
