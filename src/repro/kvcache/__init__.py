from .manager import (Session, Stats, TwoTierConfig, TwoTierKVManager,
                      quota_with_floor)
from .baseline import GlobalLRUManager
