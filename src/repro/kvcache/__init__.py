from .manager import Session, Stats, TwoTierConfig, TwoTierKVManager
from .baseline import GlobalLRUManager
