"""Baseline one-tier / naive two-tier KV managers for comparison.

* ``GlobalLRUManager`` — the conventional design: one global LRU over the
  HBM pool, no per-tenant partitioning, push-mode (every activation
  promotes, every capacity eviction WRITES the page back to host even
  though a copy exists — the datapath write-back the paper's WB policy
  implies). This is the ECI-Cache-like comparison point for
  `benchmarks/serving_two_tier.py`.
"""
from __future__ import annotations

import numpy as np

from .manager import Stats, TwoTierConfig, TwoTierKVManager


class GlobalLRUManager(TwoTierKVManager):
    """LRU + write-back eviction + no partitioning."""

    def __init__(self, cfg: TwoTierConfig, num_tenants: int):
        # controller is inert here (no maintenance), so skip the batched
        # plane's device popularity table
        super().__init__(cfg, num_tenants, batched=False)
        self._clock = 0
        self._slot_time: dict[int, int] = {}

    def _alloc_slot(self, sid: int, lp: int) -> int:
        slot = super()._alloc_slot(sid, lp)
        self._slot_time[slot] = self._clock
        self._clock += 1
        return slot

    def _evict_one(self, exclude_sid: int):
        cands = [(self._slot_time.get(slot, 0), slot, sid, lp)
                 for slot, (sid, lp) in self.slot_owner.items()
                 if sid != exclude_sid]
        if not cands:
            raise RuntimeError("HBM pool exhausted by a single session")
        _, slot, sid, lp = min(cands)
        # WB-style datapath write-back on eviction (the wear the paper's
        # WBWO assignment avoids):
        self.stats.dma_write_bytes += self.cfg.page_bytes
        self.stats.latency_s += self.cfg.page_bytes / 8e9
        self._release_slot(sid, lp)

    def activate(self, sid: int) -> np.ndarray:
        sess = self.sessions[sid]
        for lp in sess.pages:
            if lp in sess.hbm_slots:
                self._slot_time[sess.hbm_slots[lp]] = self._clock
                self._clock += 1
        return super().activate(sid)

    # no POD repartitioning, no popularity maintenance
    def _maintenance_tick(self, active_sid: int | None = None):
        pass
