"""Two-tier KV page manager — ETICA's policy engine applied to serving.

Mapping (DESIGN.md §2): tier-1 = HBM page pool (fast, capacity-pressured,
*read-only cache* — every resident page is a clean copy, droppable at any
moment, which is the RO-level reliability argument); tier-2 = host-memory
pool over PCIe/DMA (authoritative store, *write-back/write-only* — every
appended page is written there exactly once, so host-DMA write traffic —
the wear analog — is bounded by generated tokens); the "disk subsystem"
is recompute-from-tokens.

"VMs" are tenants; the request trace is the stream of session
activations: scheduling a session into a decode batch *reads* its KV
working set (must be HBM-resident), finishing a burst *writes* (appends)
pages. The same core machinery drives the policy:

  * POD(RO) over each tenant's activation trace sizes its HBM partition
    (`repro.core.reuse`), partitioned under pressure by PPC
    (`repro.core.partition`);
  * popularity (Eq. 1, `repro.core.popularity`) ranks sessions; the
    periodic maintenance step drops cold sessions' pages (pull mode — an
    activation miss copies pages up for the active batch but does NOT
    count as a promotion decision).

Controller architecture (the serving analog of the repo's batched
convention): ``batched=True`` (default) runs the controller on the
batched machinery of PRs 1–6 — a bounded ``[T, window]`` per-tenant
trace ring, per-tenant sizing through ONE vmapped
``reuse.pod_distances_batch`` dispatch per resize interval, and
promotion/eviction over a device-resident ``[T, K]``
:class:`~repro.core.popularity.PopularityTable` driven by the fused
``kernels.maintenance.serving_maintenance`` dispatch (the HBM page
tables are the cache state it ranks over). ``batched=False`` keeps the
original host-dict controller — per-tenant
:class:`~repro.core.popularity.PopularityTracker` loops and per-tenant
``pod_distances`` calls — as the bit-identical sequential oracle:
both paths produce the same Stats, quotas, and page placements
request for request.

The controller trace is *bounded*: requests are recorded into rings of
``resize_interval`` entries (the only window any consumer ever reads),
so a serving run's host memory is O(window + live pages), not O(total
activations). Each entry snapshots the session's tenant at record time,
so windows stay well-defined after churn (``end_session``) retires a
session.

The pools are jnp arrays compatible with
`repro.kernels.decode_attention` page tables.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import popularity as core_pop
from repro.core import reuse as core_reuse
from repro.core.partition import partition as _partition, size_grid
from repro.core.policies import Policy
from repro.core.popularity import PopularityTracker, contributions
from repro.kernels.maintenance.ops import serving_maintenance

PCIE_BW = 8e9            # bytes/s per host link (dma latency model)


@dataclasses.dataclass
class TwoTierConfig:
    page_size: int = 256          # tokens per page
    hbm_pages: int = 256          # tier-1 pool capacity
    num_kv_heads: int = 8
    head_dim: int = 128
    num_layers: int = 1           # pages are per-layer-stacked
    dtype: str = "bfloat16"
    maintenance_interval: int = 64   # activations between maintenance
    resize_interval: int = 512       # activations between re-partitioning
    promo_frac: float = 0.25
    evict_frac: float = 0.25
    popularity_decay: float = 0.5
    pop_capacity: int = 256       # [T, K] popularity-table slots per tenant
    materialize: bool = True      # keep device page pools in sync; off =
                                  # controller-only mode for huge synthetic
                                  # runs (Stats identical, no decode)
    clean_quota: int = 0          # deferred write-back: max dirty-page
                                  # flushes per tenant per maintenance
                                  # interval (0 = eager commit on append)
    telemetry: object | None = None  # repro.runtime.telemetry
                                  # .TelemetryRecorder; None gets a default
                                  # bounded recorder (Stats identical)

    @property
    def page_bytes(self) -> int:
        return (2 * self.num_layers * self.page_size * self.num_kv_heads
                * self.head_dim * jnp.dtype(self.dtype).itemsize)


@dataclasses.dataclass
class Session:
    tenant: int
    length: int = 0                       # tokens
    pages: list = dataclasses.field(default_factory=list)   # logical pages
    hbm_slots: dict = dataclasses.field(default_factory=dict)
    # logical page -> hbm pool slot (only for resident pages)


@dataclasses.dataclass
class Stats:
    activations: int = 0
    hits: int = 0                  # fully HBM-resident activations
    appends: int = 0               # pages generated (WBWO commits)
    dma_read_bytes: int = 0        # host -> HBM copies (misses, promotions)
    dma_write_bytes: int = 0       # HBM -> host commits (the wear analog)
    latency_s: float = 0.0
    sessions_ended: int = 0        # churn: retired sessions
    pop_drops: int = 0             # [T, K] table merge-overflow drops
    flushes: int = 0               # background-cleaner page commits
    evict_flushes: int = 0         # dirty pages committed on slot release
    dirty_resident: int = 0        # gauge: uncommitted pages right now
    dirty_dropped: int = 0         # dirty pages retired with the session
    #                                (no DMA: host copy freed uncommitted)

    def as_dict(self):
        return dataclasses.asdict(self) | {
            "hit_ratio": self.hits / max(self.activations, 1)}


class _TraceRing:
    """Bounded controller-trace ring: the last ``window`` requests with
    their session id, record-time tenant, and write flag — exactly the
    slice ``_window()`` has always consumed, without the unbounded
    ``trace_addr``/``trace_write`` lists (which leaked host memory
    linearly in activations)."""

    def __init__(self, window: int):
        self.window = window
        self.sid = np.zeros(window, np.int32)
        self.tenant = np.zeros(window, np.int32)
        self.write = np.zeros(window, bool)
        self.n = 0               # total records ever pushed

    def push(self, sid: int, tenant: int, write: bool):
        pos = self.n % self.window
        self.sid[pos] = sid
        self.tenant[pos] = tenant
        self.write[pos] = write
        self.n += 1

    def arrays(self):
        """(sid, tenant, write) of the last ``min(n, window)`` records in
        chronological order."""
        if self.n < self.window:
            sl = slice(0, self.n)
            return self.sid[sl], self.tenant[sl], self.write[sl]
        pos = self.n % self.window
        order = np.r_[pos:self.window, 0:pos]
        return self.sid[order], self.tenant[order], self.write[order]


class _TenantRings:
    """``[T, window]`` per-tenant trace rings (batched controller).

    Each request lands in its tenant's row together with its global
    sequence number, so ``window_rows(cutoff)`` can reproduce exactly
    the per-tenant sub-traces of "last ``window`` global records, masked
    by tenant" — the oracle's semantics — without ever materializing an
    unbounded global list."""

    def __init__(self, num_tenants: int, window: int):
        self.window = window
        self.sid = np.zeros((num_tenants, window), np.int32)
        self.write = np.zeros((num_tenants, window), bool)
        self.seq = np.full((num_tenants, window), -1, np.int64)
        self.count = np.zeros(num_tenants, np.int64)  # pushes per tenant

    def push(self, tenant: int, sid: int, write: bool, seq: int):
        pos = self.count[tenant] % self.window
        self.sid[tenant, pos] = sid
        self.write[tenant, pos] = write
        self.seq[tenant, pos] = seq
        self.count[tenant] += 1

    def window_rows(self, min_seq: int):
        """Per-tenant (sid, write) arrays of records with
        ``seq >= min_seq``, each in chronological order."""
        sids, writes = [], []
        for t in range(self.seq.shape[0]):
            n = int(min(self.count[t], self.window))
            if n == 0:
                sids.append(np.empty(0, np.int32))
                writes.append(np.empty(0, bool))
                continue
            if self.count[t] < self.window:
                order = np.arange(n)
            else:
                pos = int(self.count[t] % self.window)
                order = np.r_[pos:self.window, 0:pos]
            keep = self.seq[t, order] >= min_seq
            sids.append(self.sid[t, order][keep])
            writes.append(self.write[t, order][keep])
        return sids, writes


def quota_with_floor(alloc: np.ndarray, capacity: int) -> np.ndarray:
    """Give every tenant >= 1 page WITHOUT exceeding the pool.

    The old ``np.maximum(alloc, 1)`` could push ``sum(quota)`` above
    ``capacity`` (every zero-allocation tenant added a page out of thin
    air), letting tenants collectively pin more HBM than exists. Raising
    a tenant to the 1-page floor is now paid for by shaving the largest
    allocations, one page at a time (never below the floor)."""
    alloc = np.asarray(alloc, np.int64).copy()
    if capacity < alloc.size:       # pool smaller than tenant count:
        alloc = np.minimum(alloc, 1)   # floor is unsatisfiable; best effort
        while alloc.sum() > capacity:
            alloc[np.argmax(alloc)] -= 1
        return alloc
    alloc = np.maximum(alloc, 1)
    while alloc.sum() > capacity:
        big = np.argmax(alloc)
        if alloc[big] <= 1:
            break
        alloc[big] -= 1
    return alloc


class TwoTierKVManager:
    """Host-side datapath (page tables, pools) + batched or sequential
    controller (see module docstring)."""

    def __init__(self, cfg: TwoTierConfig, num_tenants: int,
                 batched: bool = True):
        self.cfg = cfg
        self.num_tenants = num_tenants
        self.batched = batched
        shape = (cfg.hbm_pages, cfg.page_size, cfg.num_kv_heads,
                 cfg.head_dim)
        dt = jnp.dtype(cfg.dtype)
        # tier-1 device pools (per layer stacked on axis 0)
        if cfg.materialize:
            self.k_pool = jnp.zeros((cfg.num_layers,) + shape, dt)
            self.v_pool = jnp.zeros((cfg.num_layers,) + shape, dt)
        else:
            self.k_pool = self.v_pool = None
        self.free = list(range(cfg.hbm_pages))
        self.slot_owner: dict[int, tuple[int, int]] = {}  # slot -> (sid, lp)
        # tier-2 host pool: {(sid, logical_page): (k_np, v_np)}
        self.host: dict[tuple[int, int], tuple[np.ndarray, np.ndarray]] = {}
        self.sessions: dict[int, Session] = {}
        # controller state: bounded rings (the only trace anyone reads)
        self._ring = _TraceRing(cfg.resize_interval)
        if batched:
            self._trings = _TenantRings(num_tenants, cfg.resize_interval)
            self._table = core_pop.table_init(num_tenants, cfg.pop_capacity)
            # host mirror of the device table, refreshed once per
            # maintenance interval — serves the datapath's per-allocation
            # score lookups without a device round-trip each
            self._pop_addr = np.asarray(self._table.addr)
            self._pop_val = np.asarray(self._table.val)
            self.trackers = None
        else:
            self._trings = None
            self._table = None
            self.trackers = [PopularityTracker(cfg.popularity_decay)
                             for _ in range(num_tenants)]
        self.tenant_quota = np.full(num_tenants,
                                    cfg.hbm_pages // max(num_tenants, 1))
        self.tenant_used = np.zeros(num_tenants, np.int64)
        self.stats = Stats()
        # per-maintenance-tick telemetry journal (bounded; deltas come
        # from the host Stats the controller already maintains)
        if cfg.telemetry is not None:
            self.telemetry = cfg.telemetry
        else:
            from repro.runtime.telemetry import TelemetryRecorder
            self.telemetry = TelemetryRecorder()
        self._since_maint = 0
        self._since_resize = 0
        # deferred write-back (cfg.clean_quota > 0): uncommitted appended
        # pages, keyed (sid, lp) -> global append sequence (the age the
        # cleaner ranks by). Dirty pages are always HBM-resident: they
        # enter at append time and leave via flush or drop at release.
        self._dirty: dict[tuple[int, int], int] = {}
        self._append_seq = 0

    # -- session lifecycle ------------------------------------------------
    def new_session(self, sid: int, tenant: int):
        self.sessions[sid] = Session(tenant=tenant)

    def end_session(self, sid: int):
        """Churn: the session leaves for good — release its HBM slots and
        drop its authoritative tier-2 pages (no DMA: the host copies are
        simply freed)."""
        sess = self.sessions[sid]
        for lp in list(sess.hbm_slots):
            self._release_slot(sid, lp, drop=True)
        for lp in sess.pages:
            self.host.pop((sid, lp), None)
        del self.sessions[sid]
        self.stats.sessions_ended += 1

    def _alloc_slot(self, sid: int, lp: int) -> int:
        if not self.free:
            self._evict_one(exclude_sid=sid)
        slot = self.free.pop()
        self.slot_owner[slot] = (sid, lp)
        sess = self.sessions[sid]
        sess.hbm_slots[lp] = slot
        self.tenant_used[sess.tenant] += 1
        return slot

    def _release_slot(self, sid: int, lp: int, drop: bool = False):
        """Free a session's HBM slot. A dirty (uncommitted) page must
        settle before its only fast copy goes away: normally it is
        force-flushed to the host pool (``evict_flushes`` — the DMA write
        the cleaner failed to get to first); with ``drop`` the session is
        retiring, so the page is discarded uncommitted (no DMA)."""
        sess = self.sessions[sid]
        slot = sess.hbm_slots.pop(lp, None)
        if slot is not None:
            self.slot_owner.pop(slot, None)
            self.free.append(slot)
            self.tenant_used[sess.tenant] -= 1
            key = (sid, lp)
            if key in self._dirty:
                if drop:
                    self._dirty.pop(key)
                    self.stats.dirty_dropped += 1
                    self.stats.dirty_resident = len(self._dirty)
                else:
                    self._flush_page(key, evict=True)

    def _flush_page(self, key: tuple[int, int], evict: bool = False):
        """Commit an uncommitted page to the host pool: the deferred DMA
        write happens now (cleaner flush or eviction-forced flush)."""
        self._dirty.pop(key)
        self.stats.dma_write_bytes += self.cfg.page_bytes
        if evict:
            self.stats.evict_flushes += 1
        else:
            self.stats.flushes += 1
        self.stats.dirty_resident = len(self._dirty)

    def _scores(self, tenants: np.ndarray, sids: np.ndarray) -> np.ndarray:
        """Popularity of (tenant, sid) pairs — float32, bit-identical
        between the tracker (sequential) and the device-table host
        mirror (batched)."""
        tenants = np.asarray(tenants)
        sids = np.asarray(sids)
        out = np.zeros(sids.shape, np.float32)
        for t in np.unique(tenants):
            m = tenants == t
            if self.batched:
                row_a, row_v = self._pop_addr[t], self._pop_val[t]
                pos = np.searchsorted(row_a, sids[m].astype(np.int32))
                pos_c = np.minimum(pos, row_a.size - 1)
                hit = (pos < row_a.size) & (row_a[pos_c]
                                            == sids[m].astype(np.int32))
                vals = np.zeros(int(m.sum()), np.float32)
                vals[hit] = row_v[pos_c[hit]]
                out[m] = vals
            else:
                out[m] = self.trackers[int(t)].scores_for(sids[m])
        return out

    def _evict_one(self, exclude_sid: int):
        """Drop the least-popular resident page (RO tier: no write-back).

        Prefers tenants over quota; never touches the active session."""
        cands = [(slot, sid, lp) for slot, (sid, lp) in self.slot_owner.items()
                 if sid != exclude_sid]
        if not cands:
            raise RuntimeError("HBM pool exhausted by a single session")
        sids = np.array([sid for _, sid, _ in cands], np.int64)
        tens = np.array([self.sessions[int(s)].tenant for s in sids],
                        np.int64)
        over = self.tenant_used[tens] - self.tenant_quota[tens]
        pops = self._scores(tens, sids)
        # min((-over, pop)) with first-encounter tie-break, vectorized
        pick = int(np.lexsort((np.arange(len(cands)), pops, -over))[0])
        slot, sid, lp = cands[pick]
        self._release_slot(sid, lp)

    # -- datapath ----------------------------------------------------------
    def activate(self, sid: int) -> np.ndarray:
        """Make a session's pages HBM-resident; returns its page table.

        A fully-resident activation is a tier-1 hit (DRAM-speed); missing
        pages are copied up from the host pool (tier-2 "SSD" read) at DMA
        cost. This is the READ in the block-I/O mapping."""
        sess = self.sessions[sid]
        self._record(sid, write=False)
        missing = [lp for lp in sess.pages if lp not in sess.hbm_slots]
        self.stats.activations += 1
        if not missing:
            self.stats.hits += 1
        for lp in missing:
            slot = self._alloc_slot(sid, lp)
            if self.cfg.materialize:
                dt = self.k_pool.dtype
                k_np, v_np = self.host[(sid, lp)]
                self.k_pool = self.k_pool.at[:, slot].set(
                    jnp.asarray(k_np, dt))
                self.v_pool = self.v_pool.at[:, slot].set(
                    jnp.asarray(v_np, dt))
            self.stats.dma_read_bytes += self.cfg.page_bytes
            self.stats.latency_s += self.cfg.page_bytes / PCIE_BW
        self._maintenance_tick(active_sid=sid)
        pt = self.page_table(sid)
        # decode-time residency contract: maintenance above excluded the
        # active session, so every page must be resident — a -1 here
        # would read another session's KV in decode_attention
        assert (pt >= 0).all(), \
            f"activate({sid}): non-resident page in active page table"
        return pt

    def append_page(self, sid: int, k_page: np.ndarray, v_page: np.ndarray):
        """Commit a freshly generated page: written once to the host pool
        (tier-2 WBWO — the only mandatory DMA write) and installed in HBM
        for the ongoing decode. This is the WRITE in the mapping."""
        sess = self.sessions[sid]
        lp = len(sess.pages)
        sess.pages.append(lp)
        self.host[(sid, lp)] = (np.asarray(k_page), np.asarray(v_page))
        if self.cfg.clean_quota > 0:
            # deferred write-back: the page data lands in the host dict
            # (datapath unchanged) but the DMA commit is deferred — the
            # background cleaner pays it later, or eviction forces it
            self._dirty[(sid, lp)] = self._append_seq
            self.stats.dirty_resident = len(self._dirty)
        else:
            self.stats.dma_write_bytes += self.cfg.page_bytes
        self._append_seq += 1
        self.stats.appends += 1
        slot = self._alloc_slot(sid, lp)
        if self.cfg.materialize:
            dt = self.k_pool.dtype
            self.k_pool = self.k_pool.at[:, slot].set(jnp.asarray(k_page, dt))
            self.v_pool = self.v_pool.at[:, slot].set(jnp.asarray(v_page, dt))
        sess.length = lp * self.cfg.page_size + np.shape(k_page)[1]
        self._record(sid, write=True)

    def page_table(self, sid: int) -> np.ndarray:
        """Logical page -> HBM slot; ``-1`` marks a non-resident page.

        (The old ``hbm_slots.get(lp, 0)`` silently aliased slot 0, so a
        stale table would read another session's KV page; the sentinel
        makes partial residency detectable, and :meth:`activate` asserts
        full residency before handing the table to decode.)"""
        sess = self.sessions[sid]
        return np.array([sess.hbm_slots.get(lp, -1) for lp in sess.pages],
                        np.int32)

    def deactivate(self, sid: int):
        """Session leaves the active batch; pages stay until evicted
        (pull-mode: no datapath demotion)."""

    # -- controller --------------------------------------------------------
    def _record(self, sid: int, write: bool):
        tenant = self.sessions[sid].tenant
        self._ring.push(sid, tenant, write)
        if self.batched:
            self._trings.push(tenant, sid, write, self._ring.n - 1)
        self._since_maint += 1
        self._since_resize += 1

    def _maintenance_tick(self, active_sid: int | None = None):
        cfg = self.cfg
        ran = False
        if self._since_maint >= cfg.maintenance_interval:
            self._since_maint = 0
            ran = True
            if self.batched:
                self._maintain_batched(exclude_sid=active_sid)
            else:
                self._update_popularity()
                self._clean_tick()
                self._evict_cold(exclude_sid=active_sid)
        if self._since_resize >= cfg.resize_interval:
            self._since_resize = 0
            self._repartition()
        if ran:
            # one journal row per maintenance interval, from the host
            # Stats/quota state already in hand (zero added syncs)
            self.telemetry.sample_serving(self.stats,
                                          quota=self.tenant_quota,
                                          used=self.tenant_used)

    def _window(self):
        sid, tenant, wr = self._ring.arrays()
        return sid, tenant, wr

    def _resident_by_tenant(self, exclude_sid: int | None):
        """Per-tenant resident sessions (page-table insertion order) and
        their resident-page counts — the cache state both controller
        paths rank for eviction."""
        per: list[dict[int, int]] = [dict() for _ in range(self.num_tenants)]
        for slot, (sid, lp) in self.slot_owner.items():
            if sid == exclude_sid:
                continue
            t = self.sessions[sid].tenant
            per[t][sid] = per[t].get(sid, 0) + 1
        return per

    # ---- sequential oracle path (host dicts + trackers) -----------------
    def _update_popularity(self):
        addr, tenant, wr = self._window()
        if addr.size == 0:
            return
        r = core_reuse.pod_distances(addr, wr, Policy.RO)
        contrib = np.asarray(contributions(
            r.dist, r.served, max(int(self.tenant_quota.sum()), 1)))
        for t in range(self.num_tenants):
            mask = tenant == t
            if mask.any():
                self.trackers[t].update(addr[mask].astype(np.int64),
                                        contrib[mask])

    def _evict_cold(self, exclude_sid: int | None = None):
        """Pull-mode eviction queue: drop the coldest resident sessions'
        pages down to quota (clean copies — no write-back). The actively
        decoding session is never a victim: its page table was just handed
        to the batch, so its slots must stay owned until deactivation."""
        per = self._resident_by_tenant(exclude_sid)
        for t in range(self.num_tenants):
            over = self.tenant_used[t] - self.tenant_quota[t]
            if over <= 0:
                continue
            resident = per[t]
            sids = np.fromiter(resident.keys(), np.int64,
                               count=len(resident))
            scores = self._scores(np.full(sids.shape, t), sids)
            order = np.argsort(scores, kind="stable")
            for i in order:
                sid = int(sids[i])
                lps = [lp for lp in self.sessions[sid].hbm_slots]
                for lp in lps:
                    if over <= 0:
                        break
                    self._release_slot(sid, lp)
                    over -= 1

    def _clean_tick(self):
        """Background cleaner (sequential oracle): commit the
        ``clean_quota`` oldest uncommitted pages per tenant, oldest
        (lowest append sequence) first. Runs BEFORE eviction, so pages the
        cleaner reaches in time count as ``flushes``, not
        ``evict_flushes`` — the batched path applies its flush picks in
        the same order."""
        if self.cfg.clean_quota <= 0 or not self._dirty:
            return
        per: list[list] = [[] for _ in range(self.num_tenants)]
        for key, seq in self._dirty.items():
            per[self.sessions[key[0]].tenant].append((seq, key))
        for t in range(self.num_tenants):
            per[t].sort()
            for _, key in per[t][: self.cfg.clean_quota]:
                self._flush_page(key)

    # ---- batched path (device table + fused dispatch) -------------------
    def _dirty_by_tenant(self):
        """Per-tenant dirty pages in age order: ``(ditems, dirty_age)``
        where ``ditems[t]`` is ``[(seq, sid, lp), ...]`` sorted ascending
        and ``dirty_age`` is the ``[T, max_dirty]`` matrix (``-1`` pad)
        the fused dispatch ranks."""
        ditems: list[list] = [[] for _ in range(self.num_tenants)]
        for (sid, lp), seq in self._dirty.items():
            ditems[self.sessions[sid].tenant].append((seq, sid, lp))
        dmax = max([len(d) for d in ditems] + [1])
        dirty_age = np.full((self.num_tenants, dmax), -1, np.int32)
        for t, d in enumerate(ditems):
            d.sort()
            for i, (seq, _, _) in enumerate(d):
                dirty_age[t, i] = seq
        return ditems, dirty_age

    def _maintain_batched(self, exclude_sid: int | None = None):
        addr, tenant, wr = self._window()
        if addr.size == 0:
            return
        r = core_reuse.pod_distances(addr, wr, Policy.RO)
        per = self._resident_by_tenant(exclude_sid)
        smax = max((len(p) for p in per), default=0)
        smax = max(smax, 1)
        cand_sid = np.full((self.num_tenants, smax), -1, np.int32)
        cand_pages = np.zeros((self.num_tenants, smax), np.int32)
        for t, p in enumerate(per):
            for i, (sid, n) in enumerate(p.items()):
                cand_sid[t, i] = sid
                cand_pages[t, i] = n
        over = self.tenant_used - self.tenant_quota
        ditems, dirty_age = self._dirty_by_tenant()
        with self.telemetry.span("serving_maintenance") as sp:
            self._table, drops, eorder, take, fpick = serving_maintenance(
                self._table, r.dist, r.served, addr, tenant,
                cand_sid, cand_pages, over,
                max(int(self.tenant_quota.sum()), 1),
                decay=self.cfg.popularity_decay,
                dirty_age=dirty_age, clean_quota=self.cfg.clean_quota)
            sp.ready((self._table, eorder, take, fpick))
        # one host sync per interval: queues + cleaner picks + table mirror
        eorder = np.asarray(eorder)
        take = np.asarray(take)
        fpick = np.asarray(fpick)
        self._pop_addr = np.asarray(self._table.addr)
        self._pop_val = np.asarray(self._table.val)
        self.stats.pop_drops += int(np.asarray(drops).sum())
        # cleaner picks apply BEFORE the eviction queue (both were ranked
        # against the same pre-dispatch state): a page the cleaner reaches
        # is a `flushes` commit; eviction then releases it clean
        for t, d in enumerate(ditems):
            for i, (_, sid, lp) in enumerate(d):
                if fpick[t, i]:
                    self._flush_page((sid, lp))
        for t in range(self.num_tenants):
            if over[t] <= 0:
                continue
            for i in range(eorder.shape[1]):
                pos = int(eorder[t, i])
                k = int(take[t, i])
                if k <= 0 or pos >= smax or cand_sid[t, pos] < 0:
                    continue
                sid = int(cand_sid[t, pos])
                lps = list(self.sessions[sid].hbm_slots)[:k]
                for lp in lps:
                    self._release_slot(sid, lp)

    # ---- repartitioning (shared; sizing dispatch differs) ----------------
    def _tenant_subtraces(self):
        """Per-tenant (sid, write) sub-traces of the controller window —
        from the ``[T, window]`` rings (batched) or by masking the global
        ring (sequential); identical by construction."""
        if self.batched:
            return self._trings.window_rows(
                max(self._ring.n - self._ring.window, 0))
        addr, tenant, wr = self._window()
        return ([addr[tenant == t] for t in range(self.num_tenants)],
                [wr[tenant == t] for t in range(self.num_tenants)])

    def _repartition(self):
        """POD(RO) per tenant over the activation window -> PPC split of
        the HBM pool (paper §4.3 applied to pages). Batched: all tenants'
        POD decompositions in ONE vmapped dispatch."""
        sids, writes = self._tenant_subtraces()
        if sum(int(s.size) for s in sids) == 0:
            return
        grid = size_grid(self.cfg.hbm_pages, 16)
        demands = np.zeros(self.num_tenants, np.int64)
        curves = np.zeros((self.num_tenants, grid.size))
        if self.batched:
            with self.telemetry.span("serving_sizing") as sp:
                rs = core_reuse.pod_distances_batch(sids, writes, Policy.RO)
                sp.ready(rs)
        else:
            rs = [core_reuse.pod_distances(s, w, Policy.RO)
                  if s.size else None for s, w in zip(sids, writes)]
        for t, r in enumerate(rs):
            if r is None:
                continue
            # demand in sessions -> pages (mean pages per session of tenant)
            sess_pages = [len(s.pages) or 1 for s in self.sessions.values()
                          if s.tenant == t] or [1]
            per = int(np.ceil(np.mean(sess_pages)))
            demands[t] = min(core_reuse.demand_blocks(int(r.max)) * per,
                             self.cfg.hbm_pages)
            hits = core_reuse.hit_counts_at_sizes(
                r.dist, r.served, np.maximum(grid // per, 1))
            curves[t] = np.asarray(hits, np.float64) / max(sids[t].size, 1)
        res = _partition(demands, curves, grid, self.cfg.hbm_pages)
        self.tenant_quota = quota_with_floor(res.alloc, self.cfg.hbm_pages)
