"""Two-tier KV page manager — ETICA's policy engine applied to serving.

Mapping (DESIGN.md §2): tier-1 = HBM page pool (fast, capacity-pressured,
*read-only cache* — every resident page is a clean copy, droppable at any
moment, which is the RO-level reliability argument); tier-2 = host-memory
pool over PCIe/DMA (authoritative store, *write-back/write-only* — every
appended page is written there exactly once, so host-DMA write traffic —
the wear analog — is bounded by generated tokens); the "disk subsystem"
is recompute-from-tokens.

"VMs" are tenants; the request trace is the stream of session
activations: scheduling a session into a decode batch *reads* its KV
working set (must be HBM-resident), finishing a burst *writes* (appends)
pages. The same core machinery drives the policy:

  * POD(RO) over each tenant's activation trace sizes its HBM partition
    (`repro.core.reuse`), partitioned under pressure by PPC
    (`repro.core.partition`);
  * popularity (Eq. 1, `repro.core.popularity`) ranks sessions; the
    periodic maintenance step promotes hot sessions' pages into HBM and
    drops cold ones (pull mode — an activation miss copies pages up for
    the active batch but does NOT count as a promotion decision).

The pools are jnp arrays compatible with
`repro.kernels.decode_attention` page tables.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import reuse as core_reuse
from repro.core.partition import partition as _partition
from repro.core.policies import Policy
from repro.core.popularity import PopularityTracker, contributions

PCIE_BW = 8e9            # bytes/s per host link (dma latency model)


@dataclasses.dataclass
class TwoTierConfig:
    page_size: int = 256          # tokens per page
    hbm_pages: int = 256          # tier-1 pool capacity
    num_kv_heads: int = 8
    head_dim: int = 128
    num_layers: int = 1           # pages are per-layer-stacked
    dtype: str = "bfloat16"
    maintenance_interval: int = 64   # activations between maintenance
    resize_interval: int = 512       # activations between re-partitioning
    promo_frac: float = 0.25
    evict_frac: float = 0.25
    popularity_decay: float = 0.5

    @property
    def page_bytes(self) -> int:
        return (2 * self.num_layers * self.page_size * self.num_kv_heads
                * self.head_dim * jnp.dtype(self.dtype).itemsize)


@dataclasses.dataclass
class Session:
    tenant: int
    length: int = 0                       # tokens
    pages: list = dataclasses.field(default_factory=list)   # logical pages
    hbm_slots: dict = dataclasses.field(default_factory=dict)
    # logical page -> hbm pool slot (only for resident pages)


@dataclasses.dataclass
class Stats:
    activations: int = 0
    hits: int = 0                  # fully HBM-resident activations
    dma_read_bytes: int = 0        # host -> HBM copies (misses, promotions)
    dma_write_bytes: int = 0       # HBM -> host commits (the wear analog)
    latency_s: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self) | {
            "hit_ratio": self.hits / max(self.activations, 1)}


class TwoTierKVManager:
    """Host-side controller + device page pools."""

    def __init__(self, cfg: TwoTierConfig, num_tenants: int):
        self.cfg = cfg
        self.num_tenants = num_tenants
        shape = (cfg.hbm_pages, cfg.page_size, cfg.num_kv_heads,
                 cfg.head_dim)
        dt = jnp.dtype(cfg.dtype)
        # tier-1 device pools (per layer stacked on axis 0)
        self.k_pool = jnp.zeros((cfg.num_layers,) + shape, dt)
        self.v_pool = jnp.zeros((cfg.num_layers,) + shape, dt)
        self.free = list(range(cfg.hbm_pages))
        self.slot_owner: dict[int, tuple[int, int]] = {}  # slot -> (sid, lp)
        # tier-2 host pool: {(sid, logical_page): (k_np, v_np)}
        self.host: dict[tuple[int, int], tuple[np.ndarray, np.ndarray]] = {}
        self.sessions: dict[int, Session] = {}
        # controller state
        self.trace_addr: list[int] = []
        self.trace_write: list[bool] = []
        self.trackers = [PopularityTracker(cfg.popularity_decay)
                         for _ in range(num_tenants)]
        self.tenant_quota = np.full(num_tenants,
                                    cfg.hbm_pages // max(num_tenants, 1))
        self.tenant_used = np.zeros(num_tenants, np.int64)
        self.stats = Stats()
        self._since_maint = 0
        self._since_resize = 0

    # -- session lifecycle ------------------------------------------------
    def new_session(self, sid: int, tenant: int):
        self.sessions[sid] = Session(tenant=tenant)

    def _alloc_slot(self, sid: int, lp: int) -> int:
        if not self.free:
            self._evict_one(exclude_sid=sid)
        slot = self.free.pop()
        self.slot_owner[slot] = (sid, lp)
        sess = self.sessions[sid]
        sess.hbm_slots[lp] = slot
        self.tenant_used[sess.tenant] += 1
        return slot

    def _release_slot(self, sid: int, lp: int):
        sess = self.sessions[sid]
        slot = sess.hbm_slots.pop(lp, None)
        if slot is not None:
            self.slot_owner.pop(slot, None)
            self.free.append(slot)
            self.tenant_used[sess.tenant] -= 1

    def _evict_one(self, exclude_sid: int):
        """Drop the least-popular resident page (RO tier: no write-back).

        Prefers tenants over quota; never touches the active session."""
        cands = [(slot, sid, lp) for slot, (sid, lp) in self.slot_owner.items()
                 if sid != exclude_sid]
        if not cands:
            raise RuntimeError("HBM pool exhausted by a single session")

        def score(item):
            _, sid, _ = item
            sess = self.sessions[sid]
            over = self.tenant_used[sess.tenant] - self.tenant_quota[sess.tenant]
            pop = self.trackers[sess.tenant].score(sid)
            return (-over, pop)  # most-over-quota, then least popular

        slot, sid, lp = min(cands, key=score)
        self._release_slot(sid, lp)

    # -- datapath ----------------------------------------------------------
    def activate(self, sid: int) -> np.ndarray:
        """Make a session's pages HBM-resident; returns its page table.

        A fully-resident activation is a tier-1 hit (DRAM-speed); missing
        pages are copied up from the host pool (tier-2 "SSD" read) at DMA
        cost. This is the READ in the block-I/O mapping."""
        sess = self.sessions[sid]
        self._record(sid, write=False)
        missing = [lp for lp in sess.pages if lp not in sess.hbm_slots]
        self.stats.activations += 1
        if not missing:
            self.stats.hits += 1
        dt = self.k_pool.dtype
        for lp in missing:
            slot = self._alloc_slot(sid, lp)
            k_np, v_np = self.host[(sid, lp)]
            self.k_pool = self.k_pool.at[:, slot].set(jnp.asarray(k_np, dt))
            self.v_pool = self.v_pool.at[:, slot].set(jnp.asarray(v_np, dt))
            self.stats.dma_read_bytes += self.cfg.page_bytes
            self.stats.latency_s += self.cfg.page_bytes / PCIE_BW
        self._maintenance_tick(active_sid=sid)
        return self.page_table(sid)

    def append_page(self, sid: int, k_page: np.ndarray, v_page: np.ndarray):
        """Commit a freshly generated page: written once to the host pool
        (tier-2 WBWO — the only mandatory DMA write) and installed in HBM
        for the ongoing decode. This is the WRITE in the mapping."""
        sess = self.sessions[sid]
        lp = len(sess.pages)
        sess.pages.append(lp)
        self.host[(sid, lp)] = (np.asarray(k_page), np.asarray(v_page))
        self.stats.dma_write_bytes += self.cfg.page_bytes
        dt = self.k_pool.dtype
        slot = self._alloc_slot(sid, lp)
        self.k_pool = self.k_pool.at[:, slot].set(jnp.asarray(k_page, dt))
        self.v_pool = self.v_pool.at[:, slot].set(jnp.asarray(v_page, dt))
        sess.length = lp * self.cfg.page_size + k_page.shape[1]
        self._record(sid, write=True)

    def page_table(self, sid: int) -> np.ndarray:
        sess = self.sessions[sid]
        return np.array([sess.hbm_slots.get(lp, 0) for lp in sess.pages],
                        np.int32)

    def deactivate(self, sid: int):
        """Session leaves the active batch; pages stay until evicted
        (pull-mode: no datapath demotion)."""

    # -- controller --------------------------------------------------------
    def _record(self, sid: int, write: bool):
        self.trace_addr.append(sid)
        self.trace_write.append(write)
        self._since_maint += 1
        self._since_resize += 1

    def _maintenance_tick(self, active_sid: int | None = None):
        cfg = self.cfg
        if self._since_maint >= cfg.maintenance_interval:
            self._since_maint = 0
            self._update_popularity()
            self._evict_cold(exclude_sid=active_sid)
        if self._since_resize >= cfg.resize_interval:
            self._since_resize = 0
            self._repartition()

    def _window(self):
        n = self.cfg.resize_interval
        addr = np.asarray(self.trace_addr[-n:], np.int32)
        wr = np.asarray(self.trace_write[-n:], bool)
        return addr, wr

    def _update_popularity(self):
        addr, wr = self._window()
        if addr.size == 0:
            return
        r = core_reuse.pod_distances(addr, wr, Policy.RO)
        contrib = np.asarray(contributions(
            r.dist, r.served, max(int(self.tenant_quota.sum()), 1)))
        for t in range(self.num_tenants):
            mask = np.array([self.sessions[s].tenant == t if s in
                             self.sessions else False for s in addr])
            if mask.any():
                self.trackers[t].update(addr[mask], contrib[mask])

    def _evict_cold(self, exclude_sid: int | None = None):
        """Pull-mode eviction queue: drop the coldest resident sessions'
        pages down to quota (clean copies — no write-back). The actively
        decoding session is never a victim: its page table was just handed
        to the batch, so its slots must stay owned until deactivation."""
        for t in range(self.num_tenants):
            over = self.tenant_used[t] - self.tenant_quota[t]
            if over <= 0:
                continue
            resident = {}
            for slot, (sid, lp) in list(self.slot_owner.items()):
                if self.sessions[sid].tenant == t and sid != exclude_sid:
                    resident.setdefault(sid, []).append(lp)
            order = sorted(resident, key=lambda s: self.trackers[t].score(s))
            for sid in order:
                for lp in resident[sid]:
                    if over <= 0:
                        break
                    self._release_slot(sid, lp)
                    over -= 1

    def _repartition(self):
        """POD(RO) per tenant over the activation window -> PPC split of
        the HBM pool (paper §4.3 applied to pages)."""
        addr, wr = self._window()
        if addr.size == 0:
            return
        demands = np.zeros(self.num_tenants, np.int64)
        grid = np.arange(0, self.cfg.hbm_pages + 1,
                         max(self.cfg.hbm_pages // 16, 1), dtype=np.int64)
        curves = np.zeros((self.num_tenants, grid.size))
        for t in range(self.num_tenants):
            mask = np.array([s in self.sessions
                             and self.sessions[s].tenant == t for s in addr])
            if not mask.any():
                continue
            r = core_reuse.pod_distances(addr[mask], wr[mask], Policy.RO)
            # demand in sessions -> pages (mean pages per session of tenant)
            sess_pages = [len(s.pages) or 1 for s in self.sessions.values()
                          if s.tenant == t] or [1]
            per = int(np.ceil(np.mean(sess_pages)))
            demands[t] = min(core_reuse.demand_blocks(int(r.max)) * per,
                             self.cfg.hbm_pages)
            hits = core_reuse.hit_counts_at_sizes(
                r.dist, r.served, np.maximum(grid // per, 1))
            curves[t] = np.asarray(hits, np.float64) / max(mask.sum(), 1)
        res = _partition(demands, curves, grid, self.cfg.hbm_pages)
        alloc = np.maximum(res.alloc, 1)
        self.tenant_quota = alloc
