"""Tests for the interval telemetry runtime (PR 9).

The load-bearing guarantees, each pinned here:

* **Bit-identity** — threading a configured :class:`TelemetryRecorder`
  through ``EticaCache``, ``PartitionedSingleLevelCache`` or
  ``TwoTierKVManager`` changes *nothing* about cache behaviour: the
  final Stats are byte-equal to a default run.
* **Zero added syncs** — the recorder only consumes host values the
  controller already fetched; the ``jax.device_get`` call count is
  identical with telemetry configured (span timing stays opt-in because
  it is the documented exception).
* **Bounded journal + JSONL spill** — memory stays O(window) while the
  spill file keeps every row; :func:`load_journal` round-trips.
* **Histogram exposition** — golden-pinned render of the cumulative
  ``_bucket``/``_sum``/``_count`` triplet and a strict parser that
  rejects the ways histogram text goes wrong.
* **Overload detection** — LBICA-style flags are exact on synthetic
  hit-ratio collapses, end to end through ``sample_cache``.
* **Live scrape** — the stdlib endpoint serves parseable exposition
  with the telemetry families present.
"""
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EticaCache, EticaConfig, Geometry, interleave
from repro.core.baselines import make_eci_cache
from repro.kvcache import TwoTierConfig, TwoTierKVManager
from repro.runtime import metrics
from repro.runtime import telemetry as T
from repro.runtime.http import CONTENT_TYPE, MetricsServer
from repro.runtime.metrics import HistogramValue, Metric
from repro.runtime.telemetry import (DISPATCH_BUCKETS, Journal,
                                     OverloadConfig, SpanStats,
                                     TelemetryRecorder, load_journal,
                                     overload_flags)
from repro.traces import (SESSION_ACTIVATE, SESSION_APPEND, SESSION_END,
                          SESSION_NEW, SessionSpec, generate_sessions, make)

GEO = Geometry(num_sets=8, max_ways=16)


def _mix(num_vms=2, n=1000):
    return interleave(
        [make(name, n, seed=i, addr_offset=i * 10_000_000, scale=0.25)
         for i, name in enumerate(["hm_1", "web_3", "usr_0"][:num_vms])],
        seed=42)


def _etica_cfg(**kw):
    kw.setdefault("clean_quota", 2)
    return EticaConfig(dram_capacity=40, ssd_capacity=80,
                       geometry_dram=GEO, geometry_ssd=GEO,
                       resize_interval=600, promo_interval=200, **kw)


def _stats_dicts(res):
    return [dict(r.stats) for r in res]


# ---------------------------------------------------------------------------
# bit-identity + sync-count parity on all three controller families
# ---------------------------------------------------------------------------

class _CountingGet:
    """Wraps jax.device_get, counting calls — the sync budget meter."""

    def __init__(self, real):
        self.real, self.n = real, 0

    def __call__(self, x):
        self.n += 1
        return self.real(x)


def test_etica_bit_identity_and_zero_added_syncs(tmp_path, monkeypatch):
    trace = _mix()
    counter = _CountingGet(jax.device_get)
    monkeypatch.setattr(jax, "device_get", counter)

    base = EticaCache(_etica_cfg(), num_vms=2).run(trace)
    base_syncs = counter.n

    counter.n = 0
    rec = TelemetryRecorder(window=16, spill=tmp_path / "cache.jsonl",
                            overload=OverloadConfig(window=4))
    cache = EticaCache(_etica_cfg(telemetry=rec), num_vms=2)
    res = cache.run(trace)
    rec.journal.close()

    assert _stats_dicts(res) == _stats_dicts(base)
    assert counter.n == base_syncs, (
        "telemetry recorder added device->host syncs")
    # the journal actually recorded the run, interval by interval
    assert rec.journal.total >= 4
    cols = load_journal(tmp_path / "cache.jsonl")
    assert abs(cols["requests"].sum()
               - sum(s["reads"] + s["writes"] for s in _stats_dicts(res))
               ) < 1e-9
    # journal-backed clean_log view keeps the PR 8 cleaner semantics
    logs = cache.clean_log
    assert logs and all(isinstance(c, np.ndarray) for c in logs)
    for v in range(2):
        assert sum(int(c[v]) for c in logs) == res[v].stats["flushes"]


def test_chassis_bit_identity(tmp_path):
    trace = _mix(num_vms=3)
    base = make_eci_cache(120, 3, geometry=GEO,
                          resize_interval=600).run(trace)
    rec = TelemetryRecorder(window=8, spill=tmp_path / "eci.jsonl")
    cache = make_eci_cache(120, 3, geometry=GEO, resize_interval=600,
                           telemetry=rec)
    res = cache.run(trace)
    rec.journal.close()
    assert _stats_dicts(res) == _stats_dicts(base)
    assert rec.journal.total >= 1
    cols = load_journal(tmp_path / "eci.jsonl")
    assert cols["requests"].shape[1] == 3          # per-VM columns


SERVE_CFG = dict(page_size=8, hbm_pages=24, num_kv_heads=2, head_dim=4,
                 num_layers=1, dtype="float32", maintenance_interval=16,
                 resize_interval=64, pop_capacity=128, materialize=False)


def _replay_sessions(mgr, n_events=800):
    tr = generate_sessions(SessionSpec(num_tenants=3, target_live=48,
                                       max_pages=4, lifetime=20),
                           n_events, seed=0)
    rng = np.random.default_rng(7)
    pg = rng.normal(size=(1, mgr.cfg.page_size, mgr.cfg.num_kv_heads,
                          mgr.cfg.head_dim)).astype(np.float32)
    for i in range(len(tr)):
        kind, sid = int(tr.kind[i]), int(tr.sid[i])
        if kind == SESSION_NEW:
            mgr.new_session(sid, int(tr.tenant[i]))
        elif kind == SESSION_APPEND:
            mgr.append_page(sid, pg, pg)
        elif kind == SESSION_ACTIVATE:
            mgr.activate(sid)
            mgr.deactivate(sid)
        elif kind == SESSION_END:
            mgr.end_session(sid)
    return mgr.stats


def test_serving_bit_identity(tmp_path):
    base = _replay_sessions(
        TwoTierKVManager(TwoTierConfig(**SERVE_CFG), num_tenants=3))
    rec = TelemetryRecorder(window=32, spill=tmp_path / "serve.jsonl")
    mgr = TwoTierKVManager(TwoTierConfig(telemetry=rec, **SERVE_CFG),
                           num_tenants=3)
    stats = _replay_sessions(mgr)
    rec.journal.close()
    assert stats.as_dict() == base.as_dict()
    assert rec.journal.total >= 1
    row = rec.journal.last_row()
    assert row["quota"].shape == (3,)              # per-tenant columns
    assert row["overloaded"].shape == (3,)
    cols = load_journal(tmp_path / "serve.jsonl")
    # the journal covers activations up to the LAST maintenance tick;
    # events after it are in Stats but not yet journaled
    assert 0 < cols["requests"].sum() <= stats.activations


# ---------------------------------------------------------------------------
# journal: bounded ring, ordering, spill round-trip
# ---------------------------------------------------------------------------

def test_journal_ring_and_spill_roundtrip(tmp_path):
    spill = tmp_path / "j.jsonl"
    j = Journal(window=4, spill=spill)
    for i in range(10):
        j.append({"x": np.array([i, 2 * i]), "s": i})
    j.close()
    # bounded memory: ring buffers never grow past the window
    assert j.total == 10 and j.retained == 4
    assert j._cols["x"].shape == (4, 2)
    assert np.array_equal(j.column("x"),
                          [[6, 12], [7, 14], [8, 16], [9, 18]])
    assert np.array_equal(j.column("s"), [6, 7, 8, 9])
    assert j.last_row()["s"] == 9
    assert [r["s"] for r in j.rows()] == [6, 7, 8, 9]
    # the spill kept ALL rows, not just the retained window
    cols = load_journal(spill)
    assert np.array_equal(cols["i"], np.arange(10))
    assert cols["x"].shape == (10, 2)
    assert np.array_equal(cols["x"][-4:], j.column("x"))


def test_journal_rejects_bad_shapes_and_schemas(tmp_path):
    with pytest.raises(ValueError):
        Journal(window=0)
    j = Journal(window=4)
    j.append({"x": np.zeros(3)})
    with pytest.raises(ValueError):
        j.append({"x": np.zeros(2)})               # shape drift
    ragged = tmp_path / "ragged.jsonl"
    ragged.write_text('{"i": 0, "a": 1}\n{"i": 1, "b": 2}\n')
    with pytest.raises(ValueError):
        load_journal(ragged)
    garbled = tmp_path / "garbled.jsonl"
    garbled.write_text('{"i": 0}\nnot json\n')
    with pytest.raises(ValueError):
        load_journal(garbled)
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert load_journal(empty) == {}


# ---------------------------------------------------------------------------
# dispatch spans: opt-in timers, golden histogram exposition
# ---------------------------------------------------------------------------

def test_span_timing_opt_in():
    rec = TelemetryRecorder()                      # default: off
    assert rec.span("x") is T._NULL_SPAN
    with rec.span("x") as sp:
        sp.ready(jnp.arange(4))
    assert rec.spans == {}                         # nothing recorded

    rec = TelemetryRecorder(span_timing=True)
    with rec.span("demo") as sp:
        out = jnp.arange(8) * 2
        sp.ready(out)
    s = rec.spans["demo"]
    assert s.n == 1 and s.total > 0.0
    assert int(s.counts.sum()) == 1
    # a span body that raises records nothing
    with pytest.raises(RuntimeError):
        with rec.span("demo"):
            raise RuntimeError("boom")
    assert rec.spans["demo"].n == 1


HIST_GOLDEN = """\
# HELP d_seconds dispatch wall-clock
# TYPE d_seconds histogram
d_seconds_bucket{span="x",le="0.001"} 1
d_seconds_bucket{span="x",le="0.01"} 3
d_seconds_bucket{span="x",le="0.1"} 3
d_seconds_bucket{span="x",le="+Inf"} 4
d_seconds_sum{span="x"} 0.5105
d_seconds_count{span="x"} 4
"""


def test_histogram_golden_render_and_parse():
    s = SpanStats(buckets=(0.001, 0.01, 0.1))
    for v in (0.0005, 0.005, 0.005, 0.5):
        s.observe(v)
    assert list(s.counts) == [1, 2, 0, 1]          # per-bucket, +Inf last
    hv = HistogramValue(s.buckets, tuple(int(c) for c in s.counts),
                        float(s.total))
    m = Metric("d_seconds", "histogram", "dispatch wall-clock")
    m.add({"span": "x"}, hv)
    text = metrics.render([m])
    assert text == HIST_GOLDEN
    fams = metrics.parse_exposition(text)
    assert fams["d_seconds"]["type"] == "histogram"
    key = ("count", ("span", "x"))
    assert fams["d_seconds"]["samples"][key] == 4.0
    assert fams["d_seconds"]["samples"][
        ("bucket", ("le", "+Inf"), ("span", "x"))] == 4.0


def test_dispatch_buckets_are_pinned():
    assert DISPATCH_BUCKETS == (0.0001, 0.00025, 0.0005, 0.001, 0.0025,
                                0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                                1.0, 2.5)


def test_histogram_render_rejections():
    ok = HistogramValue((0.1,), (1, 0), 0.05)
    with pytest.raises(ValueError):                # scalar in histogram
        metrics.render([Metric("h", "histogram", "x").add({}, 1.0)])
    with pytest.raises(ValueError):                # HistogramValue in counter
        metrics.render([Metric("h_total", "counter", "x").add({}, ok)])
    with pytest.raises(ValueError):                # reserved 'le' label
        metrics.render([Metric("h", "histogram", "x").add({"le": "1"}, ok)])
    with pytest.raises(ValueError):                # wrong counts arity
        metrics.render([Metric("h", "histogram", "x")
                        .add({}, HistogramValue((0.1, 0.2), (1, 2), 0.0))])
    with pytest.raises(ValueError):                # bounds not ascending
        metrics.render([Metric("h", "histogram", "x")
                        .add({}, HistogramValue((0.2, 0.1), (1, 2, 3), 0.0))])
    with pytest.raises(ValueError):                # negative count
        metrics.render([Metric("h", "histogram", "x")
                        .add({}, HistogramValue((0.1,), (1, -2), 0.0))])


@pytest.mark.parametrize("bad", [
    # bare sample inside a histogram family
    "# TYPE h histogram\nh 1\n",
    # bucket without the le label
    "# TYPE h histogram\nh_bucket 1\nh_sum 0\nh_count 1\n",
    # missing +Inf bucket
    '# TYPE h histogram\nh_bucket{le="0.1"} 1\nh_sum 0\nh_count 1\n',
    # bucket series not cumulative
    '# TYPE h histogram\nh_bucket{le="0.1"} 2\n'
    'h_bucket{le="+Inf"} 1\nh_sum 0\nh_count 1\n',
    # +Inf bucket disagrees with _count
    '# TYPE h histogram\nh_bucket{le="+Inf"} 1\nh_sum 0\nh_count 2\n',
    # missing _sum/_count
    '# TYPE h histogram\nh_bucket{le="+Inf"} 1\n',
])
def test_histogram_parse_rejections(bad):
    with pytest.raises(ValueError):
        metrics.parse_exposition(bad)


# ---------------------------------------------------------------------------
# overload detection: exactness on synthetic collapses
# ---------------------------------------------------------------------------

def test_overload_flags_pure_function():
    ocfg = OverloadConfig(window=8, drop=0.6, min_requests=32)
    prev_h = np.array([[80.0, 80.0]] * 4)
    prev_r = np.array([[100.0, 100.0]] * 4)
    no_pressure = np.zeros(2, bool)
    # vm0 collapses to 0.3 < 0.6 * 0.8 = 0.48 -> flagged; vm1 holds 0.7
    f = overload_flags(prev_h, prev_r, np.array([30.0, 70.0]),
                       np.array([100.0, 100.0]), no_pressure, ocfg)
    assert f.tolist() == [True, False]
    # below the request floor: no verdict even on a collapse
    f = overload_flags(prev_h, prev_r, np.array([1.0, 70.0]),
                       np.array([10.0, 100.0]), no_pressure, ocfg)
    assert f.tolist() == [False, False]
    # unqualified baseline (all prevs under the floor): no verdict
    f = overload_flags(prev_h / 10, prev_r / 10, np.array([30.0, 70.0]),
                       np.array([100.0, 100.0]), no_pressure, ocfg)
    assert f.tolist() == [False, False]
    # pressure flags regardless of ratios
    f = overload_flags(prev_h, prev_r, np.array([80.0, 80.0]),
                       np.array([100.0, 100.0]),
                       np.array([False, True]), ocfg)
    assert f.tolist() == [False, True]


def _cum(reads, hits):
    """Cumulative per-VM stats dicts from per-interval delta lists."""
    out = []
    for v in range(len(reads[0])):
        out.append({"reads": float(sum(r[v] for r in reads)),
                    "read_hits_l1": float(sum(h[v] for h in hits))})
    return out


def test_overload_through_sample_cache():
    rec = TelemetryRecorder(overload=OverloadConfig(window=4, drop=0.6,
                                                    min_requests=32))
    reads, hits = [], []
    # four healthy intervals at 0.8, then vm0 collapses to 0.3
    for delta_h in ([80, 80], [80, 80], [80, 80], [80, 80], [30, 70]):
        reads.append([100, 100])
        hits.append(delta_h)
        row = rec.sample_cache(_cum(reads, hits))
    assert row["overloaded"].tolist() == [True, False]
    assert rec.journal.column("overloaded")[:-1].sum() == 0
    # recovery interval: baseline window still holds 0.8, 0.7 passes
    reads.append([100, 100])
    hits.append([70, 70])
    row = rec.sample_cache(_cum(reads, hits))
    assert row["overloaded"].tolist() == [False, False]
    # queue pressure path: dirty occupancy pressing the allocation
    row = rec.sample_cache(_cum(reads, hits),
                           alloc_l2=[100, 100], dirty=[96, 10])
    assert row["overloaded"].tolist() == [True, False]


# ---------------------------------------------------------------------------
# exporter + live scrape
# ---------------------------------------------------------------------------

def _demo_recorder():
    rec = TelemetryRecorder(span_timing=True)
    with rec.span("demo") as sp:
        sp.ready(jnp.ones(4))
    rec.sample_cache([{"reads": 100.0, "read_hits_l1": 60.0},
                      {"reads": 50.0, "read_hits_l1": 10.0}])
    return rec


def test_collect_telemetry_families():
    rec = _demo_recorder()
    fams = metrics.parse_exposition(
        metrics.render(metrics.collect_telemetry(rec)))
    assert fams["etica_dispatch_seconds"]["type"] == "histogram"
    assert fams["etica_telemetry_intervals_total"]["samples"][()] == 1.0
    s = fams["etica_interval_requests"]["samples"]
    assert s[(("vm", "0"),)] == 100.0 and s[(("vm", "1"),)] == 50.0
    assert fams["etica_interval_hits"]["samples"][(("vm", "0"),)] == 60.0
    assert fams["etica_overloaded"]["samples"][(("vm", "1"),)] == 0.0
    assert ("count", ("span", "demo")) in \
        fams["etica_dispatch_seconds"]["samples"]


def test_live_scrape_round_trips():
    rec = _demo_recorder()
    with MetricsServer(lambda: metrics.collect_telemetry(rec)) as srv:
        base = "http://%s:%d" % srv.address
        assert srv.url == f"{base}/metrics"
        with urllib.request.urlopen(srv.url) as r:
            assert r.status == 200
            assert r.headers["Content-Type"] == CONTENT_TYPE
            body = r.read().decode()
        with urllib.request.urlopen(f"{base}/healthz") as r:
            assert r.read() == b"ok\n"
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{base}/nope")
        # a second scrape sees updated state: the endpoint is live
        rec.sample_cache([{"reads": 120.0, "read_hits_l1": 70.0},
                          {"reads": 60.0, "read_hits_l1": 15.0}])
        with urllib.request.urlopen(srv.url) as r:
            body2 = r.read().decode()
    fams = metrics.parse_exposition(body)
    assert fams["etica_telemetry_intervals_total"]["samples"][()] == 1.0
    assert fams["etica_dispatch_seconds"]["type"] == "histogram"
    fams2 = metrics.parse_exposition(body2)
    assert fams2["etica_telemetry_intervals_total"]["samples"][()] == 2.0
    assert fams2["etica_interval_requests"]["samples"][(("vm", "0"),)] == 20.0


def test_scrape_collector_failure_is_500_not_crash():
    def boom():
        raise RuntimeError("collector exploded")
    with MetricsServer(boom) as srv:
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url)
        assert ei.value.code == 500
        # the server thread survived the failing scrape
        base = "http://%s:%d" % srv.address
        with urllib.request.urlopen(f"{base}/healthz") as r:
            assert r.read() == b"ok\n"
