"""The paper's worked examples, transcribed verbatim (Figs. 5, 8, 9)."""
import numpy as np
import pytest

from repro.core import (Policy, Trace, demand_blocks, make_cache, pod,
                        simulate_single_level, simulate_two_level, trd, urd)

# Fig. 8 workload: R S1, R S2, R S3, W S4, W S5, R S1, R S4
FIG8 = Trace.from_ops([('R', 1), ('R', 2), ('R', 3), ('W', 4), ('W', 5),
                       ('R', 1), ('R', 4)])
# Fig. 9 workload: W S1, R S2, R S3, W S4, W S5, R S3, R S1
FIG9 = Trace.from_ops([('W', 1), ('R', 2), ('R', 3), ('W', 4), ('W', 5),
                       ('R', 3), ('R', 1)])
# Fig. 5 workload: R S1, R S2, R S3, W S1, W S4, R S1, R S4
FIG5 = Trace.from_ops([('R', 1), ('R', 2), ('R', 3), ('W', 1), ('W', 4),
                       ('R', 1), ('R', 4)])


class TestFig8WBWO:
    def test_urd_is_4(self):
        assert urd(FIG8) == 4          # RAR S1: {S2,S3,S4,S5} in between

    def test_urd_allocates_5_blocks(self):
        assert demand_blocks(urd(FIG8)) == 5

    def test_pod_wbwo_is_1(self):
        assert pod(FIG8, Policy.WBWO) == 1  # RAW S4: {S5} in between

    def test_pod_wbwo_allocates_2_blocks(self):
        assert demand_blocks(pod(FIG8, Policy.WBWO)) == 2


class TestFig9RO:
    def test_urd_is_4(self):
        assert urd(FIG9) == 4

    def test_pod_ro_is_0(self):
        assert pod(FIG9, Policy.RO) == 0    # RAR S3, nothing read between

    def test_pod_ro_allocates_1_block(self):
        assert demand_blocks(pod(FIG9, Policy.RO)) == 1


class TestFig5TwoLevel:
    """One-level WB SSD: 5 SSD writes / 2 read hits; ETICA two-level:
    2 SSD writes with the same hit count (paper: '60% fewer')."""

    def test_one_level_wb(self):
        st = make_cache(1, 3)
        _, stats, _ = simulate_single_level(
            np.asarray(FIG5.addr), np.asarray(FIG5.is_write), st, 3,
            Policy.WB)
        assert int(stats.cache_writes_l2) == 5
        assert int(stats.read_hits_l2) == 2

    def test_two_level_etica(self):
        dram, ssd = make_cache(1, 3), make_cache(1, 3)
        _, _, stats, _ = simulate_two_level(
            np.asarray(FIG5.addr), np.asarray(FIG5.is_write), dram, ssd,
            3, 3, mode="npe")
        assert int(stats.cache_writes_l2) == 2
        assert int(stats.read_hits_l1) + int(stats.read_hits_l2) == 2

    def test_reduction_is_60_percent(self):
        assert 1 - 2 / 5 == pytest.approx(0.6)


class TestPolicySemantics:
    """Paper §3 policy table."""

    def test_alloc_predicates(self):
        assert Policy.WB.allocates_reads and Policy.WB.allocates_writes
        assert Policy.WT.allocates_reads and Policy.WT.allocates_writes
        assert Policy.RO.allocates_reads and not Policy.RO.allocates_writes
        assert not Policy.WBWO.allocates_reads
        assert Policy.WBWO.allocates_writes

    def test_reliability(self):
        # RO and WT never hold dirty data (reliability of write-pending)
        assert not Policy.RO.holds_dirty
        assert not Policy.WT.holds_dirty
        assert Policy.WB.holds_dirty

    def test_pod_wb_equals_urd(self):
        # paper key idea 4: in a WB cache URD and POD work similarly
        for tr in (FIG5, FIG8, FIG9):
            assert pod(tr, Policy.WB) == urd(tr)
