"""The streaming trace-store subsystem, end to end.

Covers the new ingestion layer (``repro.traces.store`` +
``repro.traces.stream``) and its controller integration:

  * exact ``Trace`` -> ``TraceStore`` -> ``Trace`` round-trips (incl.
    shard-boundary crossing, append resume, vm-less stores);
  * the MSR-Cambridge CSV and blktrace text parsers on fixture logs;
  * the stable-sort per-VM demux (``split_by_vm`` and the shard-level
    streaming demux) against the ``for_vm`` boolean-mask oracle,
    including ragged windows and VMs absent from whole windows;
  * streamed-vs-in-memory **bit-identical** aggregate Stats for both
    controllers (the acceptance bar for the whole subsystem);
  * the batched ECI policy chooser against its host-loop oracle.
"""
import numpy as np
import pytest

from repro.core import (EticaCache, EticaConfig, Geometry, Policy, Trace,
                        interleave, make_eci_cache, pad_batch, split_by_vm)
from repro.core.baselines import eci_policy
from repro.traces import (StreamingTraceSource, TraceStore, make, make_store,
                          parse_blktrace, parse_msr_csv, window_source)
from repro.traces.store import main as store_cli

GEO = Geometry(num_sets=8, max_ways=16)


def _mixed_trace(num_vms=3, reqs=2000, workloads=("hm_1", "usr_0", "web_3")):
    return interleave(
        [make(n, reqs, seed=i, addr_offset=i * 10_000_000, scale=0.25)
         for i, n in enumerate(workloads[:num_vms])], seed=0)


def _assert_trace_equal(a: Trace, b: Trace):
    assert np.array_equal(np.asarray(a.addr), np.asarray(b.addr))
    assert np.array_equal(np.asarray(a.is_write), np.asarray(b.is_write))
    if a.vm is None or b.vm is None:
        assert a.vm is None and b.vm is None
    else:
        assert np.array_equal(np.asarray(a.vm), np.asarray(b.vm))


# ---------------------------------------------------------------------------
# store round-trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shard_size", [64, 700, 10_000])
def test_store_roundtrip_exact(tmp_path, shard_size):
    trace = _mixed_trace(reqs=600)
    store = TraceStore.from_trace(tmp_path / "s", trace,
                                  shard_size=shard_size)
    assert len(store) == len(trace)
    _assert_trace_equal(store.to_trace(), trace)
    # re-open read-only: same contents, mmap-backed shards
    ro = TraceStore.open(tmp_path / "s")
    assert len(ro) == len(trace)
    assert ro.num_vms == 3 and ro.has_vm
    assert ro.num_shards == -(-len(trace) // shard_size)
    _assert_trace_equal(ro.to_trace(), trace)
    # windowed reads equal in-memory slicing
    for i, win in enumerate(ro.iter_windows(257)):
        _assert_trace_equal(win, trace[i * 257: (i + 1) * 257])


def test_store_append_resume_and_vmless(tmp_path):
    t = _mixed_trace(reqs=400)
    a, b = t[:123], t[123:]
    with TraceStore.create(tmp_path / "s", shard_size=100) as store:
        store.append(a)
    with TraceStore.open(tmp_path / "s", mode="a") as store:
        store.append(b)
    _assert_trace_equal(TraceStore.open(tmp_path / "s").to_trace(), t)

    # vm-less store: no vm column on disk, vm=None round-trip
    plain = Trace(np.asarray(t.addr), np.asarray(t.is_write))
    store = TraceStore.from_trace(tmp_path / "p", plain, shard_size=64)
    assert not store.has_vm and store.num_vms is None
    _assert_trace_equal(store.to_trace(), plain)
    with pytest.raises(ValueError):
        with TraceStore.open(tmp_path / "p", mode="a") as w:
            w.append(t)          # mixing vm-tagged into a vm-less store


def test_store_create_and_mode_guards(tmp_path):
    TraceStore.from_trace(tmp_path / "s", _mixed_trace(reqs=50))
    with pytest.raises(FileExistsError):
        TraceStore.create(tmp_path / "s")
    ro = TraceStore.open(tmp_path / "s")
    with pytest.raises(PermissionError):
        ro.append(_mixed_trace(reqs=10))


def test_unflushed_reads_rejected(tmp_path):
    """Reading past unflushed appends must fail loudly, not short-read."""
    t = _mixed_trace(reqs=50)
    store = TraceStore.create(tmp_path / "s", shard_size=1000)
    store.append(t)
    assert len(store) == len(t)      # logical length counts the buffer
    with pytest.raises(RuntimeError, match="unflushed"):
        store.to_trace()
    with pytest.raises(RuntimeError, match="unflushed"):
        store.read(0, 10)
    store.flush()
    _assert_trace_equal(store.to_trace(), t)   # flushed: reads see it all
    store.close()


# ---------------------------------------------------------------------------
# external-format parsers
# ---------------------------------------------------------------------------

MSR_FIXTURE = """\
Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime
128166372003061629,hm,0,Read,8192,4096,151
128166372016382155,hm,0,Write,12288,8192,512
128166372033741215,prxy,1,Read,0,4096,426
128166372033744415,hm,0,Read,8192,512,90
not,a,real,line
"""


def test_parse_msr_csv():
    chunks = list(parse_msr_csv(MSR_FIXTURE.splitlines(), block_size=4096))
    t = Trace.concat(chunks)
    # row 2 spans blocks 3..4 (8 KiB write at offset 12 KiB)
    assert np.asarray(t.addr).tolist() == [2, 3, 4, 0, 2]
    assert np.asarray(t.is_write).tolist() == [False, True, True, False,
                                               False]
    # vm ids per (host, disk) first appearance: hm.0 -> 0, prxy.1 -> 1
    assert np.asarray(t.vm).tolist() == [0, 0, 0, 1, 0]


BLKTRACE_FIXTURE = """\
  8,16   1        1     0.000000000  1234  Q   R 8 + 8 [fio]
  8,16   1        2     0.000104000  1234  D   R 8 + 8 [fio]
  8,32   0        3     0.000221000  1235  Q  WS 16 + 16 [fio]
  8,16   1        4     0.000300000  1234  C   R 8 + 8 [0]
  8,16   1        5     0.000412000  1234  Q   W 24 + 8 [fio]
CPU0 (fio): reads queued: 1
"""


def test_parse_blktrace():
    chunks = list(parse_blktrace(BLKTRACE_FIXTURE.splitlines(),
                                 block_size=4096))
    t = Trace.concat(chunks)
    # Q events only; sectors are 512 B: 8+8 -> block 1, 16+16 -> blocks
    # 2..3, 24+8 -> block 3 (one 4 KiB block each)
    assert np.asarray(t.addr).tolist() == [1, 2, 3, 3]
    assert np.asarray(t.is_write).tolist() == [False, True, True, True]
    assert np.asarray(t.vm).tolist() == [0, 1, 1, 0]   # per-device vms


def test_store_import_cli(tmp_path, capsys):
    csv = tmp_path / "t.csv"
    csv.write_text(MSR_FIXTURE)
    assert store_cli(["import", "--format", "msr", str(csv),
                      str(tmp_path / "s"), "--shard-size", "2"]) == 0
    assert store_cli(["info", str(tmp_path / "s")]) == 0
    out = capsys.readouterr().out
    assert "imported 5 requests" in out and "num_vms=2" in out
    store = TraceStore.open(tmp_path / "s")
    assert len(store) == 5 and store.num_shards == 3


# ---------------------------------------------------------------------------
# per-VM demux: one stable sort == V boolean-mask scans
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_split_by_vm_matches_for_vm(seed):
    rng = np.random.default_rng(seed)
    n, v = 500, 5
    t = Trace(rng.integers(0, 64, n).astype(np.int32),
              rng.random(n) < 0.4,
              rng.integers(0, v, n).astype(np.int32))
    subs = split_by_vm(t, v)
    for vm_id in range(v):
        _assert_trace_equal(subs[vm_id], t.for_vm(vm_id))
    # vm-less windows keep the shared-window convention
    plain = Trace(np.asarray(t.addr), np.asarray(t.is_write))
    assert all(s is plain for s in split_by_vm(plain, 3))


def test_streaming_demux_matches_split_across_shards(tmp_path):
    """Shard-level demux + window binary search == per-window split, even
    when windows straddle shard boundaries and VMs skip whole windows."""
    rng = np.random.default_rng(7)
    n, v = 1000, 4
    vm = rng.integers(0, v, n).astype(np.int32)
    vm[100:400] = 2          # VMs 0,1,3 absent for a long stretch
    t = Trace(rng.integers(0, 64, n).astype(np.int32), rng.random(n) < 0.3,
              vm)
    store = TraceStore.from_trace(tmp_path / "s", t, shard_size=333)
    src = StreamingTraceSource(TraceStore.open(tmp_path / "s"), num_vms=v,
                               window=170, chunk=50)
    wins = list(src.windows())
    ref = list(t.intervals(170))
    assert len(wins) == len(ref)
    for win, rw in zip(wins, ref):
        ref_subs = split_by_vm(rw, v)
        for a, b in zip(win.subs, ref_subs):
            _assert_trace_equal(a, b)


def test_stream_blocks_padding_ragged_and_empty_vms(tmp_path):
    """[V, chunk] blocks match pad_batch on the reference chunk lists —
    including all-empty VMs (all-pad rows) and ragged tails — with and
    without prefetch."""
    t = _mixed_trace(reqs=300)           # 900 requests, 3 VMs
    # VM 3 never appears: rectangular rows must still be emitted for it
    store = TraceStore.from_trace(tmp_path / "s", t, shard_size=256)
    for prefetch in (True, False):
        src = StreamingTraceSource(TraceStore.open(tmp_path / "s"),
                                   num_vms=4, window=400, chunk=150,
                                   prefetch=prefetch)
        for win, rw in zip(src.windows(), t.intervals(400)):
            lists = [list(s.intervals(150))
                     for s in split_by_vm(rw, 4)]
            n_chunks = max(map(len, lists), default=0)
            got = list(win.blocks())
            assert len(got) == n_chunks
            for k, (a, w, kth) in enumerate(got):
                ref_kth = [c[k] if k < len(c) else None for c in lists]
                ra, rw_ = pad_batch(ref_kth, 150)
                assert np.array_equal(np.asarray(a), ra)
                assert np.array_equal(np.asarray(w), rw_)
                assert a.shape == (4, 150)
                for ck, rk in zip(kth, ref_kth):
                    if rk is None or len(rk) == 0:
                        assert ck is None or len(ck) == 0
                    else:
                        _assert_trace_equal(ck, rk)


def test_window_source_type_errors_and_reparameterization():
    with pytest.raises(TypeError):
        window_source(object(), 2, 100, 10)
    # a pre-built source is re-parameterized to the controller's settings,
    # including prefetch
    pre = StreamingTraceSource(Trace(np.arange(4, dtype=np.int32),
                                     np.zeros(4, bool)),
                               num_vms=1, window=2, chunk=1, prefetch=True)
    src = window_source(pre, 3, 100, 10, prefetch=False)
    assert (src.num_vms, src.window, src.chunk, src.prefetch) == \
        (3, 100, 10, False)


def test_parser_int32_overflow_rejected():
    """Offsets past 2^31 blocks must fail loudly, not wrap into the
    datapath's negative-address no-op convention."""
    line = f"1,h,0,Read,{(2**31) * 4096},4096,1"
    with pytest.raises(ValueError, match="int32"):
        list(parse_msr_csv([line]))
    # corrupt negative offsets must not become pad/no-op addresses either
    with pytest.raises(ValueError, match="int32"):
        list(parse_msr_csv(["1,h,0,Read,-8192,4096,1"]))


# ---------------------------------------------------------------------------
# controllers: streamed == in-memory, bit for bit
# ---------------------------------------------------------------------------

def _etica(batched=True, prefetch=True, prefetch_depth=2):
    cfg = EticaConfig(dram_capacity=60, ssd_capacity=120, geometry_dram=GEO,
                      geometry_ssd=GEO, resize_interval=1500,
                      promo_interval=500, mode="full", batched=batched,
                      prefetch=prefetch, prefetch_depth=prefetch_depth)
    return EticaCache(cfg, 3)


def test_etica_streamed_equals_in_memory(tmp_path):
    trace = _mixed_trace(reqs=2500)
    store = TraceStore.from_trace(tmp_path / "s", trace, shard_size=1024)
    res_mem = _etica().run(trace)
    res_str = _etica().run(TraceStore.open(tmp_path / "s"))
    res_nopf = _etica(prefetch=False).run(TraceStore.open(tmp_path / "s"))
    res_seq = _etica(batched=False).run(TraceStore.open(tmp_path / "s"))
    for v in range(3):
        assert res_mem[v].stats == res_str[v].stats, v
        assert res_mem[v].stats == res_nopf[v].stats, v
        assert res_mem[v].stats == res_seq[v].stats, v
        assert np.array_equal(res_mem[v].alloc_history,
                              res_str[v].alloc_history)


def test_etica_streamed_prefetch_depths_bit_identical(tmp_path):
    """The depth-d host->device pipeline never changes results: streamed
    Stats at depths 0 (host arrays), 1 (classic double buffer) and 2
    (default) are bit-identical."""
    trace = _mixed_trace(reqs=2000)
    store = TraceStore.from_trace(tmp_path / "s", trace, shard_size=777)
    ref = _etica(prefetch_depth=0).run(TraceStore.open(tmp_path / "s"))
    for depth in (1, 2):
        res = _etica(prefetch_depth=depth).run(
            TraceStore.open(tmp_path / "s"))
        for v in range(3):
            assert ref[v].stats == res[v].stats, (depth, v)
            assert np.array_equal(ref[v].alloc_history,
                                  res[v].alloc_history), (depth, v)


def test_eci_streamed_equals_in_memory(tmp_path):
    trace = _mixed_trace(reqs=2500)
    store = TraceStore.from_trace(tmp_path / "s", trace, shard_size=900)

    def build(batched=True):
        return make_eci_cache(120, 3, geometry=GEO, resize_interval=1500,
                              sim_chunk=500, batched=batched)

    res_mem = build().run(trace)
    caches = {}
    res = {}
    for batched in (True, False):
        cache = build(batched)
        res[batched] = cache.run(TraceStore.open(tmp_path / "s"))
        caches[batched] = cache
    for v in range(3):
        assert res_mem[v].stats == res[True][v].stats, v
        assert res_mem[v].stats == res[False][v].stats, v
    # dynamic per-VM policies chosen by the batched chooser == host loop
    for log_b, log_s in zip(caches[True].logs, caches[False].logs):
        assert log_b.policies == log_s.policies


def test_generated_store_streams_like_memory(tmp_path):
    """make_store (generate-to-store) == the in-memory vm_mix recipe."""
    workloads = ["hm_1", "usr_0", "web_3"]
    store = make_store(tmp_path / "s", workloads, reqs_per_vm=1200,
                       scale=0.25, interleave_seed=0, shard_size=500)
    trace = _mixed_trace(reqs=1200, workloads=tuple(workloads))
    _assert_trace_equal(TraceStore.open(tmp_path / "s").to_trace(), trace)


# ---------------------------------------------------------------------------
# batched policy chooser == host-loop oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_eci_policy_chooser_batch_matches_ref(seed):
    chooser = eci_policy()
    rng = np.random.default_rng(seed)
    lens = [0, 1, 7, 50, 200]
    subs = [Trace(rng.integers(0, 32, n).astype(np.int32),
                  rng.random(n) < rng.random())  # varied read ratios
            for n in lens]
    reads = [s.n_reads for s in subs]
    got = chooser.batch(reads, lens)
    want = [chooser(s) if len(s) else Policy.WB for s in subs]
    assert got == want
    # threshold boundary: ratio exactly at the threshold picks RO
    assert chooser.batch([4], [5]) == [Policy.RO]      # 0.8 >= 0.8
    assert chooser.batch([3], [5]) == [Policy.WB]
