"""Substrate tests: optimizer, compression, checkpointing, data pipeline,
fault-tolerance runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (AsyncCheckpointer, all_steps,
                                    latest_step, restore, save)
from repro.data.pipeline import TokenPipeline
from repro.optim import (OptConfig, apply_updates, clip_by_global_norm,
                         ef_compress_update, init_error_buf,
                         init_opt_state, quantize_int8, dequantize_int8,
                         schedule)
from repro.runtime.fault import (StepFailure, StragglerMonitor, remesh,
                                 run_with_recovery)
from repro import configs


class TestAdamW:
    def test_quadratic_convergence(self):
        cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                        total_steps=200)
        params = {"w": jnp.array([5.0, -3.0])}
        opt = init_opt_state(params, cfg)
        for _ in range(150):
            g = {"w": 2 * params["w"]}      # d/dw of w^2
            params, opt, _ = apply_updates(params, g, opt, cfg)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.2

    def test_clip(self):
        g = {"a": jnp.full((4,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        from repro.optim import global_norm
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_schedule_warmup_and_decay(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        min_lr_ratio=0.1)
        assert float(schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
        assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
        assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(0.1)

    def test_bf16_moments(self):
        cfg = OptConfig(moment_dtype="bfloat16")
        opt = init_opt_state({"w": jnp.zeros((3,))}, cfg)
        assert opt["m"]["w"].dtype == jnp.bfloat16


class TestCompression:
    def test_quant_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
        q, scale = quantize_int8(x)
        deq = dequantize_int8(q, scale, x.shape)
        # error bounded by half a quantization step per row
        bound = np.asarray(scale).max() * 0.5 + 1e-7
        assert float(jnp.max(jnp.abs(deq - x))) <= bound

    def test_error_feedback_accumulates(self):
        g = {"w": jnp.full((2, 8), 0.001)}
        e = init_error_buf(g)
        total = jnp.zeros((2, 8))
        for _ in range(50):
            deq, e = ef_compress_update(g, e)
            total = total + deq["w"]
        # EF keeps the long-run mean unbiased
        assert float(jnp.mean(total)) == pytest.approx(0.05, rel=0.05)


class TestCheckpoint:
    def _state(self):
        return {"p": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "opt": {"m": jnp.ones((4,)), "step": jnp.int32(7)}}

    def test_roundtrip(self, tmp_path):
        d = str(tmp_path)
        save(d, 3, self._state())
        out, step, _ = restore(d, self._state())
        assert step == 3
        np.testing.assert_array_equal(np.asarray(out["p"]),
                                      np.asarray(self._state()["p"]))

    def test_retention_and_latest(self, tmp_path):
        d = str(tmp_path)
        for s in (1, 2, 3, 4, 5):
            save(d, s, self._state(), keep=2)
        assert sorted(all_steps(d)) == [4, 5]
        assert latest_step(d) == 5

    def test_tmp_dirs_never_restored(self, tmp_path):
        d = str(tmp_path)
        save(d, 1, self._state())
        os.makedirs(os.path.join(d, "step_9.tmp"))  # simulated crash
        assert latest_step(d) == 1

    def test_async(self, tmp_path):
        d = str(tmp_path)
        ck = AsyncCheckpointer(d)
        ck.save(11, self._state())
        ck.wait()
        assert latest_step(d) == 11


class TestDataPipeline:
    def test_deterministic_replay(self):
        cfg = configs.get_reduced("qwen3-4b")
        p = TokenPipeline(cfg, batch=4, seq_len=16, seed=3)
        a = p.batch_at(10)
        b = p.batch_at(10)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_process_shards_differ(self):
        cfg = configs.get_reduced("qwen3-4b")
        a = TokenPipeline(cfg, 4, 16, seed=3, process_index=0,
                          process_count=2).batch_at(0)
        b = TokenPipeline(cfg, 4, 16, seed=3, process_index=1,
                          process_count=2).batch_at(0)
        assert not np.array_equal(a["tokens"], b["tokens"])
        assert a["tokens"].shape[0] == 2  # local batch

    def test_prefetch_thread(self):
        cfg = configs.get_reduced("qwen3-4b")
        p = TokenPipeline(cfg, 2, 8, seed=0).start(step=5)
        s, batch = p.next()
        assert s == 5 and batch["tokens"].shape == (2, 8)
        p.stop()


class TestFaultRuntime:
    def test_straggler_flags_outlier(self):
        m = StragglerMonitor(warmup=3)
        for i in range(10):
            m.observe(i, 0.1)
        assert not m.flagged
        assert m.observe(10, 1.0)
        assert m.flagged[0][0] == 10

    def test_recovery_retries_and_restores(self):
        calls = {"n": 0}

        def step(state, batch):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("boom")
            return state + batch

        out = run_with_recovery(step, 10, 5, restore_fn=lambda: 100)
        assert out == 105 and calls["n"] == 2

    def test_recovery_gives_up(self):
        def step(state, batch):
            raise RuntimeError("always")
        with pytest.raises(StepFailure):
            run_with_recovery(step, 0, 0, max_retries=2,
                              restore_fn=lambda: 0)

    def test_remesh_roundtrip(self):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        state = {"w": np.arange(8, dtype=np.float32)}
        sh = {"w": NamedSharding(mesh, P())}
        out = remesh(state, sh)
        np.testing.assert_array_equal(np.asarray(out["w"]), state["w"])
