"""PPC partitioning (Eq. 3) and popularity (Eq. 1) unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import PopularityTracker, block_scores, contributions, partition


def _mk_curves(v, grid):
    rng = np.random.default_rng(v)
    # concave-ish random hit curves, one per VM
    raw = np.sort(rng.random((v, grid.size)), axis=1)
    raw[:, 0] = 0.0
    return raw


class TestPartition:
    GRID = np.array([0, 16, 32, 64, 128, 256], np.int64)

    def test_under_capacity_returns_demands(self):
        d = np.array([10, 20, 30])
        res = partition(d, _mk_curves(3, self.GRID), self.GRID, 100)
        assert not res.saturated
        assert (res.alloc == d).all()

    def test_over_capacity_respects_budget_and_demand(self):
        d = np.array([256, 256, 256, 256])
        res = partition(d, _mk_curves(4, self.GRID), self.GRID, 300)
        assert res.saturated
        assert res.alloc.sum() <= 300
        assert (res.alloc <= d).all()

    @given(st.integers(1, 6), st.integers(1, 500), st.integers(0, 10))
    @settings(max_examples=25, deadline=None)
    def test_property_budget_and_demand(self, v, cap, seed):
        rng = np.random.default_rng(seed)
        d = rng.integers(0, 256, v)
        res = partition(d, _mk_curves(v, self.GRID), self.GRID, cap)
        assert res.alloc.sum() <= max(cap, d.sum())
        if res.saturated:
            assert res.alloc.sum() <= cap
        assert (res.alloc <= np.maximum(d, 0)).all()

    def test_knee_preferred(self):
        """A VM with a sharp knee at 32 gets its knee before a flat VM
        gets anything beyond minimum."""
        grid = self.GRID
        curves = np.zeros((2, grid.size))
        curves[0] = np.where(grid >= 32, 0.9, 0.0)   # sharp knee at 32
        curves[1] = grid / grid.max() * 0.2          # weak, flat
        d = np.array([256, 256])
        res = partition(d, curves, grid, 64)
        assert res.alloc[0] >= 32


class TestPopularity:
    def test_eq1_shape(self):
        dist = np.array([0, 10, 100, -1], np.int32)
        served = np.array([True, True, True, False])
        c = np.asarray(contributions(dist, served, cache_size=100))
        # monotone decreasing in POD; cold access contributes 0
        assert c[0] > c[1] > c[2] > 0
        assert c[3] == 0
        assert c[0] == pytest.approx(1.0)
        assert c[2] == pytest.approx(np.exp(-1.0), rel=1e-5)

    def test_frequency_accumulates(self):
        addr = np.array([7, 7, 7, 9])
        contrib = np.array([0.5, 0.5, 0.5, 0.9])
        uniq, scores = block_scores(addr, contrib)
        assert dict(zip(uniq.tolist(), scores.tolist())) == \
            pytest.approx({7: 1.5, 9: 0.9})

    def test_tracker_top_bottom(self):
        t = PopularityTracker(decay=1.0)
        t.update(np.array([1, 1, 2, 3]), np.array([1.0, 1.0, 0.5, 0.01]))
        cands = np.array([1, 2, 3])
        assert t.most_popular(cands, 0.3).tolist() == [1]
        assert t.least_popular(cands, 0.3).tolist() == [3]

    def test_tracker_decay(self):
        t = PopularityTracker(decay=0.5)
        t.update(np.array([1]), np.array([1.0]))
        t.update(np.array([2]), np.array([1.0]))
        assert t.score(1) == pytest.approx(0.5)
        assert t.score(2) == pytest.approx(1.0)
