"""Property tests for ETICA's two-level content/reliability invariants.

Paper §4.1/§4.2: the DRAM level is a read-only cache — it may never hold
dirty (write-pending) data, so all dirty blocks live in the non-volatile
SSD level, and a write to a DRAM-resident address must invalidate the
stale DRAM copy rather than update it.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import Trace, make_cache, simulate_two_level
from repro.core.simulator import (clean_blocks, clean_blocks_ref,
                                  resident_blocks)

SETTINGS = dict(max_examples=15, deadline=None)
SETS_D, WAYS_D = 4, 4
SETS_S, WAYS_S = 8, 4


def traces(max_size=150, addr_space=20):
    return st.lists(
        st.tuples(st.integers(0, addr_space - 1), st.booleans()),
        min_size=1, max_size=max_size,
    ).map(lambda ops: Trace(
        addr=np.array([a for a, _ in ops], np.int32),
        is_write=np.array([w for _, w in ops], bool)))


def run(tr, mode, ways_dram=WAYS_D, ways_ssd=WAYS_S):
    return simulate_two_level(
        np.asarray(tr.addr), np.asarray(tr.is_write),
        make_cache(SETS_D, WAYS_D), make_cache(SETS_S, WAYS_S),
        ways_dram, ways_ssd, mode=mode)


@given(traces())
@settings(**SETTINGS)
def test_dram_never_dirty(tr):
    """The volatile level is RO: it never holds write-pending data."""
    for mode in ("full", "npe"):
        dram, _, _, _ = run(tr, mode)
        assert not bool(np.asarray(dram.dirty).any()), mode


@given(traces())
@settings(**SETTINGS)
def test_dirty_blocks_live_only_in_ssd(tr):
    """Every dirty block in the hierarchy sits in the SSD level and holds
    an address that was actually written at some point."""
    written = set(np.asarray(tr.addr)[np.asarray(tr.is_write)].tolist())
    for mode in ("full", "npe"):
        dram, ssd, _, _ = run(tr, mode)
        assert not bool(np.asarray(dram.dirty).any())
        tags = np.asarray(ssd.tags)
        dirty = np.asarray(ssd.dirty)
        assert not (dirty & (tags < 0)).any()       # dirty implies valid
        for a in tags[dirty].tolist():
            assert a in written, (mode, a)


@given(traces())
@settings(**SETTINGS)
def test_write_invalidates_dram_copy(tr):
    """After the window, no address whose LAST access was a write is
    DRAM-resident: the write bypassed DRAM and killed the stale copy, and
    only reads re-promote."""
    addr = np.asarray(tr.addr)
    is_write = np.asarray(tr.is_write)
    last_op_is_write = {}
    for a, w in zip(addr.tolist(), is_write.tolist()):
        last_op_is_write[a] = w
    for mode in ("full", "npe"):
        dram, _, _, _ = run(tr, mode)
        for a in resident_blocks(dram, WAYS_D).tolist():
            assert not last_op_is_write[a], (mode, a)


def test_write_invalidate_worked_example():
    """R(7) promotes 7 into DRAM; W(7) must evict the now-stale copy."""
    tr = Trace.from_ops([('R', 7), ('W', 7)])
    for mode in ("full", "npe"):
        dram, ssd, stats, _ = run(tr, mode)
        assert 7 not in resident_blocks(dram, WAYS_D).tolist()
        if mode == "npe":   # write-allocated into the SSD, dirty there
            assert 7 in resident_blocks(ssd, WAYS_S).tolist()
            assert bool(np.asarray(ssd.dirty).any())


# ---------------------------------------------------------------------------
# background cleaning variants (PR 8): flushing dirty bits between
# windows must preserve every content invariant and the hit/miss stats
# ---------------------------------------------------------------------------

@given(traces(), st.integers(0, 6))
@settings(**SETTINGS)
def test_cleaning_preserves_content_invariants(tr, quota):
    """Cleaning the SSD level after a window: residency is untouched
    (flushed blocks stay cached), dirty bits only ever clear, the RO-DRAM
    and dirty-implies-valid invariants survive, and the vectorized op
    agrees with the sequential oracle."""
    dram, ssd, _, _ = run(tr, "npe")
    before_res = set(resident_blocks(ssd, WAYS_S).tolist())
    before_dirty = np.asarray(ssd.dirty).copy()
    cleaned, n_fl, left = clean_blocks(ssd, WAYS_S, quota)
    assert set(resident_blocks(cleaned, WAYS_S).tolist()) == before_res
    after = np.asarray(cleaned.dirty)
    assert not (after & ~before_dirty).any()
    assert int(n_fl) == min(quota, int(before_dirty.sum()))
    assert int(left) == int(before_dirty.sum()) - int(n_fl)
    assert not (after & (np.asarray(cleaned.tags) < 0)).any()
    assert not bool(np.asarray(dram.dirty).any())
    want, want_fl, want_left = clean_blocks_ref(ssd, WAYS_S, quota)
    np.testing.assert_array_equal(after, np.asarray(want.dirty))
    assert (int(n_fl), int(left)) == (want_fl, want_left)


@given(traces(max_size=100), traces(max_size=100), st.integers(1, 8))
@settings(**SETTINGS)
def test_cleaning_does_not_change_hit_miss_stats(tr1, tr2, quota):
    """Running a second window from the cleaned state vs the dirty state:
    every hit/miss channel is bit-identical — the cleaner only moves
    write-back traffic, it never changes what the cache serves."""
    dram, ssd, _, _ = run(tr1, "npe")
    cleaned, _, _ = clean_blocks(ssd, WAYS_S, quota)
    a2, w2 = np.asarray(tr2.addr), np.asarray(tr2.is_write)
    _, _, s_dirty, _ = simulate_two_level(a2, w2, dram, ssd,
                                          WAYS_D, WAYS_S, mode="npe")
    _, _, s_clean, _ = simulate_two_level(a2, w2, dram, cleaned,
                                          WAYS_D, WAYS_S, mode="npe")
    for f in ("reads", "writes", "read_hits_l1", "read_hits_l2",
              "write_hits_l2", "disk_reads", "bypassed"):
        assert int(getattr(s_dirty, f)) == int(getattr(s_clean, f)), f


@given(traces(max_size=80))
@settings(**SETTINGS)
def test_full_mode_ssd_only_dirties_existing_blocks(tr):
    """Pull-mode SSD: the datapath never allocates, so every SSD-resident
    block after the window was already there (here: none, starting empty)
    — write misses go straight to disk."""
    _, ssd, stats, _ = run(tr, "full")
    assert resident_blocks(ssd, WAYS_S).size == 0
    assert int(stats.cache_writes_l2) == 0
