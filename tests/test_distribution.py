"""Sharding-rule tests against the production mesh topology (abstract —
no devices needed) + host-mesh lowering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh


def _abstract_mesh(shape, names):
    try:
        return jax.sharding.AbstractMesh(shape, names)
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(names, shape)))  # older signature


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _leaf_specs(cfg, mesh, fsdp=False):
    params = ST.abstract_params(cfg)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: (leaf, SH.param_spec(path, leaf, cfg, mesh, fsdp)),
        params)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["pod", "multipod"])
def test_param_specs_divisible(arch, mesh):
    """Every assigned mesh axis divides its tensor dimension — the
    invariant that makes lower+compile succeed."""
    cfg = configs.get(arch)
    flat = jax.tree_util.tree_leaves(
        _leaf_specs(cfg, mesh), is_leaf=lambda x: isinstance(x, tuple))
    n_sharded = 0
    for leaf, spec in flat:
        for dim, s in enumerate(spec):
            if s is None:
                continue
            axes = s if isinstance(s, tuple) else (s,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % size == 0, (arch, spec, leaf.shape)
            n_sharded += 1
    assert n_sharded > 0, f"{arch}: nothing sharded at all"


@pytest.mark.parametrize("arch", ["llama3-405b", "mixtral-8x22b"])
def test_fsdp_auto_enabled_for_big_models(arch):
    cfg = configs.get(arch)
    assert SH.should_fsdp(cfg, MESH)


def test_fsdp_off_for_small_models():
    assert not SH.should_fsdp(configs.get("qwen3-4b"), MESH)
    assert not SH.should_fsdp(configs.get("mamba2-370m"), MESH)


def test_moe_expert_parallel_vs_tensor_parallel():
    """deepseek (64 experts) shards experts over the 16-way axis; mixtral
    (8 experts) falls back to TP inside experts."""
    ds = configs.get("deepseek-moe-16b")
    mx = configs.get("mixtral-8x22b")
    for cfg, expect_ep in ((ds, True), (mx, False)):
        flat = jax.tree_util.tree_leaves(
            _leaf_specs(cfg, MESH), is_leaf=lambda x: isinstance(x, tuple))
        for leaf, spec in flat:
            if leaf.ndim - 1 == 3 and leaf.shape[-1] != leaf.shape[-2]:
                pass
        # look at a stacked moe w_up leaf [R, E, D, F]
        found = False
        params = ST.abstract_params(cfg)
        def visit(path, leaf):
            nonlocal found
            names = [getattr(p, "key", "") for p in path]
            if names[-1] == "w_up" and leaf.ndim == 4:
                spec = SH.param_spec(path, leaf, cfg, MESH, False)
                if expect_ep:
                    assert spec[1] == "model", (cfg.name, spec)
                else:
                    assert spec[3] == "model", (cfg.name, spec)
                found = True
            return leaf
        jax.tree_util.tree_map_with_path(visit, params)
        assert found, cfg.name


def test_kv_cache_specs_divisible():
    from repro.models.config import SHAPES
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        shape = SHAPES["decode_32k"]
        cache, tokens, pos = ST.decode_specs(cfg, shape)
        def visit(path, leaf):
            spec = SH.cache_leaf_spec(path, leaf, MESH)
            for dim, s in enumerate(spec):
                if s is None:
                    continue
                axes = s if isinstance(s, tuple) else (s,)
                size = int(np.prod([MESH.shape[a] for a in axes]))
                assert leaf.shape[dim] % size == 0, (arch, spec, leaf.shape)
            return leaf
        jax.tree_util.tree_map_with_path(visit, cache)


def test_host_mesh_train_step_runs_sharded():
    """Full train step jitted with explicit shardings on the host mesh."""
    mesh = make_host_mesh()
    cfg = configs.get_reduced("qwen3-4b")
    specs = ST.input_specs(cfg, __import__(
        "repro.models.config", fromlist=["ShapeSpec"]).ShapeSpec(
        "t", 32, 2, "train"))
    psh = SH.param_shardings(cfg, specs["params"], mesh)
    osh = SH.opt_shardings(cfg, specs["params"], mesh)
    bsh = SH.batch_shardings(specs["batch"], mesh)
    step = ST.make_train_step(cfg)
    from repro.models import model as M
    from repro.optim import OptConfig, init_opt_state
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, OptConfig())
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32)}
    with mesh:
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh))
        p2, o2, metrics = jitted(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
