"""Serving-path correctness: prefill/decode equivalence, SWA ring cache,
and the ETICA two-tier KV manager's policy behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kvcache import GlobalLRUManager, TwoTierConfig, TwoTierKVManager
from repro.models import model as M


def _mk(arch, **over):
    cfg = configs.get_reduced(arch)
    if over:
        cfg = dataclasses.replace(cfg, **over)
    return cfg


@pytest.mark.parametrize("arch", [
    "qwen3-4b", "mamba2-370m", "jamba-v0.1-52b", "seamless-m4t-large-v2",
    "deepseek-moe-16b", "internvl2-26b"])
def test_prefill_decode_matches_full_forward(arch):
    over = {"moe_capacity_factor": 8.0} if "moe" in arch or "jamba" in arch \
        else {}
    cfg = _mk(arch, **over)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, P, EXTRA = 2, 32, 2
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (B, P + EXTRA), 0, cfg.vocab_size)
    if cfg.is_encdec:
        frames = jax.random.normal(key, (B, 16, cfg.d_model))
        mk_batch = lambda s: {"frames": frames, "dec_tokens": toks[:, :s]}
        offset = 0
    elif cfg.frontend == "vision":
        patches = jax.random.normal(key, (B, cfg.frontend_tokens, cfg.d_model))
        mk_batch = lambda s: {"tokens": toks[:, :s], "patches": patches}
        offset = cfg.frontend_tokens
    else:
        mk_batch = lambda s: {"tokens": toks[:, :s]}
        offset = 0
    cache_len = P + EXTRA + offset
    _, cache = M.prefill(params, cfg, mk_batch(P), cache_len=cache_len)
    for i in range(EXTRA):
        pos = P + i + offset
        logits_d, cache = M.decode_step(params, cfg, toks[:, P+i:P+i+1],
                                        cache, pos)
        logits_p, _ = M.prefill(params, cfg, mk_batch(P + i + 1),
                                cache_len=cache_len)
        scale = float(jnp.max(jnp.abs(logits_p[:, -1]))) + 1e-6
        err = float(jnp.max(jnp.abs(logits_d[:, -1] - logits_p[:, -1])))
        assert err / scale < 2e-2, (arch, i, err / scale)


def test_swa_ring_cache_matches_full_cache():
    """mixtral-style sliding window: decoding with a ring cache of size
    `window` must match decoding with the full-length cache."""
    cfg = _mk("mixtral-8x22b", moe_capacity_factor=8.0, sliding_window=32)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    B, P, EXTRA = 1, 48, 4
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, P + EXTRA), 0,
                              cfg.vocab_size)
    mk_batch = lambda s: {"tokens": toks[:, :s]}
    # full cache
    _, cache_full = M.prefill(params, cfg, mk_batch(P), cache_len=P + EXTRA)
    # ring cache at window size
    _, cache_ring = M.prefill(params, cfg, mk_batch(P),
                              cache_len=cfg.sliding_window)
    for i in range(EXTRA):
        pos = P + i
        lf, cache_full = M.decode_step(params, cfg, toks[:, pos:pos+1],
                                       cache_full, pos)
        lr, cache_ring = M.decode_step(params, cfg, toks[:, pos:pos+1],
                                       cache_ring, pos)
        scale = float(jnp.max(jnp.abs(lf))) + 1e-6
        assert float(jnp.max(jnp.abs(lf - lr))) / scale < 2e-2, i


class TestTwoTierManager:
    CFG = TwoTierConfig(page_size=8, hbm_pages=24, num_kv_heads=2,
                        head_dim=8, num_layers=1, dtype="float32",
                        maintenance_interval=16, resize_interval=64)

    def _drive(self, mgr, steps=300, seed=0):
        rng = np.random.default_rng(seed)
        for sid in range(12):
            mgr.new_session(sid, 0 if sid < 3 else 1)
        for _ in range(steps):
            sid = int(rng.integers(0, 3)) if rng.random() < 0.7 \
                else int(rng.integers(3, 12))
            mgr.activate(sid)
            if rng.random() < 0.3 and len(mgr.sessions[sid].pages) < 4:
                pg = rng.normal(size=(1, 8, 2, 8)).astype(np.float32)
                mgr.append_page(sid, pg, pg)
        return mgr.stats

    def test_wbwo_write_bound(self):
        """Tier-2 writes == pages generated (each committed exactly once)
        — the WBWO endurance bound."""
        mgr = TwoTierKVManager(self.CFG, 2)
        st = self._drive(mgr)
        assert st.dma_write_bytes == len(mgr.host) * self.CFG.page_bytes

    def test_beats_lru_writeback_on_dma_writes(self):
        a = self._drive(TwoTierKVManager(self.CFG, 2)).as_dict()
        b = self._drive(GlobalLRUManager(self.CFG, 2)).as_dict()
        assert a["dma_write_bytes"] < b["dma_write_bytes"]

    def test_page_table_points_at_resident_pages(self):
        mgr = TwoTierKVManager(self.CFG, 2)
        self._drive(mgr, steps=100)
        sid = 0
        pt = mgr.activate(sid)
        sess = mgr.sessions[sid]
        for lp, slot in enumerate(pt):
            assert mgr.slot_owner[int(slot)] == (sid, lp)

    def test_repartition_tracks_hot_tenant(self):
        mgr = TwoTierKVManager(self.CFG, 2)
        self._drive(mgr, steps=400)
        # tenant 0 gets 70% of activations across 3 sessions: its quota
        # should be at least its fair share
        assert mgr.tenant_quota[0] >= self.CFG.hbm_pages // 4
