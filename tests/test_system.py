"""End-to-end behaviour tests for the paper's system.

The small-scale equivalents of the paper's §5 experiments: ETICA vs
ECI-Cache on a multi-VM trace (endurance + reliability + sizing), the
training driver with failure injection, and the HLO analyzer used by the
dry-run/roofline pipeline.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (EticaCache, EticaConfig, Geometry, Policy, Trace,
                        interleave, make_centaur, make_eci_cache,
                        make_scave, make_vcacheshare, pod, urd)
from repro.traces import make, names


GEO = Geometry(num_sets=16, max_ways=32)


@pytest.fixture(scope="module")
def mv_trace():
    vms = ["hm_1", "usr_0", "web_3"]
    traces = [make(n, 4000, seed=i, addr_offset=i * 10_000_000, scale=0.25)
              for i, n in enumerate(vms)]
    return interleave(traces, seed=42)


@pytest.fixture(scope="module")
def results(mv_trace):
    cfg = EticaConfig(dram_capacity=400, ssd_capacity=800,
                      geometry_dram=GEO, geometry_ssd=GEO,
                      resize_interval=3000, promo_interval=1000)
    etica = EticaCache(cfg, num_vms=3).run(mv_trace)
    eci = make_eci_cache(1200, 3, geometry=GEO,
                         resize_interval=3000).run(mv_trace)
    return etica, eci


def test_etica_improves_endurance(results):
    """Paper §5.4: ETICA reduces SSD writes vs ECI-Cache (33.8% avg)."""
    etica, eci = results
    total_e = sum(r.ssd_writes for r in etica)
    total_c = sum(r.ssd_writes for r in eci)
    assert total_e < total_c
    assert 1 - total_e / total_c > 0.2


def test_etica_read_hits_served_fast(results):
    etica, _ = results
    for r in etica:
        s = r.stats
        assert s["read_hits_l1"] >= 0
        assert s["reads"] + s["writes"] > 0
        assert 0 <= r.hit_ratio <= 1


def test_pod_sizing_below_urd(mv_trace):
    """Paper §5.2: POD allocates less than URD for RO/WBWO policies."""
    for v in range(3):
        sub = mv_trace.for_vm(v)[:2000]
        u = urd(sub)
        assert pod(sub, Policy.RO) <= u
        assert pod(sub, Policy.WBWO) <= u


def test_all_baselines_run(mv_trace):
    short = mv_trace[:3000]
    for factory in (make_centaur, make_scave, make_vcacheshare):
        res = factory(600, 3, geometry=GEO, resize_interval=1500).run(short)
        assert len(res) == 3
        for r in res:
            assert 0 <= r.hit_ratio <= 1


def test_trace_generators_match_spec():
    from repro.traces import SPECS
    for name in names():
        tr = make(name, 1000, seed=0)
        assert len(tr) == 1000
        spec = SPECS[name]
        read_frac = tr.n_reads / len(tr)
        assert abs(read_frac - spec.read_ratio) < 0.1, name


def test_train_driver_with_failure_injection(tmp_path):
    from repro.launch.train import main
    losses = main(["--arch", "phi4-mini-3.8b", "--steps", "8",
                   "--batch", "2", "--seq", "32",
                   "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
                   "--inject-failure-at", "5", "--log-every", "100"])
    assert len(losses) == 8
    assert np.isfinite(losses).all()
    from repro.checkpoint.store import latest_step
    assert latest_step(str(tmp_path)) == 6


def test_hlo_analyzer_ground_truth():
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_analysis import analyze

    def f(x, w):
        def body(c, _):
            return jnp.dot(c, w, preferred_element_type=jnp.float32), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r = analyze(jax.jit(f).lower(x, w).compile().as_text())
    assert r["dot_flops"] == 2 * 128 * 256 * 256 * 7


def test_dryrun_cell_subprocess():
    """One real dry-run cell end-to-end in a subprocess (512 placeholder
    devices, 16x16 mesh, lower+compile+analyze)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-370m", "--shape", "decode_32k", "--out",
         "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, (r.stderr or "")[-2000:]
    rec = json.loads(r.stdout)
    assert rec["status"] == "ok"
    assert rec["flops"] > 0 and rec["collective_bytes"] >= 0


def test_fast_global_two_level_baseline():
    """Table 1's FAST-style global two-level baseline runs and promotes
    hot blocks into the SSD tier."""
    from repro.core.baselines import make_fast
    tr = make("hm_1", 3000, seed=3, scale=0.25)
    r = make_fast(200, 400).run(tr)
    assert 0 < r.hit_ratio <= 1
    assert r.ssd_writes > 0  # hot promotions happened


def test_l2arc_global_two_level_baseline():
    """L2ARC-style baseline: DRAM evictions spill to the SSD FIFO; a
    re-read of a spilled block hits the SSD tier."""
    from repro.core.baselines import make_l2arc
    tr = make("hm_1", 3000, seed=5, scale=0.25)
    r = make_l2arc(100, 400).run(tr)
    assert 0 < r.hit_ratio <= 1
    assert r.stats.get("read_hits_l2", 0) > 0  # SSD served spilled reads
