"""Property tests for the IO-classification subsystem.

Three layers, matching the package convention:

  1. the vectorized rule engine (:func:`classify_block`, one fused jnp
     dispatch over ``[V, N]`` blocks with per-VM sequential-run carry)
     against the scalar per-request oracle :func:`classify_ref`, on
     random rule sets and random request blocks — class ids and carries
     bit-identical, including across window splits;
  2. the controllers with a single match-all class against
     ``classifier=None`` — per-VM Stats bit-identical on both the
     two-level ETICA controller and the one-level chassis, batched and
     sequential;
  3. bypass semantics: a bypass class never allocates (the cache stays
     empty under an always-bypass classifier) and its traffic is
     surfaced through the new ``Stats.bypassed`` channel.
"""
import dataclasses

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.classify import (ClassRule, Classifier, IOClass, classify_block,
                            classify_ref, compile_rules, match_all,
                            seq_cutoff)
from repro.core import (EticaCache, EticaConfig, Geometry, Policy,
                        make_centaur)
from repro.core.trace import Trace

SETTINGS = dict(max_examples=20, deadline=None)

GEO = Geometry(num_sets=8, max_ways=16)


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

def _interval(lo_max, width_max):
    return st.one_of(
        st.none(),
        st.tuples(st.integers(0, lo_max), st.integers(1, width_max)).map(
            lambda t: (t[0], t[0] + t[1])),
        st.tuples(st.integers(0, lo_max)).map(lambda t: (t[0], None)),
        st.tuples(st.integers(1, lo_max)).map(lambda t: (None, t[0])),
    )


rules = st.builds(ClassRule,
                  size=_interval(8, 8),
                  lba=_interval(600, 400),
                  run_len=_interval(96, 64),
                  direction=st.sampled_from([None, "read", "write"]))

io_classes = st.builds(IOClass,
                       name=st.just("c"),
                       rules=st.lists(rules, min_size=0, max_size=3),
                       bypass=st.booleans())


@st.composite
def rule_sets(draw):
    """A valid class list: default first (never bypass), 1-4 others."""
    default = IOClass("default",
                      rules=tuple(draw(st.lists(rules, max_size=2))))
    rest = draw(st.lists(io_classes, min_size=0, max_size=4))
    return [default, *rest]


@st.composite
def blocks(draw):
    """Random ``[V, N]`` request blocks with some sequential structure."""
    v = draw(st.integers(1, 3))
    n = draw(st.integers(0, 70))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    addr = rng.integers(0, 800, (v, n))
    size = rng.integers(1, 9, (v, n))
    # splice contiguous continuations so run_len rules actually fire
    for row in range(v):
        i = 1
        while i < n:
            if rng.random() < 0.5:
                addr[row, i] = addr[row, i - 1] + size[row, i - 1]
            i += 1
    return (addr.astype(np.int64), rng.random((v, n)) < 0.4,
            size.astype(np.int64))


# ---------------------------------------------------------------------------
# 1. vectorized engine == scalar oracle
# ---------------------------------------------------------------------------

@given(rule_sets(), blocks(), st.integers(0, 60))
@settings(**SETTINGS)
def test_classify_block_matches_ref(classes, block, split):
    plan = compile_rules(classes)
    addr, is_write, size = block
    v, n = addr.shape
    lens = np.full(v, n, np.int32)
    ce = np.full(v, -1, np.int32)
    cl = np.zeros(v, np.int32)
    cls, ce2, cl2 = classify_block(addr, is_write, size, lens, ce, cl, plan)
    cls, ce2, cl2 = np.asarray(cls), np.asarray(ce2), np.asarray(cl2)
    for row in range(v):
        want, we, wr = classify_ref(addr[row], is_write[row], size[row], plan)
        assert np.array_equal(cls[row], want), (row, cls[row], want)
        assert ce2[row] == we and cl2[row] == wr

    # window-split equivalence: carry threads runs across the cut
    k = min(split, n)
    c1, e1, l1 = classify_block(addr[:, :k], is_write[:, :k], size[:, :k],
                                np.full(v, k, np.int32), ce, cl, plan)
    c2, e2, l2 = classify_block(addr[:, k:], is_write[:, k:], size[:, k:],
                                np.full(v, n - k, np.int32),
                                np.asarray(e1), np.asarray(l1), plan)
    joined = np.concatenate([np.asarray(c1), np.asarray(c2)], axis=1)
    assert np.array_equal(joined, cls)
    assert np.array_equal(np.asarray(e2), ce2)
    assert np.array_equal(np.asarray(l2), cl2)


@given(blocks(), st.integers(1, 128))
@settings(**SETTINGS)
def test_classifier_subs_matches_trace_ref(block, threshold):
    """Classifier.classify_subs (padded-bucket dispatch over ragged
    sub-traces) == the scalar per-trace oracle, carries included."""
    addr, is_write, size = block
    c = seq_cutoff(threshold)
    subs = [Trace(addr=addr[i].astype(np.int32), is_write=is_write[i],
                  size=size[i].astype(np.int32))
            for i in range(addr.shape[0])]
    ce, cl = c.init_carry(len(subs))
    got, ce2, cl2 = c.classify_subs(subs, ce, cl)
    for i, sub in enumerate(subs):
        want, we, wr = c.classify_trace_ref(sub)
        assert np.array_equal(got[i], want)
        assert ce2[i] == we and cl2[i] == wr


# ---------------------------------------------------------------------------
# 2. match-all class == unclassified, bit for bit
# ---------------------------------------------------------------------------

def _mix(seed=0, v=3, n=3000):
    rng = np.random.default_rng(seed)
    return Trace(addr=rng.integers(0, 300, n).astype(np.int32),
                 is_write=rng.random(n) < 0.4,
                 vm=rng.integers(0, v, n).astype(np.int32)), v


def _etica(classifier, v, batched):
    cfg = EticaConfig(dram_capacity=48, ssd_capacity=96, geometry_dram=GEO,
                      geometry_ssd=GEO, resize_interval=1000,
                      promo_interval=250, batched=batched,
                      classifier=classifier)
    return EticaCache(cfg, v)


def _chassis(classifier, v, batched):
    return make_centaur(96, v, geometry=GEO, resize_interval=1000,
                        sim_chunk=250, batched=batched,
                        classifier=classifier)


@given(st.integers(0, 2**31 - 1), st.booleans())
@settings(max_examples=4, deadline=None)
def test_match_all_bit_identical(seed, batched):
    trace, v = _mix(seed)
    for build in (_etica, _chassis):
        base = build(None, v, batched).run(trace)
        ma = build(match_all(), v, batched).run(trace)
        for r0, r1 in zip(base, ma):
            assert r0.stats == r1.stats
            assert np.array_equal(r0.alloc_history, r1.alloc_history)


def test_classified_batched_matches_sequential():
    """seq-cutoff engaged (scans long enough to trip it): the classified
    batched datapath == the classified sequential oracle on both
    controllers, and requests actually bypass."""
    trace, v = _mix(7)
    runs = [np.arange(50_000 + i * 500, 50_000 + i * 500 + 64,
                      dtype=np.int32) for i in range(30)]
    seq = np.concatenate(runs)
    big = Trace(addr=np.concatenate([np.asarray(trace.addr), seq]),
                is_write=np.concatenate([np.asarray(trace.is_write),
                                         np.zeros(len(seq), bool)]),
                vm=np.concatenate([np.asarray(trace.vm),
                                   np.full(len(seq), 0, np.int32)]))
    c = seq_cutoff(32)
    for build in (_etica, _chassis):
        rb = build(c, v, True).run(big)
        rs = build(c, v, False).run(big)
        for r0, r1 in zip(rb, rs):
            assert r0.stats == r1.stats
        assert rb[0].stats["bypassed"] == 30 * (64 - 32 + 1)


# ---------------------------------------------------------------------------
# 3. bypass never allocates
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.booleans())
@settings(max_examples=4, deadline=None)
def test_bypass_class_never_allocates(seed, batched):
    """An always-bypass classifier: every request bypasses, nothing is
    ever inserted (no cache writes, no hits), all traffic goes to disk."""
    trace, v = _mix(seed, n=1500)
    bypass_all = Classifier([
        IOClass("default"),
        IOClass("void", rules=(ClassRule(),), bypass=True),
    ])
    for build in (_etica, _chassis):
        res = build(bypass_all, v, batched).run(trace)
        for r in res:
            s = r.stats
            assert s["bypassed"] == s["reads"] + s["writes"]
            assert s["read_hits_l1"] == s["read_hits_l2"] == 0
            assert s["write_hits_l2"] == 0
            assert s["cache_writes_l2"] == 0
            assert s["disk_reads"] == s["reads"]
            assert s["disk_writes"] >= s["writes"]


def test_way_bounds_partitioning():
    """Explicit ways_frac classes carve exclusive top slices in class
    order; pool classes share the remainder; bypass classes get none."""
    c = Classifier([
        IOClass("default"),
        IOClass("a", ways_frac=0.25),
        IOClass("b", ways_frac=0.5),
        IOClass("skip", rules=(ClassRule(run_len=(8, None)),), bypass=True),
    ])
    lo, hi = c.way_bounds(np.asarray([16, 0], np.int32))
    assert lo[0].tolist() == [0, 12, 4, 0]
    assert hi[0].tolist() == [4, 16, 12, 0]
    assert lo[1].tolist() == hi[1].tolist() == [0, 0, 0, 0]


def test_policy_override_per_class():
    c = Classifier([IOClass("default"),
                    IOClass("wt", policy=Policy.WT)])
    pol = c.vm_policies([Policy.WB, Policy.RO])
    assert pol[0] == [Policy.WB, Policy.WT]
    assert pol[1] == [Policy.RO, Policy.WT]
