"""Batched serving controller vs the host-dict oracle, churn generator
validity, bounded controller memory, and the repartition edge-case fixes.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.partition import size_grid
from repro.kvcache import (GlobalLRUManager, TwoTierConfig, TwoTierKVManager,
                           quota_with_floor)
from repro.traces import (SESSION_ACTIVATE, SESSION_APPEND, SESSION_END,
                          SESSION_NEW, SessionSpec, SessionTrace,
                          generate_sessions)

CFG = TwoTierConfig(page_size=8, hbm_pages=24, num_kv_heads=2, head_dim=4,
                    num_layers=1, dtype="float32",
                    maintenance_interval=16, resize_interval=64,
                    pop_capacity=128, materialize=False)


def _replay(mgr, trace, bank_seed=7):
    rng = np.random.default_rng(bank_seed)
    pg = rng.normal(size=(1, mgr.cfg.page_size, mgr.cfg.num_kv_heads,
                          mgr.cfg.head_dim)).astype(np.float32)
    for i in range(len(trace)):
        kind, sid = int(trace.kind[i]), int(trace.sid[i])
        if kind == SESSION_NEW:
            mgr.new_session(sid, int(trace.tenant[i]))
        elif kind == SESSION_APPEND:
            mgr.append_page(sid, pg, pg)
        elif kind == SESSION_ACTIVATE:
            mgr.activate(sid)
        elif kind == SESSION_END:
            mgr.end_session(sid)
    return mgr


def _snapshot(mgr):
    return (mgr.stats.as_dict(), dict(mgr.slot_owner), tuple(mgr.free),
            tuple(int(q) for q in mgr.tenant_quota),
            tuple(int(u) for u in mgr.tenant_used),
            sorted(mgr.host))


class TestChurnGenerator:
    def test_stream_is_well_formed(self):
        spec = SessionSpec(num_tenants=3, target_live=64, max_pages=5,
                           lifetime=25)
        tr = generate_sessions(spec, 4000, seed=3)
        born, dead = set(), set()
        pages = {}
        for k, s in zip(tr.kind, tr.sid):
            s = int(s)
            if k == SESSION_NEW:
                assert s not in born
                born.add(s)
                pages[s] = 0
            else:
                assert s in born and s not in dead
                if k == SESSION_APPEND:
                    pages[s] += 1
                    assert pages[s] <= spec.max_pages
                elif k == SESSION_END:
                    dead.add(s)
        assert (tr.tenant[tr.kind == SESSION_NEW] >= 0).all()
        assert (tr.tenant[tr.kind == SESSION_NEW] < 3).all()
        assert tr.max_live <= spec.target_live
        assert len(dead) > 0, "no churn generated"

    def test_deterministic_and_scales_to_thousands(self):
        spec = SessionSpec(num_tenants=4, target_live=512, lifetime=15,
                           p_end=0.05)
        a = generate_sessions(spec, 25000, seed=1)
        b = generate_sessions(spec, 25000, seed=1)
        assert (a.kind == b.kind).all() and (a.sid == b.sid).all()
        assert a.num_sessions >= 1000


class TestBatchedOracleEquality:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_bit_identical_on_churn_traces(self, seed):
        """The tentpole gate: batched controller == sequential oracle on
        randomized arrival/churn streams — stats, placements, free-list
        order, quotas, and tier-2 contents all equal."""
        spec = SessionSpec(num_tenants=3, target_live=48, max_pages=4,
                           lifetime=20)
        tr = generate_sessions(spec, 1500, seed=seed)
        a = _replay(TwoTierKVManager(CFG, 3, batched=True), tr)
        b = _replay(TwoTierKVManager(CFG, 3, batched=False), tr)
        assert _snapshot(a) == _snapshot(b)
        assert a.stats.pop_drops == 0

    def test_popularity_mirror_matches_tracker(self):
        """After the same stream, the batched device table's host mirror
        scores every live session exactly like the oracle's trackers."""
        spec = SessionSpec(num_tenants=2, target_live=24, max_pages=4)
        tr = generate_sessions(spec, 800, seed=5)
        a = _replay(TwoTierKVManager(CFG, 2, batched=True), tr)
        b = _replay(TwoTierKVManager(CFG, 2, batched=False), tr)
        sids = np.array(sorted(a.sessions), np.int64)
        tens = np.array([a.sessions[int(s)].tenant for s in sids])
        assert (a._scores(tens, sids) == b._scores(tens, sids)).all()


class TestBoundedControllerMemory:
    def test_trace_state_is_bounded(self):
        """Satellite 1: controller trace memory stays O(window), not
        O(activations) — ten windows of traffic leave the rings at their
        fixed capacity and no unbounded trace lists exist."""
        mgr = TwoTierKVManager(CFG, 2, batched=True)
        rng = np.random.default_rng(0)
        pg = rng.normal(size=(1, CFG.page_size, 2, 4)).astype(np.float32)
        for sid in range(6):
            mgr.new_session(sid, sid % 2)
            mgr.append_page(sid, pg, pg)
        n_events = CFG.resize_interval * 10
        for i in range(n_events):
            mgr.activate(i % 6)
        assert not hasattr(mgr, "trace_addr")
        assert mgr._ring.sid.size == CFG.resize_interval
        assert mgr._ring.n >= n_events
        assert mgr._trings.sid.shape == (2, CFG.resize_interval)

    def test_host_pool_shrinks_with_churn(self):
        """Tier-2 host memory tracks the live population, not the total
        session count."""
        spec = SessionSpec(num_tenants=2, target_live=16, max_pages=3,
                           lifetime=10, p_end=0.2)
        tr = generate_sessions(spec, 3000, seed=9)
        mgr = _replay(TwoTierKVManager(CFG, 2, batched=True), tr)
        assert mgr.stats.sessions_ended > 50
        live_pages = sum(len(s.pages) for s in mgr.sessions.values())
        assert len(mgr.host) == live_pages


class TestPageTableSentinel:
    def test_non_resident_pages_are_minus_one(self):
        """Satellite 2: a page evicted from HBM shows as -1 in the page
        table (the old code aliased slot 0, silently reading another
        session's KV)."""
        cfg = TwoTierConfig(page_size=4, hbm_pages=4, num_kv_heads=1,
                            head_dim=4, num_layers=1, dtype="float32",
                            maintenance_interval=1000,
                            resize_interval=1000, materialize=False)
        mgr = TwoTierKVManager(cfg, 1, batched=True)
        pg = np.zeros((1, 4, 1, 4), np.float32)
        mgr.new_session(0, 0)
        mgr.new_session(1, 0)
        for _ in range(3):
            mgr.append_page(0, pg, pg)
        for _ in range(3):                 # pool is 4: evicts sid 0 pages
            mgr.append_page(1, pg, pg)
        pt0 = mgr.page_table(0)
        assert (pt0 == -1).any()
        assert 0 not in pt0[pt0 == -1]
        # re-activation restores residency and the table is clean again
        pt0 = mgr.activate(0)
        assert (pt0 >= 0).all()


class TestRepartitionEdgeCases:
    def test_size_grid_includes_capacity_endpoint(self):
        """Satellite 3a: capacity not divisible by the step used to drop
        the top grid point, capping any tenant below the full pool."""
        grid = size_grid(50, 16)           # step = 3; old arange topped at 48
        assert grid[-1] == 50
        grid = size_grid(7, 16)            # step = 1
        assert grid[-1] == 7 and grid[0] == 0
        grid = size_grid(1024, 16)
        assert grid[-1] == 1024 and grid[0] == 0

    def test_quota_floor_conserves_pool(self):
        """Satellite 3b: the min-1 floor is paid for by shaving the
        largest allocations instead of minting pages (old behavior let
        sum(quota) exceed the pool)."""
        q = quota_with_floor(np.array([0, 0, 0, 16]), 16)
        assert q.sum() <= 16 and (q >= 1).all()
        q = quota_with_floor(np.array([8, 8]), 16)
        assert list(q) == [8, 8]
        # pool smaller than tenant count: best effort, never over
        q = quota_with_floor(np.array([5, 5, 5]), 2)
        assert q.sum() <= 2

    def test_repartition_can_grant_whole_pool_minus_floors(self):
        """With one hot tenant and an indivisible pool size, the hot
        tenant can now reach the grid's top sizes."""
        cfg = TwoTierConfig(page_size=4, hbm_pages=50, num_kv_heads=1,
                            head_dim=4, num_layers=1, dtype="float32",
                            maintenance_interval=10, resize_interval=40,
                            materialize=False)
        mgr = TwoTierKVManager(cfg, 2, batched=True)
        pg = np.zeros((1, 4, 1, 4), np.float32)
        for sid in range(8):
            mgr.new_session(sid, 0 if sid < 7 else 1)
            mgr.append_page(sid, pg, pg)
        for i in range(cfg.resize_interval * 3):
            mgr.activate(i % 7)            # tenant 0 does all the work
        assert mgr.tenant_quota.sum() <= cfg.hbm_pages
        assert mgr.tenant_quota[0] > mgr.tenant_quota[1]
        assert (mgr.tenant_quota >= 1).all()


class TestLRUBaselineOnChurn:
    def test_lru_pays_writeback_dma(self):
        """The push-mode baseline writes back on eviction, so its DMA
        writes strictly exceed the WBWO bound on an over-committed pool."""
        spec = SessionSpec(num_tenants=2, target_live=32, max_pages=4)
        tr = generate_sessions(spec, 1200, seed=11)
        lru = _replay(GlobalLRUManager(CFG, 2), tr)
        etica = _replay(TwoTierKVManager(CFG, 2, batched=True), tr)
        assert etica.stats.appends == lru.stats.appends
        assert (etica.stats.dma_write_bytes
                == etica.stats.appends * CFG.page_bytes)
        assert lru.stats.dma_write_bytes > etica.stats.dma_write_bytes


class TestServingCleaner:
    """PR 8 cleaning variants: deferred write-back with the background
    cleaner enabled (``clean_quota > 0``) keeps batched == oracle bit
    identity, tightens the WBWO write bound, and never changes what the
    cache serves."""

    @pytest.mark.parametrize("seed,quota", [(0, 1), (1, 2), (2, 4)])
    def test_bit_identical_with_cleaner(self, seed, quota):
        cfg = dataclasses.replace(CFG, clean_quota=quota)
        spec = SessionSpec(num_tenants=3, target_live=48, max_pages=4,
                           lifetime=20)
        tr = generate_sessions(spec, 1500, seed=seed)
        a = _replay(TwoTierKVManager(cfg, 3, batched=True), tr)
        b = _replay(TwoTierKVManager(cfg, 3, batched=False), tr)
        assert _snapshot(a) == _snapshot(b)
        assert a._dirty == b._dirty
        assert a.stats.flushes > 0, "cleaner never flushed on this trace"

    def test_wbwo_bound_and_flush_conservation(self):
        """One write per append holds *exactly*: every appended page is
        flushed by the cleaner, force-flushed on eviction, retired with
        its session, or still dirty-resident — each exactly once — and
        only the flushed ones paid DMA."""
        cfg = dataclasses.replace(CFG, clean_quota=2)
        spec = SessionSpec(num_tenants=3, target_live=48, max_pages=4,
                           lifetime=20)
        tr = generate_sessions(spec, 1500, seed=3)
        for batched in (True, False):
            s = _replay(TwoTierKVManager(cfg, 3, batched=batched), tr).stats
            assert s.appends == (s.flushes + s.evict_flushes
                                 + s.dirty_resident + s.dirty_dropped)
            assert s.dma_write_bytes == \
                (s.flushes + s.evict_flushes) * cfg.page_bytes
            # deferral never writes MORE than eager WBWO, and dropping
            # dead sessions' pages makes it strictly cheaper under churn
            assert s.dma_write_bytes < s.appends * cfg.page_bytes

    def test_cleaning_does_not_change_hit_miss_stats(self):
        """Cleaning only moves write-back traffic: read-side stats are
        bit-identical to the eager-commit (clean_quota=0) run, and dirty
        pages only ever live in HBM-resident slots."""
        spec = SessionSpec(num_tenants=3, target_live=48, max_pages=4,
                           lifetime=20)
        tr = generate_sessions(spec, 1500, seed=4)
        base = _replay(TwoTierKVManager(CFG, 3, batched=True), tr)
        mgr = _replay(TwoTierKVManager(
            dataclasses.replace(CFG, clean_quota=2), 3, batched=True), tr)
        for f in ("activations", "hits", "appends", "dma_read_bytes",
                  "sessions_ended", "pop_drops"):
            assert getattr(mgr.stats, f) == getattr(base.stats, f), f
        assert dict(mgr.slot_owner) == dict(base.slot_owner)
        # dirty subset-of-resident invariant
        resident = set(mgr.slot_owner.values())
        for key in mgr._dirty:
            assert key in resident, key
