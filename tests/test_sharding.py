"""VM-axis sharding: sharded dispatches == single-device batched, bit for bit.

The mesh spans every visible device (``make_vm_mesh()``), so under the
plain tier-1 run (one CPU device) these tests exercise the sharded code
paths on a degenerate 1-device mesh, and under the CI ``sharding-smoke``
job (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) they
exercise real 8-way splits with per-device row blocks. Covered:

  * the three hot dispatches (two-level datapath, single-level datapath,
    fused maintenance) plus the resize/sizing/POD routes are
    **bit-identical** to the single-device batched oracle;
  * per-VM work is **shard-local** — the compiled HLO of every sharded
    dispatch except the Stats aggregation contains no collectives, and
    :func:`aggregate_stats_sharded` contains exactly the one intended
    all-reduce (its psum);
  * both controllers produce identical VMResults with a mesh configured,
    including a **ragged** VM count (padded with dead VMs to a multiple
    of the mesh size) and streamed per-shard block feeding;
  * the mesh helpers and controller configs reject unusable setups with
    descriptive ``ValueError``\\ s.
"""
import dataclasses
import re

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (EticaCache, EticaConfig, Geometry, Policy, Stats,
                        aggregate_stats_sharded, interleave, make_cache_batch,
                        make_centaur, make_eci_cache, pad_batch,
                        policy_flags, resize_batch, resize_batch_sharded,
                        resize_levels, resize_levels_sharded,
                        simulate_single_level_batch,
                        simulate_single_level_sharded,
                        simulate_two_level_batch, simulate_two_level_sharded,
                        split_by_vm, table_init)
from repro.core import reuse, simulator as sim
from repro.core.controller import PartitionedSingleLevelCache
from repro.kernels.maintenance import ops as maint_ops
from repro.kernels.reuse_distance import ops as kernel_ops
from repro.launch.mesh import (device_row_blocks, make_host_mesh,
                               make_production_mesh, make_vm_mesh,
                               require_vm_divisible, vm_spec)
from repro.traces import StreamingTraceSource, make
from repro.traces.stream import StreamWindow

MESH = make_vm_mesh()                 # every visible device
D = MESH.size
V = 2 * D                             # evenly divisible row count
S, W = 4, 4                           # small geometry, all sets exercised

_COLLECTIVE = re.compile(r"all-reduce\(|all-gather\(|collective-permute\("
                         r"|all-to-all\(|reduce-scatter\(")


def _assert_local(jitted, *args, label=""):
    """The compiled dispatch moves no per-VM arrays across devices."""
    txt = jitted.lower(*args).compile().as_text()
    hits = _COLLECTIVE.findall(txt)
    assert not hits, f"{label}: unexpected collectives {hits}"


def _assert_tree_equal(a, b, msg=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), msg


def _requests(seed=0, n=96, pad_frac=0.15, addr_space=24):
    rng = np.random.default_rng(seed)
    addr = rng.integers(0, addr_space, (V, n)).astype(np.int32)
    addr[rng.random((V, n)) < pad_frac] = -1     # no-op pads mid-stream
    return addr, rng.random((V, n)) < 0.4


def _ragged(seed=3, num=V, lo=0, hi=160, addr_space=50):
    rng = np.random.default_rng(seed)
    addrs = [rng.integers(0, addr_space,
                          size=int(rng.integers(lo, hi))).astype(np.int32)
             for _ in range(num)]
    addrs[min(1, num - 1)] = np.empty(0, np.int32)   # an idle VM
    return addrs, [rng.random(a.shape[0]) < 0.4 for a in addrs]


# ---------------------------------------------------------------------------
# datapath dispatches
# ---------------------------------------------------------------------------

def test_two_level_sharded_bit_identical_and_local():
    addr, is_write = _requests(seed=1)
    rng = np.random.default_rng(11)
    wd = rng.integers(0, W + 1, V).astype(np.int32)
    ws = rng.integers(0, W + 1, V).astype(np.int32)
    t0 = rng.integers(0, 9, V).astype(np.int32)
    for mode in ("full", "npe"):
        dram = make_cache_batch(V, S, W)
        ssd = make_cache_batch(V, S, W)
        ref = simulate_two_level_batch(addr, is_write, dram, ssd, wd, ws,
                                       mode=mode, t0=t0)
        got = simulate_two_level_sharded(addr, is_write, dram, ssd, wd, ws,
                                         MESH, mode=mode, t0=t0)
        _assert_tree_equal(ref, got, f"two-level {mode}")
    _assert_local(sim._two_level_sharded(MESH, "full"),
                  jnp.asarray(addr), jnp.asarray(is_write),
                  make_cache_batch(V, S, W), make_cache_batch(V, S, W),
                  jnp.asarray(wd), jnp.asarray(ws), jnp.asarray(t0),
                  label="two-level")


def test_single_level_sharded_bit_identical_and_local():
    addr, is_write = _requests(seed=2)
    rng = np.random.default_rng(12)
    ways = rng.integers(0, W + 1, V).astype(np.int32)
    t0 = rng.integers(0, 9, V).astype(np.int32)
    policies = [list(Policy)[v % len(Policy)] for v in range(V)]
    flags = policy_flags(policies)
    state = make_cache_batch(V, S, W)
    ref = simulate_single_level_batch(addr, is_write, state, ways, flags,
                                      t0=t0)
    got = simulate_single_level_sharded(addr, is_write, state, ways, flags,
                                        MESH, t0=t0)
    _assert_tree_equal(ref, got, "single-level heterogeneous policies")
    bflags = sim.PolicyFlags(
        *[jnp.broadcast_to(jnp.asarray(f), (V,)) for f in flags])
    _assert_local(sim._single_level_sharded(MESH),
                  jnp.asarray(addr), jnp.asarray(is_write), state,
                  jnp.asarray(ways), bflags, jnp.float32(1.0),
                  jnp.asarray(t0), label="single-level")


def test_resize_sharded_bit_identical_and_local():
    addr, is_write = _requests(seed=4)
    rng = np.random.default_rng(14)
    wd = rng.integers(0, W + 1, V).astype(np.int32)
    ws = rng.integers(0, W + 1, V).astype(np.int32)
    dram, ssd, _, _ = simulate_two_level_batch(
        addr, is_write, make_cache_batch(V, S, W), make_cache_batch(V, S, W),
        wd, ws, mode="full")
    nd = rng.integers(0, W + 1, V).astype(np.int32)
    ns = rng.integers(0, W + 1, V).astype(np.int32)
    _assert_tree_equal(resize_levels(dram, ssd, wd, nd, ws, ns),
                       resize_levels_sharded(dram, ssd, wd, nd, ws, ns, MESH),
                       "resize_levels")
    _assert_tree_equal(resize_batch(ssd, ws, ns),
                       resize_batch_sharded(ssd, ws, ns, MESH),
                       "resize_batch")
    as_i32 = lambda x: jnp.asarray(x, jnp.int32)
    _assert_local(sim._resize_levels_sharded(MESH), dram, ssd, as_i32(wd),
                  as_i32(nd), as_i32(ws), as_i32(ns), label="resize_levels")
    _assert_local(sim._resize_batch_sharded(MESH), ssd, as_i32(ws),
                  as_i32(ns), label="resize_batch")


def test_aggregate_stats_sharded_is_the_only_collective():
    addr, is_write = _requests(seed=5)
    ways = np.full(V, 2, np.int32)
    _, per_vm, _ = simulate_single_level_batch(
        addr, is_write, make_cache_batch(V, S, W), ways,
        policy_flags([Policy.WB] * V))
    total = aggregate_stats_sharded(per_vm, MESH)
    for leaf, tot in zip(per_vm, total):
        assert np.asarray(tot) == np.asarray(leaf).sum()
    txt = sim._aggregate_stats_sharded(MESH).lower(
        Stats(*[jnp.asarray(x) for x in per_vm])).compile().as_text()
    if D > 1:
        # the psum is the one intended cross-device reduction of a
        # sharded controller run
        assert "all-reduce(" in txt or "all-reduce-start(" in txt
    assert not re.search(r"all-gather\(|collective-permute\(|all-to-all\(",
                         txt)


# ---------------------------------------------------------------------------
# fused maintenance
# ---------------------------------------------------------------------------

def test_maintenance_sharded_bit_identical_and_local():
    addr, is_write = _requests(seed=6, n=64)
    ways = np.full(V, 3, np.int32)
    # populate dirty SSD states by running the datapath first
    _, ssd, _, _ = simulate_two_level_batch(
        addr, is_write, make_cache_batch(V, S, W), make_cache_batch(V, S, W),
        np.full(V, 2, np.int32), ways, mode="full")
    rng = np.random.default_rng(16)
    n = 48
    waddr = rng.integers(0, 24, (V, n)).astype(np.int32)
    dist = rng.integers(-1, 8, (V, n)).astype(np.int32)
    served = (rng.random((V, n)) < 0.5) & (dist >= 0)
    wlen = rng.integers(0, n + 1, V).astype(np.int32)
    wlen[0] = 0                      # an idle VM rides along untouched
    t = rng.integers(1, 9, V).astype(np.int32)
    table = table_init(V, 64)
    kw = dict(evict_frac=0.25, decay=0.5, clean_quota=2, interpret=True)
    ref = maint_ops.maintenance_interval(ssd, table, dist, served, waddr,
                                         wlen, ways, t, **kw)
    got = maint_ops.maintenance_interval(ssd, table, dist, served, waddr,
                                         wlen, ways, t, mesh=MESH, **kw)
    _assert_tree_equal(ref, got, "fused maintenance")
    _assert_local(
        maint_ops._maintenance_sharded(MESH, 0.25, 0.5, 2,
                                       maint_ops.DEFAULT_TS,
                                       maint_ops.DEFAULT_QC, True),
        ssd, table, jnp.asarray(dist), jnp.asarray(served, bool),
        jnp.asarray(waddr), jnp.asarray(wlen), jnp.asarray(ways),
        jnp.asarray(t), label="maintenance")


# ---------------------------------------------------------------------------
# sizing / POD reductions (manual per-device dispatch)
# ---------------------------------------------------------------------------

def test_sizing_sharded_matches_jnp_and_kernel_routes():
    addrs, writes = _ragged(seed=7)
    grid = np.array([1, 4, 16, 64], np.int32)
    for kind in reuse.SIZING_KINDS:
        ref = reuse.sizing_metrics_batch(addrs, writes, kind, grid)
        got = reuse.sizing_metrics_batch(addrs, writes, kind, grid,
                                         mesh=MESH)
        for x, y in zip(ref, got):
            assert np.array_equal(x, y), f"jnp {kind}"
    for kind in ("urd", "wss"):      # the kernel-backed route
        ref = kernel_ops.sizing_metrics_batch(addrs, writes, kind, grid)
        got = kernel_ops.sizing_metrics_batch(addrs, writes, kind, grid,
                                              mesh=MESH)
        for x, y in zip(ref, got):
            assert np.array_equal(x, y), f"kernel {kind}"


def test_pod_distances_sharded_matches():
    addrs, writes = _ragged(seed=8)
    for policy in (Policy.WB, Policy.RO, Policy.WBWO):
        ref = reuse.pod_distances_batch(addrs, writes, policy)
        got = reuse.pod_distances_batch(addrs, writes, policy, mesh=MESH)
        for x, y in zip(ref, got):
            assert (x is None) == (y is None)
            if x is not None:
                assert np.array_equal(np.asarray(x.dist),
                                      np.asarray(y.dist)), policy
                assert np.array_equal(np.asarray(x.served),
                                      np.asarray(y.served)), policy


def test_device_row_blocks_partition():
    blocks = device_row_blocks(V, MESH)
    assert len(blocks) == D
    assert [b[1] for b in blocks] == [
        slice(i * (V // D), (i + 1) * (V // D)) for i in range(D)]
    assert [b[0] for b in blocks] == list(MESH.devices.flat)


# ---------------------------------------------------------------------------
# controllers: sharded run == batched run, ragged V
# ---------------------------------------------------------------------------

GEO = Geometry(num_sets=8, max_ways=16)
RAGGED_V = max(3, D - 1)             # never a multiple of D when D > 1


def _mixed_trace(num_vms, reqs=1800):
    names = ["hm_1", "usr_0", "web_3", "proj_0", "src2_0", "mds_0",
             "stg_1", "wdev_0"]
    return interleave(
        [make(names[i % len(names)], reqs, seed=i,
              addr_offset=i * 10_000_000, scale=0.25)
         for i in range(num_vms)], seed=0)


def _assert_results_equal(ref, got, num_vms):
    for v in range(num_vms):
        assert ref[v].stats == got[v].stats, v
        assert np.array_equal(ref[v].alloc_history, got[v].alloc_history), v


def test_etica_controller_sharded_ragged_v():
    trace = _mixed_trace(RAGGED_V)
    cfg = EticaConfig(dram_capacity=60, ssd_capacity=120, geometry_dram=GEO,
                      geometry_ssd=GEO, resize_interval=1500,
                      promo_interval=500, mode="full", clean_quota=2)
    ref = EticaCache(cfg, RAGGED_V).run(trace)
    cache = EticaCache(dataclasses.replace(cfg, mesh=MESH), RAGGED_V)
    assert cache._rows % D == 0 and cache._rows >= RAGGED_V
    got = cache.run(trace)
    _assert_results_equal(ref, got, RAGGED_V)


@pytest.mark.parametrize("factory", [make_eci_cache, make_centaur])
def test_single_level_controller_sharded_ragged_v(factory):
    trace = _mixed_trace(RAGGED_V)
    ref = factory(120, RAGGED_V, geometry=GEO, resize_interval=1500).run(
        trace)
    c = factory(120, RAGGED_V, geometry=GEO, resize_interval=1500)
    sharded = PartitionedSingleLevelCache(
        dataclasses.replace(c.cfg, mesh=MESH), RAGGED_V, c.metric,
        c.policy_fn)
    _assert_results_equal(ref, sharded.run(trace), RAGGED_V)


# ---------------------------------------------------------------------------
# streamed per-shard feeding
# ---------------------------------------------------------------------------

def test_stream_blocks_sharded_placement_and_values():
    from jax.sharding import NamedSharding
    trace = _mixed_trace(3, reqs=600)
    subs = split_by_vm(trace, 3)
    pad = (-3) % D if D > 1 else 1          # pad 3 real VMs up to rows
    rows = 3 + pad
    sharding = NamedSharding(MESH, vm_spec(MESH)) if rows % D == 0 else None
    host = StreamWindow(0, subs, chunk=64, prefetch_depth=0, pad_vms=pad)
    dev = StreamWindow(0, subs, chunk=64, prefetch_depth=2, pad_vms=pad,
                      sharding=sharding)
    got = list(dev.blocks())
    ref = list(host.blocks())
    assert len(got) == len(ref) > 0
    for (a, w, kth), (ra, rw, rkth) in zip(got, ref):
        assert a.shape == (rows, 64)
        assert np.array_equal(np.asarray(a), np.asarray(ra))
        assert np.array_equal(np.asarray(w), np.asarray(rw))
        assert np.all(np.asarray(ra)[3:] == -1)     # dead-VM pad rows
        assert len(kth) == len(rkth) == 3           # maintenance sees real VMs
        if sharding is not None:
            assert a.sharding.is_equivalent_to(sharding, a.ndim)


def test_streaming_source_depths_bit_identical():
    trace = _mixed_trace(3, reqs=900)
    outs = []
    for depth in (0, 1, 2, 3):
        src = StreamingTraceSource(trace, num_vms=3, window=400, chunk=64,
                                   prefetch=True, prefetch_depth=depth)
        blocks = [(np.asarray(a), np.asarray(w))
                  for win in src.windows() for a, w, _ in win.blocks()]
        outs.append(blocks)
    for blocks in outs[1:]:
        assert len(blocks) == len(outs[0])
        for (a, w), (ra, rw) in zip(blocks, outs[0]):
            assert np.array_equal(a, ra) and np.array_equal(w, rw)


# ---------------------------------------------------------------------------
# descriptive errors
# ---------------------------------------------------------------------------

def test_mesh_helper_errors():
    with pytest.raises(ValueError, match="devices"):
        make_vm_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError, match="1-d mesh"):
        vm_spec(make_host_mesh())               # ('data', 'model') is 2-d
    with pytest.raises(ValueError, match="divisible"):
        make_host_mesh(model=len(jax.devices()) + 1)
    if len(jax.devices()) < 256:
        with pytest.raises(ValueError, match="devices"):
            make_production_mesh()
    if D > 1:
        with pytest.raises(ValueError, match="divisible"):
            require_vm_divisible(D + 1, MESH)
        with pytest.raises(ValueError, match="divisible"):
            device_row_blocks(D + 1, MESH)


def test_controller_mesh_config_errors():
    cfg = EticaConfig(dram_capacity=60, ssd_capacity=120, geometry_dram=GEO,
                      geometry_ssd=GEO, mesh=MESH)
    with pytest.raises(ValueError, match="batched"):
        EticaCache(dataclasses.replace(cfg, batched=False), 2)
    with pytest.raises(ValueError, match="fused_maintenance"):
        EticaCache(dataclasses.replace(cfg, fused_maintenance=False), 2)
    with pytest.raises(ValueError, match="classifier"):
        EticaCache(dataclasses.replace(cfg, classifier=object()), 2)
    c = make_eci_cache(60, 2, geometry=GEO)
    with pytest.raises(ValueError, match="batched"):
        PartitionedSingleLevelCache(
            dataclasses.replace(c.cfg, mesh=MESH, batched=False), 2,
            c.metric, c.policy_fn)
