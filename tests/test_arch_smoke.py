"""Per-architecture smoke tests: reduced config of the same family runs
one forward/train step on CPU; output shapes + no NaNs (assignment
requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.models.config import SHAPES, shape_applicable
from repro.optim import OptConfig, init_opt_state


def _batch(cfg, b=2, s=32):
    if cfg.is_encdec:
        return {"frames": jnp.zeros((b, 16, cfg.d_model), jnp.float32),
                "dec_tokens": jnp.zeros((b, s), jnp.int32)}
    if cfg.frontend == "vision":
        return {"tokens": jnp.zeros((b, s - cfg.frontend_tokens), jnp.int32),
                "patches": jnp.zeros((b, cfg.frontend_tokens, cfg.d_model),
                                     jnp.float32)}
    return {"tokens": jnp.zeros((b, s), jnp.int32)}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = configs.get_reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = M.forward_train(params, cfg, batch)
    assert np.isfinite(float(loss)), arch
    # one optimizer step
    opt_cfg = OptConfig(total_steps=10, warmup_steps=1)
    opt_state = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    p2, o2, m2 = step(params, opt_state, batch)
    assert np.isfinite(float(m2["loss"])), arch
    assert int(o2["step"]) == 1
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, ab: acc or bool(jnp.any(ab)),
        jax.tree_util.tree_map(lambda a, b: jnp.any(a != b), params, p2),
        False)
    assert moved, arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_consistency(arch):
    """The full config is structurally valid (superlayer divisibility,
    head geometry, MoE/SSM fields) and sized in the documented range."""
    cfg = configs.get(arch)
    assert cfg.num_superlayers >= 1
    total, active = cfg.param_counts()
    assert active <= total
    if cfg.family not in ("ssm",):
        assert cfg.num_heads % max(cfg.num_kv_heads, 1) == 0
    if cfg.moe_num_experts:
        assert cfg.moe_top_k <= cfg.moe_num_experts
    for shape in SHAPES.values():
        ok, reason = shape_applicable(cfg, shape)
        if not ok:
            assert "sub-quadratic" in reason


def test_param_count_sanity():
    """Full-config parameter totals roughly match the advertised sizes."""
    expect = {
        "llama3-405b": 405e9, "mixtral-8x22b": 141e9,
        "deepseek-moe-16b": 16e9, "phi4-mini-3.8b": 3.8e9,
        "qwen3-4b": 4e9, "nemotron-4-15b": 15e9, "mamba2-370m": 0.37e9,
        "jamba-v0.1-52b": 52e9,
    }
    for arch, n in expect.items():
        total, _ = configs.get(arch).param_counts()
        assert 0.5 * n < total < 1.9 * n, (arch, total, n)
