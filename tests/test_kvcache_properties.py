"""Property-based invariants of the two-tier KV manager (hypothesis).

Ops streams include churn (``end``) — a retired session is immediately
replaced by a fresh arrival, so the population keeps turning over while
the per-slot invariants must keep holding. The batched controller is
additionally pinned to the sequential host-dict oracle bit for bit.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kvcache import TwoTierConfig, TwoTierKVManager, quota_with_floor

CFG = TwoTierConfig(page_size=4, hbm_pages=16, num_kv_heads=1, head_dim=4,
                    num_layers=1, dtype="float32",
                    maintenance_interval=8, resize_interval=32,
                    pop_capacity=128)


def _ops():
    return st.lists(
        st.tuples(st.integers(0, 7),           # live-session index
                  st.sampled_from(["activate", "append", "end"])),
        min_size=1, max_size=120)


def _drive(ops, batched=True, cfg=CFG):
    mgr = TwoTierKVManager(cfg, num_tenants=2, batched=batched)
    rng = np.random.default_rng(0)
    live = list(range(8))
    next_sid = 8
    for sid in live:
        mgr.new_session(sid, sid % 2)
    for idx, action in ops:
        sid = live[idx]
        if action == "end":
            mgr.end_session(sid)
            live[idx] = next_sid
            mgr.new_session(next_sid, next_sid % 2)
            next_sid += 1
            continue
        if action == "append" and len(mgr.sessions[sid].pages) < 4:
            pg = rng.normal(size=(1, cfg.page_size, 1, 4)).astype(np.float32)
            mgr.append_page(sid, pg, pg)
        mgr.activate(sid)
    return mgr, live


@given(_ops())
@settings(max_examples=20, deadline=None)
def test_slot_accounting_consistent(ops):
    """free + owned slots == pool size; owners and sessions agree (the
    slot_owner <-> hbm_slots bijection)."""
    mgr, _ = _drive(ops)
    assert len(mgr.free) + len(mgr.slot_owner) == CFG.hbm_pages
    for slot, (sid, lp) in mgr.slot_owner.items():
        assert mgr.sessions[sid].hbm_slots.get(lp) == slot
    owned = sum(len(s.hbm_slots) for s in mgr.sessions.values())
    assert owned == len(mgr.slot_owner)


@given(_ops())
@settings(max_examples=20, deadline=None)
def test_tenant_used_matches_recount(ops):
    """The incremental per-tenant residency counters equal a from-scratch
    recount over the page tables."""
    mgr, _ = _drive(ops)
    recount = np.zeros(mgr.num_tenants, np.int64)
    for sess in mgr.sessions.values():
        recount[sess.tenant] += len(sess.hbm_slots)
    assert (mgr.tenant_used == recount).all()


@given(_ops())
@settings(max_examples=20, deadline=None)
def test_tier2_is_authoritative(ops):
    """Every logical page of every live session has a host (tier-2) copy
    — the RO-tier reliability invariant: HBM loss can never lose data.
    Ended sessions' pages are gone (no tier-2 leak)."""
    mgr, _ = _drive(ops)
    live_pages = set()
    for sid, sess in mgr.sessions.items():
        for lp in sess.pages:
            assert (sid, lp) in mgr.host
            live_pages.add((sid, lp))
    assert set(mgr.host) == live_pages


@given(_ops())
@settings(max_examples=20, deadline=None)
def test_wbwo_write_bound(ops):
    """Tier-2 DMA writes == pages generated, each written exactly once
    (WBWO bound) — churn frees host copies without extra DMA."""
    mgr, _ = _drive(ops)
    assert mgr.stats.dma_write_bytes == mgr.stats.appends * CFG.page_bytes


@given(_ops())
@settings(max_examples=20, deadline=None)
def test_activation_makes_resident(ops):
    """After activate(sid), every page of sid is HBM-resident and its
    page table points at slots owned by (sid, page) — no -1 sentinel
    survives an activation."""
    mgr, live = _drive(ops)
    for sid in live:
        if not mgr.sessions[sid].pages:
            continue
        pt = mgr.activate(sid)
        assert (pt >= 0).all()
        for lp, slot in enumerate(pt):
            assert mgr.slot_owner[int(slot)] == (sid, lp)


@given(_ops())
@settings(max_examples=20, deadline=None)
def test_quota_totals_bounded(ops):
    """Quotas never promise more than the physical pool (the old min-1
    floor could), and every tenant keeps the floor page."""
    mgr, _ = _drive(ops)
    assert mgr.tenant_quota.sum() <= CFG.hbm_pages
    assert (mgr.tenant_quota >= 1).all()
    assert (mgr.tenant_used >= 0).all()


@given(_ops())
@settings(max_examples=10, deadline=None)
def test_batched_matches_sequential_oracle(ops):
    """The batched controller (device popularity table + fused
    maintenance) reproduces the host-dict oracle bit for bit: same
    stats, same final placements, same free-list order, same quotas."""
    cfg = TwoTierConfig(page_size=4, hbm_pages=16, num_kv_heads=1,
                        head_dim=4, num_layers=1, dtype="float32",
                        maintenance_interval=8, resize_interval=32,
                        pop_capacity=128, materialize=False)
    a, _ = _drive(ops, batched=True, cfg=cfg)
    b, _ = _drive(ops, batched=False, cfg=cfg)
    assert a.stats.as_dict() == b.stats.as_dict()
    assert a.slot_owner == b.slot_owner
    assert a.free == b.free
    assert (a.tenant_quota == b.tenant_quota).all()
    assert (a.tenant_used == b.tenant_used).all()


@given(st.integers(1, 2048), st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_size_grid_covers_endpoints(capacity, points):
    """The candidate-size grid always includes 0 and the full capacity
    (the old arange dropped the endpoint when capacity % step != 0)."""
    from repro.core.partition import size_grid
    grid = size_grid(capacity, points)
    assert grid[0] == 0 and grid[-1] == capacity
    assert (np.diff(grid) > 0).all()


@given(st.lists(st.integers(0, 64), min_size=1, max_size=12),
       st.integers(1, 128))
@settings(max_examples=50, deadline=None)
def test_quota_floor_never_exceeds_pool(alloc, capacity):
    """quota_with_floor keeps sum(quota) <= capacity while giving every
    tenant a page whenever the pool is big enough."""
    q = quota_with_floor(np.asarray(alloc, np.int64), capacity)
    assert q.sum() <= capacity
    if capacity >= len(alloc):
        assert (q >= 1).all()
    assert (q >= 0).all()
