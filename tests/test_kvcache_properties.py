"""Property-based invariants of the two-tier KV manager (hypothesis)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kvcache import TwoTierConfig, TwoTierKVManager

CFG = TwoTierConfig(page_size=4, hbm_pages=16, num_kv_heads=1, head_dim=4,
                    num_layers=1, dtype="float32",
                    maintenance_interval=8, resize_interval=32)


def _ops():
    return st.lists(
        st.tuples(st.integers(0, 7),           # session id
                  st.booleans()),              # append a page?
        min_size=1, max_size=120)


def _drive(ops):
    mgr = TwoTierKVManager(CFG, num_tenants=2)
    rng = np.random.default_rng(0)
    for sid in range(8):
        mgr.new_session(sid, sid % 2)
    for sid, do_append in ops:
        if do_append and len(mgr.sessions[sid].pages) < 4:
            pg = rng.normal(size=(1, CFG.page_size, 1, 4)).astype(np.float32)
            mgr.append_page(sid, pg, pg)
        mgr.activate(sid)
    return mgr


@given(_ops())
@settings(max_examples=20, deadline=None)
def test_slot_accounting_consistent(ops):
    """free + owned slots == pool size; owners and sessions agree."""
    mgr = _drive(ops)
    assert len(mgr.free) + len(mgr.slot_owner) == CFG.hbm_pages
    for slot, (sid, lp) in mgr.slot_owner.items():
        assert mgr.sessions[sid].hbm_slots.get(lp) == slot
    owned = sum(len(s.hbm_slots) for s in mgr.sessions.values())
    assert owned == len(mgr.slot_owner)


@given(_ops())
@settings(max_examples=20, deadline=None)
def test_tier2_is_authoritative(ops):
    """Every logical page of every session has a host (tier-2) copy —
    the RO-tier reliability invariant: HBM loss can never lose data."""
    mgr = _drive(ops)
    for sid, sess in mgr.sessions.items():
        for lp in sess.pages:
            assert (sid, lp) in mgr.host


@given(_ops())
@settings(max_examples=20, deadline=None)
def test_wbwo_write_bound(ops):
    """Tier-2 DMA writes == pages generated exactly once (WBWO bound)."""
    mgr = _drive(ops)
    assert mgr.stats.dma_write_bytes == len(mgr.host) * CFG.page_bytes


@given(_ops())
@settings(max_examples=20, deadline=None)
def test_activation_makes_resident(ops):
    """After activate(sid), every page of sid is HBM-resident and its
    page table points at slots owned by (sid, page)."""
    mgr = _drive(ops)
    for sid in range(8):
        if not mgr.sessions[sid].pages:
            continue
        pt = mgr.activate(sid)
        for lp, slot in enumerate(pt):
            assert mgr.slot_owner[int(slot)] == (sid, lp)


@given(_ops())
@settings(max_examples=20, deadline=None)
def test_quota_totals_bounded(ops):
    mgr = _drive(ops)
    assert mgr.tenant_quota.sum() <= CFG.hbm_pages + len(mgr.tenant_quota)
    assert (mgr.tenant_used >= 0).all()
