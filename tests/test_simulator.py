"""Exact-simulator invariants (single- and two-level datapaths)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Policy, Stats, Trace, make_cache,
                        simulate_single_level, simulate_two_level)
from repro.core.simulator import resident_blocks, resize

SETTINGS = dict(max_examples=15, deadline=None)


def run_single(tr, policy, sets=4, ways=4, active=4):
    st_ = make_cache(sets, ways)
    st_, stats, _ = simulate_single_level(
        np.asarray(tr.addr), np.asarray(tr.is_write), st_, active, policy)
    return st_, stats


def traces(max_size=150, addr_space=20):
    return st.lists(
        st.tuples(st.integers(0, addr_space - 1), st.booleans()),
        min_size=1, max_size=max_size,
    ).map(lambda ops: Trace(
        addr=np.array([a for a, _ in ops], np.int32),
        is_write=np.array([w for _, w in ops], bool)))


@given(traces())
@settings(**SETTINGS)
def test_conservation(tr):
    """reads+writes == len; every read is a hit or a disk read."""
    for p in (Policy.WB, Policy.RO, Policy.WBWO, Policy.WT):
        _, s = run_single(tr, p)
        assert int(s.reads) + int(s.writes) == len(tr)
        assert int(s.reads) == int(s.read_hits_l2) + int(s.disk_reads)


@given(traces())
@settings(**SETTINGS)
def test_endurance_ordering(tr):
    """WB commits at least as many cache writes as WBWO and RO —
    the paper's Fig. 3 motivation."""
    _, wb = run_single(tr, Policy.WB)
    _, wbwo = run_single(tr, Policy.WBWO)
    _, ro = run_single(tr, Policy.RO)
    assert int(wbwo.cache_writes_l2) <= int(wb.cache_writes_l2)
    assert int(ro.cache_writes_l2) <= int(wb.cache_writes_l2)


@given(traces())
@settings(**SETTINGS)
def test_wt_no_dirty_and_syncs_to_disk(tr):
    st_, s = run_single(tr, Policy.WT)
    assert not bool(np.asarray(st_.dirty).any())   # reliability: no dirty
    assert int(s.disk_writes) >= int(s.writes)     # every write committed


@given(traces())
@settings(**SETTINGS)
def test_ro_never_caches_writes(tr):
    st_, s = run_single(tr, Policy.RO)
    assert int(s.disk_writes) == int(s.writes)
    assert not bool(np.asarray(st_.dirty).any())


@given(traces())
@settings(**SETTINGS)
def test_two_level_dram_never_dirty(tr):
    """ETICA reliability claim: the volatile level never holds dirty."""
    dram, ssd = make_cache(4, 4), make_cache(4, 4)
    for mode in ("full", "npe"):
        d2, _, _, _ = simulate_two_level(
            np.asarray(tr.addr), np.asarray(tr.is_write), dram, ssd,
            4, 4, mode=mode)
        assert not bool(np.asarray(d2.dirty).any())


@given(traces())
@settings(**SETTINGS)
def test_full_mode_ssd_writes_below_npe(tr):
    """Pull-mode SSD (no datapath write-miss allocation) can only reduce
    SSD writes relative to the datapath-allocating NPE mode."""
    def run(mode):
        dram, ssd = make_cache(4, 4), make_cache(4, 4)
        _, _, s, _ = simulate_two_level(
            np.asarray(tr.addr), np.asarray(tr.is_write), dram, ssd,
            4, 4, mode=mode)
        return s
    assert int(run("full").cache_writes_l2) <= int(run("npe").cache_writes_l2)


def test_zero_capacity_bypasses():
    tr = Trace.from_ops([('R', 1), ('R', 1), ('W', 2), ('R', 2)])
    _, s = run_single(tr, Policy.WB, active=0)
    assert int(s.hits) == 0
    assert int(s.disk_reads) == tr.n_reads
    assert int(s.cache_writes_l2) == 0


def test_padding_requests_are_noops():
    tr = Trace.from_ops([('R', 1), ('R', 1)])
    addr = np.concatenate([np.asarray(tr.addr), np.full(5, -1, np.int32)])
    w = np.concatenate([np.asarray(tr.is_write), np.zeros(5, bool)])
    st_ = make_cache(2, 2)
    _, s, _ = simulate_single_level(addr, w, st_, 2, Policy.WB)
    assert int(s.reads) == 2 and int(s.writes) == 0
    assert int(s.read_hits_l2) == 1


def test_resize_flushes_dirty():
    tr = Trace.from_ops([('W', i) for i in range(8)])
    st_, s = run_single(tr, Policy.WB, sets=2, ways=4, active=4)
    st2, flushed = resize(st_, 4, 1)
    assert flushed > 0
    assert resident_blocks(st2, 1).size <= 2


def test_lru_eviction_order():
    # cache of 2 (1 set x 2 ways): A B A C -> evicts B (LRU), A survives
    tr = Trace.from_ops([('R', 1), ('R', 2), ('R', 1), ('R', 3), ('R', 1)])
    st_, s = run_single(tr, Policy.WB, sets=1, ways=2, active=2)
    # hits: A(2nd)=hit, A(3rd)=hit; B evicted by C
    assert int(s.read_hits_l2) == 2
    assert set(resident_blocks(st_, 2).tolist()) == {1, 3}
