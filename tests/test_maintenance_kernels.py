"""Property tests: interpret-mode Pallas maintenance kernels == numpy.

Three layers, matching the package convention:

  1. the promote/evict Pallas kernels (run through the interpreter on
     CPU) against ``repro.kernels.maintenance.ref``'s sequential numpy
     oracles, on randomized stacked ``[V, S, W]`` states with ragged /
     empty / duplicate-laden queues, including full-set promote
     starvation;
  2. the batched device popularity ops against the host
     :class:`PopularityTracker` — bit-identical float32 tables and
     identically-ordered promotion/eviction queues;
  3. the fused ``maintenance_interval`` dispatch against a staged host
     reference (trackers + ``*_ref`` scatters), states and counts exact.
"""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import popularity as pop
from repro.core.simulator import CacheState, resident_blocks
from repro.kernels.maintenance import ops, ref

SETTINGS = dict(max_examples=20, deadline=None)

geometries = st.tuples(st.integers(1, 4),    # V
                       st.integers(2, 10),   # S (non-pow2 exercised)
                       st.integers(1, 7))    # W


def _random_state(rng, num_vms, num_sets, ways, addr_space=48,
                  set_consistent=False):
    """Stacked random state; ``set_consistent`` places every tag in its
    own set (``tag % S == s``), the invariant real simulator states obey
    (and that the set-local residency checks rely on)."""
    tags = np.full((num_vms, num_sets, ways), -1, np.int32)
    for v in range(num_vms):
        for s in range(num_sets):
            if set_consistent:
                cand = rng.permutation(np.arange(s, addr_space, num_sets))
            else:
                cand = rng.permutation(np.arange(addr_space))
            nfill = int(rng.integers(0, ways + 1))
            tags[v, s, :nfill] = cand[: min(nfill, cand.size)]
    lru = rng.integers(-1, 100, tags.shape).astype(np.int32)
    dirty = (rng.random(tags.shape) < 0.5) & (tags >= 0)
    return CacheState(jnp.asarray(tags), jnp.asarray(lru),
                      jnp.asarray(dirty))


def _assert_state(got: CacheState, tags, lru, dirty, msg=""):
    assert np.array_equal(np.asarray(got.tags), tags), msg
    assert np.array_equal(np.asarray(got.lru), lru), msg
    assert np.array_equal(np.asarray(got.dirty), dirty.astype(bool)), msg


@given(geometries, st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_evict_kernel_matches_ref(geom, seed):
    v, s, w = geom
    rng = np.random.default_rng(seed)
    st_ = _random_state(rng, v, s, w)
    # ragged queues: empty, -1-padded, duplicate and absent addresses
    queues = [rng.integers(-1, 60, int(rng.integers(0, 20)))
              for _ in range(v)]
    got, flushed = ops.evict(st_, queues, interpret=True)
    tags, lru, dirty, want_fl = ref.evict_ref(
        np.asarray(st_.tags), np.asarray(st_.lru),
        np.asarray(st_.dirty, np.int32), queues)
    _assert_state(got, tags, lru, dirty, "evict state")
    assert np.array_equal(np.asarray(flushed), want_fl)


@given(geometries, st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_promote_kernel_matches_ref(geom, seed):
    v, s, w = geom
    rng = np.random.default_rng(seed)
    st_ = _random_state(rng, v, s, w)
    queues = [rng.integers(-1, 80, int(rng.integers(0, 30)))
              for _ in range(v)]
    ways = rng.integers(0, w + 1, v).astype(np.int32)
    t = rng.integers(0, 100, v).astype(np.int32)
    got, n = ops.promote(st_, queues, ways, t, interpret=True)
    tags, lru, dirty, want_n = ref.promote_ref(
        np.asarray(st_.tags), np.asarray(st_.lru),
        np.asarray(st_.dirty, np.int32), queues, ways, t)
    _assert_state(got, tags, lru, dirty, "promote state")
    assert np.array_equal(np.asarray(n), want_n)


def test_promote_duplicates_first_occurrence_wins():
    """The in-kernel dedupe: later duplicates never displace the first."""
    rng = np.random.default_rng(5)
    st_ = _random_state(rng, 2, 4, 3)
    queues = [np.array([9, 9, 13, 9, 13, 17, 17], np.int32),
              np.array([4, 4, 4, 4], np.int32)]
    ways = np.array([3, 3], np.int32)
    t = np.array([7, 7], np.int32)
    got, n = ops.promote(st_, queues, ways, t, interpret=True)
    tags, lru, dirty, want_n = ref.promote_ref(
        np.asarray(st_.tags), np.asarray(st_.lru),
        np.asarray(st_.dirty, np.int32), queues, ways, t)
    _assert_state(got, tags, lru, dirty, "dup promote")
    assert np.array_equal(np.asarray(n), want_n)


def test_promote_assume_unique_matches_dedupe_on_unique_queues():
    rng = np.random.default_rng(6)
    st_ = _random_state(rng, 3, 5, 4)
    queues = [rng.permutation(60)[: int(rng.integers(0, 25))].astype(np.int32)
              for _ in range(3)]
    ways = rng.integers(0, 5, 3).astype(np.int32)
    t = np.array([1, 2, 3], np.int32)
    a, na = ops.promote(st_, queues, ways, t, interpret=True)
    b, nb = ops.promote(st_, queues, ways, t, assume_unique=True,
                        interpret=True)
    _assert_state(a, np.asarray(b.tags), np.asarray(b.lru),
                  np.asarray(b.dirty, np.int32), "assume_unique")
    assert np.array_equal(np.asarray(na), np.asarray(nb))


def test_promote_starvation_on_full_sets():
    """Full active sets admit nothing; promotion count stays 0."""
    v, s, w = 2, 3, 4
    # every active way occupied (set-consistent tags)
    tags = np.stack([np.arange(s)[:, None] + s * np.arange(w)[None, :]
                     for _ in range(v)]).astype(np.int32)
    st_ = CacheState(jnp.asarray(tags),
                     jnp.zeros_like(jnp.asarray(tags)),
                     jnp.zeros(tags.shape, bool))
    fresh = np.arange(100, 130, dtype=np.int32)
    got, n = ops.promote(st_, [fresh, fresh], np.full(v, w, np.int32),
                         np.zeros(v, np.int32), interpret=True)
    assert np.array_equal(np.asarray(n), np.zeros(v, np.int32))
    assert np.array_equal(np.asarray(got.tags), tags)


def test_rectangular_queue_width_not_chunk_multiple():
    """A pre-rectangular [V, Q] queue whose Q is not a power-of-two /
    chunk multiple must still process its tail columns (regression: the
    tail used to be silently skipped by the chunked kernel loop)."""
    rng = np.random.default_rng(9)
    st_ = _random_state(rng, 2, 4, 4)
    q = np.full((2, 192), -1, np.int32)
    q[:, 150:] = rng.integers(0, 48, (2, 42))
    got, flushed = ops.evict(st_, q, interpret=True)
    tags, lru, dirty, want_fl = ref.evict_ref(
        np.asarray(st_.tags), np.asarray(st_.lru),
        np.asarray(st_.dirty, np.int32), list(q))
    _assert_state(got, tags, lru, dirty, "tail-column evict")
    assert np.array_equal(np.asarray(flushed), want_fl)
    ways = np.array([4, 4], np.int32)
    t = np.array([5, 5], np.int32)
    got, n = ops.promote(st_, q, ways, t, interpret=True)
    tags, lru, dirty, want_n = ref.promote_ref(
        np.asarray(st_.tags), np.asarray(st_.lru),
        np.asarray(st_.dirty, np.int32), list(q), ways, t)
    _assert_state(got, tags, lru, dirty, "tail-column promote")
    assert np.array_equal(np.asarray(n), want_n)
    # zero-width queues are no-ops, not a trace-time division error
    got, flushed = ops.evict(st_, np.empty((2, 0), np.int32),
                             interpret=True)
    assert np.array_equal(np.asarray(flushed), np.zeros(2, np.int32))


def test_evict_empty_queues_are_noops():
    rng = np.random.default_rng(7)
    st_ = _random_state(rng, 3, 4, 4)
    got, flushed = ops.evict(st_, [np.empty(0, np.int64)] * 3,
                             interpret=True)
    assert np.array_equal(np.asarray(flushed), np.zeros(3, np.int32))
    _assert_state(got, np.asarray(st_.tags), np.asarray(st_.lru),
                  np.asarray(st_.dirty, np.int32), "noop evict")


# ---------------------------------------------------------------------------
# batched popularity ops vs the host tracker
# ---------------------------------------------------------------------------

windows = st.lists(
    st.lists(st.tuples(st.integers(0, 29), st.integers(0, 100)),
             min_size=0, max_size=40),
    min_size=1, max_size=6)


@given(st.integers(1, 4), windows, st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_popularity_table_matches_tracker(num_vms, steps, seed):
    """table_update == PopularityTracker.update, float32 bit for bit,
    including non-live rows (no decay) and zero-contribution entries."""
    rng = np.random.default_rng(seed)
    table = pop.table_init(num_vms, 64)
    trackers = [pop.PopularityTracker(decay=0.5) for _ in range(num_vms)]
    width = 48
    for step_ops in steps:
        waddr = np.full((num_vms, width), -1, np.int32)
        contrib = np.zeros((num_vms, width), np.float32)
        nval = np.zeros(num_vms, np.int32)
        live = np.zeros(num_vms, bool)
        for v in range(num_vms):
            if rng.random() < 0.25 or not step_ops:
                continue  # this VM skips the window (stays un-decayed)
            n = min(len(step_ops), width)
            live[v] = True
            nval[v] = n
            waddr[v, :n] = [a for a, _ in step_ops[:n]]
            contrib[v, :n] = np.float32(
                [c / 100.0 for _, c in step_ops[:n]])
            trackers[v].update(waddr[v, :n], contrib[v, :n])
        table, _ = pop.table_update(table, waddr, contrib, nval, live, 0.5)
    ta, tv = np.asarray(table.addr), np.asarray(table.val)
    for v in range(num_vms):
        occupied = ta[v] != pop.TABLE_EMPTY
        assert np.array_equal(ta[v][occupied],
                              trackers[v]._addr.astype(np.int32))
        assert np.array_equal(tv[v][occupied], trackers[v]._val)


@given(st.integers(1, 8), st.integers(1, 32), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_popularity_merge_overflow_drops(k, d, seed):
    """Overflowing a K-entry table with D distinct addresses reports
    exactly ``max(D - K, 0)`` merge drops and keeps ``min(D, K)``
    entries; a second full-table update drops every new address."""
    rng = np.random.default_rng(seed)
    table = pop.table_init(1, k)
    addrs = rng.choice(1000, size=d, replace=False).astype(np.int32)
    contrib = (rng.random(d) + 0.01).astype(np.float32)
    nval = np.asarray([d], np.int32)
    live = np.asarray([True])
    table, drops = pop.table_update(table, addrs[None], contrib[None],
                                    nval, live, 0.5)
    assert int(np.asarray(drops)[0]) == max(d - k, 0)
    assert int(np.asarray(pop.table_len(table))[0]) == min(d, k)
    if d >= k:
        # table is full: a disjoint batch must drop all its survivors
        fresh = (addrs + 1000)[:d]
        _, drops2 = pop.table_update(table, fresh[None], contrib[None],
                                     nval, live, 0.5)
        assert int(np.asarray(drops2)[0]) == d


def test_maintenance_interval_surfaces_pop_drops():
    """The fused interval's 9-tuple carries the merge-drop counter:
    a 4-entry popularity table fed 16 distinct addresses drops 12."""
    from repro.core import reuse
    from repro.core.policies import Policy

    rng = np.random.default_rng(7)
    num_vms, s, w = 2, 4, 4
    st_ = _random_state(rng, num_vms, s, w, addr_space=32,
                        set_consistent=True)
    table = pop.table_init(num_vms, 4)
    addrs = [np.arange(16, dtype=np.int32), np.arange(2, dtype=np.int32)]
    writes = [np.zeros(16, bool), np.zeros(2, bool)]
    lens = [16, 2]
    amat, wmat = reuse._pad_rows(addrs, writes, list(range(num_vms)), lens)
    r = reuse._decompose_vmapped(amat, wmat, policy=Policy.WB,
                                 sizing_reads_only=False, chunk=256)
    *_, drops, _cleaned, _left = ops.maintenance_interval(
        st_, table, r.dist, r.served, amat, np.asarray(lens, np.int32),
        np.full(num_vms, w, np.int32), np.zeros(num_vms, np.int32),
        evict_frac=0.25, decay=0.5, interpret=True)
    drops = np.asarray(drops)
    assert drops[0] == 12   # 16 distinct into capacity 4
    assert drops[1] == 0    # 2 distinct fit


@given(st.integers(1, 3), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_popularity_queues_match_tracker(num_vms, seed):
    """Eviction/promotion queues from the device table == the tracker's
    least_popular / top_known (exact entries; exact order for promote)."""
    rng = np.random.default_rng(seed)
    s, w = 5, 4
    table = pop.table_init(num_vms, 64)
    trackers = [pop.PopularityTracker(decay=0.5) for _ in range(num_vms)]
    for _ in range(4):
        waddr = rng.integers(0, 30, (num_vms, 16)).astype(np.int32)
        contrib = rng.random((num_vms, 16)).astype(np.float32)
        for v in range(num_vms):
            trackers[v].update(waddr[v], contrib[v])
        table, _ = pop.table_update(table, waddr, contrib,
                                    np.full(num_vms, 16, np.int32),
                                    np.ones(num_vms, bool), 0.5)
    st_ = _random_state(rng, num_vms, s, w, addr_space=30,
                        set_consistent=True)
    ways = rng.integers(0, w + 1, num_vms).astype(np.int32)
    alloc = ways * s
    live = np.ones(num_vms, bool)

    eq, eqlen = pop.table_least_popular(table, st_.tags, ways, alloc,
                                        live, 0.3)
    eq, eqlen = np.asarray(eq), np.asarray(eqlen)
    limit = rng.integers(0, 15, num_vms).astype(np.int32)
    pq, pqlen = pop.table_top_known(table, st_.tags, ways, limit, live)
    pq, pqlen = np.asarray(pq), np.asarray(pqlen)

    for v in range(num_vms):
        vm_state = CacheState(*[jnp.asarray(np.asarray(x)[v])
                                for x in st_])
        res = resident_blocks(vm_state, int(ways[v]))
        if res.size and res.size * 10 >= int(alloc[v]) * 9:
            want = trackers[v].least_popular(res, 0.3)
        else:
            want = np.empty(0, np.int64)
        got = eq[v][eq[v] >= 0]
        assert eqlen[v] == want.size
        # eviction is membership-based; compare as sets
        assert np.array_equal(np.sort(got.astype(np.int64)), np.sort(want))

        want = trackers[v].top_known(res, int(limit[v]))
        got = pq[v][pq[v] >= 0]
        assert pqlen[v] == want.size
        # promotion order is the contract: exact sequence match
        assert np.array_equal(got.astype(np.int64), want)


# ---------------------------------------------------------------------------
# the fused dispatch vs a staged host reference
# ---------------------------------------------------------------------------

@given(st.integers(1, 3), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_fused_interval_matches_staged_host_reference(num_vms, seed):
    """maintenance_interval == tracker update + *_ref evict/promote,
    chained by hand on the host: states, table, and counts exact."""
    from repro.core import reuse
    from repro.core.policies import Policy

    rng = np.random.default_rng(seed)
    s, w = 4, 4
    st_ = _random_state(rng, num_vms, s, w, addr_space=32,
                        set_consistent=True)
    table = pop.table_init(num_vms, 128)
    trackers = [pop.PopularityTracker(decay=0.5) for _ in range(num_vms)]
    ways = rng.integers(0, w + 1, num_vms).astype(np.int32)
    t = rng.integers(0, 50, num_vms).astype(np.int32)
    lens = [int(rng.integers(0, 40)) for _ in range(num_vms)]
    addrs = [rng.integers(0, 32, n).astype(np.int32) for n in lens]
    writes = [rng.random(n) < 0.4 for n in lens]
    live = [v for v, n in enumerate(lens) if n > 0]
    if not live:
        return

    amat, wmat = reuse._pad_rows(addrs, writes, list(range(num_vms)), lens)
    r = reuse._decompose_vmapped(amat, wmat, policy=Policy.WB,
                                 sizing_reads_only=False, chunk=256)
    (got_ssd, got_table, flushed, promoted, eqlen, pqlen, drops,
     _cleaned, _left) = ops.maintenance_interval(
            st_, table, r.dist, r.served, amat,
            np.asarray(lens, np.int32), ways, t,
            evict_frac=0.25, decay=0.5, interpret=True)
    # 128-entry table over a 32-address space: merge never overflows
    assert np.asarray(drops).sum() == 0

    # staged host reference
    tags = np.asarray(st_.tags).copy()
    lru = np.asarray(st_.lru).copy()
    dirty = np.asarray(st_.dirty, np.int32).copy()
    want_fl = np.zeros(num_vms, np.int32)
    want_n = np.zeros(num_vms, np.int32)
    for v in live:
        d = reuse.trd_distances(addrs[v], writes[v])
        alloc = int(ways[v]) * s
        contrib = pop.contributions(d.dist, d.served, max(alloc, 1))
        trackers[v].update(addrs[v], np.asarray(contrib))
        vm = CacheState(jnp.asarray(tags[v]), jnp.asarray(lru[v]),
                        jnp.asarray(dirty[v].astype(bool)))
        res = resident_blocks(vm, int(ways[v]))
        if res.size and res.size * 10 >= alloc * 9:
            evq = trackers[v].least_popular(res, 0.25)
            assert eqlen[v] == evq.size
            tg, lr, dr, fl = ref.evict_ref(tags[v][None], lru[v][None],
                                           dirty[v][None], [evq])
            tags[v], lru[v], dirty[v] = tg[0], lr[0], dr[0]
            want_fl[v] = fl[0]
        else:
            assert eqlen[v] == 0
        vm = CacheState(jnp.asarray(tags[v]), jnp.asarray(lru[v]),
                        jnp.asarray(dirty[v].astype(bool)))
        res = resident_blocks(vm, int(ways[v]))
        free = max(alloc - res.size, 0)
        prq = trackers[v].top_known(res, free) if free else \
            np.empty(0, np.int64)
        assert pqlen[v] == prq.size
        if prq.size:
            tg, lr, dr, n = ref.promote_ref(
                tags[v][None], lru[v][None], dirty[v][None], [prq],
                ways[v:v + 1], t[v:v + 1])
            tags[v], lru[v], dirty[v] = tg[0], lr[0], dr[0]
            want_n[v] = n[0]

    _assert_state(got_ssd, tags, lru, dirty, "fused vs staged state")
    assert np.array_equal(np.asarray(flushed)[live], want_fl[live])
    assert np.array_equal(np.asarray(promoted)[live], want_n[live])
    ta, tv = np.asarray(got_table.addr), np.asarray(got_table.val)
    for v in live:
        occupied = ta[v] != pop.TABLE_EMPTY
        assert np.array_equal(ta[v][occupied],
                              trackers[v]._addr.astype(np.int32))
        assert np.array_equal(tv[v][occupied], trackers[v]._val)
