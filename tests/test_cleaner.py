"""Property-test sweep for the background dirty-block cleaner (PR 8).

Locks down the third maintenance stage at every layer:

1. the Pallas clean kernel (``kernels.maintenance.ops.clean``) against
   the sequential numpy oracle (``ref.clean_ref``) on randomized stacked
   states — ragged active ways, empty/all-clean states, quota 0 and
   quota > candidates — including the quota bound, age order, and the
   flushed-blocks-stay-resident contract;
2. the fused 9-tuple ``maintenance_interval`` third stage against
   chaining the cleaner oracle onto the 2-stage dispatch by hand;
3. the vmapped simulator ops (``clean_batch``) against
   ``clean_blocks_ref``;
4. the controller: fused == staged == sequential Stats bit-identity with
   cleaning enabled, flush conservation across intervals
   (``clean_log`` == the ``flushes`` stat; ``dirty_log`` == the final
   state's dirty occupancy), and the RO-DRAM invariant under cleaning.
"""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import EticaCache, EticaConfig, Geometry, interleave
from repro.core.simulator import CacheState, clean_batch, clean_blocks, \
    clean_blocks_ref
from repro.kernels.maintenance import ops, ref
from repro.traces import make

SETTINGS = dict(max_examples=20, deadline=None)

geometries = st.tuples(st.integers(1, 4),    # num_vms
                       st.integers(2, 10),   # num_sets
                       st.integers(1, 7))    # num_ways


def _random_state(rng, num_vms, num_sets, num_ways, addr_space=48,
                  dirty_frac=0.5):
    shape = (num_vms, num_sets, num_ways)
    tags = np.where(rng.random(shape) < 0.35, -1,
                    rng.integers(0, addr_space, shape)).astype(np.int32)
    lru = np.where(tags < 0, -1,
                   rng.integers(0, 30, shape)).astype(np.int32)
    dirty = (tags >= 0) & (rng.random(shape) < dirty_frac)
    return CacheState(jnp.asarray(tags), jnp.asarray(lru),
                      jnp.asarray(dirty))


def _active_dirty(state, ways):
    d = np.asarray(state.dirty)
    w = d.shape[-1]
    act = np.arange(w)[None, None, :] < np.asarray(ways).reshape(-1, 1, 1)
    return d & act


# ---------------------------------------------------------------------------
# 1. the clean kernel vs the sequential oracle
# ---------------------------------------------------------------------------

@given(geometries, st.sampled_from([0.0, 0.2, 0.5, 1.0]),
       st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_clean_kernel_matches_oracle(geom, dirty_frac, seed):
    """Kernel == oracle bit for bit: post-state, flush counts, and the
    remaining-dirty counts — over ragged ways and quotas spanning 0,
    partial, and larger-than-candidates (all-clean states included via
    ``dirty_frac = 0``)."""
    num_vms, s, w = geom
    rng = np.random.default_rng(seed)
    st_ = _random_state(rng, num_vms, s, w, dirty_frac=dirty_frac)
    ways = rng.integers(0, w + 1, num_vms).astype(np.int32)
    quota = rng.integers(0, s * w + 2, num_vms).astype(np.int32)

    got_st, got_fl, got_left = ops.clean(st_, ways, quota, interpret=True)
    want_tags, want_lru, want_dirty, want_fl = ref.clean_ref(
        st_.tags, st_.lru, np.asarray(st_.dirty, np.int32), ways, quota)

    np.testing.assert_array_equal(np.asarray(got_st.tags), want_tags)
    np.testing.assert_array_equal(np.asarray(got_st.lru), want_lru)
    np.testing.assert_array_equal(
        np.asarray(got_st.dirty).astype(np.int32), want_dirty)
    np.testing.assert_array_equal(np.asarray(got_fl), want_fl)
    # remaining dirty candidates after cleaning
    np.testing.assert_array_equal(
        np.asarray(got_left), _active_dirty(got_st, ways).sum((1, 2)))


@given(geometries, st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_clean_quota_and_residency_contracts(geom, seed):
    """Per VM: flushed == min(quota, candidates) — the quota is never
    exceeded and never left unused; tags/lru are untouched (flushed
    blocks stay resident); dirty only ever clears (no new dirty)."""
    num_vms, s, w = geom
    rng = np.random.default_rng(seed)
    st_ = _random_state(rng, num_vms, s, w)
    ways = rng.integers(0, w + 1, num_vms).astype(np.int32)
    quota = rng.integers(0, s * w + 2, num_vms).astype(np.int32)
    n_cand = _active_dirty(st_, ways).sum((1, 2))

    got_st, got_fl, got_left = ops.clean(st_, ways, quota, interpret=True)
    got_fl = np.asarray(got_fl)

    np.testing.assert_array_equal(got_fl, np.minimum(quota, n_cand))
    np.testing.assert_array_equal(np.asarray(got_left), n_cand - got_fl)
    np.testing.assert_array_equal(np.asarray(got_st.tags),
                                  np.asarray(st_.tags))
    np.testing.assert_array_equal(np.asarray(got_st.lru),
                                  np.asarray(st_.lru))
    # dirty_after is a subset of dirty_before, smaller by exactly flushed
    before = np.asarray(st_.dirty)
    after = np.asarray(got_st.dirty)
    assert not (after & ~before).any()
    np.testing.assert_array_equal(
        before.sum((1, 2)) - after.sum((1, 2)), got_fl)


@given(geometries, st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_clean_flushes_oldest_first(geom, seed):
    """Age order: every surviving dirty candidate is younger (greater
    (lru, flat-index) key) than every flushed one, per VM."""
    num_vms, s, w = geom
    rng = np.random.default_rng(seed)
    st_ = _random_state(rng, num_vms, s, w)
    ways = rng.integers(0, w + 1, num_vms).astype(np.int32)
    quota = rng.integers(0, s * w + 2, num_vms).astype(np.int32)
    got_st, _, _ = ops.clean(st_, ways, quota, interpret=True)
    lru = np.asarray(st_.lru)
    flushed = _active_dirty(st_, ways) & ~np.asarray(got_st.dirty)
    survived = _active_dirty(got_st, ways)
    for v in range(num_vms):
        fk = [(int(lru[v, i, j]), i * w + j)
              for i, j in zip(*np.nonzero(flushed[v]))]
        sk = [(int(lru[v, i, j]), i * w + j)
              for i, j in zip(*np.nonzero(survived[v]))]
        if fk and sk:
            assert max(fk) < min(sk)


# ---------------------------------------------------------------------------
# 2. the fused interval's third stage == chaining the oracle by hand
# ---------------------------------------------------------------------------

@given(st.integers(1, 3), st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_fused_third_stage_matches_chained_oracle(num_vms, seed):
    """``maintenance_interval(clean_quota=q)`` == the 2-stage dispatch
    followed by ``clean_ref`` with the quota gated on live VMs — states
    and both new counters exact (and ``clean_quota=0`` stays the exact
    pre-cleaner dispatch)."""
    from repro.core import popularity as pop
    from repro.core import reuse
    from repro.core.policies import Policy

    rng = np.random.default_rng(seed)
    s, w = 4, 4
    st_ = _random_state(rng, num_vms, s, w, addr_space=32)
    # set-consistent tags so eviction/promotion behave
    tags = np.asarray(st_.tags).copy()
    for v in range(num_vms):
        for i in range(s):
            row = tags[v, i]
            row[row >= 0] = (row[row >= 0] // s) * s + i
    st_ = CacheState(jnp.asarray(tags), st_.lru, st_.dirty)
    table = pop.table_init(num_vms, 128)
    ways = rng.integers(0, w + 1, num_vms).astype(np.int32)
    t = rng.integers(0, 50, num_vms).astype(np.int32)
    lens = [int(rng.integers(0, 40)) for _ in range(num_vms)]
    addrs = [rng.integers(0, 32, n).astype(np.int32) for n in lens]
    writes = [rng.random(n) < 0.4 for n in lens]
    quota = int(rng.integers(1, 8))
    if sum(lens) == 0:
        return
    amat, wmat = reuse._pad_rows(addrs, writes, list(range(num_vms)), lens)
    r = reuse._decompose_vmapped(amat, wmat, policy=Policy.WB,
                                 sizing_reads_only=False, chunk=256)
    args = (st_, table, r.dist, r.served, amat, np.asarray(lens, np.int32),
            ways, t)
    kw = dict(evict_frac=0.25, decay=0.5, interpret=True)
    base = ops.maintenance_interval(*args, **kw)
    got = ops.maintenance_interval(*args, clean_quota=quota, **kw)

    # stages 1-2 identical; counters 2-6 shared
    for i in (2, 3, 4, 5, 6):
        np.testing.assert_array_equal(np.asarray(base[i]),
                                      np.asarray(got[i]))
    live = np.asarray([n > 0 for n in lens])
    want_tags, want_lru, want_dirty, want_fl = ref.clean_ref(
        base[0].tags, base[0].lru, np.asarray(base[0].dirty, np.int32),
        ways, np.where(live, quota, 0))
    np.testing.assert_array_equal(np.asarray(got[0].tags), want_tags)
    np.testing.assert_array_equal(np.asarray(got[0].lru), want_lru)
    np.testing.assert_array_equal(
        np.asarray(got[0].dirty).astype(np.int32), want_dirty)
    np.testing.assert_array_equal(np.asarray(got[7]), want_fl)  # cleaned
    np.testing.assert_array_equal(                              # dirty_left
        np.asarray(got[8]), _active_dirty(got[0], ways).sum((1, 2)))
    # quota=0 default: cleaned == 0 and the state is the 2-stage state
    np.testing.assert_array_equal(np.asarray(base[7]), 0)
    np.testing.assert_array_equal(np.asarray(base[0].dirty),
                                  np.asarray(got[0].dirty) | (
                                      np.asarray(base[0].dirty)
                                      & ~np.asarray(got[0].dirty)))


# ---------------------------------------------------------------------------
# 3. simulator-level vmapped ops vs the per-VM numpy oracle
# ---------------------------------------------------------------------------

@given(geometries, st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_clean_batch_matches_blocks_ref(geom, seed):
    num_vms, s, w = geom
    rng = np.random.default_rng(seed)
    st_ = _random_state(rng, num_vms, s, w)
    ways = rng.integers(0, w + 1, num_vms).astype(np.int32)
    quota = rng.integers(0, s * w + 2, num_vms).astype(np.int32)
    got_st, got_fl, got_left = clean_batch(st_, ways, quota)
    for v in range(num_vms):
        one = CacheState(st_.tags[v], st_.lru[v], st_.dirty[v])
        want_st, want_fl, want_left = clean_blocks_ref(
            one, int(ways[v]), int(quota[v]))
        assert int(got_fl[v]) == want_fl
        assert int(got_left[v]) == want_left
        np.testing.assert_array_equal(np.asarray(got_st.dirty[v]),
                                      np.asarray(want_st.dirty))
        # unbatched wrapper agrees too
        one_st, one_fl, one_left = clean_blocks(one, int(ways[v]),
                                                int(quota[v]))
        assert int(one_fl) == want_fl and int(one_left) == want_left
        np.testing.assert_array_equal(np.asarray(one_st.dirty),
                                      np.asarray(want_st.dirty))


# ---------------------------------------------------------------------------
# 4. controller-level: mode identity, conservation, invariants
# ---------------------------------------------------------------------------

GEO = Geometry(num_sets=8, max_ways=16)


def _mix(reqs=1200, seed=0):
    vms = ["hm_1", "usr_0"]
    return interleave(
        [make(n, reqs, seed=seed + i, addr_offset=i * 10_000_000,
              scale=0.25) for i, n in enumerate(vms)], seed=seed + 42)


def _cfg(**kw):
    base = dict(dram_capacity=40, ssd_capacity=80, geometry_dram=GEO,
                geometry_ssd=GEO, resize_interval=600, promo_interval=200,
                clean_quota=3)
    base.update(kw)
    return EticaConfig(**base)


def test_modes_bit_identical_with_cleaner():
    """fused == staged == sequential Stats (incl. ``flushes``,
    ``dirty_resident``, ``evict_flushes``) with the cleaner enabled."""
    trace = _mix()
    runs = {}
    for name, kw in (
            ("fused", dict(batched=True, fused_maintenance=True)),
            ("staged", dict(batched=True, fused_maintenance=False)),
            ("sequential", dict(batched=False))):
        cache = EticaCache(_cfg(**kw), num_vms=2)
        runs[name] = [r.stats for r in cache.run(trace)]
        total_fl = sum(s.get("flushes", 0) for s in runs[name])
        assert total_fl > 0, f"{name}: cleaner never flushed"
    for v in range(2):
        f, s_, q = (runs["fused"][v], runs["staged"][v],
                    runs["sequential"][v])
        assert set(f) == set(s_) == set(q), (v, set(f) ^ set(q))
        for k in f:
            assert f[k] == s_[k] == q[k], (v, k, f[k], s_[k], q[k])


def test_cleaner_conservation_and_invariants():
    """Fused batched run with cleaning: the per-interval ``clean_log``
    sums to the ``flushes`` stat per VM, the last ``dirty_log`` row is
    the final state's active-dirty occupancy AND the ``dirty_resident``
    gauge, flushes ride ``disk_writes``, and DRAM stays clean."""
    trace = _mix(reqs=1500, seed=7)
    cache = EticaCache(_cfg(resize_interval=500, promo_interval=100),
                       num_vms=2)
    base = EticaCache(_cfg(resize_interval=500, promo_interval=100,
                           clean_quota=0), num_vms=2)
    res = cache.run(trace)
    res_base = base.run(trace)

    assert len(cache.clean_log) > 0
    clog = np.stack(cache.clean_log)          # [intervals, V]
    dlog = np.stack(cache.dirty_log)
    for v in range(2):
        st_v = res[v].stats
        assert st_v["flushes"] == clog[:, v].sum() > 0
        assert st_v["dirty_resident"] == dlog[-1, v]
        # cleaning traffic is accounted as disk writes on top of the
        # base run's (same datapath: hit/miss stats must be unchanged)
        bs = res_base[v].stats
        for k in ("reads", "writes", "read_hits_l1", "read_hits_l2",
                  "write_hits_l2"):
            assert st_v[k] == bs[k], (v, k)
        assert st_v["disk_writes"] >= bs["disk_writes"]
    # final state agrees with the last telemetry row
    final_dirty = _active_dirty(cache.ssd, cache.ways_ssd).sum((1, 2))
    np.testing.assert_array_equal(final_dirty, dlog[-1])
    # the RO level never holds dirty data, cleaner or not
    assert not np.asarray(cache.dram.dirty).any()
    # cleaner drains: dirty occupancy dips below its peak at least once
    assert dlog.sum(1).min() < dlog.sum(1).max() or dlog.sum() == 0
