"""Per-kernel allclose sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Policy, pod_distances
from repro.kernels.decode_attention.kernel import paged_decode_attention
from repro.kernels.decode_attention.ref import paged_decode_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.popularity.kernel import popularity
from repro.kernels.popularity.ref import popularity_ref
from repro.kernels.reuse_distance.kernel import count_between
from repro.kernels.reuse_distance.ops import reuse_distances
from repro.kernels.reuse_distance.ref import count_between_ref


class TestReuseDistanceKernel:
    @pytest.mark.parametrize("n", [17, 64, 257, 1024, 3000])
    def test_vs_ref(self, n):
        rng = np.random.default_rng(n)
        prev = rng.integers(-1, n, n).astype(np.int32)
        touch = rng.integers(0, 2, n).astype(np.int32)
        nt = rng.integers(0, n + 1, n).astype(np.int32)
        got = count_between(jnp.asarray(prev), jnp.asarray(touch),
                            jnp.asarray(nt))
        want = count_between_ref(jnp.asarray(prev), jnp.asarray(touch),
                                 jnp.asarray(nt))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("policy", [Policy.WB, Policy.RO, Policy.WBWO])
    @pytest.mark.parametrize("seed", [0, 1])
    def test_pipeline_vs_core_engine(self, policy, seed):
        rng = np.random.default_rng(seed)
        n = 400
        addr = rng.integers(0, 50, n).astype(np.int32)
        w = rng.random(n) < 0.4
        got = reuse_distances(addr, w, policy)
        want = pod_distances(addr, w, policy)
        np.testing.assert_array_equal(np.asarray(got.dist),
                                      np.asarray(want.dist))

    @pytest.mark.parametrize("kind", ["urd", "trd", "wss", "reuse_intensity"])
    def test_sizing_reduction_vs_core_engine(self, kind):
        """Kernel-backed baseline sizing == the batched jnp reduction."""
        from repro.core import reuse as core_reuse
        from repro.kernels.reuse_distance.ops import sizing_reduction
        rng = np.random.default_rng(3)
        n = 400
        addr = rng.integers(0, 50, n).astype(np.int32)
        w = rng.random(n) < 0.4
        grid = np.arange(0, 321, 20, dtype=np.int64)
        demands, hits, reads = core_reuse.sizing_metrics_batch([addr], [w],
                                                               kind, grid)
        got_d, got_h = sizing_reduction(addr, w, kind, grid)
        assert int(got_d) == int(demands[0])
        np.testing.assert_array_equal(np.asarray(got_h, np.int64), hits[0])
        assert int(reads[0]) == int(np.sum(~w))
        # bucket-padded row + n_valid must give the same answers (the
        # padding convention of core_reuse._pad_rows)
        pad = core_reuse._PAD_BASE + np.arange(112, dtype=np.int32)
        a_pad = np.concatenate([addr, pad])
        w_pad = np.concatenate([w, np.ones(112, bool)])
        pad_d, pad_h, pad_r = sizing_reduction(a_pad, w_pad, kind, grid,
                                               n_valid=n, with_reads=True)
        assert int(pad_d) == int(demands[0])
        np.testing.assert_array_equal(np.asarray(pad_h, np.int64), hits[0])
        assert int(pad_r) == int(reads[0])

    @pytest.mark.parametrize("kind", ["urd", "trd", "wss", "reuse_intensity"])
    def test_batched_sizing_kernel_route_matches_jnp(self, kind):
        """The vmapped kernel-backed sizing batch (SizingMetric's TPU
        route) == the pure-jnp batched reduction, ragged rows included."""
        from repro.core import reuse as core_reuse
        from repro.kernels.reuse_distance.ops import sizing_metrics_batch
        rng = np.random.default_rng(11)
        addrs = [rng.integers(0, 40, n).astype(np.int32)
                 for n in (300, 0, 77)]
        writes = [rng.random(a.shape[0]) < 0.4 for a in addrs]
        grid = np.arange(0, 257, 16, dtype=np.int64)
        want = core_reuse.sizing_metrics_batch(addrs, writes, kind, grid)
        got = sizing_metrics_batch(addrs, writes, kind, grid,
                                   interpret=True)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_sizing_metric_env_routing(self, monkeypatch):
        """ETICA_SIZING_KERNEL=1 routes SizingMetric.batch through the
        kernel path with identical results to the jnp fallback."""
        from repro.core.baselines import urd_metric
        from repro.core.controller import Geometry
        rng = np.random.default_rng(13)
        addrs = [rng.integers(0, 40, 150).astype(np.int32)]
        writes = [rng.random(150) < 0.4]
        m = urd_metric(Geometry(num_sets=8, max_ways=16))
        monkeypatch.setenv("ETICA_SIZING_KERNEL", "0")
        want = m.batch(addrs, writes, with_reads=True)
        monkeypatch.setenv("ETICA_SIZING_KERNEL", "1")
        got = m.batch(addrs, writes, with_reads=True)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    @pytest.mark.parametrize("ti,tj", [(64, 128), (128, 256), (256, 512)])
    def test_tile_shapes(self, ti, tj):
        rng = np.random.default_rng(7)
        n = 777
        prev = rng.integers(-1, n, n).astype(np.int32)
        touch = rng.integers(0, 2, n).astype(np.int32)
        nt = rng.integers(0, n + 1, n).astype(np.int32)
        got = count_between(jnp.asarray(prev), jnp.asarray(touch),
                            jnp.asarray(nt), ti=ti, tj=tj)
        want = count_between_ref(jnp.asarray(prev), jnp.asarray(touch),
                                 jnp.asarray(nt))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestPopularityKernel:
    @pytest.mark.parametrize("n,nb", [(64, 5), (1000, 300), (5000, 997)])
    @pytest.mark.parametrize("cs", [1.0, 64.0, 4096.0])
    def test_vs_ref(self, n, nb, cs):
        rng = np.random.default_rng(n + int(cs))
        dist = rng.integers(-1, 300, n).astype(np.int32)
        served = rng.integers(0, 2, n).astype(bool)
        seg = rng.integers(0, nb, n).astype(np.int32)
        got = popularity(jnp.asarray(dist), jnp.asarray(served),
                         jnp.asarray(seg), nb, cs)
        want = popularity_ref(jnp.asarray(dist), jnp.asarray(served),
                              jnp.asarray(seg), nb, cs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("b,h,hkv,s,d", [
        (1, 2, 1, 128, 32), (2, 4, 2, 256, 64), (1, 8, 8, 128, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal(self, b, h, hkv, s, d, dtype):
        ks = jax.random.split(jax.random.PRNGKey(b * h + s), 3)
        q = jax.random.normal(ks[0], (b, h, s, d), dtype)
        k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
        v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
        got = flash_attention(q, k, v, causal=True, tq=64, tk=64)
        want = attention_ref(q, k, v, causal=True)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=tol)

    def test_sliding_window(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64))
        k = jax.random.normal(ks[1], (1, 2, 256, 64))
        v = jax.random.normal(ks[2], (1, 2, 256, 64))
        got = flash_attention(q, k, v, causal=True, window=64, tq=64, tk=64)
        want = attention_ref(q, k, v, causal=True, window=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def test_non_causal(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64))
        k = jax.random.normal(ks[1], (1, 1, 128, 64))
        v = jax.random.normal(ks[2], (1, 1, 128, 64))
        got = flash_attention(q, k, v, causal=False, tq=64, tk=64)
        want = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)


class TestPagedDecodeKernel:
    @pytest.mark.parametrize("b,h,hkv,d,np_,ps,npages", [
        (2, 4, 2, 64, 16, 32, 4), (3, 8, 4, 128, 32, 16, 8),
        (1, 2, 2, 32, 8, 64, 2)])
    def test_vs_ref(self, b, h, hkv, d, np_, ps, npages):
        ks = jax.random.split(jax.random.PRNGKey(b + h + d), 5)
        q = jax.random.normal(ks[0], (b, h, d))
        kp = jax.random.normal(ks[1], (np_, ps, hkv, d))
        vp = jax.random.normal(ks[2], (np_, ps, hkv, d))
        pt = jax.random.randint(ks[3], (b, npages), 0, np_)
        lengths = jax.random.randint(ks[4], (b,), 1, npages * ps + 1)
        got = paged_decode_attention(q, kp, vp, pt, lengths)
        want = paged_decode_ref(q, kp, vp, pt, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def test_length_masking(self):
        """Tokens beyond `lengths` must not influence the output."""
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        q = jax.random.normal(ks[0], (1, 2, 32))
        kp = jax.random.normal(ks[1], (4, 16, 2, 32))
        vp = jax.random.normal(ks[2], (4, 16, 2, 32))
        pt = jnp.array([[0, 1]], jnp.int32)
        out1 = paged_decode_attention(q, kp, vp, pt, jnp.array([20]))
        kp2 = kp.at[1, 10:].set(999.0)   # poison beyond length
        vp2 = vp.at[1, 10:].set(999.0)
        out2 = paged_decode_attention(q, kp2, vp2, pt, jnp.array([20]))
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   atol=1e-6)
