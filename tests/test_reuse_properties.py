"""Property-based tests of the reuse-distance engine (hypothesis)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (Policy, Trace, hit_counts_at_sizes, pod,
                        pod_distances, trd, trd_distances, urd,
                        urd_distances)

SETTINGS = dict(max_examples=25, deadline=None)


def traces(min_size=1, max_size=200, addr_space=24):
    return st.lists(
        st.tuples(st.integers(0, addr_space - 1), st.booleans()),
        min_size=min_size, max_size=max_size,
    ).map(lambda ops: Trace(
        addr=np.array([a for a, _ in ops], np.int32),
        is_write=np.array([w for _, w in ops], bool)))


@given(traces())
@settings(**SETTINGS)
def test_metric_ordering(tr):
    """POD <= URD <= TRD for every trace and policy (paper's core claim:
    POD never over-allocates relative to URD)."""
    t, u = trd(tr), urd(tr)
    assert u <= t
    for p in (Policy.RO, Policy.WBWO, Policy.WB, Policy.WT, Policy.WO):
        assert pod(tr, p) <= u, p


@given(traces())
@settings(**SETTINGS)
def test_pod_wb_equals_urd(tr):
    assert pod(tr, Policy.WB) == urd(tr)
    assert pod(tr, Policy.WT) == urd(tr)


@given(traces())
@settings(**SETTINGS)
def test_read_only_trace_pod_ro_equals_urd(tr):
    """With no writes, RO serves exactly what URD counts."""
    tr = Trace(addr=tr.addr, is_write=np.zeros_like(tr.is_write))
    assert pod(tr, Policy.RO) == urd(tr)


@given(traces())
@settings(**SETTINGS)
def test_served_have_distance_and_cold_dont(tr):
    for p in (Policy.RO, Policy.WBWO, Policy.WB):
        r = pod_distances(tr.addr, tr.is_write, p)
        dist = np.asarray(r.dist)
        served = np.asarray(r.served)
        assert (dist[served] >= 0).all()
        assert (dist[~served] == -1).all()


@given(traces())
@settings(**SETTINGS)
def test_distance_bounded_by_distinct_addresses(tr):
    bound = np.unique(np.asarray(tr.addr)).size
    for p in (Policy.RO, Policy.WBWO, Policy.WB):
        assert pod(tr, p) <= bound


@given(traces(), st.integers(0, 3))
@settings(**SETTINGS)
def test_mrc_monotone_nondecreasing(tr, _):
    sizes = np.array([0, 1, 2, 4, 8, 16, 64], np.int64)
    for p in (Policy.RO, Policy.WBWO, Policy.WB):
        r = pod_distances(tr.addr, tr.is_write, p)
        hits = hit_counts_at_sizes(r.dist, r.served, sizes)
        assert (np.diff(hits) >= 0).all()
        # a cache big enough for every distinct block serves every
        # served access
        assert hits[-1] == int(np.asarray(r.served).sum())


@given(traces())
@settings(**SETTINGS)
def test_first_access_never_served(tr):
    r = urd_distances(tr.addr, tr.is_write)
    first = {}
    served = np.asarray(r.served)
    for i, a in enumerate(np.asarray(tr.addr)):
        if a not in first:
            first[a] = i
            assert not served[i]


@given(traces())
@settings(**SETTINGS)
def test_write_appended_suffix_does_not_change_metrics(tr):
    """Bucket-padding correctness: fresh trailing writes are inert."""
    n = len(tr)
    suffix = Trace(addr=np.arange(10_000, 10_003, dtype=np.int32),
                   is_write=np.ones(3, bool))
    tr2 = Trace.concat([tr, suffix])
    for p in (Policy.RO, Policy.WBWO, Policy.WB):
        r1 = pod_distances(tr.addr, tr.is_write, p)
        r2 = pod_distances(tr2.addr, tr2.is_write, p)
        assert (np.asarray(r1.dist) == np.asarray(r2.dist)[:n]).all()


@given(traces(max_size=120))
@settings(**SETTINGS)
def test_trd_counts_all_reaccesses(tr):
    r = trd_distances(tr.addr, tr.is_write)
    served = np.asarray(r.served)
    seen = set()
    for i, a in enumerate(np.asarray(tr.addr)):
        assert served[i] == (int(a) in seen)
        seen.add(int(a))
