"""Batched baseline sizing == sequential ``*_ref`` closures, bit for bit.

The one-level baselines' sizing metrics (URD / TRD / WSS / reuse
intensity) now ride the same vmapped reuse-distance dispatch as ETICA's
POD sizing. Every value the batched path produces — demands, float64 hit
curves, and the controller results downstream of them — must equal the
original per-VM Python closures exactly, including ragged inputs with
empty, all-write, and single-request VMs.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (Geometry, SizingMetric, interleave, make_centaur,
                        make_eci_cache, make_scave, make_vcacheshare,
                        reuse_intensity_metric, trd_metric, urd_metric,
                        wss_metric)
from repro.core import reuse
from repro.core.controller import _mrc_grid
from repro.core.trace import Trace

GEO = Geometry(num_sets=8, max_ways=16)
METRICS = {
    "urd": urd_metric,
    "trd": trd_metric,
    "wss": wss_metric,
    "reuse_intensity": reuse_intensity_metric,
}
FACTORIES = [make_eci_cache, make_centaur, make_scave, make_vcacheshare]


def _ragged_requests(seed: int):
    """Per-VM request lists with awkward shapes: empty, all-write, len-1."""
    rng = np.random.default_rng(seed)
    lens = [int(n) for n in rng.integers(0, 200, 6)]
    lens[1] = 0       # VM with no requests this interval
    lens[4] = 1       # single request
    addrs = [rng.integers(0, 48, n).astype(np.int32) for n in lens]
    writes = [rng.random(n) < 0.4 for n in lens]
    if lens[3]:
        writes[3][:] = True   # all-write VM: nothing served under RO/URD
    return addrs, writes


@pytest.mark.parametrize("kind", list(METRICS))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_batched_metric_matches_ref_closure(kind, seed):
    metric: SizingMetric = METRICS[kind](GEO)
    addrs, writes = _ragged_requests(seed)
    demands, grid, curves = metric.batch(addrs, writes)
    assert np.array_equal(grid, metric.grid)
    for v, (a, w) in enumerate(zip(addrs, writes)):
        if len(a) == 0:
            assert demands[v] == 0 and not curves[v].any()
            continue
        d_ref, g_ref, c_ref = metric.ref(Trace(a, w))
        assert int(demands[v]) == int(d_ref), (kind, v)
        assert np.array_equal(g_ref, grid)
        # float64 curves must be BIT-identical, not just allclose
        assert np.array_equal(curves[v], c_ref), (kind, v)


def test_all_empty_and_kind_validation():
    metric = urd_metric(GEO)
    demands, _, curves = metric.batch([np.empty(0, np.int32)] * 3,
                                      [np.empty(0, bool)] * 3)
    assert not demands.any() and not curves.any()
    with pytest.raises(ValueError):
        reuse.sizing_metrics_batch([np.arange(4)], [np.zeros(4, bool)],
                                   "pod", _mrc_grid(GEO))


def _mixed_trace(num_vms=3, reqs=2000):
    from repro.traces import make
    return interleave(
        [make(n, reqs, seed=i, addr_offset=i * 10_000_000, scale=0.25)
         for i, n in enumerate(["hm_1", "usr_0", "web_3"][:num_vms])],
        seed=0)


@pytest.mark.parametrize("factory", FACTORIES,
                         ids=lambda f: f.__name__)
def test_controller_batched_equals_sequential(factory):
    """Every one-level baseline policy: batched == sequential exactly."""
    trace = _mixed_trace()
    results, caches = {}, {}
    for batched in (True, False):
        cache = factory(120, 3, geometry=GEO, resize_interval=1000,
                        sim_chunk=500, batched=batched)
        results[batched] = cache.run(trace)
        caches[batched] = cache
    for v in range(3):
        assert results[True][v].stats == results[False][v].stats, v
        assert np.array_equal(results[True][v].alloc_history,
                              results[False][v].alloc_history), v
    for log_b, log_s in zip(caches[True].logs, caches[False].logs):
        assert np.array_equal(log_b.demands, log_s.demands)
        assert np.array_equal(log_b.alloc, log_s.alloc)
        assert log_b.policies == log_s.policies


def test_zero_per_vm_metric_calls_when_batched():
    """The batched resize path must never invoke the per-VM closure."""
    trace = _mixed_trace(reqs=1200)
    calls = {"n": 0}

    def run(batched: bool):
        cache = make_eci_cache(120, 3, geometry=GEO, resize_interval=600,
                               sim_chunk=300, batched=batched)
        ref = cache.metric.ref

        def counting_ref(sub):
            calls["n"] += 1
            return ref(sub)

        cache.metric = dataclasses.replace(cache.metric, ref=counting_ref)
        cache.run(trace)

    run(batched=True)
    assert calls["n"] == 0
    run(batched=False)
    assert calls["n"] > 0


def test_plain_closure_metric_still_supported():
    """Third-party MetricFn closures (no .batch) fall back to the loop."""
    metric = urd_metric(GEO)
    from repro.core.controller import (PartitionedSingleLevelCache,
                                       SingleLevelConfig)
    from repro.core.baselines import eci_policy
    trace = _mixed_trace(reqs=1200)
    results = {}
    for m in (metric, metric.ref):
        cfg = SingleLevelConfig(capacity=120, geometry=GEO,
                                resize_interval=600, sim_chunk=300)
        cache = PartitionedSingleLevelCache(cfg, 3, m, eci_policy())
        results[m is metric] = cache.run(trace)
    for v in range(3):
        assert results[True][v].stats == results[False][v].stats, v
