"""Batched (vmap) datapath == sequential per-VM loop, bit for bit.

The batched entry points must produce identical Stats and final
CacheStates to running the unbatched simulators per VM — including
heterogeneous per-VM ways/policies and padded ``addr == -1`` no-ops —
and the controllers must produce identical VMResults in both modes.
"""
import dataclasses

import numpy as np
import jax

from repro.core import (EticaCache, EticaConfig, Geometry, Policy, Stats,
                        Trace, interleave, make_cache, make_cache_batch,
                        make_eci_cache, policy_flags, simulate_single_level,
                        simulate_single_level_batch, simulate_two_level,
                        simulate_two_level_batch)

V, N, S, W = 3, 96, 4, 4
WAYS = np.array([4, 2, 0], np.int32)       # heterogeneous allocations
T0 = np.array([0, 5, 7], np.int32)         # heterogeneous clocks


def _requests(seed=0, pad_frac=0.15, addr_space=24):
    rng = np.random.default_rng(seed)
    addr = rng.integers(0, addr_space, (V, N)).astype(np.int32)
    addr[rng.random((V, N)) < pad_frac] = -1   # padded no-ops mid-stream
    is_write = rng.random((V, N)) < 0.4
    return addr, is_write


def _assert_tree_equal(a, b, msg=""):
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y)), msg


def _vm(tree, v):
    return jax.tree_util.tree_map(lambda x: x[v], tree)


def test_single_level_batch_matches_sequential_all_policies():
    addr, is_write = _requests()
    for policy in Policy:
        batch = simulate_single_level_batch(
            addr, is_write, make_cache_batch(V, S, W), WAYS,
            policy_flags([policy] * V), t0=T0)
        for v in range(V):
            st, stats, t_end = simulate_single_level(
                addr[v], is_write[v], make_cache(S, W), WAYS[v], policy,
                t0=int(T0[v]))
            _assert_tree_equal(st, _vm(batch[0], v), f"{policy} state")
            _assert_tree_equal(stats, Stats(*[f[v] for f in batch[1]]),
                               f"{policy} stats")
            assert int(t_end) == int(batch[2][v])


def test_single_level_batch_heterogeneous_policies():
    """ECI-Cache's regime: different write policies per VM, one dispatch."""
    addr, is_write = _requests(seed=1)
    policies = [Policy.RO, Policy.WB, Policy.WT]
    batch = simulate_single_level_batch(
        addr, is_write, make_cache_batch(V, S, W), WAYS,
        policy_flags(policies), t0=T0)
    for v in range(V):
        st, stats, _ = simulate_single_level(
            addr[v], is_write[v], make_cache(S, W), WAYS[v], policies[v],
            t0=int(T0[v]))
        _assert_tree_equal(st, _vm(batch[0], v))
        _assert_tree_equal(stats, Stats(*[f[v] for f in batch[1]]))


def test_two_level_batch_matches_sequential_both_modes():
    addr, is_write = _requests(seed=2)
    ways_ssd = np.array([4, 3, 1], np.int32)
    for mode in ("full", "npe"):
        batch = simulate_two_level_batch(
            addr, is_write, make_cache_batch(V, S, W),
            make_cache_batch(V, 8, 4), WAYS, ways_ssd, mode=mode, t0=T0)
        for v in range(V):
            dram, ssd, stats, t_end = simulate_two_level(
                addr[v], is_write[v], make_cache(S, W), make_cache(8, 4),
                WAYS[v], ways_ssd[v], mode=mode, t0=int(T0[v]))
            _assert_tree_equal(dram, _vm(batch[0], v), f"{mode} dram")
            _assert_tree_equal(ssd, _vm(batch[1], v), f"{mode} ssd")
            _assert_tree_equal(stats, Stats(*[f[v] for f in batch[2]]),
                               f"{mode} stats")
            assert int(t_end) == int(batch[3][v])


def test_fully_padded_rows_are_noops():
    """A VM with only addr == -1 requests keeps its state and clock."""
    addr, is_write = _requests(seed=3)
    addr[1] = -1
    is_write[1] = False
    batch = simulate_two_level_batch(
        addr, is_write, make_cache_batch(V, S, W), make_cache_batch(V, S, W),
        WAYS, WAYS, mode="npe", t0=T0)
    empty = make_cache(S, W)
    _assert_tree_equal(empty, _vm(batch[0], 1))
    _assert_tree_equal(empty, _vm(batch[1], 1))
    assert all(int(f[1]) == 0 for f in batch[2][:-1])
    assert float(batch[2].latency_sum[1]) == 0.0
    assert int(batch[3][1]) == int(T0[1])


def _mixed_trace(num_vms=3, reqs=2500):
    from repro.traces import make
    return interleave(
        [make(n, reqs, seed=i, addr_offset=i * 10_000_000, scale=0.25)
         for i, n in enumerate(["hm_1", "usr_0", "web_3"][:num_vms])],
        seed=0)


def test_etica_controller_batched_equals_sequential():
    geo = Geometry(num_sets=8, max_ways=16)
    trace = _mixed_trace()
    for mode in ("full", "npe"):
        results = {}
        caches = {}
        for batched in (True, False):
            cfg = EticaConfig(dram_capacity=60, ssd_capacity=120,
                              geometry_dram=geo, geometry_ssd=geo,
                              resize_interval=1500, promo_interval=500,
                              mode=mode, batched=batched)
            cache = EticaCache(cfg, 3)
            results[batched] = cache.run(trace)
            caches[batched] = cache
        for v in range(3):
            assert results[True][v].stats == results[False][v].stats, (mode, v)
            assert np.array_equal(results[True][v].alloc_history,
                                  results[False][v].alloc_history)
            _assert_tree_equal(caches[True].vm_dram(v),
                               caches[False].vm_dram(v), f"{mode} dram {v}")
            _assert_tree_equal(caches[True].vm_ssd(v),
                               caches[False].vm_ssd(v), f"{mode} ssd {v}")


def test_single_level_controller_batched_equals_sequential():
    """ECI-Cache chassis: dynamic per-VM policies through the batched path."""
    geo = Geometry(num_sets=8, max_ways=16)
    trace = _mixed_trace()
    results = {}
    for batched in (True, False):
        cache = make_eci_cache(120, 3, geometry=geo, resize_interval=1500,
                               sim_chunk=500)
        cache.cfg = dataclasses.replace(cache.cfg, batched=batched)
        cache.__init__(cache.cfg, 3, cache.metric, cache.policy_fn)
        results[batched] = cache.run(trace)
    for v in range(3):
        assert results[True][v].stats == results[False][v].stats, v
