"""Test-suite bootstrap.

The property tests use ``hypothesis`` when it is installed (CI installs it
via ``requirements-dev.txt``). Environments without it — the tier-1
command must run everywhere — get a minimal deterministic stand-in that
implements exactly the surface these tests use (``given``, ``settings``,
and the ``integers``/``booleans``/``tuples``/``lists``/``map`` strategy
combinators). The stand-in draws from a fixed-seed numpy generator, so
runs are reproducible; it performs no shrinking.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np

try:  # pragma: no cover - exercised implicitly when hypothesis exists
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def example(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def tuples(*elems):
        return _Strategy(lambda rng: tuple(e._draw(rng) for e in elems))

    def lists(elem, min_size=0, max_size=None):
        hi = 32 if max_size is None else max_size

        def draw(rng):
            n = int(rng.integers(min_size, hi + 1))
            return [elem._draw(rng) for _ in range(n)]

        return _Strategy(draw)

    def settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            fn._stub_settings = {"max_examples": max_examples}
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                conf = (getattr(wrapper, "_stub_settings", None)
                        or getattr(fn, "_stub_settings", None) or {})
                examples = conf.get("max_examples") or 20
                rng = np.random.default_rng(0xE71CA)
                for _ in range(examples):
                    drawn = tuple(s._draw(rng) for s in strategies)
                    fn(*args, *drawn, **kwargs)
            # pytest must not mistake the drawn parameters for fixtures:
            # hide the wrapped signature and present a parameterless one
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature([])
            return wrapper
        return deco

    stub = types.ModuleType("hypothesis")
    stub.given = given
    stub.settings = settings
    strategies_mod = types.ModuleType("hypothesis.strategies")
    strategies_mod.integers = integers
    strategies_mod.booleans = booleans
    strategies_mod.tuples = tuples
    strategies_mod.lists = lists
    stub.strategies = strategies_mod
    stub.__is_stub__ = True
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies_mod
