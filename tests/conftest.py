"""Test-suite bootstrap.

The property tests use ``hypothesis`` when it is installed (CI installs it
via ``requirements-dev.txt``). Environments without it — the tier-1
command must run everywhere — get a minimal deterministic stand-in that
implements exactly the surface these tests use (``given``, ``settings``,
the ``integers``/``booleans``/``tuples``/``lists``/``none``/``just``/
``sampled_from``/``one_of``/``builds``/``map`` strategy combinators and
``composite``). The stand-in draws from a fixed-seed numpy generator, so
runs are reproducible; it performs no shrinking.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np

try:  # pragma: no cover - exercised implicitly when hypothesis exists
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def example(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def tuples(*elems):
        return _Strategy(lambda rng: tuple(e._draw(rng) for e in elems))

    def lists(elem, min_size=0, max_size=None):
        hi = 32 if max_size is None else max_size

        def draw(rng):
            n = int(rng.integers(min_size, hi + 1))
            return [elem._draw(rng) for _ in range(n)]

        return _Strategy(draw)

    def none():
        return _Strategy(lambda rng: None)

    def just(value):
        return _Strategy(lambda rng: value)

    def sampled_from(options):
        options = list(options)
        return _Strategy(
            lambda rng: options[int(rng.integers(0, len(options)))])

    def one_of(*strategies):
        return _Strategy(
            lambda rng: strategies[int(rng.integers(0,
                                                    len(strategies)))]
            ._draw(rng))

    def builds(target, *args, **kwargs):
        def draw(rng):
            return target(*[s._draw(rng) for s in args],
                          **{k: s._draw(rng) for k, s in kwargs.items()})
        return _Strategy(draw)

    def composite(fn):
        def make(*args, **kwargs):
            def draw_all(rng):
                return fn(lambda s: s._draw(rng), *args, **kwargs)
            return _Strategy(draw_all)
        return make

    def settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            fn._stub_settings = {"max_examples": max_examples}
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                conf = (getattr(wrapper, "_stub_settings", None)
                        or getattr(fn, "_stub_settings", None) or {})
                examples = conf.get("max_examples") or 20
                rng = np.random.default_rng(0xE71CA)
                for _ in range(examples):
                    drawn = tuple(s._draw(rng) for s in strategies)
                    fn(*args, *drawn, **kwargs)
            # pytest must not mistake the drawn parameters for fixtures:
            # hide the wrapped signature and present a parameterless one
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature([])
            return wrapper
        return deco

    stub = types.ModuleType("hypothesis")
    stub.given = given
    stub.settings = settings
    strategies_mod = types.ModuleType("hypothesis.strategies")
    strategies_mod.integers = integers
    strategies_mod.booleans = booleans
    strategies_mod.tuples = tuples
    strategies_mod.lists = lists
    strategies_mod.none = none
    strategies_mod.just = just
    strategies_mod.sampled_from = sampled_from
    strategies_mod.one_of = one_of
    strategies_mod.builds = builds
    strategies_mod.composite = composite
    stub.strategies = strategies_mod
    stub.__is_stub__ = True
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies_mod


# The property suites compile hundreds of distinct executable shapes
# (every hypothesis-drawn trace length is its own jit cache entry).
# Left to accumulate over the whole run, the CPU backend eventually
# segfaults inside XLA's backend_compile, so bound the live-executable
# population by dropping jit caches at every module boundary. Costs a
# few recompiles per module; buys a suite-length-independent process.
import pytest  # noqa: E402


@pytest.fixture(autouse=True, scope="module")
def _bounded_jit_cache():
    yield
    import jax
    jax.clear_caches()
