"""Vectorized maintenance ops == sequential numpy references.

`resize` / `evict_blocks` / `promote_blocks` are jit-able jnp ops; the
original numpy implementations are kept as ``*_ref`` oracles. On
randomized states the vectorized versions must produce identical states
and counts — including promote's ordering contract (first occurrence
wins, free active ways fill in ascending order in queue order) and -1
padding entries being ignored. The controller-level test at the bottom
closes the loop across every maintenance mode: one interval of
`EticaCache` maintenance through the fused kernel dispatch, the staged
vmapped path, and the sequential per-VM numpy oracle must agree bit for
bit on Stats, allocations, and final cache states.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.simulator import (CacheState, evict_blocks, evict_blocks_ref,
                                  evict_blocks_batch, promote_blocks,
                                  promote_blocks_batch, promote_blocks_ref,
                                  resize, resize_batch, resize_levels,
                                  resize_ref, resident_blocks, stack_states)


def random_state(rng, num_sets, ways, addr_space=40):
    tags = rng.integers(-1, addr_space, (num_sets, ways)).astype(np.int32)
    for s in range(num_sets):       # a set never holds duplicate tags
        seen = set()
        for w in range(ways):
            if int(tags[s, w]) in seen:
                tags[s, w] = -1
            elif tags[s, w] >= 0:
                seen.add(int(tags[s, w]))
    lru = rng.integers(-1, 100, (num_sets, ways)).astype(np.int32)
    dirty = (rng.random((num_sets, ways)) < 0.5) & (tags >= 0)
    return CacheState(jnp.asarray(tags), jnp.asarray(lru),
                      jnp.asarray(dirty))


def assert_state_equal(a: CacheState, b: CacheState, msg=""):
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y)), msg


def test_maintenance_ops_match_numpy_reference():
    rng = np.random.default_rng(7)
    for trial in range(60):
        num_sets = int(rng.integers(2, 9))
        ways = int(rng.integers(1, 9))
        st = random_state(rng, num_sets, ways)

        old_w, new_w = (int(rng.integers(0, ways + 1)),
                        int(rng.integers(0, ways + 1)))
        got, flushed = resize(st, old_w, new_w)
        want, flushed_ref = resize_ref(st, old_w, new_w)
        assert int(flushed) == flushed_ref, (trial, old_w, new_w)
        assert_state_equal(got, want, f"resize trial {trial}")

        ev = rng.integers(-1, 40, int(rng.integers(0, 20)))
        got, flushed = evict_blocks(st, ev)
        want, flushed_ref = evict_blocks_ref(st, np.asarray(ev))
        assert int(flushed) == flushed_ref, trial
        assert_state_equal(got, want, f"evict trial {trial}")

        pr = rng.integers(-1, 60, int(rng.integers(0, 30)))
        active = int(rng.integers(0, ways + 1))
        got, n = promote_blocks(st, pr, active, 99)
        want, n_ref = promote_blocks_ref(st, np.asarray(pr), active, 99)
        assert int(n) == n_ref, trial
        assert_state_equal(got, want, f"promote trial {trial}")


def test_promote_fills_only_free_active_ways():
    rng = np.random.default_rng(11)
    for trial in range(20):
        num_sets, ways = 4, 6
        st = random_state(rng, num_sets, ways)
        active = int(rng.integers(0, ways + 1))
        before = np.asarray(st.tags).copy()
        pr = rng.integers(0, 60, 25)
        got, n = promote_blocks(st, pr, active, 50)
        after = np.asarray(got.tags)
        changed = before != after
        # only previously-free cells inside the active ways may change
        assert not changed[:, active:].any()
        assert (before[changed] == -1).all()
        assert int(n) == int(changed.sum())
        # promoted blocks arrive clean with the given timestamp
        assert not np.asarray(got.dirty)[changed].any()
        assert (np.asarray(got.lru)[changed] == 50).all()


def test_evict_flush_counts_dirty_only():
    st = CacheState(
        tags=jnp.asarray([[3, 5], [4, -1]], jnp.int32),
        lru=jnp.asarray([[1, 2], [3, -1]], jnp.int32),
        dirty=jnp.asarray([[True, False], [True, False]]),
    )
    got, flushed = evict_blocks(st, np.array([3, 4, 99, -1]))
    assert int(flushed) == 2
    assert set(resident_blocks(got, 2).tolist()) == {5}


def test_batched_maintenance_matches_per_vm():
    """One vmapped dispatch over stacked states == per-VM calls."""
    rng = np.random.default_rng(13)
    num_vms, num_sets, ways = 4, 4, 6
    states = [random_state(rng, num_sets, ways) for _ in range(num_vms)]
    stacked = stack_states(states)

    old_w = rng.integers(0, ways + 1, num_vms).astype(np.int32)
    new_w = rng.integers(0, ways + 1, num_vms).astype(np.int32)
    got, flushed = resize_batch(stacked, old_w, new_w)
    for v in range(num_vms):
        want, fl = resize_ref(states[v], int(old_w[v]), int(new_w[v]))
        assert int(flushed[v]) == fl
        for x, y in zip(want, got):
            assert np.array_equal(np.asarray(x), np.asarray(y[v]))

    queues = [rng.integers(0, 40, int(rng.integers(0, 12)))
              for _ in range(num_vms)]
    got, flushed = evict_blocks_batch(stacked, queues)
    for v in range(num_vms):
        want, fl = evict_blocks_ref(states[v], queues[v])
        assert int(flushed[v]) == fl
        for x, y in zip(want, got):
            assert np.array_equal(np.asarray(x), np.asarray(y[v]))

    active = rng.integers(0, ways + 1, num_vms).astype(np.int32)
    ts = rng.integers(0, 100, num_vms).astype(np.int32)
    got, n = promote_blocks_batch(stacked, queues, active, ts)
    for v in range(num_vms):
        want, n_ref = promote_blocks_ref(states[v], queues[v],
                                         int(active[v]), int(ts[v]))
        assert int(n[v]) == n_ref
        for x, y in zip(want, got):
            assert np.array_equal(np.asarray(x), np.asarray(y[v]))


def test_resize_levels_matches_two_resize_batches():
    """The fused two-level resize == two separate vmapped resizes."""
    rng = np.random.default_rng(17)
    num_vms, num_sets, ways = 3, 4, 6
    dram = stack_states([random_state(rng, num_sets, ways)
                         for _ in range(num_vms)])
    ssd = stack_states([random_state(rng, num_sets, ways)
                        for _ in range(num_vms)])
    old_d = rng.integers(0, ways + 1, num_vms).astype(np.int32)
    new_d = rng.integers(0, ways + 1, num_vms).astype(np.int32)
    old_s = rng.integers(0, ways + 1, num_vms).astype(np.int32)
    new_s = rng.integers(0, ways + 1, num_vms).astype(np.int32)
    gd, gs, fd, fs = resize_levels(dram, ssd, old_d, new_d, old_s, new_s)
    wd, wfd = resize_batch(dram, old_d, new_d)
    ws, wfs = resize_batch(ssd, old_s, new_s)
    assert np.array_equal(np.asarray(fd), np.asarray(wfd))
    assert np.array_equal(np.asarray(fs), np.asarray(wfs))
    for got, want in ((gd, wd), (gs, ws)):
        for x, y in zip(got, want):
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_etica_maintenance_modes_bit_identical():
    """One EticaCache workload through all three maintenance modes —
    fused kernel dispatch (default), staged vmapped path, sequential
    per-VM numpy — must agree exactly: Stats dicts, allocation
    histories, and final DRAM/SSD states."""
    from repro.core import EticaCache, EticaConfig, Geometry, interleave
    from repro.traces import make

    geo = Geometry(num_sets=8, max_ways=16)
    trace = interleave(
        [make(n, 2000, seed=i, addr_offset=i * 10_000_000, scale=0.25)
         for i, n in enumerate(["hm_1", "usr_0", "web_3"])], seed=0)
    base = EticaConfig(dram_capacity=60, ssd_capacity=120,
                       geometry_dram=geo, geometry_ssd=geo,
                       resize_interval=1000, promo_interval=250,
                       mode="full")
    variants = {
        "fused": dataclasses.replace(base),
        "staged": dataclasses.replace(base, fused_maintenance=False),
        "sequential": dataclasses.replace(base, batched=False),
    }
    results, caches = {}, {}
    for name, cfg in variants.items():
        cache = EticaCache(cfg, 3)
        results[name] = cache.run(trace)
        caches[name] = cache
    for other in ("staged", "sequential"):
        for v in range(3):
            assert results["fused"][v].stats == results[other][v].stats, \
                (other, v)
            assert np.array_equal(results["fused"][v].alloc_history,
                                  results[other][v].alloc_history)
            for x, y in zip(caches["fused"].vm_ssd(v),
                            caches[other].vm_ssd(v)):
                assert np.array_equal(np.asarray(x), np.asarray(y)), \
                    (other, "ssd", v)
            for x, y in zip(caches["fused"].vm_dram(v),
                            caches[other].vm_dram(v)):
                assert np.array_equal(np.asarray(x), np.asarray(y)), \
                    (other, "dram", v)
