"""Golden-file tests for the Prometheus telemetry exporter (PR 8).

The metric names and label sets emitted by ``repro.runtime.metrics`` are
a stable public contract — dashboards and alert rules key on them.
These tests pin:

* the full exposition text for a hand-built stats snapshot (byte-exact
  golden comparison — a rename or reorder fails loudly);
* the strict parser (``parse_exposition``) as a validator: rejects
  samples without ``# TYPE``, duplicates, and malformed lines;
* a seeded controller run whose ``pop_drops`` and ``bypassed`` counters
  are nonzero, asserting the rendered text carries the *exact* counts
  (regression: those channels used to be easy to drop silently);
* the serving exporter against the deferred write-back conservation law.
"""
import types

import numpy as np
import pytest

from repro.classify import seq_cutoff
from repro.core import EticaCache, EticaConfig, Geometry, interleave
from repro.kvcache import TwoTierConfig, TwoTierKVManager
from repro.runtime import metrics
from repro.traces import SessionSpec, generate_sessions, make

# ---------------------------------------------------------------------------
# renderer + parser
# ---------------------------------------------------------------------------

GOLDEN = """\
# HELP demo_requests_total Requests served.
# TYPE demo_requests_total counter
demo_requests_total{vm="0",op="read"} 12
demo_requests_total{vm="1",op="read"} 0
# HELP demo_depth Current queue depth.
# TYPE demo_depth gauge
demo_depth 2.5
"""


def test_render_golden():
    req = metrics.Metric("demo_requests_total", "counter",
                         "Requests served.")
    req.add({"vm": "0", "op": "read"}, 12.0)
    req.add({"vm": "1", "op": "read"}, 0)
    depth = metrics.Metric("demo_depth", "gauge", "Current queue depth.")
    depth.add({}, 2.5)
    assert metrics.render([req, depth]) == GOLDEN


def test_render_escapes_labels_and_rejects_bad_names():
    m = metrics.Metric("m_total", "counter", "h")
    m.add({"path": 'a"b\\c\nd'}, 1)
    text = metrics.render([m])
    assert r'path="a\"b\\c\nd"' in text
    fams = metrics.parse_exposition(text)
    assert fams["m_total"]["samples"][(("path", r"a\"b\\c\nd"),)] == 1.0
    with pytest.raises(ValueError):
        metrics.render([metrics.Metric("bad name", "counter", "h")])
    with pytest.raises(ValueError):
        metrics.render([metrics.Metric("m", "summary", "h")])
    with pytest.raises(ValueError):
        # histogram is a valid TYPE since PR 9, but its samples must be
        # HistogramValues — a scalar sample still fails loudly
        metrics.render([metrics.Metric("m", "histogram", "h").add({}, 1)])
    with pytest.raises(ValueError):
        metrics.render([metrics.Metric("m", "counter", "h")
                        .add({"0bad": "x"}, 1)])


def test_parse_round_trips_golden():
    fams = metrics.parse_exposition(GOLDEN)
    assert fams["demo_requests_total"]["type"] == "counter"
    assert fams["demo_requests_total"]["help"] == "Requests served."
    assert fams["demo_requests_total"]["samples"][
        (("op", "read"), ("vm", "0"))] == 12.0
    assert fams["demo_depth"]["samples"][()] == 2.5


@pytest.mark.parametrize("bad", [
    "orphan_sample 1\n",                                   # no # TYPE
    "# TYPE a counter\na 1\na 1\n",                        # duplicate
    "# TYPE a counter\na{x=1} 1\n",                        # unquoted label
    "# TYPE a counter\n# TYPE b counter\na 1\n",           # outside block
    "# TYPE a counter\na one\n",                           # bad value
])
def test_parse_rejects_malformed(bad):
    with pytest.raises(ValueError):
        metrics.parse_exposition(bad)


# ---------------------------------------------------------------------------
# cache collector: golden names on a synthetic snapshot
# ---------------------------------------------------------------------------

# The stable name contract. Extending this list is fine; renaming or
# dropping an entry is a breaking change.
CACHE_FAMILIES = [
    ("etica_requests_total", "counter"),
    ("etica_hits_total", "counter"),
    ("etica_ssd_writes_total", "counter"),
    ("etica_disk_reads_total", "counter"),
    ("etica_disk_writes_total", "counter"),
    ("etica_flushes_total", "counter"),
    ("etica_evict_flushes_total", "counter"),
    ("etica_dirty_resident", "gauge"),
    ("etica_bypassed_total", "counter"),
    ("etica_pop_drops_total", "counter"),
    ("etica_latency_seconds_total", "counter"),
]


def _fake_cache():
    return types.SimpleNamespace(
        stats=[{"reads": 10.0, "writes": 4.0, "read_hits_l1": 6.0,
                "read_hits_l2": 2.0, "write_hits_l2": 1.0,
                "cache_writes_l2": 5.0, "disk_reads": 2.0,
                "disk_writes": 7.0, "latency_sum": 0.125,
                "bypassed": 3.0, "pop_drops": 9.0, "flushes": 4.0,
                "evict_flushes": 2.0, "dirty_resident": 1.0}],
        classifier=None)


def test_cache_exposition_names_are_stable():
    text = metrics.render_cache(_fake_cache())
    fams = metrics.parse_exposition(text)
    assert [(n, fams[n]["type"]) for n in fams] == CACHE_FAMILIES
    s = fams["etica_hits_total"]["samples"]
    assert s[(("level", "dram"), ("op", "read"), ("vm", "0"))] == 6.0
    assert s[(("level", "ssd"), ("op", "read"), ("vm", "0"))] == 2.0
    assert s[(("level", "ssd"), ("op", "write"), ("vm", "0"))] == 1.0
    assert fams["etica_flushes_total"]["samples"][(("vm", "0"),)] == 4.0
    assert fams["etica_dirty_resident"]["samples"][(("vm", "0"),)] == 1.0
    assert fams["etica_latency_seconds_total"]["samples"][
        (("vm", "0"),)] == 0.125


def test_missing_keys_render_as_zero_not_absent():
    """Fixed-shape scrapes: a stats dict without the cleaner keys (e.g.
    ``clean_quota=0``) still emits every family, at 0."""
    cache = types.SimpleNamespace(stats=[{"reads": 1.0}], classifier=None)
    fams = metrics.parse_exposition(metrics.render_cache(cache))
    assert [(n, fams[n]["type"]) for n in fams] == CACHE_FAMILIES
    assert fams["etica_flushes_total"]["samples"][(("vm", "0"),)] == 0.0
    assert fams["etica_pop_drops_total"]["samples"][(("vm", "0"),)] == 0.0


# ---------------------------------------------------------------------------
# seeded end-to-end regressions: exact pop_drops / bypassed counts
# ---------------------------------------------------------------------------

GEO = Geometry(num_sets=8, max_ways=16)


def test_seeded_run_exports_exact_drop_bypass_and_class_counts():
    mix = interleave(
        [make(n, 1200, seed=i, addr_offset=i * 10_000_000, scale=0.25)
         for i, n in enumerate(["hm_1", "web_3"])], seed=42)
    # splice in long sequential scans so seq_cutoff(8) actually trips
    runs = [np.arange(50_000 + i * 500, 50_000 + i * 500 + 24,
                      dtype=np.int32) for i in range(10)]
    seq = np.concatenate(runs)
    from repro.core import Trace
    trace = Trace(addr=np.concatenate([np.asarray(mix.addr), seq]),
                  is_write=np.concatenate([np.asarray(mix.is_write),
                                           np.zeros(len(seq), bool)]),
                  vm=np.concatenate([np.asarray(mix.vm),
                                     np.full(len(seq), 0, np.int32)]))
    cfg = EticaConfig(dram_capacity=40, ssd_capacity=80, geometry_dram=GEO,
                      geometry_ssd=GEO, resize_interval=600,
                      promo_interval=200, pop_capacity=8,   # tiny: overflow
                      classifier=seq_cutoff(8), clean_quota=2)
    cache = EticaCache(cfg, num_vms=2)
    res = cache.run(trace)
    text = metrics.render_cache(cache)
    fams = metrics.parse_exposition(text)

    total_drops = total_byp = 0
    for v in range(2):
        s = res[v].stats
        key = (("vm", str(v)),)
        assert fams["etica_pop_drops_total"]["samples"][key] == \
            s["pop_drops"]
        assert fams["etica_bypassed_total"]["samples"][key] == s["bypassed"]
        assert fams["etica_flushes_total"]["samples"][key] == s["flushes"]
        assert fams["etica_dirty_resident"]["samples"][key] == \
            s["dirty_resident"]
        total_drops += s["pop_drops"]
        total_byp += s["bypassed"]
        # per-class counts reconcile with the scalar stats
        cs = fams["etica_class_requests_total"]["samples"]
        hits = sum(cs[k] for k in cs
                   if (("vm", str(v)) in k and ("result", "hit") in k))
        miss = sum(cs[k] for k in cs
                   if (("vm", str(v)) in k and ("result", "miss") in k))
        assert hits == s["read_hits_l1"] + s["read_hits_l2"] + \
            s["write_hits_l2"]
        assert hits + miss == s["reads"] + s["writes"] - s["bypassed"]
    # the regression the golden file exists for: both channels nonzero
    assert total_drops > 0, "pop_capacity=8 produced no drops"
    assert total_byp > 0, "seq_cutoff(8) produced no bypasses"
    for cname in ("default", "seq_bypass"):
        assert f'io_class="{cname}"' in text


# ---------------------------------------------------------------------------
# serving collector
# ---------------------------------------------------------------------------

def test_serving_exposition_and_conservation():
    cfg = TwoTierConfig(page_size=8, hbm_pages=24, num_kv_heads=2,
                        head_dim=4, num_layers=1, dtype="float32",
                        maintenance_interval=16, resize_interval=64,
                        pop_capacity=128, materialize=False, clean_quota=2)
    mgr = TwoTierKVManager(cfg, num_tenants=3)
    tr = generate_sessions(SessionSpec(num_tenants=3, target_live=48,
                                       max_pages=4, lifetime=20),
                           800, seed=0)
    rng = np.random.default_rng(7)
    pg = rng.normal(size=(1, cfg.page_size, cfg.num_kv_heads,
                          cfg.head_dim)).astype(np.float32)
    from repro.traces import (SESSION_ACTIVATE, SESSION_APPEND,
                              SESSION_END, SESSION_NEW)
    for i in range(len(tr)):
        kind, sid = int(tr.kind[i]), int(tr.sid[i])
        if kind == SESSION_NEW:
            mgr.new_session(sid, int(tr.tenant[i]))
        elif kind == SESSION_APPEND:
            mgr.append_page(sid, pg, pg)
        elif kind == SESSION_ACTIVATE:
            mgr.activate(sid)
        elif kind == SESSION_END:
            mgr.end_session(sid)

    fams = metrics.parse_exposition(metrics.render_serving(mgr))
    g = lambda n: fams[f"etica_serving_{n}"]["samples"][()]
    s = mgr.stats
    assert g("appends_total") == s.appends
    assert g("flushes_total") == s.flushes
    assert g("evict_flushes_total") == s.evict_flushes
    assert g("dirty_dropped_total") == s.dirty_dropped
    assert g("dirty_resident") == s.dirty_resident == len(mgr._dirty)
    assert fams["etica_serving_dirty_resident"]["type"] == "gauge"
    # deferred write-back conservation: every append is eventually
    # cleaned, force-flushed, still dirty, or retired with its session
    assert g("appends_total") == (g("flushes_total")
                                  + g("evict_flushes_total")
                                  + g("dirty_resident")
                                  + g("dirty_dropped_total"))
    assert g("dma_write_bytes_total") == (
        (s.flushes + s.evict_flushes) * mgr.cfg.page_bytes)
    assert g("flushes_total") > 0, "cleaner never ran in seeded trace"
