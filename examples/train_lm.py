"""End-to-end training example: a ~100M-parameter qwen3-family model for a
few hundred steps with checkpointing, failure injection, and recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This is the driver deliverable (b): real data pipeline -> jitted train
step (scan-over-layers + remat) -> AdamW -> async atomic checkpoints ->
bounded-retry recovery; the loss should fall from ~10.8 (ln 49k) toward
memorization of the synthetic stream.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/etica_train_lm")
    args = ap.parse_args()

    # a ~100M-param qwen3-family config (8 layers, 768 wide, 32k vocab).
    # CPU throughput is ~5 s/step at batch 4 x seq 256; pass --steps 60
    # for a quick run.
    import repro.configs.qwen3_4b as q
    from repro import configs
    cfg100m = dataclasses.replace(
        q.CONFIG, name="qwen3-100m", num_layers=8, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2304,
        vocab_size=32768)
    configs._MODULES["qwen3-100m"] = None  # registered ad hoc below
    get_orig = configs.get_reduced
    configs.get_reduced = lambda a: cfg100m if a == "qwen3-100m" else get_orig(a)

    total, _ = cfg100m.param_counts()
    print(f"training {cfg100m.name}: {total/1e6:.0f}M params")
    losses = train_main([
        "--arch", "qwen3-100m", "--steps", str(args.steps),
        "--batch", "4", "--seq", "256",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--inject-failure-at", str(args.steps // 2),
        "--log-every", "20"])
    assert losses[-1] < losses[0], "loss did not improve"
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
