"""Import an external trace into a TraceStore and stream it through ETICA.

End-to-end walk of the streaming ingestion layer:

  1. synthesize an MSR-Cambridge-style CSV (stand-in for a real download
     from SNIA IOTTA — the format is identical);
  2. import it into a chunked on-disk :class:`TraceStore` with the same
     parser the CLI uses (``python -m repro.traces.store import``);
  3. run :class:`EticaCache` straight off the store — per-VM demux done
     with one stable sort per shard, ``[V, chunk]`` blocks double-buffered
     host->device — and verify the aggregate Stats are **bit-identical**
     to running the materialized in-memory trace.

Also serves as the CI streaming smoke test (exits non-zero on any
mismatch).

    PYTHONPATH=src python examples/stream_external_trace.py
"""
import tempfile
from pathlib import Path

import numpy as np

from repro.core import EticaCache, EticaConfig, Geometry, interleave
from repro.traces import TraceStore, make

BLOCK = 4096


def synthesize_msr_csv(path: Path, num_vms: int = 4,
                       reqs_per_vm: int = 2000) -> None:
    """Write a consolidated multi-VM mix in the MSR CSV format."""
    traces = [make(w, reqs_per_vm, seed=i, addr_offset=i * 1_000_000,
                   scale=0.25)
              for i, w in enumerate(["hm_1", "usr_0", "web_3", "src2_0"]
                                    [:num_vms])]
    mixed = interleave(traces, seed=7)
    with path.open("w") as f:
        f.write("Timestamp,Hostname,DiskNumber,Type,Offset,Size,"
                "ResponseTime\n")
        for i in range(len(mixed)):
            vm = int(mixed.vm[i])
            typ = "Write" if bool(mixed.is_write[i]) else "Read"
            off = int(mixed.addr[i]) * BLOCK
            f.write(f"{128166372003061629 + i},vm{vm},0,{typ},{off},"
                    f"{BLOCK},100\n")


def build_cache(num_vms: int) -> EticaCache:
    geo = Geometry(num_sets=16, max_ways=32)
    cfg = EticaConfig(dram_capacity=300, ssd_capacity=600, geometry_dram=geo,
                      geometry_ssd=geo, resize_interval=2000,
                      promo_interval=500)
    return EticaCache(cfg, num_vms)


def main() -> None:
    with tempfile.TemporaryDirectory() as tmp:
        csv = Path(tmp) / "trace.csv"
        synthesize_msr_csv(csv)
        store_dir = Path(tmp) / "store"
        # same code path as: python -m repro.traces.store import --format msr
        store = TraceStore.from_msr_csv(store_dir, csv, shard_size=3000)
        num_vms = store.num_vms
        print(f"imported {len(store)} requests, {store.num_shards} shards, "
              f"{num_vms} VMs")

        streamed = build_cache(num_vms).run(TraceStore.open(store_dir))
        in_memory = build_cache(num_vms).run(store.to_trace())

        for v in range(num_vms):
            assert streamed[v].stats == in_memory[v].stats, (
                f"VM {v}: streamed != in-memory\n"
                f"  streamed:  {streamed[v].stats}\n"
                f"  in-memory: {in_memory[v].stats}")
        hit = np.mean([r.hit_ratio for r in streamed])
        lat = np.mean([r.mean_latency for r in streamed])
        print(f"streamed == in-memory (bit-identical Stats) for "
              f"{num_vms} VMs")
        print(f"avg hit ratio {hit:.3f}, avg latency {lat:.3f}")


if __name__ == "__main__":
    main()
