"""Quickstart: the paper's metric and system in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Computes TRD / URD / POD on the paper's own worked examples.
2. Runs ETICA's two-level cache vs ECI-Cache on a 3-VM workload mix and
   prints the endurance/latency comparison.
"""
import numpy as np

from repro.core import (EticaCache, EticaConfig, Geometry, Policy, Trace,
                        interleave, make_eci_cache, pod, trd, urd)
from repro.traces import make

# --- 1. the POD metric (paper Figs. 8 & 9) -------------------------------
fig8 = Trace.from_ops([('R', 1), ('R', 2), ('R', 3), ('W', 4), ('W', 5),
                       ('R', 1), ('R', 4)])
print("Fig. 8 workload:  TRD =", trd(fig8), " URD =", urd(fig8),
      " POD(WBWO) =", pod(fig8, Policy.WBWO))
print("  -> URD reserves", urd(fig8) + 1, "blocks; POD reserves only",
      pod(fig8, Policy.WBWO) + 1, "for the same hit ratio\n")

# --- 2. the two-level cache vs ECI-Cache ----------------------------------
vms = ["hm_1", "usr_0", "web_3"]
trace = interleave(
    [make(n, 6000, seed=i, addr_offset=i * 10_000_000, scale=0.25)
     for i, n in enumerate(vms)], seed=0)

geo = Geometry(num_sets=16, max_ways=32)
# The controller batches the datapath across VMs by default: per promo
# window, one vmapped lax.scan simulates all VMs' partitions at once
# (EticaConfig(batched=False) gives the bit-identical per-VM loop).
etica = EticaCache(
    EticaConfig(dram_capacity=400, ssd_capacity=800, geometry_dram=geo,
                geometry_ssd=geo, resize_interval=3000, promo_interval=500),
    num_vms=len(vms)).run(trace)
eci = make_eci_cache(1200, len(vms), geometry=geo,
                     resize_interval=3000).run(trace)

print(f"{'VM':8s} {'ETICA lat':>10s} {'ECI lat':>10s} "
      f"{'ETICA ssd_w':>12s} {'ECI ssd_w':>10s}")
for vm, a, b in zip(vms, etica, eci):
    print(f"{vm:8s} {a.mean_latency*1e3:9.3f}ms {b.mean_latency*1e3:9.3f}ms"
          f" {a.ssd_writes:12.0f} {b.ssd_writes:10.0f}")
tot_a = sum(r.ssd_writes for r in etica)
tot_b = sum(r.ssd_writes for r in eci)
print(f"\nSSD write reduction: {1 - tot_a/tot_b:.1%} (paper: 33.8%)")
