"""Serving example: churn-driven multi-tenant decode with the ETICA
two-tier KV manager, real paged-attention decode steps, and the LRU
baseline for comparison.

    PYTHONPATH=src python examples/serve_two_tier.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main


def main():
    common = ["--events", "800", "--live", "48", "--hbm-pages", "40",
              "--tenants", "3"]
    print("=== ETICA two-tier manager (batched controller) ===")
    a = serve_main(["--manager", "etica", *common])
    print("\n=== global-LRU write-back baseline ===")
    b = serve_main(["--manager", "lru", *common])
    print(f"\nhost-DMA write reduction: "
          f"{1 - a['dma_write_bytes']/max(b['dma_write_bytes'],1):.1%}")


if __name__ == "__main__":
    main()
