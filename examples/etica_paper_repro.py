"""Full paper reproduction at the configured scale: runs the 12-VM mix of
§5.1 through ETICA-Full / ETICA-NPE / ECI-Cache and prints the three
headline claims next to the paper's numbers.

    PYTHONPATH=src python examples/etica_paper_repro.py [--reqs 8000]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.etica_paper import CONFIG as PAPER
from repro.core import (EticaCache, EticaConfig, Geometry, Policy,
                        demand_blocks, interleave, make_eci_cache, pod, urd)
from repro.traces import make


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reqs", type=int, default=6000)
    ap.add_argument("--vms", type=int, default=8)
    args = ap.parse_args()

    names = list(PAPER.vms)[: args.vms]
    traces = [make(n, args.reqs, seed=i, addr_offset=i * 10_000_000,
                   scale=0.25) for i, n in enumerate(names)]
    trace = interleave(traces, seed=7)
    geo = Geometry(num_sets=16, max_ways=32)

    # claim 3: POD sizes below URD
    urd_t = ro_t = wbwo_t = 0
    for tr in traces:
        head = tr[:2000]
        urd_t += demand_blocks(urd(head))
        ro_t += demand_blocks(pod(head, Policy.RO))
        wbwo_t += demand_blocks(pod(head, Policy.WBWO))
    size_red = 1 - (ro_t + wbwo_t) / (2 * urd_t)

    # batched=True (default): each promo window simulates ALL VMs in one
    # vmapped dispatch; batched=False keeps the per-VM dispatch loop and
    # produces bit-identical results (see benchmarks/fig15_vm_scaling.py)
    cfg = EticaConfig(dram_capacity=400, ssd_capacity=800,
                      geometry_dram=geo, geometry_ssd=geo,
                      resize_interval=2000, promo_interval=500,
                      batched=True)
    etica = EticaCache(cfg, len(names)).run(trace)
    eci = make_eci_cache(1200, len(names), geometry=geo,
                         resize_interval=2000).run(trace)

    lat_e = np.mean([r.mean_latency for r in etica])
    lat_c = np.mean([r.mean_latency for r in eci])
    w_e = sum(r.ssd_writes for r in etica)
    w_c = sum(r.ssd_writes for r in eci)

    print(f"{'claim':34s} {'paper':>8s} {'this repro':>11s}")
    print(f"{'cache size reduction (POD vs URD)':34s} {'51.7%':>8s} "
          f"{size_red:>10.1%}")
    print(f"{'SSD write reduction (endurance)':34s} {'33.8%':>8s} "
          f"{1 - w_e/max(w_c,1):>10.1%}")
    print(f"{'I/O latency improvement':34s} {'45%':>8s} "
          f"{1 - lat_e/lat_c:>10.1%}")
    print("\n(latency: see EXPERIMENTS.md — the paper's testbed couples "
          "write load to SSD latency; our clean device model does not)")


if __name__ == "__main__":
    main()
